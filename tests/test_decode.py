"""Prefill/forward vs token-by-token decode equivalence for every family."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import ARCHS, reduced
from repro.models.io import synth_batch
from repro.models.transformer import Transformer

CASES = ["granite-34b", "gemma2-2b", "deepseek-v2-lite-16b", "mamba2-2.7b",
         "zamba2-7b", "musicgen-medium", "internvl2-1b",
         "llama4-maverick-400b-a17b", "starcoder2-3b", "phi3-medium-14b"]


@pytest.mark.parametrize("name", CASES)
def test_decode_matches_forward(name):
    B, S = 2, 16
    cfg = reduced(ARCHS[name])
    if cfg.sliding_window:
        cfg = cfg.with_overrides(sliding_window=0)
    if cfg.is_moe:
        # no-drop capacity so train/decode dispatch identically
        cfg = cfg.with_overrides(capacity_factor=float(cfg.num_experts))
    model = Transformer(cfg)
    params = model.init(jax.random.PRNGKey(1))
    batch = synth_batch(cfg, "train", B, S, seed=3)
    hidden, _, _ = model.forward(params, batch)
    full_logits = model.logits(params, hidden)

    cache = model.init_cache(B, S)
    step = jax.jit(model.decode_step)
    errs = []
    F = batch["embeds"].shape[1] if cfg.frontend == "vision" else 0
    for t in range(S):
        if cfg.frontend == "audio":
            sb = {"embeds": batch["embeds"][:, t:t + 1]}
        elif cfg.frontend == "vision" and t < F:
            sb = {"embeds": batch["embeds"][:, t:t + 1], "tokens": None}
        elif cfg.frontend == "vision":
            sb = {"tokens": batch["tokens"][:, t - F:t - F + 1]}
        else:
            sb = {"tokens": batch["tokens"][:, t:t + 1]}
        logits, cache = step(params, cache, sb, jnp.int32(t))
        errs.append(float(jnp.max(jnp.abs(logits[:, 0] - full_logits[:, t]))))
    assert max(errs) < 2e-3, (name, max(errs))


def test_sliding_window_ring_cache():
    """Ring-buffer window cache must equal a full cache once positions
    exceed the window (zamba2/starcoder2 long-context serving)."""
    cfg = reduced(ARCHS["starcoder2-3b"]).with_overrides(sliding_window=8)
    model = Transformer(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 1, 24
    batch = synth_batch(cfg, "train", B, S, seed=5)
    # reference: full-length cache (kv_len returns window when S>window,
    # so build an oversized cache via max_len=window exactly -> ring).
    ring_cache = model.init_cache(B, S)       # window-sized => ring
    assert ring_cache["kv"]["k"].shape[-3] == 8
    hidden, _, _ = model.forward(params, batch)
    full_logits = model.logits(params, hidden)
    step = jax.jit(model.decode_step)
    errs = []
    for t in range(S):
        sb = {"tokens": batch["tokens"][:, t:t + 1]}
        logits, ring_cache = step(params, ring_cache, sb, jnp.int32(t))
        errs.append(float(jnp.max(jnp.abs(logits[:, 0] - full_logits[:, t]))))
    assert max(errs) < 2e-3, errs
