"""ISSUE 7: the EROICA loop over REAL jit'd training jobs (DESIGN.md §11).

Four layers of coverage:

  * the instrumented ``Trainer.train_iteration`` itself — loss decreases,
    the checkpoint save/resume round-trip (through the fixed shardings
    path), tracer phase events present and ordered with HLO-cost
    sub-events nested inside the fenced ``train.step`` span, and the
    explicit per-resource stream set (satellite: no aliased gpu_sm /
    pcie_tx / membw streams);
  * in-process ``TrainerWorkload`` scenarios — each live fault
    (dataloader burn / step throttle / GC pause) detected and localized
    to the right function on the right workers, with the paper-playbook
    mitigation plan on the ladder;
  * fleet/wire byte-parity of the diagnosis over real trainer profiles;
  * ``@pytest.mark.train`` multi-process integration — the acceptance
    bar: >= 3 fault scenarios against real trainer processes over the
    socket transport, each producing a localized incident with no
    ``FleetSimulator`` involvement anywhere.
"""
import time
from dataclasses import replace

import numpy as np
import pytest

from repro.core.mitigation import Action
from repro.core.service import PerfTrackerService
from repro.online import ScenarioRunner, ScheduledFault
from repro.train.loop import Trainer
from repro.train.workload import (DataloaderBurn, GcPause, StepThrottle,
                                  TrainerWorkload,
                                  default_trainer_detector_cfg,
                                  tiny_train_setup)

pytestmark = pytest.mark.train

IPW = 8                       # iterations per profiling window
N_WIN = 7                     # fault active for windows [2, 7)

#: functions a degraded-step incident may localize to — all phases of the
#: fenced train.step span (the HLO sub-events split it by cost)
STEP_FUNCTIONS = {"train.step", "xla.gemm", "xla.other", "optimizer.step"}


@pytest.fixture(scope="module")
def wl4():
    wl = TrainerWorkload(n_workers=4)
    wl._ensure_workers()
    yield wl
    wl.close()


def _scenario(wl, fault):
    return ScenarioRunner(
        None, [ScheduledFault(fault, 2, N_WIN)], n_windows=N_WIN,
        iters_per_window=IPW,
        detector_cfg=default_trainer_detector_cfg(IPW), workload=wl)


def _incident(result, functions, workers, action=None):
    """The incident localizing ``functions`` (str or set) that implicates
    every worker in ``workers`` (and, when given, whose plan ladder holds
    ``action``).  Extra noise incidents are tolerated — the scenario's
    contract is that the GENUINE one exists."""
    fns = {functions} if isinstance(functions, str) else set(functions)
    for inc in result.incidents:
        if inc.function in fns and set(workers) <= set(inc.workers) \
                and (action is None
                     or action in [p.action for p in inc.plans]):
            return inc
    raise AssertionError(
        f"no incident for {sorted(fns)} on {workers} with {action}; got "
        f"{[(i.function, i.workers, [p.action for p in i.plans]) for i in result.incidents]}")


# -- the instrumented real loop ----------------------------------------------

def test_train_iteration_loss_decreases():
    mc, dc, oc, tc = tiny_train_setup()
    tr = Trainer(mc, dc, oc, tc)
    params, opt_state, start = tr.init_state()
    assert start == 0
    losses = []
    for _ in range(30):
        params, opt_state, m = tr.train_iteration(params, opt_state)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5])
    tr.loader.close()


def test_checkpoint_save_resume_roundtrip(tmp_path):
    mc, dc, oc, tc = tiny_train_setup()
    tc = replace(tc, ckpt_every=5, ckpt_dir=str(tmp_path))
    tr = Trainer(mc, dc, oc, tc)
    params, opt_state, _ = tr.init_state()
    for _ in range(10):
        params, opt_state, _ = tr.train_iteration(params, opt_state)
    tr.ckpt.wait()
    tr.loader.close()
    # a fresh trainer resumes from the iteration-10 save via the (fixed)
    # shardings-threaded restore path
    tr2 = Trainer(mc, dc, oc, tc)
    p2, o2, start2 = tr2.init_state()
    assert start2 == 10
    assert int(o2["step"]) == 10
    np.testing.assert_array_equal(np.asarray(p2["embed"]["table"]),
                                  np.asarray(params["embed"]["table"]))
    tr2.loader.close()


def test_tracer_phases_present_and_ordered(wl4):
    tw = wl4.workers[0]
    _, prof = tw.run_window(3)
    # satellite: the stream set is explicit — only the real cpu sampler,
    # no aliased hardware streams
    assert set(prof.streams) == {"cpu"}
    top = sorted((e for e in prof.events if e.depth == 1),
                 key=lambda e: e.start)
    assert [e.name for e in top] == \
        ["dataloader.next", "train.step", "optimizer.step"] * 3
    for a, b in zip(top, top[1:]):
        assert a.end <= b.start + 1e-9
    # HLO-cost attribution: depth-2 sub-events split each fenced
    # train.step span, gemm first, boundaries inside the parent
    assert tw.trainer.bundle.gemm_frac is not None
    steps = [e for e in top if e.name == "train.step"]
    gemm = sorted((e for e in prof.events if e.name == "xla.gemm"),
                  key=lambda e: e.start)
    other = sorted((e for e in prof.events if e.name == "xla.other"),
                   key=lambda e: e.start)
    assert len(gemm) == len(other) == len(steps) == 3
    for s, g, o in zip(steps, gemm, other):
        assert g.depth == o.depth == 2
        assert s.start <= g.start < g.end <= o.start < o.end <= s.end
    # anchors are measured wall durations covering each full iteration
    spans = [top[3 * i + 2].end - top[3 * i].start for i in range(3)]
    assert all(d > 0 for d in spans)


def test_default_tracer_streams_cpu_only():
    from repro.instrument.tracer import Tracer
    tr = Tracer(worker=0, rate_hz=200.0)
    tr.start_window()
    time.sleep(0.02)
    prof = tr.stop_window()
    assert set(prof.streams) == {"cpu"}


# -- in-process fault scenarios ----------------------------------------------

def test_dataloader_burn_localizes_and_plans_migration(wl4):
    res = _scenario(wl4, DataloaderBurn(workers=(1,))).run()
    _incident(res, "dataloader.next", (1,), Action.MIGRATE_DATALOADER)


def test_step_throttle_localizes_to_step_phase(wl4):
    res = _scenario(wl4, StepThrottle(workers=(2,))).run()
    _incident(res, STEP_FUNCTIONS, (2,), Action.REPLACE_HOSTS)


def test_gc_pause_on_subset_plans_gc_synchronization(wl4):
    res = _scenario(wl4, GcPause(workers=(0, 1, 2))).run()
    _incident(res, "runtime.gc", (0, 1, 2), Action.SYNCHRONIZE_GC)


def test_param_corruption_resolved_by_real_rollback(wl4):
    """DESIGN.md §14 on the REAL trainer: a live numerics fault (corrupted
    params, NaN planted) diverges actual jit'd training; the numerics
    incident's ROLLBACK_TO_CHECKPOINT rung restores the window-0 on-disk
    checkpoint into the running trainers (parameter-equality verified) and
    the incident resolves because the loss genuinely came back."""
    from repro.ckpt import RecoveryManager
    from repro.train.workload import ParamCorruption
    n_win = 8
    # save only at window 0: the periodic cadence must not checkpoint the
    # corrupted state the rollback is supposed to erase
    rec = RecoveryManager.for_workload(wl4, save_every=n_win)
    fault = ParamCorruption(workers=(1,), nan=True)
    r = ScenarioRunner(
        None, [ScheduledFault(fault, 2, n_win,
                              cures=(Action.ROLLBACK_TO_CHECKPOINT,))],
        n_windows=n_win, iters_per_window=IPW,
        detector_cfg=default_trainer_detector_cfg(IPW), workload=wl4,
        mitigation=True, recovery=rec)
    res = r.run()
    inc = next(i for i in res.incidents
               if i.channel == "numerics" and i.applied)
    assert inc.state == "resolved"
    assert inc.applied[0][1].action is Action.ROLLBACK_TO_CHECKPOINT
    # the rollback was REAL: a step restored from disk, verified equal to
    # the saved arrays, with the diverged iterations honestly discarded
    m = next(m for m in r.engine.log
             if m.plan.action is Action.ROLLBACK_TO_CHECKPOINT)
    assert not m.rollback_failed and m.rollback_verified
    assert m.restored_step is not None and m.lost_steps > 0
    # and the live params really are healthy again (the NaN is gone)
    import jax
    for tw in wl4.workers:
        for leaf in jax.tree_util.tree_leaves(tw.params):
            assert np.isfinite(np.asarray(jax.device_get(leaf))).all()


# -- fleet/wire parity on real profiles ---------------------------------------

def _assert_identical(a, b):
    assert a.functions() == b.functions()
    for aa, bb in zip((d.abnormality for d in a.diagnoses),
                      (d.abnormality for d in b.diagnoses)):
        np.testing.assert_array_equal(aa.workers, bb.workers)
        np.testing.assert_array_equal(aa.patterns, bb.patterns)
        np.testing.assert_array_equal(aa.d_expect, bb.d_expect)
        np.testing.assert_array_equal(aa.delta, bb.delta)


def test_fleet_wire_parity_on_trainer_profiles(wl4):
    wd = wl4.run_window(0, [DataloaderBurn(workers=(1,))], IPW, None)
    svc = PerfTrackerService(family="host", summarize_backend="numpy")
    fleet = svc.diagnose_profiles(wd.profiles, mode="fleet")
    assert "dataloader.next" in fleet.functions()
    _assert_identical(fleet, svc.diagnose_profiles(wd.profiles, mode="wire"))


# -- multi-process socket integration (the acceptance bar) --------------------

MP_CASES = [
    pytest.param(DataloaderBurn(workers=(1,)), "dataloader.next", (1,),
                 Action.MIGRATE_DATALOADER, id="dataloader-burn"),
    pytest.param(StepThrottle(workers=(2,)), STEP_FUNCTIONS, (2,),
                 Action.REPLACE_HOSTS, id="step-throttle"),
    pytest.param(GcPause(workers=(0, 1, 2)), "runtime.gc", (0, 1, 2),
                 Action.SYNCHRONIZE_GC, id="gc-pause"),
]


@pytest.mark.timeout(600)
@pytest.mark.parametrize("fault,functions,workers,action", MP_CASES)
def test_multiprocess_trainer_scenario(fault, functions, workers, action):
    """Real trainer processes over the socket transport: spawned children
    run actual jit'd training, upload patterns + measured anchors, and the
    parent (no simulator, no model) diagnoses end-to-end."""
    wl = TrainerWorkload(n_workers=4)
    r = _scenario(wl, fault)
    res = r.run_multiprocess(n_procs=2, window_timeout=240.0)
    ws = res.wire_summary()
    assert ws["expected"] == 4 * N_WIN
    assert ws["delivered"] == ws["expected"]
    _incident(res, functions, workers, action)
