"""ISSUE 3: online multi-window incident pipeline (DESIGN.md §7).

The scenario matrix drives every fault model through a multi-window
simulated run with mid-run injection and removal, asserting the paper's
online story end-to-end: the incident opens within 2 windows of injection,
names the faulty worker(s), and resolves within 2 windows of removal —
with the fleet profiled at the cheap base rate and only implicated workers
escalated to the full rate.
"""
import numpy as np
import pytest

from repro.core import faults as F
from repro.core.detector import (DetectorConfig, IterationDetector, Recovery)
from repro.core.events import Kind
from repro.core.localizer import Abnormality
from repro.core.service import PerfTrackerService
from repro.core.simulation import (ALLGATHER, DATALOADER_STACK, FORWARD_STACK,
                                   GC_STACK, GEMM, FleetSimulator, SimConfig)
from repro.online import (CONFIRMED, MITIGATING, OPEN, RESOLVED,
                          EmaPatternAggregator, EscalationPolicy,
                          IncidentManager, ScenarioRunner, ScheduledFault)
from repro.summarize.aggregate import PatternAggregator

W = 24
INJECT, REMOVE = 2, 6
BASE_HZ, FULL_HZ = 250.0, 2000.0

#: (fault, expected incident function, culprit workers or None=fleet-wide)
SCENARIOS = [
    pytest.param(F.GpuThrottle(workers=(3, 11)), GEMM, {3, 11},
                 id="C1P1_gpu_throttle"),
    pytest.param(F.NvlinkDown(workers=[5], group_size=8), ALLGATHER, {5},
                 id="C1P2_nvlink_down"),
    pytest.param(F.RingSlowLink(slow_worker=9, rho=0.4), ALLGATHER, {9},
                 id="S3_ring_slow_link"),
    pytest.param(F.SlowDataloader(), DATALOADER_STACK, None,
                 id="C2P1_slow_dataloader"),
    pytest.param(F.CpuBoundForward(workers=range(6)), FORWARD_STACK,
                 set(range(6)), id="C2P2_cpu_forward"),
    pytest.param(F.AsyncGc(probability=0.5, pause_s=0.25), GC_STACK, None,
                 id="C2P3_async_gc"),
]


def run_scenario(schedule, n_windows=10, seed=5, escalation=True):
    esc = EscalationPolicy(n_workers=W, base_rate_hz=BASE_HZ,
                           full_rate_hz=FULL_HZ) if escalation else None
    return ScenarioRunner(
        SimConfig(n_workers=W, window_s=1.0, rate_hz=FULL_HZ, seed=seed),
        schedule, n_windows=n_windows, escalation=esc).run()


# -- the multi-window fault matrix -------------------------------------------

@pytest.mark.parametrize("fault,expect,culprits", SCENARIOS)
def test_scenario_lifecycle(fault, expect, culprits):
    res = run_scenario([ScheduledFault(fault, INJECT, REMOVE)])
    incs = [i for i in res.incidents if i.function == expect]
    assert incs, (expect, [i.function for i in res.incidents])
    inc = incs[0]
    # opens within 2 windows of injection (trigger is anchor-driven)
    assert INJECT <= res.window_of(inc.opened_at) <= INJECT + 2
    # names the faulty worker(s)
    if culprits is not None:
        assert culprits <= set(inc.workers), (culprits, inc.workers)
    else:
        assert len(inc.workers) > 0
    # full lifecycle, in order
    states = [s for _, s in inc.history]
    assert states == [OPEN, CONFIRMED, MITIGATING, RESOLVED]
    # resolves within 2 windows of fault removal
    assert inc.state == RESOLVED
    assert res.window_of(inc.resolved_at) <= REMOVE + 2
    # a mitigation plan was attached while mitigating
    assert inc.plans


def test_scenario_healthy_run_no_incidents():
    res = run_scenario([])
    assert res.incidents == []
    assert all(r.functions() == [] for r in res.reports)


def test_scenario_escalates_implicated_workers_only():
    res = run_scenario(
        [ScheduledFault(F.GpuThrottle(workers=(3, 11)), INJECT, REMOVE)])
    # before the fault: nobody escalated, whole fleet at base rate
    assert res.reports[0].escalated == []
    np.testing.assert_allclose(res.reports[1].rates, BASE_HZ)
    # during the fault: the culprits (and only a small set) run full rate
    mid = res.reports[INJECT + 1]
    assert {3, 11} <= set(mid.escalated)
    assert len(mid.escalated) <= 4
    assert mid.rates[3] == FULL_HZ and mid.rates[0] == BASE_HZ
    # cooldown after resolution: escalation drains back to empty
    assert res.reports[-1].escalated == []


def test_scenario_overlapping_incidents_stay_distinct():
    res = run_scenario(
        [ScheduledFault(F.GpuThrottle(workers=(3, 11)), 2, 8),
         ScheduledFault(F.SlowDataloader(), 4, 10)], n_windows=14)
    gemm = next(i for i in res.incidents if i.function == GEMM)
    dl = next(i for i in res.incidents if i.function == DATALOADER_STACK)
    assert gemm.id != dl.id
    # the second fault opened its own incident while the first was active
    assert 4 <= res.window_of(dl.opened_at) <= 6
    assert res.window_of(dl.opened_at) >= res.window_of(gemm.opened_at)
    # both resolve, independently
    assert gemm.state == RESOLVED and dl.state == RESOLVED
    assert res.window_of(gemm.resolved_at) <= 8 + 2
    assert res.window_of(dl.resolved_at) <= 10 + 2
    # the throttled workers stayed attributed to the GPU incident
    assert {3, 11} <= set(gemm.workers)


def test_scenario_diagnoses_sharpen_not_restart():
    """Cross-window EMA: consecutive windows of one incident keep the
    diagnosis stable (same function, same culprits) instead of flapping."""
    res = run_scenario(
        [ScheduledFault(F.GpuThrottle(workers=(3, 11)), INJECT, REMOVE)])
    flagged = [GEMM in r.functions()
               for r in res.reports[INJECT + 1:REMOVE]]
    assert all(flagged)


# -- EMA aggregator -----------------------------------------------------------

def _window_agg(values):
    """A (W=2, F, 3) one-window aggregator from {name: [w0row, w1row]}."""
    agg = PatternAggregator(expected_workers=2)
    agg.reserve_workers(2)
    names = list(values)
    for nm in names:
        agg.intern(nm, Kind.GPU)
    block = np.stack([np.asarray(values[nm], np.float32).reshape(2, 3)
                      for nm in names], axis=1)
    agg.scatter_block(0, block)
    return agg


def test_ema_first_window_initializes_full_value():
    ema = EmaPatternAggregator(2, alpha=0.5)
    ema.fold(_window_agg({"f": [[0.4, 0.8, 0.1]] * 2}))
    pats, kinds = ema.finalize()
    np.testing.assert_allclose(pats["f"], [[0.4, 0.8, 0.1]] * 2, rtol=1e-6)
    assert kinds["f"] == Kind.GPU


def test_ema_fold_is_exponential_average():
    ema = EmaPatternAggregator(2, alpha=0.5)
    ema.fold(_window_agg({"f": [[0.4, 0.8, 0.1]] * 2}))
    ema.fold(_window_agg({"f": [[0.8, 0.4, 0.3]] * 2}))
    pats, _ = ema.finalize()
    np.testing.assert_allclose(pats["f"], [[0.6, 0.6, 0.2]] * 2, rtol=1e-6)


def test_ema_absent_function_decays_toward_zero():
    ema = EmaPatternAggregator(2, alpha=0.5)
    ema.fold(_window_agg({"f": [[0.4, 0.8, 0.1]] * 2}))
    ema.fold(_window_agg({"g": [[0.2, 0.2, 0.2]] * 2}))   # f absent
    pats, _ = ema.finalize()
    np.testing.assert_allclose(pats["f"], [[0.2, 0.4, 0.05]] * 2, rtol=1e-6)
    # g is first-seen: full value, no alpha ramp-up
    np.testing.assert_allclose(pats["g"], [[0.2, 0.2, 0.2]] * 2, rtol=1e-6)


def test_ema_rejects_worker_mismatch():
    ema = EmaPatternAggregator(3, alpha=0.5)
    with pytest.raises(ValueError):
        ema.fold(_window_agg({"f": [[0.4, 0.8, 0.1]] * 2}))


def test_ema_grows_function_axis():
    ema = EmaPatternAggregator(2, alpha=0.5, expected_functions=1)
    for i in range(10):
        ema.fold(_window_agg({f"f{i}": [[0.1, 0.2, 0.3]] * 2}))
    assert ema.n_functions == 10
    pats, _ = ema.finalize()
    assert pats["f9"].shape == (2, 3)


# -- escalation policy --------------------------------------------------------

def _abn(workers):
    idx = np.asarray(sorted(workers), np.int64)
    return Abnormality(function="f", workers=idx, kind=Kind.GPU,
                       d_expect=np.zeros(idx.size), delta=np.zeros(idx.size),
                       patterns=np.zeros((idx.size, 3), np.float32),
                       typical=np.zeros(3, np.float32))


def test_escalation_base_until_implicated():
    esc = EscalationPolicy(8, base_rate_hz=100.0, full_rate_hz=1000.0,
                           cooldown_windows=2)
    np.testing.assert_allclose(esc.rates(), 100.0)
    esc.observe([_abn({2, 5})])
    rates = esc.rates()
    assert rates[2] == rates[5] == 1000.0
    assert rates[0] == 100.0
    assert esc.escalated == [2, 5]


def test_escalation_cooldown_expires():
    esc = EscalationPolicy(8, base_rate_hz=100.0, full_rate_hz=1000.0,
                           cooldown_windows=2)
    esc.observe([_abn({2})])
    esc.observe([])                  # 1 clean window: still escalated
    assert esc.escalated == [2]
    esc.observe([])                  # cooldown exhausted
    assert esc.escalated == []


def test_escalation_reimplication_resets_cooldown():
    esc = EscalationPolicy(8, base_rate_hz=100.0, full_rate_hz=1000.0,
                           cooldown_windows=2)
    esc.observe([_abn({2})])
    esc.observe([_abn({2})])
    esc.observe([])
    assert esc.escalated == [2]


def test_escalation_budget_caps_fleet_wide_faults():
    esc = EscalationPolicy(16, base_rate_hz=100.0, full_rate_hz=1000.0,
                           cooldown_windows=2, max_escalated=4)
    esc.observe([_abn(set(range(16)))])          # fleet-wide abnormality
    assert len(esc.escalated) == 4
    assert (esc.rates() == 1000.0).sum() == 4
    # fresh implications evict cooldown holdovers beyond the budget
    esc.observe([_abn({8, 9, 10, 11})])
    assert esc.escalated == [8, 9, 10, 11]


def test_escalation_budget_is_hard_with_truncated_holdovers():
    """Regression: a holdover implicated this window but truncated out of
    the budget must still count against it — the budget is a hard cap."""
    esc = EscalationPolicy(8, base_rate_hz=100.0, full_rate_hz=1000.0,
                           cooldown_windows=2, max_escalated=2)
    esc.observe([_abn({5, 6})])
    esc.observe([_abn({1, 2, 3, 5})])     # fresh truncates to {1, 2}
    assert len(esc.escalated) <= 2
    assert esc.escalated == [1, 2]


def test_escalation_rejects_inverted_rates():
    with pytest.raises(ValueError):
        EscalationPolicy(8, base_rate_hz=1000.0, full_rate_hz=100.0)


def test_escalation_window_bytes_tracks_rates():
    esc = EscalationPolicy(4, base_rate_hz=100.0, full_rate_hz=1000.0)
    base = esc.window_bytes(window_s=2.0)
    assert base == 4 * 100.0 * 2.0 * 4 * 8
    esc.escalate([0])
    assert esc.window_bytes(window_s=2.0) > base


# -- per-worker sample rates through simulator + fleet batching ---------------

def test_profile_window_per_worker_rates():
    cfg = SimConfig(n_workers=4, window_s=1.0, rate_hz=2000.0, seed=3)
    sim = FleetSimulator(cfg, [F.GpuThrottle(workers=[1])])
    rates = np.array([250.0, 2000.0, 250.0, 250.0])
    profiles = sim.profile_window(rates=rates)
    for p, r in zip(profiles, rates):
        for st in p.streams.values():
            assert st.rate_hz == r
            assert len(st.values) == int(r * cfg.window_s)


def test_profile_window_uniform_rates_match_default():
    cfg = SimConfig(n_workers=3, window_s=1.0, rate_hz=500.0, seed=3)
    fault = [F.GpuThrottle(workers=[1])]
    a = FleetSimulator(cfg, fault).profile_window()
    b = FleetSimulator(cfg, fault).profile_window(
        rates=np.full(3, cfg.rate_hz))
    for pa, pb in zip(a, b):
        assert [e.name for e in pa.events] == [e.name for e in pb.events]
        for k in pa.streams:
            np.testing.assert_array_equal(pa.streams[k].values,
                                          pb.streams[k].values)


def test_profile_window_rejects_bad_rate_shape():
    sim = FleetSimulator(SimConfig(n_workers=4))
    with pytest.raises(ValueError):
        sim.profile_window(rates=np.array([100.0, 200.0]))


# -- incident manager unit behavior -------------------------------------------

def _trig(t=10.0):
    from repro.core.detector import Trigger
    return Trigger("slowdown", t, 1.3, 1.0)


def test_incident_single_trigger_single_incident():
    mgr = IncidentManager(fleet_size=8)
    assert mgr.on_trigger(_trig(10.0)) is not None
    # reminder trigger while the incident is active: no second incident
    assert mgr.on_trigger(_trig(20.0)) is None
    assert len(mgr.incidents) == 1


def test_incident_transient_trigger_resolves_on_recovery():
    mgr = IncidentManager(fleet_size=8)
    mgr.on_trigger(_trig(10.0))
    resolved = mgr.on_recovery(Recovery("slowdown", 30.0))
    assert [i.state for i in resolved] == [RESOLVED]
    assert mgr.active == []


def test_incident_triggerless_needs_consecutive_windows():
    mgr = IncidentManager(fleet_size=8, confirm_windows=2)
    d = PerfTrackerService().diagnose_patterns(
        {"f": np.tile([0.5, 0.2, 0.1], (8, 1)).astype(np.float32)},
        {"f": Kind.PYTHON}).diagnoses
    assert d                                   # beta 0.5 >> 1% python box
    mgr.on_window(1.0, d)                      # first sighting: candidate
    assert mgr.incidents == []
    mgr.on_window(2.0, [])                     # streak broken
    mgr.on_window(3.0, d)
    assert mgr.incidents == []
    mgr.on_window(4.0, d)                      # two consecutive: incident
    assert len(mgr.incidents) == 1
    assert mgr.incidents[0].state == CONFIRMED


# -- detector recovery events + config aliasing (bugfix regressions) ----------

D, O = "dataloader.next", "optimizer.step"


def _feed(det, n, t0, dur):
    t = t0
    for _ in range(n):
        det.feed(D, t)
        det.feed(O, t + dur * 0.97)
        t += dur
    return t


def test_detector_emits_slowdown_recovery():
    det = IterationDetector(DetectorConfig(n_recent=20, rearm_cooldown=0))
    t = _feed(det, 30, 0.0, 1.0)
    t = _feed(det, 30, t, 1.3)
    assert len(det.triggers) == 1 and det.recoveries == []
    assert not det.healthy
    _feed(det, 40, t, 1.0)
    assert [r.reason for r in det.recoveries] == ["slowdown"]
    assert det.healthy


def test_detector_emits_blockage_recovery():
    det = IterationDetector()
    t = _feed(det, 15, 0.0, 1.0)
    assert det.check_blockage(t + 10.0) is not None
    assert not det.healthy
    _feed(det, 1, t + 60.0, 1.0)
    assert [r.reason for r in det.recoveries] == ["blockage"]
    assert det.healthy


def test_service_detector_cfg_not_aliased():
    """Regression: the old ``detector_cfg: DetectorConfig = DetectorConfig()``
    default evaluated ONCE — every default-constructed service shared (and
    could retune) the same config instance."""
    a = PerfTrackerService()
    b = PerfTrackerService()
    assert a.detector.cfg is not b.detector.cfg
    a.detector.cfg.slowdown_ratio = 99.0
    assert b.detector.cfg.slowdown_ratio == 1.05


def test_iteration_detector_cfg_not_aliased():
    a = IterationDetector()
    b = IterationDetector()
    assert a.cfg is not b.cfg
    a.cfg.n_recent = 7
    assert b.cfg.n_recent == 50
