"""ISSUE 5: the closed mitigation loop (DESIGN.md §9).

Three layers of coverage:

  * the mitigation matrix — every fault kind maps to the expected first
    Action (including the widespread-hardware branch that used to fall
    through to NONE) and a ranked ladder;
  * the act->verify->resolve loop — for all six fault models the correct
    first plan executes against the simulator, the fault clears, and the
    incident reaches ``resolved`` within ``verify_windows`` of the
    application; wrong-plan-first scenarios escalate to the second rung
    and resolve within ``verify_windows * 2``; a fault nothing cures
    leaves the incident ``escalated`` (never silently resolved);
  * the mechanics — elastic re-mesh keeps fleet/wire byte-parity on the
    shrunk fleet, lifecycle states only ever move forward through STATES,
    and recurring signatures link to their prior incident.
"""
import numpy as np
import pytest

from repro.core import faults as F
from repro.core.events import Kind
from repro.core.localizer import Abnormality
from repro.core.mitigation import (Action, format_plans, plan_ladder,
                                   plan_mitigations)
from repro.core.report import Diagnosis, root_cause_hint
from repro.core.service import PerfTrackerService
from repro.core.simulation import (ALLGATHER, DATALOADER_STACK, FORWARD_STACK,
                                   GC_STACK, GEMM, FleetSimulator, SimConfig)
from repro.online import (ESCALATED, RESOLVED, STATES, EscalationPolicy,
                          ScenarioRunner, ScheduledFault)
from tests.test_fleet import assert_identical

W = 24
N_STANDBY = 4
INJECT = 2
BASE_HZ, FULL_HZ = 250.0, 2000.0
VERIFY, SETTLE = 2, 1


def make_mitigated(schedule, n_windows=12, seed=5, n_standby=N_STANDBY,
                   **kw):
    esc = EscalationPolicy(n_workers=W + n_standby, base_rate_hz=BASE_HZ,
                           full_rate_hz=FULL_HZ)
    return ScenarioRunner(
        SimConfig(n_workers=W, window_s=1.0, rate_hz=FULL_HZ, seed=seed,
                  n_standby=n_standby),
        schedule, n_windows=n_windows, escalation=esc, mitigation=True,
        verify_windows=VERIFY, settle_windows=SETTLE, **kw)


def run_mitigated(schedule, **kw):
    runner = make_mitigated(schedule, **kw)
    return runner, runner.run()


def _assert_monotone(res):
    """Lifecycle monotonicity: state only ever moves forward in STATES."""
    order = {s: i for i, s in enumerate(STATES)}
    for inc in res.incidents:
        seq = [order[s] for _, s in inc.history]
        assert seq == sorted(seq), (inc.id, inc.history)
        assert len(set(seq)) == len(seq), (inc.id, inc.history)


# -- the mitigation plan matrix (unit level) ----------------------------------

def _diag(kind, fn, workers, fleet=W, beta=0.5, mu=0.5, sigma=0.05):
    idx = np.asarray(sorted(workers), np.int64)
    pats = np.tile(np.asarray([beta, mu, sigma], np.float32),
                   (len(idx), 1))
    a = Abnormality(function=fn, workers=idx, kind=kind,
                    d_expect=np.ones(len(idx)), delta=np.zeros(len(idx)),
                    patterns=pats,
                    typical=np.asarray([0.1, 0.5, 0.05], np.float32))
    return Diagnosis(a, root_cause_hint(a, fleet))


PLAN_MATRIX = [
    pytest.param(_diag(Kind.GPU, GEMM, [3, 11], mu=0.3),
                 Action.REPLACE_HOSTS, Action.FLAG_CODE,
                 id="gpu_narrow"),
    pytest.param(_diag(Kind.GPU, GEMM, range(16), mu=0.3),
                 Action.CHECKPOINT_NOW, None,
                 id="gpu_widespread"),
    pytest.param(_diag(Kind.COMM, ALLGATHER, [5], mu=0.9),
                 Action.REPLACE_HOSTS, Action.CHECKPOINT_NOW,
                 id="comm_narrow"),
    pytest.param(_diag(Kind.COMM, ALLGATHER, range(20), mu=0.9),
                 Action.CHECKPOINT_NOW, None,
                 id="comm_widespread"),
    pytest.param(_diag(Kind.PYTHON, DATALOADER_STACK, range(22), mu=0.35),
                 Action.MIGRATE_DATALOADER, Action.FLAG_CODE,
                 id="python_dataloader"),
    pytest.param(_diag(Kind.PYTHON, GC_STACK, [2, 9], mu=0.08),
                 Action.SYNCHRONIZE_GC, Action.FLAG_CODE,
                 id="python_gc"),
    pytest.param(_diag(Kind.PYTHON, FORWARD_STACK, range(6), mu=0.9),
                 Action.FLAG_CODE, Action.REPLACE_HOSTS,
                 id="python_generic"),
    pytest.param(_diag(Kind.MEM, "memcpy_h2d", [4], mu=0.7),
                 Action.FLAG_CODE, None,
                 id="mem_explicit"),
    # -- ISSUE 8: the new fault classes ------------------------------------
    pytest.param(_diag(Kind.NUMERICS, "numerics.loss", [0]),
                 Action.ROLLBACK_TO_CHECKPOINT, Action.FLAG_CODE,
                 id="numerics_rollback"),
    pytest.param(_diag(Kind.PYTHON, FORWARD_STACK, [7, 19],
                       mu=0.35, sigma=0.003),
                 Action.REPLACE_HOSTS, Action.FLAG_CODE,
                 id="python_cgroup_quota"),
    pytest.param(_diag(Kind.PYTHON, DATALOADER_STACK, [2, 9],
                       mu=0.2, sigma=0.12),
                 Action.REPLACE_HOSTS, Action.MIGRATE_DATALOADER,
                 id="python_pagecache_thrash"),
]


@pytest.mark.parametrize("diag,first,second", PLAN_MATRIX)
def test_plan_ladder_matrix(diag, first, second):
    ladder = plan_ladder(diag, W)
    assert ladder[0].action == first
    if second is not None:
        assert len(ladder) >= 2 and ladder[1].action == second
    # the flat batch view leads with the same action class
    flat = plan_mitigations([diag], W)
    assert flat and flat[0].action == first
    assert all(p.action != Action.NONE for p in flat)


def test_plan_widespread_hardware_regression():
    """Regression: a GPU/COMM abnormality on >= 50% of the fleet used to
    fall through to Action.NONE."""
    d = _diag(Kind.GPU, GEMM, range(12), mu=0.3)    # exactly 50%
    plans = plan_mitigations([d], W)
    assert [p.action for p in plans] == [Action.CHECKPOINT_NOW]
    assert "topology" in plans[0].detail


def test_plan_mitigations_merges_replace_hosts():
    a = _diag(Kind.GPU, GEMM, [3], mu=0.3)
    b = _diag(Kind.COMM, ALLGATHER, [7], mu=0.9)
    plans = plan_mitigations([a, b], W)
    heads = [p for p in plans if p.action == Action.REPLACE_HOSTS]
    assert len(heads) == 1 and heads[0].workers == [3, 7]
    assert plans[0].action == Action.REPLACE_HOSTS


def test_format_plans_one_line_per_plan():
    d = _diag(Kind.GPU, GEMM, [3, 11], mu=0.3)
    out = format_plans(plan_ladder(d, W))
    assert out.count("mitigation:") == 2
    assert "replace_hosts" in out


# -- fault-model helpers ------------------------------------------------------

def test_affected_workers():
    assert F.affected_workers(F.GpuThrottle(workers=(3, 11))) == {3, 11}
    assert F.affected_workers(F.RingSlowLink(slow_worker=9)) == {9}
    assert F.affected_workers(F.SlowDataloader()) is None
    assert F.affected_workers(F.CpuBoundForward()) is None
    assert F.affected_workers(F.CpuBoundForward(workers=(1,))) == {1}


def test_remap_workers():
    f = F.GpuThrottle(workers=(3, 11))
    moved = F.remap_workers(f, {3: 24, 11: 25})
    assert set(moved.workers) == {24, 25} and moved.slowdown == f.slowdown
    assert F.remap_workers(f, {7: 26}) is f            # untouched
    assert F.remap_workers(f, {3: None, 11: None}) is None
    part = F.remap_workers(f, {3: None})
    assert set(part.workers) == {11}
    ring = F.RingSlowLink(slow_worker=9)
    assert F.remap_workers(ring, {9: 24}) is ring      # NIC stays put


def test_replace_hosts_mapping_and_standby_exhaustion():
    sim = FleetSimulator(SimConfig(n_workers=6, n_standby=1))
    assert sim.total_workers == 7
    mapping = sim.replace_hosts([1, 4, 4, 99])
    assert mapping == {1: 6, 4: None}                  # pool of 1, dedup
    assert sim.active_workers == [0, 2, 3, 5, 6]
    # dropped workers stay out even if named again
    assert sim.replace_hosts([1]) == {}


def test_iteration_multiplier_ignores_dropped_fault_hosts():
    sim = FleetSimulator(SimConfig(n_workers=8, n_standby=2),
                         [F.GpuThrottle(workers=(3,))])
    assert sim.iteration_multiplier() > 1.0
    sim.replace_hosts([3])
    assert sim.iteration_multiplier() == 1.0
    # fleet-wide faults keep gating regardless of membership
    sim.faults = [F.SlowDataloader()]
    assert sim.iteration_multiplier() > 1.0


# -- the act -> verify -> resolve matrix --------------------------------------

#: (fault, expected incident function, expected first action)
SCENARIOS = [
    pytest.param(F.GpuThrottle(workers=(3, 11)), GEMM,
                 Action.REPLACE_HOSTS, id="C1P1_gpu_throttle"),
    pytest.param(F.NvlinkDown(workers=[5], group_size=8), ALLGATHER,
                 Action.REPLACE_HOSTS, id="C1P2_nvlink_down"),
    pytest.param(F.RingSlowLink(slow_worker=9, rho=0.4), ALLGATHER,
                 Action.REPLACE_HOSTS, id="S3_ring_slow_link"),
    pytest.param(F.SlowDataloader(), DATALOADER_STACK,
                 Action.MIGRATE_DATALOADER, id="C2P1_slow_dataloader"),
    pytest.param(F.CpuBoundForward(workers=range(6)), FORWARD_STACK,
                 Action.FLAG_CODE, id="C2P2_cpu_forward"),
    pytest.param(F.AsyncGc(probability=0.5, pause_s=0.25), GC_STACK,
                 Action.SYNCHRONIZE_GC, id="C2P3_async_gc"),
]


@pytest.mark.parametrize("fault,expect,action", SCENARIOS)
def test_mitigation_matrix_act_verify_resolve(fault, expect, action):
    """Correct first plan applied -> fault cleared in the simulator ->
    incident resolved within verify_windows of the application."""
    runner, res = run_mitigated(
        [ScheduledFault(fault, INJECT, 12)])       # schedule never removes
    inc = next(i for i in res.incidents if i.function == expect)
    # the expected first plan was executed, exactly once for this incident
    mine = [m for m in runner.engine.log if m.incident_id == inc.id]
    assert mine and mine[0].plan.action == action
    assert inc.escalations == 0
    # the plan actually cleared the injected fault in the simulator
    cure_w = runner.engine.cured_window(0)
    assert cure_w == mine[0].window
    assert runner.engine.faults_at(cure_w + 1) == []
    # ... and the incident verified and resolved within verify_windows
    assert inc.state == RESOLVED
    resolved_w = res.window_of(inc.resolved_at)
    assert resolved_w - mine[0].window <= VERIFY
    # the full forward-only lifecycle was walked
    states = [s for _, s in inc.history]
    assert states == ["open", "confirmed", "mitigating", "verifying",
                      "resolved"]
    _assert_monotone(res)


def test_membership_tracks_active_mesh_not_row_space():
    """Plan sizing (the widespread-fault fraction) and localization run
    over the ACTIVE mesh, not the pipeline's row space: cold standbys
    must not dilute ``fleet_size`` (with or without an engine)."""
    runner, _ = run_mitigated(
        [ScheduledFault(F.GpuThrottle(workers=(3, 11)), INJECT, 12)])
    assert runner.pipeline.n_workers == W + N_STANDBY
    assert runner.pipeline.incidents.fleet_size == W     # 24, not 28
    # no engine: standbys still stay out of the mesh statistics
    esc = EscalationPolicy(n_workers=W + 2, base_rate_hz=BASE_HZ,
                           full_rate_hz=FULL_HZ)
    r2 = ScenarioRunner(
        SimConfig(n_workers=W, window_s=1.0, rate_hz=FULL_HZ, seed=5,
                  n_standby=2),
        [], n_windows=2, escalation=esc)
    res2 = r2.run()
    assert r2.pipeline.incidents.fleet_size == W
    assert res2.incidents == []
    assert all(r.functions() == [] for r in res2.reports)


def test_replace_hosts_remeshes_onto_standbys():
    runner, res = run_mitigated(
        [ScheduledFault(F.GpuThrottle(workers=(3, 11)), INJECT, 12)])
    active = runner.sim.active_workers
    assert 3 not in active and 11 not in active
    assert {24, 25} <= set(active)                  # standbys joined
    assert len(active) == W                          # fleet size held
    # post-re-mesh windows carry a present mask excluding the dropped rows
    last = res.reports[-1]
    assert last.present is not None
    assert not last.present[3] and not last.present[11]
    assert last.present[24] and last.present[25]


WRONG_PLAN = [
    # "GPU" signature that is really software: replacing hosts moves the
    # fault onto the standbys, rung 2 (flag-code) cures it
    pytest.param(F.GpuThrottle(workers=(3, 11)), GEMM,
                 (Action.FLAG_CODE,),
                 [Action.REPLACE_HOSTS, Action.FLAG_CODE],
                 id="gpu_actually_software"),
    # "slow Python forward" that is really bad hosts: flagging code does
    # nothing, rung 2 (replace) drops the hosts
    pytest.param(F.CpuBoundForward(workers=(4, 9)), FORWARD_STACK,
                 (Action.REPLACE_HOSTS,),
                 [Action.FLAG_CODE, Action.REPLACE_HOSTS],
                 id="python_actually_hardware"),
]


@pytest.mark.parametrize("fault,expect,cures,actions", WRONG_PLAN)
def test_wrong_plan_first_escalates_then_resolves(fault, expect, cures,
                                                  actions):
    runner, res = run_mitigated(
        [ScheduledFault(fault, INJECT, 14, cures=cures)], n_windows=14)
    inc = next(i for i in res.incidents if i.function == expect)
    assert inc.state == RESOLVED
    assert inc.escalations == 1
    assert [p.action for _, p in inc.applied] == actions
    # the second rung is what cured it
    mine = [m for m in runner.engine.log if m.incident_id == inc.id]
    assert mine[-1].cured == [type(fault).__name__]
    # resolved within verify_windows * 2 of the FIRST application
    resolved_w = res.window_of(inc.resolved_at)
    assert resolved_w - mine[0].window <= VERIFY * 2
    _assert_monotone(res)


def test_wrong_replace_moves_software_fault_to_standbys():
    """The remap story in detail: the signature reappears on the
    replacement workers, which is exactly what fails verification."""
    runner, res = run_mitigated(
        [ScheduledFault(F.GpuThrottle(workers=(3, 11)), INJECT, 14,
                        cures=(Action.FLAG_CODE,))], n_windows=14)
    replace = runner.engine.log[0]
    assert replace.plan.action == Action.REPLACE_HOSTS
    assert replace.remapped == ["GpuThrottle"]
    inc = next(i for i in res.incidents if i.function == GEMM)
    # the last implication before the cure named the standbys
    assert {24, 25} <= set(inc.workers)


def test_max_escalations_exhaustion_leaves_escalated():
    """A fault nothing cures: the ladder runs dry and the incident ends
    ``escalated`` — never silently resolved, even after the schedule
    removes the fault — and no duplicate incident flaps underneath."""
    runner, res = run_mitigated(
        [ScheduledFault(F.GpuThrottle(workers=(3, 11)), INJECT, 9,
                        cures=())], n_windows=13)
    incs = [i for i in res.incidents if i.function == GEMM]
    assert len(incs) == 1                       # suppression: no flapping
    inc = incs[0]
    assert inc.state == ESCALATED
    assert inc.resolved_at is None
    assert inc.escalations >= 1
    assert len(inc.applied) == len(inc.plans)   # every rung was tried
    assert [s for _, s in inc.history][-1] == "escalated"
    _assert_monotone(res)


def test_partial_fix_residual_fault_stays_live():
    """``on_cure`` leaves a weaker residual: the cure downgrades the fault
    instead of clearing it."""
    runner, _ = run_mitigated(
        [ScheduledFault(F.SlowDataloader(slowdown=20.0), INJECT, 12,
                        on_cure=F.SlowDataloader(slowdown=5.0))])
    cure_w = runner.engine.cured_window(0)
    assert cure_w is not None
    residual = runner.engine.faults_at(cure_w + 1)
    assert len(residual) == 1 and residual[0].slowdown == 5.0


# -- recurrence linking -------------------------------------------------------

def test_recurrence_links_to_prior_incident_with_engine():
    runner, res = run_mitigated(
        [ScheduledFault(F.SlowDataloader(), 2, 14),
         ScheduledFault(F.SlowDataloader(), 8, 14)], n_windows=14)
    incs = [i for i in res.incidents if i.function == DATALOADER_STACK]
    assert len(incs) == 2
    first, second = incs
    assert first.state == RESOLVED and second.state == RESOLVED
    assert second.recurrence_of == first.id
    assert f"recurrence_of=#{first.id}" in res.timeline()
    _assert_monotone(res)


def test_recurrence_links_without_engine():
    """ROADMAP item 4 (small version): schedule-driven recurrence on the
    plain runner links too."""
    esc = EscalationPolicy(n_workers=W, base_rate_hz=BASE_HZ,
                           full_rate_hz=FULL_HZ)
    res = ScenarioRunner(
        SimConfig(n_workers=W, window_s=1.0, rate_hz=FULL_HZ, seed=5),
        [ScheduledFault(F.GpuThrottle(workers=(3, 11)), 2, 5),
         ScheduledFault(F.GpuThrottle(workers=(3, 11)), 9, 12)],
        n_windows=15, escalation=esc).run()
    incs = [i for i in res.incidents if i.function == GEMM]
    assert len(incs) == 2
    assert incs[1].recurrence_of == incs[0].id
    _assert_monotone(res)


# -- re-mesh byte parity (fleet vs wire on the shrunk fleet) ------------------

def test_remesh_fleet_wire_byte_parity():
    """After REPLACE_HOSTS shrinks the fleet onto standbys, the in-process
    fleet-batched path and the real-socket wire path still produce
    byte-identical diagnoses on the shrunk fleet."""
    cfg = SimConfig(n_workers=12, window_s=1.0, rate_hz=1000.0, seed=3,
                    n_standby=2)
    sim = FleetSimulator(cfg, [F.GpuThrottle(workers=(2, 5))])
    mapping = sim.replace_hosts([2, 5])
    assert mapping == {2: 12, 5: 13}
    # the replacement cured the original fault; a residual fault on a
    # surviving worker keeps the diagnosis non-trivial
    sim.faults = [F.GpuThrottle(workers=(7,))]
    profiles = sim.profile_window()
    assert len(profiles) == 12
    assert {p.worker for p in profiles} == set(sim.active_workers)
    svc = PerfTrackerService()
    fleet = svc.diagnose_profiles(profiles, mode="fleet")
    wire = PerfTrackerService().diagnose_profiles(profiles, mode="wire")
    assert fleet.diagnoses, "shrunk fleet lost the diagnosis"
    assert_identical(fleet, wire)


def test_diagnosis_report_mitigation_section():
    cfg = SimConfig(n_workers=8, window_s=1.0, rate_hz=1000.0, seed=3)
    sim = FleetSimulator(cfg, [F.GpuThrottle(workers=(2,))])
    res = PerfTrackerService().diagnose_profiles(sim.profile_window())
    assert "mitigation:" not in res.report()
    out = res.report(mitigation=True)
    assert "mitigation: replace_hosts" in out
    assert any(p.action == Action.REPLACE_HOSTS
               for p in res.suggested_plans())


# -- mitigation across real process boundaries (DESIGN.md §10) ----------------

def _mp_log_path(tmp_path):
    import os
    return os.environ.get("REPRO_WIRE_LOG",
                          str(tmp_path / "wire-collector.log"))


def _engine_trace(runner):
    return [(m.window, m.plan.action, tuple(m.plan.workers),
             tuple(m.cured), tuple(m.dropped), tuple(m.replacements))
            for m in runner.engine.log]


def _outcomes(res):
    return [(i.function, i.state, i.escalations) for i in res.incidents]


@pytest.mark.wire
@pytest.mark.timeout(300)
@pytest.mark.parametrize("fault,expect,action", SCENARIOS)
def test_multiprocess_mitigation_matches_inprocess(fault, expect, action,
                                                   tmp_path):
    """Acceptance (ISSUE 6): ``run_multiprocess(mitigation=True)`` resolves
    every fault in the matrix with the SAME incident outcomes as the
    in-process PR 5 loop — plans ride the ``window_start`` control plane,
    children replay them on their own engines, and the re-meshed
    collectors keep assembling complete windows."""
    sched = [ScheduledFault(fault, INJECT, 12)]
    runner_in, res_in = run_mitigated(sched)
    runner_mp = make_mitigated(sched)
    res_mp = runner_mp.run_multiprocess(n_procs=4,
                                        log_path=_mp_log_path(tmp_path))
    # identical incident outcomes, engine actions, and final mesh
    assert _outcomes(res_mp) == _outcomes(res_in)
    assert _engine_trace(runner_mp) == _engine_trace(runner_in)
    assert runner_mp.sim.active_workers == runner_in.sim.active_workers
    # the expected plan resolved the incident within the verify ceiling
    inc = next(i for i in res_mp.incidents if i.function == expect)
    assert inc.state == RESOLVED and inc.escalations == 0
    mine = [m for m in runner_mp.engine.log if m.incident_id == inc.id]
    assert mine and mine[0].plan.action == action
    assert res_mp.window_of(inc.resolved_at) - mine[0].window <= VERIFY
    # every window's diagnosis matched the in-process run exactly
    assert ([r.functions() for r in res_mp.reports]
            == [r.functions() for r in res_in.reports])


@pytest.mark.wire
@pytest.mark.timeout(300)
def test_multiprocess_mitigation_through_collector_tree(tmp_path):
    """The same closed loop with uploads routed through the sharded
    collector tree: membership deltas flow root -> leaf -> rack, and the
    per-shard transport accounting surfaces in the window reports."""
    sched = [ScheduledFault(F.GpuThrottle(workers=(3, 11)), INJECT, 12)]
    runner_in, res_in = run_mitigated(sched)
    runner_mp = make_mitigated(sched)
    res_mp = runner_mp.run_multiprocess(n_procs=4, n_shards=4,
                                        log_path=_mp_log_path(tmp_path))
    assert _outcomes(res_mp) == _outcomes(res_in)
    assert _engine_trace(runner_mp) == _engine_trace(runner_in)
    assert runner_mp.sim.active_workers == runner_in.sim.active_workers
    assert ([r.functions() for r in res_mp.reports]
            == [r.functions() for r in res_in.reports])
    trs = [r.transport for r in res_mp.reports if r.transport is not None]
    assert trs and all(t["expected_shards"] == 4 for t in trs)
    assert all(t["missing_shards"] == [] and not t["timed_out"]
               for t in trs)
