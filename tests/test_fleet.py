"""ISSUE 2: fleet-batched diagnosis (DESIGN.md §5) + detector/localizer
correctness fixes.

The scenario matrix runs every fault model in ``repro/core/faults.py``
end-to-end in BOTH raw-profile and pattern mode, asserting the expected
function/kind is localized — and that the fleet-batched path returns
byte-identical diagnoses to the per-worker upload path.
"""
import numpy as np
import pytest

from repro.core import faults as F
from repro.core.daemon import summarize_and_upload
from repro.core.detector import DetectorConfig, IterationDetector
from repro.core.events import FunctionEvent, Kind, SampleStream, WorkerProfile
from repro.core.localizer import Localizer
from repro.core.critical_path import (critical_time_by_function,
                                      fleet_critical_times)
from repro.core.service import PerfTrackerService
from repro.core.simulation import (ALLGATHER, DATALOADER_STACK, FORWARD_STACK,
                                   GC_STACK, GEMM, FleetSimulator, SimConfig)
from repro.summarize import PatternAggregator, pack_fleet, summarize_fleet

#: (fault list, expected localized function substring-match, expected kind)
SCENARIOS = [
    pytest.param([F.GpuThrottle(workers=range(4))], GEMM, Kind.GPU,
                 id="C1P1_gpu_throttle"),
    pytest.param([F.NvlinkDown(workers=[5], group_size=16)], ALLGATHER,
                 Kind.COMM, id="C1P2_nvlink_down"),
    pytest.param([F.RingSlowLink(slow_worker=9, rho=0.4)], ALLGATHER,
                 Kind.COMM, id="S3_ring_slow_link"),
    pytest.param([F.SlowDataloader()], DATALOADER_STACK, Kind.PYTHON,
                 id="C2P1_slow_dataloader"),
    pytest.param([F.CpuBoundForward(workers=range(6))], FORWARD_STACK,
                 Kind.PYTHON, id="C2P2_cpu_forward"),
    pytest.param([F.AsyncGc(probability=0.5)], GC_STACK, Kind.PYTHON,
                 id="C2P3_async_gc"),
]


def assert_identical(a, b):
    """Byte-identical diagnoses between two DiagnosisResults."""
    assert len(a.diagnoses) == len(b.diagnoses)
    for da, db in zip(a.diagnoses, b.diagnoses):
        aa, bb = da.abnormality, db.abnormality
        assert aa.function == bb.function
        assert da.hint == db.hint
        assert aa.reason == bb.reason
        assert aa.kind == bb.kind
        np.testing.assert_array_equal(aa.workers, bb.workers)
        np.testing.assert_array_equal(aa.patterns, bb.patterns)
        np.testing.assert_array_equal(aa.d_expect, bb.d_expect)
        np.testing.assert_array_equal(aa.delta, bb.delta)
        np.testing.assert_array_equal(aa.typical, bb.typical)


# -- scenario matrix: raw-profile mode + fleet/wire parity --------------------

@pytest.mark.parametrize("faults,expect,kind", SCENARIOS)
def test_raw_mode_scenario(faults, expect, kind):
    sim = FleetSimulator(SimConfig(n_workers=32, window_s=2.0, rate_hz=2000,
                                   seed=7), faults)
    profiles = sim.profile_window()
    svc = PerfTrackerService(summarize_backend="numpy")
    fleet = svc.diagnose_profiles(profiles, mode="fleet")
    d = next((d for d in fleet.diagnoses
              if d.abnormality.function == expect), None)
    assert d is not None, (expect, fleet.functions())
    assert d.abnormality.kind == kind
    assert_identical(fleet, svc.diagnose_profiles(profiles, mode="wire"))


def test_raw_mode_healthy_clean_and_identical():
    sim = FleetSimulator(SimConfig(n_workers=32, window_s=2.0, rate_hz=2000,
                                   seed=3), [])
    profiles = sim.profile_window()
    svc = PerfTrackerService(summarize_backend="numpy")
    fleet = svc.diagnose_profiles(profiles, mode="fleet")
    assert fleet.functions() == []
    assert_identical(fleet, svc.diagnose_profiles(profiles, mode="wire"))


# -- scenario matrix: pattern mode --------------------------------------------

@pytest.mark.parametrize("faults,expect,kind", SCENARIOS)
def test_pattern_mode_scenario(faults, expect, kind):
    sim = FleetSimulator(SimConfig(n_workers=64, seed=7), faults)
    pats, kinds = sim.synth_patterns(20)
    res = PerfTrackerService().diagnose_patterns(pats, kinds)
    d = next((d for d in res.diagnoses
              if d.abnormality.function == expect), None)
    assert d is not None, (expect, res.functions())
    assert d.abnormality.kind == kind


def test_pattern_mode_healthy_clean():
    sim = FleetSimulator(SimConfig(n_workers=64, seed=7), [])
    pats, kinds = sim.synth_patterns(20)
    assert PerfTrackerService().diagnose_patterns(pats, kinds).functions() \
        == []


def test_pattern_mode_expected_workers():
    faults = [F.GpuThrottle(workers=[3, 11])]
    sim = FleetSimulator(SimConfig(n_workers=64, seed=1), faults)
    pats, kinds = sim.synth_patterns(12)
    res = PerfTrackerService().diagnose_patterns(pats, kinds)
    d = next(d for d in res.diagnoses if d.abnormality.function == GEMM)
    assert set(d.abnormality.workers.tolist()) == {3, 11}


# -- fleet-batched summarization unit tests ----------------------------------

def _profile(seed=0, worker=0, rate=1000.0, T=4.0, with_orphan=False):
    rng = np.random.default_rng(seed)
    n = int(T * rate)
    gpu = np.clip(rng.normal(0.7, 0.2, n), 0, 1)
    cpu = np.clip(rng.normal(0.3, 0.2, n), 0, 1)
    gpu[int(n * 0.37):int(n * 0.52)] = 0.0
    events = [
        FunctionEvent("matmul", Kind.GPU, 0.0, 0.35 * T, worker),
        FunctionEvent("matmul", Kind.GPU, 0.37 * T, 0.72 * T, worker),
        FunctionEvent("allreduce", Kind.COMM, 0.5 * T, 0.77 * T, worker),
        FunctionEvent("data.next", Kind.PYTHON, 0.77 * T, 0.97 * T, worker,
                      depth=1),
    ]
    if with_orphan:   # stream absent -> zero-weight pattern, beta only
        events.append(FunctionEvent("h2d", Kind.MEM, 0.05 * T, 0.1 * T,
                                    worker))
    return WorkerProfile(
        worker=worker, window=(0.0, T), events=events,
        streams={"gpu_sm": SampleStream(rate, 0.0, gpu),
                 "pcie_tx": SampleStream(rate, 0.0, gpu * 0.5),
                 "cpu": SampleStream(rate, 0.0, cpu)})


def _upload_aggregate(profiles, kind_of=None):
    uploads = [summarize_and_upload(p, kind_of, backend="numpy")
               for p in profiles]
    return PatternAggregator(expected_workers=len(uploads)) \
        .extend(uploads).finalize()


def test_summarize_fleet_matches_upload_path():
    profiles = [_profile(seed=s, worker=s, with_orphan=(s % 2 == 0))
                for s in range(5)]
    fs = summarize_fleet(profiles, backend="numpy")
    agg, kinds = fs.agg.finalize()
    ref_agg, ref_kinds = _upload_aggregate(profiles)
    assert kinds == ref_kinds
    assert list(agg) == list(ref_agg)
    for name in ref_agg:
        np.testing.assert_array_equal(np.asarray(agg[name]),
                                      np.asarray(ref_agg[name]))
    assert fs.n_rows > 0
    # pattern_bytes reports exactly what the wire uploads would have weighed
    wire_bytes = sum(len(summarize_and_upload(p, backend="numpy").payload)
                     for p in profiles)
    assert fs.pattern_bytes == wire_bytes


def test_summarize_fleet_kind_override():
    profiles = [_profile(seed=s, worker=s) for s in range(3)]
    kind_of = {"allreduce": Kind.PYTHON}     # reroute to the cpu stream
    agg, kinds = summarize_fleet(profiles, kind_of,
                                 backend="numpy").agg.finalize()
    ref_agg, ref_kinds = _upload_aggregate(profiles, kind_of)
    assert kinds["allreduce"] == Kind.PYTHON == ref_kinds["allreduce"]
    for name in ref_agg:
        np.testing.assert_array_equal(np.asarray(agg[name]),
                                      np.asarray(ref_agg[name]))


def test_pack_fleet_groups_by_stream_rate():
    profiles = [_profile(seed=0, worker=0, rate=1000.0),
                _profile(seed=1, worker=1, rate=500.0)]
    fb = pack_fleet(profiles)
    assert sorted({g.rate for g in fb.groups}) == [500.0, 1000.0]
    total = sum(g.u.shape[0] for g in fb.groups)
    assert total == 8                        # 4 events x 2 workers
    for g in fb.groups:
        # rows only reference events of the worker with that stream rate
        assert set(fb.events.worker[g.rows].tolist()) \
            == ({0} if g.rate == 1000.0 else {1})


def test_fleet_row_longer_than_last_length_bucket():
    from repro.summarize.fleet import _BUCKETS
    rate, T = 40000.0, 1.0
    n = int(rate * T)
    assert n > _BUCKETS[-1]
    prof = WorkerProfile(
        worker=0, window=(0.0, T),
        events=[FunctionEvent("big", Kind.GPU, 0.0, T, 0)],
        streams={"gpu_sm": SampleStream(rate, 0.0,
                                        np.full(n, 0.5))})
    fb = pack_fleet([prof])
    assert sum(g.u.shape[0] for g in fb.groups) == 1   # row not dropped
    agg, _ = summarize_fleet([prof], backend="numpy").agg.finalize()
    ref, _ = _upload_aggregate([prof])
    np.testing.assert_array_equal(np.asarray(agg["big"]),
                                  np.asarray(ref["big"]))


def test_summarize_fleet_empty_and_eventless_workers():
    profiles = [
        _profile(seed=0, worker=0),
        WorkerProfile(worker=1, window=(0.0, 4.0)),          # no events
        WorkerProfile(worker=2, window=(0.0, 4.0),           # no streams
                      events=[FunctionEvent("matmul", Kind.GPU,
                                            0.0, 2.0, 2)]),
    ]
    agg, kinds = summarize_fleet(profiles, backend="numpy").agg.finalize()
    ref_agg, ref_kinds = _upload_aggregate(profiles)
    assert kinds == ref_kinds
    for name in ref_agg:
        np.testing.assert_array_equal(np.asarray(agg[name]),
                                      np.asarray(ref_agg[name]))
    # streamless worker still reports beta (critical path needs no samples)
    assert np.asarray(agg["matmul"])[2, 0] > 0


def test_fleet_critical_times_matches_per_worker():
    profiles = [_profile(seed=s, worker=s, with_orphan=True)
                for s in range(4)]
    profiles.append(WorkerProfile(worker=4, window=(0.0, 1.0)))
    batched = fleet_critical_times(profiles)
    for p, got in zip(profiles, batched):
        ref = critical_time_by_function(p.events, p.window)
        assert list(got) == list(ref)
        for name in ref:
            assert got[name] == ref[name]    # bit-identical


# -- detector re-arm (bugfix) -------------------------------------------------

D, O = "dataloader.next", "optimizer.step"


def _feed(det, n, t0, dur):
    t, trigs = t0, []
    for _ in range(n):
        det.feed(D, t)
        trig = det.feed(O, t + dur * 0.97)
        if trig:
            trigs.append(trig)
        t += dur
    return t, trigs


def test_slowdown_fires_once_while_degraded():
    det = IterationDetector(DetectorConfig(n_recent=20, rearm_cooldown=0))
    t, _ = _feed(det, 30, 0.0, 1.0)
    _feed(det, 60, t, 1.3)
    assert len(det.triggers) == 1            # was: one per iteration


def test_slowdown_cooldown_refires_while_still_degraded():
    det = IterationDetector(DetectorConfig(n_recent=20, rearm_cooldown=25))
    t, _ = _feed(det, 30, 0.0, 1.0)
    _feed(det, 80, t, 1.3)
    # one initial trigger + periodic cooldown reminders, NOT one per iter
    assert 2 <= len(det.triggers) <= 4


def test_slowdown_rearms_after_recovery():
    det = IterationDetector(DetectorConfig(n_recent=20, rearm_cooldown=0))
    t, _ = _feed(det, 30, 0.0, 1.0)
    t, trigs1 = _feed(det, 30, t, 1.3)       # degrade: one trigger
    assert len(trigs1) == 1
    t, _ = _feed(det, 40, t, 1.0)            # recover: mean back at baseline
    _, trigs2 = _feed(det, 30, t, 1.3)       # degrade again: NEW trigger
    assert len(trigs2) == 1
    assert len(det.triggers) == 2


def test_blockage_fires_once_per_stall():
    det = IterationDetector()
    t, _ = _feed(det, 15, 0.0, 1.0)
    assert det.check_blockage(t + 10.0) is not None
    assert det.check_blockage(t + 11.0) is None      # was: every poll
    assert det.check_blockage(t + 50.0) is None
    # events flowing again re-arms blockage detection
    t2, _ = _feed(det, 3, t + 60.0, 1.0)
    assert det.check_blockage(t2 + 10.0) is not None
    assert len([g for g in det.triggers if g.reason == "blockage"]) == 2


# -- localizer self-pair masking (bugfix) -------------------------------------

def test_delta_distance_masks_self_pairs():
    W = 8
    pats = np.tile(np.array([0.5, 0.9, 0.05], np.float32), (W, 1))
    pats[3] = [0.9, 0.1, 0.05]
    # n_peers >= W: every worker's own index is in the peer sample
    delta = Localizer(n_peers=W).delta_distance(pats, function="f")
    # outlier differs from ALL other workers: exactly 1.0, not (W-1)/W
    assert delta[3] == 1.0
    # normal workers differ only from the outlier: exactly 1/(W-1)
    np.testing.assert_allclose(np.delete(delta, 3), 1.0 / (W - 1))


def test_delta_distance_single_worker_is_zero():
    pats = np.array([[0.5, 0.9, 0.05]], np.float32)
    assert Localizer().delta_distance(pats, function="f")[0] == 0.0


# -- report hint (bugfix): dead abn_beta branch removed -----------------------

def test_root_cause_hint_uses_pattern_beta():
    from repro.core.localizer import Abnormality
    from repro.core.report import root_cause_hint
    a = Abnormality(
        function=GEMM, workers=np.array([0]), kind=Kind.GPU,
        d_expect=np.array([0.0]), delta=np.array([1.0]),
        patterns=np.array([[0.9, 0.3, 0.05]], np.float32),
        typical=np.array([0.5, 0.9, 0.05], np.float32))
    assert not hasattr(a, "abn_beta")
    assert "throttling" in root_cause_hint(a, 32)
