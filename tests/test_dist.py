"""Distributed execution tests (subprocess: device count locks at first jax
init, so multi-device runs get their own interpreter with 8 host devices).

These EXECUTE (not just compile): sharded train step on a (2,4) mesh must
match the single-device step bit-for-bit-ish, including the MoE shard_map
expert-parallel path; elastic checkpoint restore re-shards to a different
mesh."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent


def run_sub(code: str, timeout=540):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(REPO / "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    out = run_sub("""
        import jax, jax.numpy as jnp
        from repro.configs.registry import ARCHS, reduced
        from repro.dist.sharding import DistCtx
        from repro.models.transformer import Transformer
        from repro.models.io import synth_batch
        from repro.optim.adamw import AdamW, OptConfig
        from repro.train.step import make_train_step

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = reduced(ARCHS["granite-34b"], d_model=64).with_overrides(
            num_heads=4, num_kv_heads=4, vocab_size=512)
        batch = synth_batch(cfg, "train", 4, 32)
        opt = AdamW(OptConfig())

        # single device
        m1 = Transformer(cfg)
        p1 = m1.init(jax.random.PRNGKey(0))
        s1 = opt.init(p1)
        step1 = jax.jit(make_train_step(m1, opt))
        p1b, _, met1 = step1(p1, s1, batch)

        # sharded
        dist = DistCtx.from_mesh(mesh)
        m2 = Transformer(cfg, dist=dist)
        p2 = m2.init(jax.random.PRNGKey(0))
        ps = dist.params_shardings(p2)
        p2 = jax.device_put(p2, ps)
        s2 = opt.init(p2)
        bs = dist.batch_shardings(batch)
        batch2 = jax.device_put(batch, bs)
        step2 = jax.jit(make_train_step(m2, opt),
                        in_shardings=(ps, None, bs))
        p2b, _, met2 = step2(p2, s2, batch2)

        d = jax.tree_util.tree_map(
            lambda a, b: float(jnp.max(jnp.abs(a - b))), p1b, p2b)
        mx = max(jax.tree_util.tree_leaves(d))
        print("loss1", float(met1["loss"]), "loss2", float(met2["loss"]),
              "maxdiff", mx)
        assert abs(float(met1["loss"]) - float(met2["loss"])) < 1e-3
        assert mx < 5e-3
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_moe_expert_parallel_matches_local():
    out = run_sub("""
        import jax, jax.numpy as jnp
        from repro.configs.registry import ARCHS, reduced
        from repro.dist.sharding import DistCtx
        from repro.models import moe as M

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = reduced(ARCHS["deepseek-v2-lite-16b"], d_model=64)
        cfg = cfg.with_overrides(num_experts=8, top_k=2,
                                 capacity_factor=8.0)
        key = jax.random.PRNGKey(0)
        p = M.init_moe(key, cfg)
        x = jax.random.normal(key, (8, 16, cfg.d_model))

        y_local, stats_local = M.apply_moe(p, x, cfg, dist=None)

        dist = DistCtx.from_mesh(mesh)
        def f(p, x):
            y, stats = M.apply_moe(p, x, cfg, dist=dist)
            return y, stats
        y_ep, stats_ep = jax.jit(f)(p, x)
        err = float(jnp.max(jnp.abs(y_local - y_ep)))
        # stats: local capacity differs (per-shard tokens), compare mean prob
        E = cfg.num_experts
        perr = float(jnp.max(jnp.abs(stats_local[E:] - stats_ep[E:])))
        print("err", err, "perr", perr)
        assert err < 5e-4 and perr < 1e-3
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_elastic_checkpoint_restore_new_mesh(tmp_path):
    out = run_sub(f"""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.ckpt.checkpoint import Checkpointer

        tree = {{"w": jnp.arange(64.0).reshape(8, 8)}}
        mesh1 = jax.make_mesh((2, 4), ("data", "model"))
        sh1 = {{"w": NamedSharding(mesh1, P("data", "model"))}}
        t1 = jax.device_put(tree, sh1)
        ck = Checkpointer("{tmp_path}")
        ck.save(1, t1, async_=False)

        # 'failure': restore onto a smaller mesh (2 hosts dropped)
        mesh2 = jax.make_mesh((2, 2), ("data", "model"),
                              devices=jax.devices()[:4])
        sh2 = {{"w": NamedSharding(mesh2, P("data", "model"))}}
        t2, meta = ck.restore(1, tree, sh2)
        assert t2["w"].sharding == sh2["w"]
        import numpy as np
        np.testing.assert_array_equal(np.asarray(t2["w"]),
                                      np.asarray(tree["w"]))
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_grad_compression_psum():
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.dist.compat import shard_map
        from repro.optim.compress import psum_compressed

        mesh = jax.make_mesh((8,), ("pod",))
        g = jnp.asarray(np.random.default_rng(0).normal(
            0, 1, (8, 32)), jnp.float32)

        def body(gl):
            out_bf16, _ = psum_compressed({"g": gl[0]}, "pod", "bf16")
            out_int8, _ = psum_compressed({"g": gl[0]}, "pod", "int8")
            exact, _ = psum_compressed({"g": gl[0]}, "pod", "none")
            return out_bf16["g"], out_int8["g"], exact["g"]

        f = jax.jit(shard_map(body, mesh=mesh, in_specs=P("pod"),
                              out_specs=P()))
        b16, i8, exact = f(g)
        e1 = float(jnp.max(jnp.abs(b16 - exact)))
        e2 = float(jnp.max(jnp.abs(i8 - exact)))
        print("bf16 err", e1, "int8 err", e2)
        assert e1 < 0.02 and e2 < 0.05
        print("OK")
    """)
    assert "OK" in out
