"""Graceful hypothesis guard (ISSUE 1 satellite): property tests use

    from _prop import HAVE_HYPOTHESIS, given, settings, st

When hypothesis is installed (pip install -r requirements-dev.txt) these are
the real decorators; when it isn't, ``@given`` turns the test into a clean
pytest skip instead of killing collection for the whole module — the
non-property tests in the same file still run.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:                     # pragma: no cover - optional dev dep
    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        def deco(fn):
            def wrapper():
                pytest.skip("hypothesis not installed "
                            "(pip install -r requirements-dev.txt)")
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    def settings(*_a, **_k):
        return lambda fn: fn

    class _AnyStrategy:
        """st.<anything>(...) placeholder; never drawn from."""
        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()
