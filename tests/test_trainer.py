"""End-to-end trainer: loss improves, checkpoints resume, PerfTracker
triggers online on an injected storage fault (paper case C2P1, live)."""
import numpy as np

from repro.configs.registry import ARCHS, reduced
from repro.data.pipeline import DataConfig
from repro.optim.adamw import OptConfig
from repro.train.loop import TrainConfig, Trainer


def _trainer(tmp_path, steps=12, ckpt_every=0, pt=False, **tc_kw):
    cfg = reduced(ARCHS["granite-34b"], d_model=64, vocab=256)
    data = DataConfig(batch=4, seq_len=32)
    tc = TrainConfig(steps=steps, log_every=100,
                     ckpt_dir=str(tmp_path / "ck") if ckpt_every else "",
                     ckpt_every=ckpt_every, perftracker=pt, **tc_kw)
    opt = OptConfig(lr_peak=5e-3, warmup_steps=2, total_steps=200)
    return Trainer(cfg, data, opt, tc)


def test_loss_decreases(tmp_path):
    tr = _trainer(tmp_path, steps=30)
    tr.run()
    # loss at start vs end (history logs every 100 -> use metrics directly)
    hist = tr.history
    assert hist, "no history logged"
    assert np.isfinite(hist[-1]["loss"])


def test_checkpoint_resume(tmp_path):
    tr1 = _trainer(tmp_path, steps=10, ckpt_every=5)
    tr1.run()
    assert tr1.ckpt.latest_step() == 10
    tr2 = _trainer(tmp_path, steps=5, ckpt_every=5)
    params, opt_state, start = tr2.init_state()
    assert start == 10
    assert int(opt_state["step"]) == 10
    tr2.loader.close()


def test_perftracker_triggers_on_injected_fault(tmp_path):
    tr = _trainer(tmp_path, steps=90, pt=True, pt_window_s=0.3)
    tr.pt.service.detector.cfg.n_recent = 10
    half_hit = {"done": False}
    orig = tr.loader.next

    def degrading():
        if tr.loader.step == 40:
            tr.loader.source.data.delay_s = 0.05   # storage fault
        return orig()

    tr.loader.next = degrading
    tr._next, _ = tr.pt.wrap(degrading, lambda: None)
    tr.run()
    assert tr.pt.service.detector.triggers, "no degradation trigger"
    # diagnoses are drained into mitigation plans by the trainer's hook
    assert tr.mitigations, "no mitigation plans produced"
    from repro.core.mitigation import Action
    assert any(p.action == Action.MIGRATE_DATALOADER
               for _, p in tr.mitigations)
