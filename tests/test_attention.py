"""Blocked flash-style attention (XLA path): fwd + custom-VJP backward vs the
unblocked oracle, folded and unfolded."""
import jax
import jax.numpy as jnp
import pytest

from _prop import given, settings, st   # hypothesis or graceful skip

from repro.models.attention import AttnSpec, attention_ref, blocked_attention


def rand_qkv(key, B, S, H, KV, D, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, D), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, D), dtype)
    return q, k, v


SPECS = [
    AttnSpec(q_block=64, kv_block=64, folded=False),
    AttnSpec(q_block=64, kv_block=64, folded=True),
    AttnSpec(q_block=64, kv_block=64, softcap=30.0),
    AttnSpec(q_block=64, kv_block=64, window=100),
    AttnSpec(q_block=64, kv_block=64, causal=False),
    AttnSpec(q_block=32, kv_block=64, folded=False),
]


@pytest.mark.parametrize("spec", SPECS)
def test_forward_matches_oracle(spec):
    q, k, v = rand_qkv(jax.random.PRNGKey(0), 2, 256, 6, 2, 32)
    out = blocked_attention(q, k, v, spec)
    exp = attention_ref(q, k, v, spec)
    assert jnp.max(jnp.abs(out - exp)) < 2e-5


@pytest.mark.parametrize("spec", SPECS)
def test_custom_vjp_matches_autodiff(spec):
    q, k, v = rand_qkv(jax.random.PRNGKey(1), 1, 128, 4, 2, 16)

    def f(impl):
        def g(q, k, v):
            return (impl(q, k, v, spec) * jnp.cos(
                jnp.arange(16, dtype=jnp.float32))).sum()
        return g

    g1 = jax.grad(f(blocked_attention), argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f(attention_ref), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        assert jnp.max(jnp.abs(a - b)) < 5e-5


def test_folded_equals_unfolded_grads():
    q, k, v = rand_qkv(jax.random.PRNGKey(2), 2, 256, 4, 4, 32)
    s1 = AttnSpec(q_block=64, kv_block=64, folded=False)
    s2 = AttnSpec(q_block=64, kv_block=64, folded=True)
    f = lambda s: jax.grad(
        lambda q: blocked_attention(q, k, v, s).sum())(q)
    assert jnp.max(jnp.abs(f(s1) - f(s2))) < 2e-5


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 2),
    nq=st.sampled_from([1, 2, 4]),
    kv=st.sampled_from([1, 2]),
    g=st.sampled_from([1, 3]),
    d=st.sampled_from([8, 32]),
    causal=st.booleans(),
    folded=st.booleans(),
)
def test_shape_sweep(b, nq, kv, g, d, causal, folded):
    S = 32 * nq
    q, k, v = rand_qkv(jax.random.PRNGKey(5), b, S, kv * g, kv, d)
    spec = AttnSpec(causal=causal, q_block=32, kv_block=32, folded=folded)
    out = blocked_attention(q, k, v, spec)
    exp = attention_ref(q, k, v, spec)
    assert out.shape == exp.shape
    assert jnp.max(jnp.abs(out - exp)) < 3e-5


def test_bf16_inputs():
    q, k, v = rand_qkv(jax.random.PRNGKey(3), 2, 128, 4, 2, 32,
                       jnp.bfloat16)
    spec = AttnSpec(q_block=64, kv_block=64)
    out = blocked_attention(q, k, v, spec)
    exp = attention_ref(q, k, v, spec)
    assert out.dtype == jnp.bfloat16
    assert jnp.max(jnp.abs(out.astype(jnp.float32)
                           - exp.astype(jnp.float32))) < 0.03


def test_decode_kv_len_mask():
    """kv_len masking for cache-backed attention."""
    q, k, v = rand_qkv(jax.random.PRNGKey(4), 1, 64, 2, 2, 16)
    spec = AttnSpec(causal=False, q_block=64, kv_block=64)
    out = blocked_attention(q, k, v, spec, 0, jnp.int32(32))
    exp = attention_ref(q, k[:, :32], v[:, :32],
                        AttnSpec(causal=False, q_block=64, kv_block=32))
    assert jnp.max(jnp.abs(out - exp)) < 2e-5
