"""ISSUE 9: the EROICA loop over the REAL serving engine (DESIGN.md §13).

Three layers of coverage:

  * the instrumented serving worker itself — real jit'd decode under the
    seeded Poisson generator, tracer frames present (dequeue wait PYTHON,
    fenced decode GPU span, cpu-only stream set), dequeue/complete anchor
    pairs and the ``slo`` metrics stream in every ``WindowData``;
  * in-process ``ServeWorkload`` scenarios — each live fault (arrival
    burst / decode stall / cache thrash) detected on the slo channel and
    localized to the right function on the right workers, with the
    serving-playbook plan on the ladder;
  * fleet/wire byte-parity of the diagnosis over real serving profiles.
"""
import numpy as np
import pytest

from repro.core.mitigation import Action
from repro.core.service import PerfTrackerService
from repro.online import ScenarioRunner, ScheduledFault
from repro.serve.workload import (BurstArrivals, CacheThrash, DecodeStall,
                                  DECODE_STEP, KV_READ, QUEUE_WAIT,
                                  RequestGen, ServeWorkload)
from repro.train.workload import default_trainer_detector_cfg

pytestmark = pytest.mark.serve

IPW = 8                       # requests per profiling window
N_WIN = 7                     # fault active for windows [2, 7)


@pytest.fixture(scope="module")
def wl4():
    wl = ServeWorkload(n_workers=4)
    wl._ensure_workers()
    yield wl
    wl.close()


def _scenario(wl, fault):
    return ScenarioRunner(
        None, [ScheduledFault(fault, 2, N_WIN)], n_windows=N_WIN,
        iters_per_window=IPW,
        detector_cfg=default_trainer_detector_cfg(IPW), workload=wl)


def _incident(result, functions, workers, action=None, channel="slo"):
    """The slo-channel incident localizing ``functions`` that implicates
    every worker in ``workers`` (and, when given, whose plan ladder holds
    ``action``).  Extra noise incidents are tolerated — the scenario's
    contract is that the GENUINE one exists."""
    fns = {functions} if isinstance(functions, str) else set(functions)
    for inc in result.incidents:
        if inc.function in fns and inc.channel == channel \
                and set(workers) <= set(inc.workers) \
                and (action is None
                     or action in [p.action for p in inc.plans]):
            return inc
    raise AssertionError(
        f"no {channel} incident for {sorted(fns)} on {workers} with "
        f"{action}; got "
        f"{[(i.function, i.channel, i.workers, [p.action for p in i.plans]) for i in result.incidents]}")


# -- the request generator ----------------------------------------------------

def test_request_gen_deterministic_and_stable_below_capacity():
    a = RequestGen(rate_rps=10.0, seed=3)
    b = RequestGen(rate_rps=10.0, seed=3)
    da = [a.delay(0.03) for _ in range(50)]
    assert da == [b.delay(0.03) for _ in range(50)]
    # utilization 0.3: delays stay bounded near zero
    assert np.median(da) < 0.03


def test_request_gen_burst_builds_backlog_then_caps():
    gen = RequestGen(rate_rps=10.0, seed=3, max_delay_s=1.0)
    healthy = [gen.delay(0.03) for _ in range(30)]
    gen.burst_mult = 8.0                 # utilization 2.4: queue builds
    burst = [gen.delay(0.03) for _ in range(60)]
    assert max(burst) > 10 * max(max(healthy), 0.01)
    assert max(burst) <= 1.0             # capped, not unbounded
    # backlog GROWS request over request (queue buildup, not jitter)
    assert np.mean(burst[30:]) > np.mean(burst[:30])


# -- the instrumented real serving worker -------------------------------------

def test_serve_window_structure(wl4):
    wd = wl4.run_window(0, [], 3, None)
    # anchors: one (dequeue, complete) pair per merged request
    names = [n for n, _ in wd.anchors]
    assert names == ["request.dequeue", "request.complete"] * 3
    ts = [t for _, t in wd.anchors]
    assert all(a < b + 1e-9 for a, b in zip(ts, ts[1:]))
    # profiles: one per worker, real cpu sampler only, serving frames
    assert len(wd.profiles) == 4
    for prof in wd.profiles:
        assert set(prof.streams) == {"cpu"}
        top = [e.name for e in prof.events if e.depth == 1]
        assert top.count(QUEUE_WAIT) == 3
        assert top.count(DECODE_STEP) >= 3
    # slo metrics stream: one (t, p99_ttft, p99_tbt) sample per request,
    # timestamps on the same job clock as the anchors
    slo = wd.metrics["slo"]
    assert len(slo) == 3
    assert all(wd.t0 <= t <= wd.clock + 1e-9 for t, _, _ in slo)
    assert all(ttft > 0 and tbt > 0 for _, ttft, tbt in slo)
    # the deprecation shim: serving windows carry no numerics stream
    assert wd.numerics == []


# -- in-process fault scenarios (the slo channel end-to-end) ------------------

def test_burst_arrivals_localizes_queue_and_sheds_load(wl4):
    res = _scenario(wl4, BurstArrivals(workers=())).run()
    inc = _incident(res, QUEUE_WAIT, (0, 1, 2, 3), Action.SHED_LOAD)
    assert inc.plans[0].action == Action.SHED_LOAD


def test_decode_stall_localizes_subset_and_drains(wl4):
    res = _scenario(wl4, DecodeStall(workers=(2,))).run()
    inc = _incident(res, DECODE_STEP, (2,), Action.DRAIN_AND_REPLACE)
    assert inc.plans[0].action == Action.DRAIN_AND_REPLACE


def test_cache_thrash_localizes_kv_reads_fleet_wide(wl4):
    res = _scenario(wl4, CacheThrash(workers=())).run()
    inc = _incident(res, KV_READ, (0, 1, 2, 3), Action.SHED_LOAD)
    assert inc.plans[0].action == Action.SHED_LOAD


# -- fleet/wire parity on real serving profiles -------------------------------

def test_fleet_wire_parity_on_serve_profiles(wl4):
    wd = wl4.run_window(0, [CacheThrash(workers=())], IPW, None)
    svc = PerfTrackerService(family="host", summarize_backend="numpy")
    fleet = svc.diagnose_profiles(wd.profiles, mode="fleet")
    assert KV_READ in fleet.functions()
    wire = svc.diagnose_profiles(wd.profiles, mode="wire")
    assert fleet.functions() == wire.functions()
    for a, b in zip((d.abnormality for d in fleet.diagnoses),
                    (d.abnormality for d in wire.diagnoses)):
        np.testing.assert_array_equal(a.workers, b.workers)
        np.testing.assert_array_equal(a.patterns, b.patterns)
