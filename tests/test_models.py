"""Per-arch smoke tests: reduced same-family config, one forward/train step
on CPU, output shapes + no NaNs; analytic param counts match eval_shape."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import shapes_for
from repro.configs.registry import ARCHS, reduced
from repro.models.io import synth_batch
from repro.models.transformer import Transformer

ALL = sorted(ARCHS)


@pytest.mark.parametrize("name", ALL)
def test_smoke_forward_and_grad(name):
    cfg = reduced(ARCHS[name])
    model = Transformer(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = synth_batch(cfg, "train", 2, 64)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert jnp.isfinite(loss), name
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(jnp.all(jnp.isfinite(g)) for g in leaves), name
    # logits shape
    hidden, _, _ = model.forward(params, batch)
    logits = model.logits(params, hidden)
    B = batch["labels"].shape[0]
    S = batch["labels"].shape[1]
    assert logits.shape == (B, S, cfg.padded_vocab)


@pytest.mark.parametrize("name", ALL)
def test_param_counts_match_eval_shape(name):
    cfg = reduced(ARCHS[name])
    model = Transformer(cfg)
    spec = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    actual = sum(int(jnp.prod(jnp.asarray(l.shape)))
                 for l in jax.tree_util.tree_leaves(spec))
    analytic = cfg.param_counts()
    # analytic count covers matmul/embed params; norms/convs/etc. add a
    # small overhead — require agreement within 8%
    assert abs(actual - analytic["total"]) / actual < 0.08, \
        (name, actual, analytic["total"])


@pytest.mark.parametrize("name", ALL)
def test_full_config_is_assigned_spec(name):
    """The FULL configs carry the exact assigned hyperparameters."""
    cfg = ARCHS[name]
    spec = {
        "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
        "granite-34b": (88, 6144, 48, 1, 24576, 49152),
        "phi3-medium-14b": (40, 5120, 40, 10, 17920, 100352),
        "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
        "mamba2-2.7b": (64, 2560, 0, 0, 0, 50280),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 1408, 102400),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
    }[name]
    assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
            cfg.d_ff, cfg.vocab_size) == spec


def test_llama4_param_budget():
    c = ARCHS["llama4-maverick-400b-a17b"].param_counts()
    assert 3.5e11 < c["total"] < 4.6e11      # ~400B
    assert 1.2e10 < c["active"] < 2.2e10     # ~17B


def test_moe_active_vs_total():
    c = ARCHS["deepseek-v2-lite-16b"].param_counts()
    assert 1.2e10 < c["total"] < 2.0e10      # ~16B
    assert c["active"] < 0.25 * c["total"]   # ~2.4B active


def test_shapes_for_long_context():
    names_with_500k = [n for n in ALL
                       if any(s.name == "long_500k"
                              for s in shapes_for(ARCHS[n]))]
    assert sorted(names_with_500k) == ["mamba2-2.7b", "zamba2-7b"]
