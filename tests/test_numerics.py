"""ISSUE 8: the numerics channel (DESIGN.md §12a).

Three layers of coverage:

  * the ``NumericsDetector`` state machines — warmup, single-spike
    forgiveness, confirm/recover hysteresis, immediate non-finite firing,
    and the no-baseline-poisoning rule;
  * channel identity in the ``IncidentManager`` — the regression fixed in
    this PR: signature matching includes the detector channel, so a
    numerics incident and a perf incident on the same function are
    distinct problems, resolve independently, and never recurrence-link;
  * the pipeline end-to-end — a loss spike during an OPEN perf incident
    produces two incidents that both run to resolution (catalog scenario
    ``N4_loss_spike_under_perf``).
"""
import math

import numpy as np
import pytest

from repro.core.detector import (NumericsConfig, NumericsDetector, Recovery,
                                 Trigger)
from repro.core.events import Kind
from repro.core.localizer import Abnormality
from repro.core.mitigation import Action
from repro.core.report import Diagnosis, root_cause_hint
from repro.online.incident import (CONFIRMED, ESCALATED, OPEN, RESOLVED,
                                   IncidentManager)

W = 24
LOSS_FN = "numerics.loss"
GRAD_FN = "numerics.grad_norm"


def warmed(loss=2.0, grad=1.0, n=16, cfg=None):
    """A detector past warmup with a stable healthy baseline."""
    det = NumericsDetector(cfg)
    for i in range(n):
        assert det.feed(float(i), loss, grad) == []
    return det


# -- NumericsDetector state machines ------------------------------------------

def test_warmup_suppresses_triggers():
    det = NumericsDetector()
    # wild values during warmup are baseline-building, not anomalies
    for i in range(det.cfg.warmup - 1):
        assert det.feed(float(i), 10.0 ** i, 5.0 ** i) == []
    assert det.healthy


def test_single_finite_spike_recovers_silently():
    """Loss routinely jumps for one step on a hard batch: one abnormal
    sample must neither trigger nor emit a recovery."""
    det = warmed()
    assert det.feed(16.0, 50.0, 1.0) == []          # spike, unconfirmed
    assert det.feed(17.0, 2.0, 1.0) == []           # back to healthy
    assert det.triggers == [] and det.recoveries == []
    assert det.healthy and det.outstanding() == []


def test_confirmed_spike_triggers_then_recovers():
    det = warmed()
    assert det.feed(16.0, 50.0, 1.0) == []
    trigs = det.feed(17.0, 50.0, 1.0)               # second consecutive
    assert len(trigs) == 1
    t = trigs[0]
    assert isinstance(t, Trigger)
    assert t.reason == "loss_spike" and t.channel == "numerics"
    assert t.mean_duration == 50.0 and t.baseline == pytest.approx(2.0)
    assert not det.healthy and det.outstanding() == ["loss"]
    # further abnormal samples stay silent (one trigger per episode)
    assert det.feed(18.0, 60.0, 1.0) == []
    # recovery needs `recover` consecutive healthy samples
    assert det.feed(19.0, 2.0, 1.0) == []
    assert det.recoveries == []
    assert det.feed(20.0, 2.0, 1.0) == []
    assert [r.reason for r in det.recoveries] == ["loss_spike"]
    assert det.recoveries[0].channel == "numerics"
    assert det.healthy


def test_grad_norm_uses_looser_ratio():
    det = warmed(grad=1.0)
    ratio = det.cfg.grad_ratio
    # 2.5x the grad baseline is jitter (< grad_ratio), not an explosion
    for i in range(4):
        assert det.feed(16.0 + i, 2.0, 2.5) == []
    trigs = []
    for i in range(2):
        trigs += det.feed(20.0 + i, 2.0, ratio * 1.5)
    assert [t.reason for t in trigs] == ["grad_explosion"]


def test_non_finite_fires_immediately_even_in_warmup():
    """There is no benign single-sample NaN: confirmation is skipped."""
    det = NumericsDetector()
    trigs = det.feed(0.0, 1.0, float("nan"))
    assert [t.reason for t in trigs] == ["grad_explosion"]
    assert "non-finite" in trigs[0].detail
    det2 = warmed()
    trigs2 = det2.feed(16.0, float("inf"), 1.0)
    assert [t.reason for t in trigs2] == ["loss_spike"]


def test_abnormal_samples_never_poison_baseline():
    """The spike must not fold into the median it is judged by: after a
    long abnormal episode the ORIGINAL baseline still judges recovery."""
    det = warmed(loss=2.0)
    det.feed(16.0, 50.0, 1.0)
    det.feed(17.0, 50.0, 1.0)                       # triggered
    for i in range(40):                             # long abnormal episode
        det.feed(18.0 + i, 50.0, 1.0)
    # healthy-at-the-old-baseline samples recover it; had 50.0 polluted
    # the median, 2.0 would read as healthy-forever and 4.5 as abnormal
    det.feed(60.0, 4.5, 1.0)
    assert det._hist["loss"].count(50.0) == 0
    det.feed(61.0, 2.0, 1.0)
    det.feed(62.0, 2.0, 1.0)
    assert det.healthy


def test_both_signals_fire_independently():
    det = warmed()
    det.feed(16.0, 50.0, 10.0)
    trigs = det.feed(17.0, 50.0, 10.0)
    assert sorted(t.reason for t in trigs) == ["grad_explosion",
                                               "loss_spike"]
    assert sorted(det.outstanding()) == ["grad_norm", "loss"]


def test_numerics_config_overrides():
    det = warmed(cfg=NumericsConfig(confirm=1), n=12)
    assert [t.reason for t in det.feed(12.0, 50.0, 1.0)] == ["loss_spike"]


# -- channel identity in the IncidentManager ----------------------------------

def _abn(fn, kind, workers=(0,), channel="perf"):
    idx = np.asarray(sorted(workers), np.int64)
    pats = np.tile(np.asarray([0.5, 0.5, 0.05], np.float32), (len(idx), 1))
    return Abnormality(function=fn, workers=idx, kind=kind,
                       d_expect=np.ones(len(idx)),
                       delta=np.zeros(len(idx)), patterns=pats,
                       typical=np.asarray([0.1, 0.5, 0.05], np.float32),
                       channel=channel)


def _diag(fn, kind, workers=(0,), channel="perf"):
    a = _abn(fn, kind, workers, channel)
    return Diagnosis(a, root_cause_hint(a, W))


def _perf_trigger(t=0.0):
    return Trigger("slowdown", t, 2.0, 1.0)


def _num_trigger(t=0.0, reason="loss_spike"):
    return Trigger(reason, t, 50.0, 2.0, channel="numerics")


def test_numerics_trigger_opens_alongside_perf_incident():
    """Regression: the channels are independent sensors — an active perf
    incident must not swallow a numerics trigger (and vice versa), while
    same-channel triggers stay reminders."""
    mgr = IncidentManager(fleet_size=W)
    perf = mgr.on_trigger(_perf_trigger(0.0))
    assert perf is not None and perf.channel == "perf"
    assert mgr.on_trigger(_perf_trigger(1.0)) is None       # reminder
    num = mgr.on_trigger(_num_trigger(2.0))
    assert num is not None and num.channel == "numerics"
    assert mgr.on_trigger(_num_trigger(3.0)) is None        # reminder
    assert len(mgr.active) == 2


def test_same_function_different_channel_is_distinct_incident():
    """The bug this PR fixes: signature matching keyed on function only,
    so a numerics abnormality would fold into a perf incident whose
    function name collided."""
    mgr = IncidentManager(fleet_size=W)
    mgr.on_trigger(_perf_trigger(0.0))
    mgr.on_window(1.0, [_diag(LOSS_FN, Kind.PYTHON)])        # perf confirms
    mgr.on_trigger(_num_trigger(2.0))
    mgr.on_window(3.0, [_diag(LOSS_FN, Kind.PYTHON),
                        _diag(LOSS_FN, Kind.NUMERICS, channel="numerics")])
    assert mgr.by_function(LOSS_FN, "perf") is not None
    assert mgr.by_function(LOSS_FN, "numerics") is not None
    assert mgr.by_function(LOSS_FN, "perf") \
        is not mgr.by_function(LOSS_FN, "numerics")


def test_recovery_resolves_only_its_channel():
    mgr = IncidentManager(fleet_size=W)
    mgr.on_trigger(_perf_trigger(0.0))
    mgr.on_trigger(_num_trigger(0.5))
    resolved = mgr.on_recovery(Recovery("loss_spike", 1.0,
                                        channel="numerics"))
    assert [i.channel for i in resolved] == ["numerics"]
    perf = mgr._pending("perf")
    assert perf is not None and perf.state == OPEN           # untouched
    resolved2 = mgr.on_recovery(Recovery("slowdown", 2.0))
    assert [i.channel for i in resolved2] == ["perf"]


def test_numerics_never_recurrence_links_to_perf():
    """A resolved PERF incident on a function must not be claimed as the
    ancestor of a later NUMERICS incident on the same function/workers."""
    mgr = IncidentManager(fleet_size=W, confirm_windows=1)
    mgr.on_trigger(_perf_trigger(0.0))
    mgr.on_window(1.0, [_diag(GRAD_FN, Kind.PYTHON, workers=(3, 7))])
    mgr.on_window(2.0, [])                       # signature clear once
    mgr.on_recovery(Recovery("slowdown", 2.5))
    prior = mgr.incidents[0]
    assert prior.state == RESOLVED and not prior.active
    mgr.on_trigger(_num_trigger(3.0))
    changed = mgr.on_window(
        4.0, [_diag(GRAD_FN, Kind.NUMERICS, workers=(3, 7),
                    channel="numerics")])
    num = next(i for i in changed if i.channel == "numerics")
    assert num.state == CONFIRMED
    assert num.recurrence_of is None
    # the same signature ON the numerics channel does link
    mgr.on_recovery(Recovery("loss_spike", 5.0, channel="numerics"))
    num.windows_clear = 1
    mgr.on_recovery(Recovery("loss_spike", 5.5, channel="numerics"))
    assert not num.active
    mgr.on_trigger(_num_trigger(6.0))
    changed2 = mgr.on_window(
        7.0, [_diag(GRAD_FN, Kind.NUMERICS, workers=(3, 7),
                    channel="numerics")])
    again = next(i for i in changed2 if i.channel == "numerics"
                 and i.active)
    assert again.recurrence_of == num.id


def test_escalated_suppression_is_per_channel():
    """An escalated perf signature suppresses fresh PERF incidents only;
    the numerics channel keeps its own book."""
    mgr = IncidentManager(fleet_size=W, confirm_windows=1)
    mgr.on_trigger(_perf_trigger(0.0))
    mgr.on_window(1.0, [_diag(LOSS_FN, Kind.PYTHON)])
    inc = mgr.incidents[0]
    inc.state = ESCALATED
    inc.escalated_at = 1.5
    mgr._suppressed[("perf", LOSS_FN)] = 0
    mgr.on_trigger(_num_trigger(2.0))
    mgr.on_window(3.0, [_diag(LOSS_FN, Kind.NUMERICS,
                              channel="numerics")])
    assert mgr.by_function(LOSS_FN, "numerics") is not None


# -- plan shape ----------------------------------------------------------------

def test_numerics_hint_and_rollback_ladder():
    from repro.core.mitigation import plan_ladder
    for fn, word in ((LOSS_FN, "loss"), (GRAD_FN, "gradient")):
        d = _diag(fn, Kind.NUMERICS, channel="numerics")
        assert word in d.hint and "roll back" in d.hint
        ladder = plan_ladder(d, W)
        assert [p.action for p in ladder] \
            == [Action.ROLLBACK_TO_CHECKPOINT, Action.FLAG_CODE]


# -- end-to-end: both channels under one roof ---------------------------------

def test_loss_spike_under_open_perf_incident():
    """Catalog scenario N4: a loss spike injected alongside a GPU
    throttle.  Both channels trigger, both incidents resolve, each via
    its own playbook — rollback never fires for the perf incident, hosts
    are never replaced for the numerics one."""
    from repro.online.catalog import by_name, evaluate, run_scenario
    sc = by_name("N4_loss_spike_under_perf")
    runner, res = run_scenario(sc)
    rows = evaluate(sc, runner, res)
    assert all(r["ok"] for r in rows)
    by_ch = {r["channel"]: r for r in rows}
    assert by_ch["perf"]["first_action"] == "replace_hosts"
    assert by_ch["numerics"]["first_action"] == "rollback_to_checkpoint"
    # cross-channel hygiene on the actual engine log
    for m in runner.engine.log:
        inc = next(i for i in res.incidents
                   if i.id == m.incident_id)
        if inc.channel == "numerics":
            assert m.plan.action != Action.REPLACE_HOSTS
        else:
            assert m.plan.action != Action.ROLLBACK_TO_CHECKPOINT


def test_nan_grad_norm_scenario_resolves():
    """Catalog scenario N3: a NaN gradient norm fires immediately and the
    rollback plan clears it."""
    from repro.online.catalog import by_name, evaluate, run_scenario
    sc = by_name("N3_grad_norm_nan")
    runner, res = run_scenario(sc)
    assert all(r["ok"] for r in evaluate(sc, runner, res))
    inc = next(i for i in res.incidents if i.channel == "numerics")
    assert inc.trigger is not None
    assert "non-finite" in inc.trigger.detail
    assert not math.isnan(inc.opened_at)
