"""Backend-parity invariant + the batched summarize pipeline (DESIGN.md §3-4).

python oracle == numpy == pallas(interpret) on randomized utilization
matrices (atol 1e-5), including the adversarial rows: all-zero, single
nonzero sample, and rows whose 80%-mass region is the whole window.
Plus: engine vs the per-event oracle path, unified kind resolution,
streaming aggregator vs the old dict stacking, deterministic localization.
"""
import numpy as np
import pytest

from repro.core.daemon import summarize_and_upload
from repro.core.events import FunctionEvent, Kind, SampleStream, WorkerProfile
from repro.core.localizer import Localizer
from repro.core.patterns import critical_duration, summarize_worker
from repro.core.service import PerfTrackerService
from repro.summarize import (PatternAggregator, available_backends,
                             get_backend, pack_profile, resolve_kinds,
                             summarize_profile)

BACKENDS = ["python", "numpy", "pallas"]
ATOL = 1e-5


def _rand_matrix(seed, E, n, zero_rows=(), single_rows=(), full_rows=()):
    rng = np.random.default_rng(seed)
    u = np.clip(rng.normal(0.45, 0.3, (E, n)), 0, 1).astype(np.float32)
    for _ in range(max(1, E // 4)):       # sprinkle zero bursts
        i = int(rng.integers(0, E))
        a = int(rng.integers(0, n))
        b = int(rng.integers(a, n)) + 1
        u[i, a:b] = 0
    for i in zero_rows:
        u[i] = 0.0
    for i in single_rows:
        u[i] = 0.0
        u[i, int(n * 0.6)] = 0.7
    for i in full_rows:                   # uniform: 80% mass needs it all
        u[i] = 0.5
    return u


def _backend(name):
    be = get_backend(name)
    if be.name != name:
        pytest.skip(f"backend {name} unavailable (got {be.name})")
    return be


# -- the parity invariant -----------------------------------------------------

@pytest.mark.parametrize("seed,E,n", [(0, 16, 256), (1, 8, 97), (2, 32, 130),
                                      (3, 1, 1), (4, 5, 2), (5, 24, 512)])
def test_backend_parity_randomized(seed, E, n):
    zero = [0] if E > 2 else []
    single = [1] if E > 2 and n > 2 else []
    full = [2] if E > 3 else []
    u = _rand_matrix(seed, E, n, zero, single, full)
    ref = _backend("python").batch_stats(u)
    for name in BACKENDS[1:]:
        out = _backend(name).batch_stats(u)
        np.testing.assert_allclose(
            np.asarray(out, np.float64), np.asarray(ref, np.float64),
            atol=ATOL, err_msg=f"{name} != python oracle (E={E}, n={n})")


def test_backend_parity_edge_rows():
    n = 64
    u = np.zeros((4, n), np.float32)
    u[1, 10] = 0.9                     # single sample
    u[2, :] = 0.25                     # uniform: full window is the region
    u[3, :20] = 0.8                    # contiguous burst
    ref = _backend("python").batch_stats(u)
    # all-zero row: count == full row width in every backend's report or
    # engine-normalized — here the protocol lets backends disagree only on
    # all-zero counts, which the engine overrides; compare the others hard
    for name in BACKENDS[1:]:
        out = _backend(name).batch_stats(u)
        np.testing.assert_allclose(out[1:], ref[1:], atol=ATOL,
                                   err_msg=name)
        np.testing.assert_allclose(out[0, :2], [0.0, 0.0], atol=ATOL)


def test_counts_match_scalar_oracle():
    u = _rand_matrix(7, 12, 200)
    for name in BACKENDS:
        out = _backend(name).batch_stats(u)
        for i, row in enumerate(u):
            if row.sum() <= 0:
                continue
            lo, hi = critical_duration(row)
            assert int(round(out[i, 2])) == hi - lo, (name, i)


# -- engine vs per-event oracle ----------------------------------------------

def _profile(seed=0, worker=0, with_orphan=False):
    rng = np.random.default_rng(seed)
    rate = 1000.0
    T = 4.0
    n = int(T * rate)
    gpu = np.clip(rng.normal(0.7, 0.2, n), 0, 1)
    cpu = np.clip(rng.normal(0.3, 0.2, n), 0, 1)
    gpu[1500:2100] = 0.0
    events = [
        FunctionEvent("matmul", Kind.GPU, 0.0, 1.4, worker),
        FunctionEvent("matmul", Kind.GPU, 1.5, 2.9, worker),
        FunctionEvent("allreduce", Kind.COMM, 2.0, 3.1, worker),
        FunctionEvent("data.next", Kind.PYTHON, 3.1, 3.9, worker, depth=1),
    ]
    if with_orphan:   # resource stream absent -> zero-weight pattern
        events.append(FunctionEvent("h2d", Kind.MEM, 0.2, 0.4, worker))
    return WorkerProfile(
        worker=worker, window=(0.0, T), events=events,
        streams={"gpu_sm": SampleStream(rate, 0.0, gpu),
                 "pcie_tx": SampleStream(rate, 0.0, gpu * 0.5),
                 "cpu": SampleStream(rate, 0.0, cpu)})


@pytest.mark.parametrize("backend", BACKENDS)
def test_summarize_worker_backend_parity(backend):
    _backend(backend)
    prof = _profile(with_orphan=True)
    ref = summarize_worker(prof, backend="python")
    out = summarize_worker(prof, backend=backend)
    assert set(out) == set(ref)
    assert "h2d" in out                       # orphan function still reported
    for name in ref:
        np.testing.assert_allclose(out[name].as_array(),
                                   ref[name].as_array(), atol=ATOL)


def test_prepacked_profile_matches_fresh_pack():
    prof = _profile(seed=3)
    ref = summarize_worker(prof, backend="numpy")
    prof.packed = pack_profile(prof)
    out = summarize_worker(prof, backend="numpy")
    for name in ref:
        np.testing.assert_allclose(out[name].as_array(),
                                   ref[name].as_array(), atol=0)


# -- unified kind resolution --------------------------------------------------

def test_kind_override_flows_to_stream_and_upload():
    prof = _profile()
    # reroute 'allreduce' to the CPU stream + PYTHON kind via kind_of
    override = {"allreduce": Kind.PYTHON}
    kinds = resolve_kinds(prof, override)
    assert kinds["allreduce"] == Kind.PYTHON
    assert kinds["matmul"] == Kind.GPU        # untouched functions keep kind

    pats_default, _ = summarize_profile(prof, backend="python")
    pats_override, k2 = summarize_profile(prof, kind_of=override,
                                          backend="python")
    assert k2["allreduce"] == Kind.PYTHON
    # different stream (cpu vs pcie_tx) -> different mu
    assert (abs(pats_override["allreduce"].mu - pats_default["allreduce"].mu)
            > 1e-3)

    up = summarize_and_upload(prof, kind_of=override)
    _, up_kinds = up.unpack()
    assert up_kinds["allreduce"] == Kind.PYTHON


def test_mixed_kind_function_keeps_per_event_streams():
    """A name recorded under two kinds reads each event's own stream
    (pre-refactor semantics); only explicit kind_of overrides reroute."""
    rate, T = 1000.0, 2.0
    n = int(T * rate)
    gpu = np.full(n, 0.9)
    pcie = np.full(n, 0.3)
    prof = WorkerProfile(
        worker=0, window=(0.0, T),
        events=[FunctionEvent("mixed", Kind.GPU, 0.0, 1.0),
                FunctionEvent("mixed", Kind.COMM, 1.0, 1.5)],
        streams={"gpu_sm": SampleStream(rate, 0.0, gpu),
                 "pcie_tx": SampleStream(rate, 0.0, pcie)})
    for backend in BACKENDS:
        _backend(backend)
        pats = summarize_worker(prof, backend=backend)
        # duration-weighted across the two per-event streams:
        # (1.0s * 0.9 + 0.5s * 0.3) / 1.5s
        assert pats["mixed"].mu == pytest.approx((1.0 * 0.9 + 0.5 * 0.3)
                                                 / 1.5, abs=1e-6)
    # an override forces both executions onto one stream
    pats = summarize_worker(prof, kinds={"mixed": Kind.COMM},
                            backend="python")
    assert pats["mixed"].mu == pytest.approx(0.3, abs=1e-6)


# -- streaming aggregator -----------------------------------------------------

def _legacy_aggregate(uploads):
    per_worker = [u.unpack() for u in uploads]
    names = sorted({n for pats, _ in per_worker for n in pats})
    kinds = {}
    W = len(uploads)
    agg = {n: np.zeros((W, 3), np.float32) for n in names}
    for w, (pats, ks) in enumerate(per_worker):
        for n, p in pats.items():
            agg[n][w] = p
            kinds.setdefault(n, ks[n])
    return agg, kinds


def test_aggregator_matches_legacy_stacking():
    uploads = [summarize_and_upload(_profile(seed=s, worker=s,
                                             with_orphan=(s % 2 == 0)))
               for s in range(5)]
    ref_agg, ref_kinds = _legacy_aggregate(uploads)
    agg, kinds = PatternAggregator().extend(uploads).finalize()
    assert list(agg) == list(ref_agg)          # sorted name order
    assert kinds == ref_kinds
    for n in ref_agg:
        np.testing.assert_array_equal(np.asarray(agg[n]), ref_agg[n])


def test_aggregator_growth_and_views():
    agg = PatternAggregator(expected_workers=1, expected_functions=1)
    rng = np.random.default_rng(0)
    expect = {}
    for w in range(40):                        # force repeated growth
        pats = {f"f{j}": rng.random(3).astype(np.float32)
                for j in rng.choice(20, size=5, replace=False)}
        for n, p in pats.items():
            expect.setdefault(n, {})[w] = p
        agg.add_patterns(pats, {n: Kind.GPU for n in pats})
    out, _ = agg.finalize()
    assert agg.n_workers == 40
    for n, rows in expect.items():
        for w, p in rows.items():
            np.testing.assert_array_equal(np.asarray(out[n][w]), p)
        mask = np.ones(40, bool)
        mask[list(rows)] = False
        assert not np.asarray(out[n][mask]).any()   # absent workers zero


def test_service_aggregate_is_streaming_equivalent():
    uploads = [summarize_and_upload(_profile(seed=s, worker=s))
               for s in range(4)]
    svc = PerfTrackerService()
    agg, kinds = svc.aggregate(uploads)
    ref_agg, ref_kinds = _legacy_aggregate(uploads)
    assert kinds == ref_kinds
    for n in ref_agg:
        np.testing.assert_array_equal(np.asarray(agg[n]), ref_agg[n])


# -- deterministic localization ----------------------------------------------

def _fleet_patterns(W=64, outlier=7):
    pats = np.tile(np.array([0.5, 0.9, 0.05], np.float32), (W, 1))
    pats[outlier] = [0.9, 0.3, 0.05]
    return pats


def test_delta_distance_order_independent():
    pats = _fleet_patterns(W=256)
    loc = Localizer()
    d1 = loc.delta_distance(pats, function="fwd")
    # interleave calls for other functions: must not perturb 'fwd'
    loc.delta_distance(pats, function="bwd")
    loc.delta_distance(pats, function="opt")
    d2 = loc.delta_distance(pats, function="fwd")
    np.testing.assert_array_equal(d1, d2)
    # a fresh Localizer reproduces the same Delta exactly
    np.testing.assert_array_equal(
        d1, Localizer().delta_distance(pats, function="fwd"))


def test_localize_independent_of_dict_order():
    pats_a = _fleet_patterns(W=256, outlier=3)
    pats_b = _fleet_patterns(W=256, outlier=9)
    kinds = {"a": Kind.GPU, "b": Kind.GPU}
    fwd = Localizer().localize({"a": pats_a, "b": pats_b}, kinds)
    rev = Localizer().localize({"b": pats_b, "a": pats_a}, kinds)
    assert {x.function: x.workers.tolist() for x in fwd} == \
           {x.function: x.workers.tolist() for x in rev}
    np.testing.assert_array_equal(
        *[sorted(x.delta.tolist() for x in r) for r in (fwd, rev)])


# -- end to end ---------------------------------------------------------------

@pytest.mark.parametrize("backend", ["python", "numpy"])
def test_service_end_to_end_backend_choice(backend):
    profiles = [_profile(seed=s, worker=s) for s in range(6)]
    svc = PerfTrackerService(summarize_backend=backend)
    res = svc.diagnose_profiles(profiles)
    assert res.fleet_size == 6
    assert res.pattern_bytes > 0 and res.raw_bytes > res.pattern_bytes
    assert "summarize_s" in res.timing


def test_available_backends_reports_all_three():
    names = available_backends()
    assert "python" in names and "numpy" in names
    # pallas present in this image (jax + interpret mode)
    assert "pallas" in names
