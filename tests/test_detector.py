"""§4.1 iteration/degradation detection."""

from repro.core.detector import DetectorConfig, IterationDetector

D, O = "dataloader.next", "optimizer.step"


def feed_iters(det, pattern, n, t0=0.0, dur=1.0):
    t = t0
    trig = None
    for _ in range(n):
        for j, name in enumerate(pattern):
            trig = det.feed(name, t + dur * (j + 1) / (len(pattern) + 1)) \
                or trig
        t += dur
    return trig, t


def test_sequence_lock_simple():
    det = IterationDetector()
    feed_iters(det, [D, O], 10)
    assert det.locked
    assert det.sequence == (D, O)


def test_sequence_lock_pipelined():
    # pipeline parallelism: several loads then several steps per iteration
    det = IterationDetector()
    feed_iters(det, [D, D, O, O], 10)
    assert det.locked
    assert det.sequence == (D, D, O, O)


def test_no_lock_on_inconsistent_sequences():
    det = IterationDetector()
    for i in range(9):
        pat = [D, O] if i % 2 else [D, D, O]
        feed_iters(det, pat, 1, t0=float(i))
    assert not det.locked


def test_slowdown_trigger():
    det = IterationDetector(DetectorConfig(n_recent=20))
    _, t = feed_iters(det, [D, O], 30, dur=1.0)
    assert det.locked and not det.triggers
    trig, _ = feed_iters(det, [D, O], 25, t0=t, dur=1.2)  # +20% > 5%
    assert trig is not None and trig.reason == "slowdown"


def test_no_trigger_within_5pct():
    det = IterationDetector(DetectorConfig(n_recent=20))
    _, t = feed_iters(det, [D, O], 30, dur=1.0)
    trig, _ = feed_iters(det, [D, O], 30, t0=t, dur=1.02)  # +2% < 5%
    assert trig is None


def test_blockage():
    det = IterationDetector()
    _, t = feed_iters(det, [D, O], 15, dur=1.0)
    assert det.check_blockage(t + 1.0) is None
    trig = det.check_blockage(t + 10.0)   # >= 5x avg
    assert trig is not None and trig.reason == "blockage"


def test_resync_after_k_mismatches():
    cfg = DetectorConfig(k_resync=50)
    det = IterationDetector(cfg)
    feed_iters(det, [D, O], 12)
    assert det.locked
    # user code changes shape: stream of only optimizer.step events
    t = 100.0
    for i in range(cfg.k_resync + 1):
        det.feed(O, t + i * 0.1)
    assert not det.locked   # back to detection phase
    # and it can re-lock on the new sequence
    feed_iters(det, [D, O, O], 12, t0=200.0)
    assert det.locked and det.sequence == (D, O, O)
