"""End-to-end reproduction of the paper's production cases (§3, §6) through
detector -> profiling -> patterns -> localization -> mitigation."""

from repro.core import faults as F
from repro.core.mitigation import Action, plan_mitigations
from repro.core.service import PerfTrackerService
from repro.core.simulation import ALLGATHER, GEMM, FleetSimulator, SimConfig


def run_case(faults, n_workers=32, family="dense", seed=7):
    cfg = SimConfig(n_workers=n_workers, window_s=2.0, rate_hz=2000,
                    seed=seed)
    sim = FleetSimulator(cfg, faults)
    svc = PerfTrackerService(family=family)
    trig = svc.feed_anchors(sim.anchor_events(80, degrade_after=40))
    assert trig is not None, "degradation not detected"
    res = svc.diagnose_profiles(sim.profile_window(), trigger=trig)
    return res


def test_c1p1_gpu_throttle():
    res = run_case([F.GpuThrottle(workers=range(4))])
    d = next(d for d in res.diagnoses if d.abnormality.function == GEMM)
    assert set(d.abnormality.workers.tolist()) == set(range(4))
    assert "throttling" in d.hint
    plans = plan_mitigations(res.diagnoses, 32)
    assert plans[0].action == Action.REPLACE_HOSTS
    assert plans[0].workers == [0, 1, 2, 3]


def test_c1p2_nvlink_down():
    res = run_case([F.NvlinkDown(workers=[5], group_size=16)])
    d = next(d for d in res.diagnoses
             if d.abnormality.function == ALLGATHER)
    assert 5 in d.abnormality.workers.tolist()
    assert "NVLink" in d.hint or "PCIe" in d.hint


def test_ring_slow_link():
    res = run_case([F.RingSlowLink(slow_worker=9, rho=0.4)])
    fns = res.functions()
    assert ALLGATHER in fns


def test_c2p1_slow_dataloader():
    res = run_case([F.SlowDataloader()])
    d = next(d for d in res.diagnoses
             if "socket" in d.abnormality.function)
    # common problem: flagged on (nearly) all workers via expectation
    assert len(d.abnormality.workers) >= 30
    assert "storage" in d.hint or "data loading" in d.hint
    plans = plan_mitigations(res.diagnoses, 32)
    assert any(p.action == Action.MIGRATE_DATALOADER for p in plans)


def test_c2p2_cpu_bound_forward():
    res = run_case([F.CpuBoundForward(workers=range(6))])
    d = next(d for d in res.diagnoses
             if "forward" in d.abnormality.function)
    assert set(d.abnormality.workers.tolist()) >= set(range(6))


def test_c2p3_async_gc():
    res = run_case([F.AsyncGc(probability=0.5)])
    d = next(d for d in res.diagnoses
             if "gradmode" in d.abnormality.function)
    assert "garbage" in d.hint
    plans = plan_mitigations(res.diagnoses, 32)
    assert any(p.action == Action.SYNCHRONIZE_GC for p in plans)


def test_healthy_fleet_no_flags():
    cfg = SimConfig(n_workers=32, window_s=2.0, rate_hz=2000, seed=3)
    sim = FleetSimulator(cfg, [])
    svc = PerfTrackerService()
    assert svc.feed_anchors(sim.anchor_events(80)) is None
    res = svc.diagnose_profiles(sim.profile_window())
    assert res.functions() == []


def test_pattern_compression_ratio():
    """Fig. 11: patterns are orders of magnitude smaller than raw data."""
    cfg = SimConfig(n_workers=4, window_s=2.0, rate_hz=2000, seed=0)
    sim = FleetSimulator(cfg, [])
    svc = PerfTrackerService()
    res = svc.diagnose_profiles(sim.profile_window())
    assert res.raw_bytes / max(1, res.pattern_bytes) > 100
