"""ISSUE 10: checkpoint-aware recovery (DESIGN.md §14).

Four layers of coverage:

  * ``Checkpointer`` hardening — a torn ``step_<n>/`` (missing/corrupt
    ``meta.json``, a leaf ``.npy`` gone) is never counted as a valid step,
    so it can never be selected as "latest"; stale ``.tmp_step_*`` from a
    crashed writer is reclaimed; retention keeps only the last ``keep``
    VALID steps;
  * ``RecoveryManager`` — the save/rollback roundtrip restores and
    parameter-verifies real on-disk state (lost steps accounted), and a
    rollback with nothing usable on disk is an honest ``ok=False``;
  * the engine — ``CHECKPOINT_NOW`` drives an actual save,
    ``ROLLBACK_TO_CHECKPOINT`` restores for real, and a failed rollback
    cures NOTHING: the signature survives verification and the incident
    escalates instead of faking a recovery;
  * chronic-fault memory — terminal incidents persist their signature +
    ladder outcome (``repro.online.history``), and a restarted run facing
    the same signature starts its ladder at the rung that worked last
    time (zero escalations the second time around).
"""

import numpy as np
import pytest

from repro.ckpt import Checkpointer, CheckpointError, RecoveryManager
from repro.core import faults as F
from repro.core.mitigation import Action, MitigationPlan
from repro.core.simulation import GEMM
from repro.online import ESCALATED, RESOLVED, ScheduledFault
from repro.online.history import IncidentHistory
from repro.online.mitigation import MitigationEngine
from tests.test_mitigation import INJECT, run_mitigated

LOSS_FN = "numerics.loss"


def _tree(v=1.0):
    return {"w": np.full(4, v, np.float32), "b": np.zeros(2, np.float32)}


# -- Checkpointer hardening ---------------------------------------------------

def test_torn_dir_missing_meta_never_latest(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(5, _tree(), async_=False)
    (tmp_path / "step_9").mkdir()          # torn: renamed but no meta.json
    assert ck.steps() == [5]
    assert ck.latest_step() == 5


def test_torn_dir_missing_leaf_never_latest(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(5, _tree(), async_=False)
    ck.save(9, _tree(2.0), async_=False)
    (tmp_path / "step_9" / "w.npy").unlink()      # partial write
    assert ck.latest_step() == 5
    with pytest.raises(CheckpointError, match="partial write"):
        ck.restore(9, _tree())


def test_corrupt_meta_never_latest(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(5, _tree(), async_=False)
    (tmp_path / "step_5" / "meta.json").write_text("{not json")
    assert ck.latest_step() is None
    with pytest.raises(CheckpointError, match="corrupt meta.json"):
        ck.restore(5, _tree())


def test_unreadable_leaf_raises_checkpoint_error(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(5, _tree(), async_=False)
    (tmp_path / "step_5" / "w.npy").write_bytes(b"garbage")
    with pytest.raises(CheckpointError, match="unreadable leaf"):
        ck.restore(5, _tree())


def test_stale_tmp_dirs_swept_on_init(tmp_path):
    tmp = tmp_path / ".tmp_step_7"
    tmp.mkdir()
    (tmp / "w.npy").write_bytes(b"half a write")
    Checkpointer(str(tmp_path))
    assert not tmp.exists()


def test_retention_keeps_last_k_valid(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree(float(s)), async_=False)
    assert ck.steps() == [3, 4]
    tree, meta = ck.restore(4, _tree())
    assert meta["step"] == 4
    np.testing.assert_array_equal(tree["w"], np.full(4, 4.0, np.float32))


# -- RecoveryManager ----------------------------------------------------------

def test_sim_rollback_roundtrip_verified(tmp_path):
    mgr = RecoveryManager.for_sim(seed=3, directory=str(tmp_path),
                                  save_every=3)
    for w in range(5):                 # saves at windows 0 and 3
        mgr.on_window(w)
    assert mgr.saved_steps == [0, 3]
    saved_w = np.asarray(mgr.state.params["w"])    # post-install compare
    out = mgr.rollback()
    assert out.ok and out.verified
    assert out.step == 3 and out.lost_steps == 2
    assert out.restore_s > 0.0
    assert mgr.state.step == 3
    assert mgr.total_lost_steps == 2
    # the installed params really are the step-3 arrays, not the live ones
    assert not np.array_equal(np.asarray(mgr.state.params["w"]), saved_w)


def test_rollback_empty_dir_is_honest_failure(tmp_path):
    mgr = RecoveryManager.for_sim(seed=3, directory=str(tmp_path),
                                  save_every=0)
    for w in range(4):
        mgr.on_window(w)               # save_every=0: nothing ever saved
    before = np.asarray(mgr.state.params["w"]).copy()
    out = mgr.rollback()
    assert not out.ok and not out.verified
    assert "no valid checkpoint" in out.error
    # the live state was not touched by the failed rollback
    np.testing.assert_array_equal(np.asarray(mgr.state.params["w"]), before)


def test_rollback_all_dirs_torn_is_honest_failure(tmp_path):
    mgr = RecoveryManager.for_sim(seed=3, directory=str(tmp_path),
                                  save_every=1)
    mgr.on_window(0)
    mgr.ckpt.wait()
    (tmp_path / "step_0" / "meta.json").unlink()
    out = mgr.rollback()
    assert not out.ok and "no valid checkpoint" in out.error


# -- the engine: real verbs, honest failure -----------------------------------

def test_engine_checkpoint_now_actually_saves(tmp_path):
    mgr = RecoveryManager.for_sim(seed=3, directory=str(tmp_path),
                                  save_every=0)
    eng = MitigationEngine(None, [], recovery=mgr)
    for w in range(3):
        eng.begin_window(w)
    rec = eng.apply(MitigationPlan(Action.CHECKPOINT_NOW, [], "save"), 3)
    assert rec.checkpoint_step == 3
    mgr.ckpt.wait()
    assert mgr.ckpt.latest_step() == 3


def test_engine_rollback_restores_and_cures(tmp_path):
    mgr = RecoveryManager.for_sim(seed=3, directory=str(tmp_path),
                                  save_every=3)
    sched = [ScheduledFault(F.LossSpike(), 0, 10)]
    eng = MitigationEngine(None, sched, recovery=mgr)
    for w in range(5):
        eng.begin_window(w)
    rec = eng.apply(MitigationPlan(Action.ROLLBACK_TO_CHECKPOINT, [],
                                   "restore"), 4)
    assert not rec.rollback_failed and rec.rollback_verified
    assert rec.restored_step == 3 and rec.lost_steps == 2
    assert rec.cured == ["LossSpike"]
    assert eng.faults_at(5) == []


def test_engine_failed_rollback_cures_nothing(tmp_path):
    mgr = RecoveryManager.for_sim(seed=3, directory=str(tmp_path),
                                  save_every=0)
    sched = [ScheduledFault(F.LossSpike(), 0, 10)]
    eng = MitigationEngine(None, sched, recovery=mgr)
    for w in range(5):
        eng.begin_window(w)
    rec = eng.apply(MitigationPlan(Action.ROLLBACK_TO_CHECKPOINT, [],
                                   "restore"), 4)
    assert rec.rollback_failed and not rec.rollback_verified
    assert rec.restored_step is None
    assert rec.cured == []
    assert [type(f).__name__ for f in eng.faults_at(5)] == ["LossSpike"]


def test_bare_engine_keeps_label_cure_semantics():
    """No recovery manager (worker-process replay engines, legacy callers):
    ROLLBACK_TO_CHECKPOINT keeps its historical label-only cure."""
    sched = [ScheduledFault(F.LossSpike(), 0, 10)]
    eng = MitigationEngine(None, sched)
    rec = eng.apply(MitigationPlan(Action.ROLLBACK_TO_CHECKPOINT, [],
                                   "restore"), 4)
    assert not rec.rollback_failed
    assert rec.cured == ["LossSpike"]


def test_scenario_rollback_without_checkpoints_escalates():
    """End-to-end honest degradation: a numerics incident whose rollback
    finds an empty checkpoint directory must NOT resolve — the cure is
    skipped, verification fails, and the ladder runs dry honestly."""
    rec = RecoveryManager.for_sim(seed=5, save_every=0)
    runner, res = run_mitigated(
        [ScheduledFault(F.LossSpike(), INJECT, 12)], n_windows=12,
        recovery=rec)
    inc = next(i for i in res.incidents if i.function == LOSS_FN)
    assert inc.state == ESCALATED
    rolls = [m for m in runner.engine.log
             if m.plan.action is Action.ROLLBACK_TO_CHECKPOINT]
    assert rolls and all(m.rollback_failed for m in rolls)
    assert all(m.cured == [] for m in rolls)


def test_scenario_rollback_with_checkpoints_resolves():
    """The same scenario WITH a checkpoint cadence does real restores and
    resolves: the auto-provisioned manager's side-car state round-trips
    through disk (restored step + parameter equality on the engine log)."""
    runner, res = run_mitigated(
        [ScheduledFault(F.LossSpike(), INJECT, 12)], n_windows=12)
    inc = next(i for i in res.incidents if i.function == LOSS_FN)
    assert inc.state == RESOLVED
    m = next(m for m in runner.engine.log
             if m.plan.action is Action.ROLLBACK_TO_CHECKPOINT)
    assert not m.rollback_failed and m.rollback_verified
    assert m.restored_step is not None and m.lost_steps > 0
    mgr = runner.engine.recovery
    assert mgr is not None and mgr.saved_steps


# -- chronic-fault memory -----------------------------------------------------

def test_history_roundtrip_and_torn_line(tmp_path):
    path = tmp_path / "incidents.jsonl"
    h = IncidentHistory(path)
    h.record("perf", GEMM, (3, 11), "resolved",
             [{"action": "replace_hosts", "rung": 0, "ok": False},
              {"action": "flag_code_for_optimization", "rung": 1, "ok": True}])
    with path.open("a") as f:
        f.write('{"channel": "perf", "torn')       # crashed writer
    h2 = IncidentHistory(path)                     # reload from disk
    assert len(h2.records) == 1
    assert h2.successful_action("perf", GEMM, (11, 40)) == "flag_code_for_optimization"
    assert h2.action_stats("perf", GEMM, (3,)) == {
        "replace_hosts": (0, 1), "flag_code_for_optimization": (1, 0)}


def test_history_matching_is_signature_overlap(tmp_path):
    h = IncidentHistory(tmp_path / "i.jsonl")
    h.record("perf", GEMM, (3, 11), "resolved",
             [{"action": "flag_code_for_optimization", "rung": 0, "ok": True}])
    assert h.successful_action("perf", GEMM, (11,)) == "flag_code_for_optimization"
    assert h.successful_action("perf", GEMM, ()) == "flag_code_for_optimization"  # job-level
    assert h.successful_action("perf", GEMM, (7,)) is None       # disjoint
    assert h.successful_action("numerics", GEMM, (3,)) is None   # channel
    assert h.successful_action("perf", "other.fn", (3,)) is None


def test_history_rerank_moves_winner_first(tmp_path):
    h = IncidentHistory(tmp_path / "i.jsonl")
    plans = [MitigationPlan(Action.REPLACE_HOSTS, [3, 11], "drop"),
             MitigationPlan(Action.FLAG_CODE, [], "flag")]
    ranked, chronic = h.rerank(list(plans), "perf", GEMM, (3, 11))
    assert [p.action for p in ranked] == [p.action for p in plans]
    assert not chronic                              # empty store: no-op
    h.record("perf", GEMM, (3, 11), "resolved",
             [{"action": "replace_hosts", "rung": 0, "ok": False},
              {"action": "flag_code_for_optimization", "rung": 1, "ok": True}])
    ranked, chronic = h.rerank(list(plans), "perf", GEMM, (3, 11))
    assert [p.action for p in ranked] == [Action.FLAG_CODE,
                                          Action.REPLACE_HOSTS]
    assert chronic


def test_restarted_run_starts_at_the_rung_that_worked(tmp_path):
    """The acceptance bar: run 1 learns (wrong plan first, one escalation,
    flag_code cures); run 2 — a 'restarted job' sharing the history file —
    re-ranks the fresh ladder and resolves at rung 0, zero escalations."""
    path = tmp_path / "incidents.jsonl"
    sched = [ScheduledFault(F.GpuThrottle(workers=(3, 11)), INJECT, 14,
                            cures=(Action.FLAG_CODE,))]
    r1, res1 = run_mitigated(list(sched), n_windows=14,
                             history=IncidentHistory(path))
    inc1 = next(i for i in res1.incidents if i.function == GEMM)
    assert inc1.state == RESOLVED and inc1.escalations == 1
    assert not inc1.chronic
    assert [p.action for _, p in inc1.applied] == [Action.REPLACE_HOSTS,
                                                   Action.FLAG_CODE]
    # run 2: cold restart, same store — the lesson survives the process
    r2, res2 = run_mitigated(list(sched), n_windows=14,
                             history=IncidentHistory(path))
    inc2 = next(i for i in res2.incidents if i.function == GEMM)
    assert inc2.state == RESOLVED and inc2.escalations == 0
    assert inc2.chronic
    assert [p.action for _, p in inc2.applied] == [Action.FLAG_CODE]
    # both runs' GEMM incidents were recorded (side incidents may add more)
    recs = [r for r in IncidentHistory(path).records
            if r["function"] == GEMM]
    assert len(recs) == 2
    assert all(r["outcome"] == "resolved" for r in recs)


def test_escalated_outcome_recorded_as_failures(tmp_path):
    path = tmp_path / "incidents.jsonl"
    run_mitigated([ScheduledFault(F.GpuThrottle(workers=(3, 11)), INJECT, 9,
                                  cures=())], n_windows=13,
                  history=IncidentHistory(path))
    recs = IncidentHistory(path).records
    assert recs and recs[-1]["outcome"] == "escalated"
    assert all(not a["ok"] for a in recs[-1]["attempts"])
