"""ISSUE 9: the serving latency-SLO channel (DESIGN.md §13).

Three layers of coverage:

  * the ``SloDetector`` state machines — warmup, single-burst
    forgiveness, confirm/recover hysteresis, immediate non-finite firing,
    per-signal ratios, and the no-baseline-poisoning rule (all shared
    with the numerics channel through ``_StreamDetector``);
  * the channel registry — unknown channels raise loudly instead of
    coercing to ``perf`` (the getattr-default bug this PR removes);
  * the serve plan ladders — registered under ``(slo, Kind)`` keys by
    ``repro.serve.playbook``; the (None, kind) training defaults are
    untouched (registry regression).
"""
import numpy as np
import pytest

import repro.serve.playbook  # noqa: F401  (registers the slo ladders)
from repro.core import channels
from repro.core.detector import (Recovery, SloConfig, SloDetector, Trigger)
from repro.core.events import Kind
from repro.core.localizer import Abnormality
from repro.core.mitigation import Action, plan_ladder
from repro.core.report import Diagnosis, root_cause_hint
from repro.serve.workload import DECODE_STEP, KV_READ, QUEUE_WAIT

W = 24
BASE_TTFT = 0.050
BASE_TBT = 0.010


def warmed(ttft=BASE_TTFT, tbt=BASE_TBT, n=16, cfg=None):
    """A detector past warmup with a stable healthy baseline."""
    det = SloDetector(cfg)
    for i in range(n):
        assert det.feed(float(i), ttft, tbt) == []
    return det


# -- SloDetector state machines -----------------------------------------------

def test_warmup_suppresses_triggers():
    det = SloDetector()
    # wild tails during warmup are baseline-building, not violations
    for i in range(det.cfg.warmup - 1):
        assert det.feed(float(i), 0.05 * 3 ** i, 0.01 * 2 ** i) == []
    assert det.healthy


def test_single_burst_recovers_silently():
    """One bad p99 chunk from a benign arrival burst must neither
    trigger nor emit a recovery (confirm=2 is the burst tolerance)."""
    det = warmed()
    assert det.feed(16.0, BASE_TTFT * 10, BASE_TBT) == []  # unconfirmed
    assert det.feed(17.0, BASE_TTFT, BASE_TBT) == []       # burst passed
    assert det.triggers == [] and det.recoveries == []
    assert det.healthy and det.outstanding() == []


def test_sustained_ttft_violation_triggers_then_recovers():
    det = warmed()
    assert det.feed(16.0, BASE_TTFT * 10, BASE_TBT) == []
    trigs = det.feed(17.0, BASE_TTFT * 10, BASE_TBT)  # second consecutive
    assert len(trigs) == 1
    t = trigs[0]
    assert isinstance(t, Trigger)
    assert t.reason == "ttft_violation" and t.channel == channels.SLO
    assert t.mean_duration == pytest.approx(BASE_TTFT * 10)
    assert t.baseline == pytest.approx(BASE_TTFT)
    assert not det.healthy and det.outstanding() == ["ttft"]
    # further violations stay silent (one trigger per episode)
    assert det.feed(18.0, BASE_TTFT * 12, BASE_TBT) == []
    # recovery needs `recover` consecutive healthy chunks (hysteresis)
    assert det.feed(19.0, BASE_TTFT, BASE_TBT) == []
    assert det.recoveries == []
    assert det.feed(20.0, BASE_TTFT, BASE_TBT) == []
    assert [r.reason for r in det.recoveries] == ["ttft_violation"]
    assert isinstance(det.recoveries[0], Recovery)
    assert det.recoveries[0].channel == channels.SLO
    assert det.healthy


def test_rearm_fires_again_after_recovery():
    """A recovered signal re-arms: a second sustained violation opens a
    second episode with its own trigger."""
    det = warmed()
    for t in (16.0, 17.0):
        det.feed(t, BASE_TTFT * 10, BASE_TBT)
    for t in (18.0, 19.0):
        det.feed(t, BASE_TTFT, BASE_TBT)
    assert det.healthy and len(det.triggers) == 1
    for t in (20.0, 21.0):
        det.feed(t, BASE_TTFT * 10, BASE_TBT)
    assert [t.reason for t in det.triggers] == ["ttft_violation"] * 2
    assert not det.healthy


def test_tbt_uses_tighter_ratio():
    """Decode is steady: the TBT bound (1.5x) is tighter than TTFT's
    (2.5x), so a 2x tail stretch is a TBT violation but TTFT jitter."""
    cfg = SloConfig()
    det = warmed()
    trigs = []
    for i in range(2):
        trigs += det.feed(16.0 + i, BASE_TTFT * 2.0, BASE_TBT * 2.0)
    assert [t.reason for t in trigs] == ["tbt_violation"]
    assert cfg.tbt_ratio < 2.0 < cfg.ttft_ratio


def test_non_finite_fires_immediately_even_in_warmup():
    """There is no benign single-sample NaN: confirmation is skipped."""
    det = SloDetector()
    trigs = det.feed(0.0, float("nan"), BASE_TBT)
    assert [t.reason for t in trigs] == ["ttft_violation"]
    assert "non-finite" in trigs[0].detail
    det2 = warmed()
    trigs2 = det2.feed(16.0, BASE_TTFT, float("inf"))
    assert [t.reason for t in trigs2] == ["tbt_violation"]


def test_violations_never_poison_baseline():
    """A long violation episode must not fold into the median it is
    judged by: the ORIGINAL baseline still judges recovery."""
    det = warmed()
    for i in range(40):
        det.feed(16.0 + i, BASE_TTFT * 10, BASE_TBT)
    assert not det.healthy
    assert all(v == pytest.approx(BASE_TTFT) for v in det._hist["ttft"])
    # healthy-at-the-old-baseline chunks recover it
    det.feed(60.0, BASE_TTFT, BASE_TBT)
    det.feed(61.0, BASE_TTFT, BASE_TBT)
    assert det.healthy


# -- channel registry ---------------------------------------------------------

def test_unknown_channel_raises():
    with pytest.raises(channels.UnknownChannelError):
        channels.validate_channel("lso")
    assert channels.SLO in channels.CHANNELS


def test_abnormality_validates_channel_at_construction():
    with pytest.raises(channels.UnknownChannelError):
        _diag(Kind.GPU, DECODE_STEP, [1], channel="slowdown")


# -- serve plan ladders (registry-keyed, no core edits) -----------------------

def _diag(kind, fn, workers, fleet=W, beta=0.5, mu=0.5, sigma=0.05,
          channel=channels.SLO):
    idx = np.asarray(sorted(workers), np.int64)
    pats = np.tile(np.asarray([beta, mu, sigma], np.float32),
                   (len(idx), 1))
    a = Abnormality(function=fn, workers=idx, kind=kind,
                    d_expect=np.ones(len(idx)), delta=np.zeros(len(idx)),
                    patterns=pats,
                    typical=np.asarray([0.1, 0.5, 0.05], np.float32),
                    channel=channel)
    return Diagnosis(a, root_cause_hint(a, fleet))


SLO_PLAN_MATRIX = [
    pytest.param(_diag(Kind.GPU, DECODE_STEP, [3], mu=0.3),
                 Action.DRAIN_AND_REPLACE, Action.SHED_LOAD,
                 id="slo_gpu_narrow"),
    pytest.param(_diag(Kind.GPU, DECODE_STEP, range(16), mu=0.3),
                 Action.SHED_LOAD, Action.FLAG_CODE,
                 id="slo_gpu_widespread"),
    pytest.param(_diag(Kind.COMM, "serve.token_sync", [5], mu=0.9),
                 Action.DRAIN_AND_REPLACE, Action.SHED_LOAD,
                 id="slo_comm_narrow"),
    pytest.param(_diag(Kind.PYTHON, QUEUE_WAIT, range(20), mu=0.1),
                 Action.SHED_LOAD, Action.FLAG_CODE,
                 id="slo_queue_fleet"),
    pytest.param(_diag(Kind.PYTHON, QUEUE_WAIT, [2], mu=0.1),
                 Action.SHED_LOAD, Action.DRAIN_AND_REPLACE,
                 id="slo_queue_subset"),
    pytest.param(_diag(Kind.MEM, KV_READ, range(20), mu=0.2),
                 Action.SHED_LOAD, Action.FLAG_CODE,
                 id="slo_kv_thrash"),
]


@pytest.mark.parametrize("diag,first,second", SLO_PLAN_MATRIX)
def test_slo_plan_ladders(diag, first, second):
    ladder = plan_ladder(diag, W)
    assert ladder[0].action == first
    assert len(ladder) >= 2 and ladder[1].action == second


def test_slo_ladders_leave_training_defaults_untouched():
    """Registry regression: the same (kind, shape) diagnoses under the
    default perf channel still walk the TRAINING ladders — registering
    the slo rules changed nothing keyed (None, kind)."""
    for diag, first in [
            (_diag(Kind.GPU, "gemm_fprop", [3], channel=channels.PERF),
             Action.REPLACE_HOSTS),
            (_diag(Kind.COMM, "nccl:all_gather", [5], mu=0.9,
                   channel=channels.PERF),
             Action.REPLACE_HOSTS),
            (_diag(Kind.MEM, "memcpy_h2d", [4], mu=0.7,
                   channel=channels.PERF),
             Action.FLAG_CODE)]:
        assert plan_ladder(diag, W)[0].action == first


def test_serve_root_cause_hints():
    queue = _diag(Kind.PYTHON, QUEUE_WAIT, range(20), mu=0.1)
    assert "arrival rate exceeds serving capacity" in queue.hint
    kv = _diag(Kind.MEM, KV_READ, range(20), mu=0.2)
    assert "KV" in kv.hint and "shed load" in kv.hint
