"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps +
hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _prop import given, settings, st   # hypothesis or graceful skip

from repro.kernels import ops, ref


# -- flash attention -----------------------------------------------------------

@pytest.mark.parametrize("B,S,H,KV,D", [
    (1, 128, 4, 4, 64), (2, 256, 6, 2, 64), (1, 256, 8, 1, 128),
    (2, 128, 2, 2, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_shapes_dtypes(B, S, H, KV, D, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, D), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, D), dtype)
    out = ops.flash_attention(q, k, v, block_q=64, block_k=64)
    exp = ref.attention_oracle(q, k, v)
    tol = 0.035 if dtype == jnp.bfloat16 else 2e-5
    assert out.shape == exp.shape and out.dtype == dtype
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                 - exp.astype(jnp.float32)))) < tol


@pytest.mark.parametrize("kw", [dict(window=100), dict(softcap=20.0),
                                dict(causal=False),
                                dict(window=64, softcap=10.0)])
def test_flash_variants(kw):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 256, 4, 32))
    k = jax.random.normal(ks[1], (2, 256, 2, 32))
    v = jax.random.normal(ks[2], (2, 256, 2, 32))
    out = ops.flash_attention(q, k, v, block_q=64, block_k=64, **kw)
    exp = ref.attention_oracle(q, k, v, **kw)
    assert float(jnp.max(jnp.abs(out - exp))) < 2e-5


# -- SSD scan -------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,P,G,N,chunk", [
    (1, 64, 2, 16, 1, 8, 16), (2, 128, 4, 32, 2, 16, 32),
    (1, 128, 4, 64, 4, 32, 64), (1, 32, 2, 16, 2, 16, 32),
])
def test_ssd_shapes(B, S, H, P, G, N, chunk):
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.uniform(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, G, N))
    Cm = jax.random.normal(ks[4], (B, S, G, N))
    out = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
    exp = ref.ssd_oracle(x, dt, A, Bm, Cm)
    scale = float(jnp.max(jnp.abs(exp))) + 1e-6
    assert float(jnp.max(jnp.abs(out - exp))) / scale < 2e-5


def test_ssd_matches_model_chunked_path():
    """Pallas kernel == the model's XLA chunked implementation."""
    from repro.models.ssm import ssd_chunked
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    B, S, H, P, G, N = 2, 128, 4, 32, 2, 16
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.uniform(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, G, N))
    Cm = jax.random.normal(ks[4], (B, S, G, N))
    y1 = ops.ssd_scan(x, dt, A, Bm, Cm, chunk=32)
    y2, _ = ssd_chunked(x, dt, A, Bm, Cm, 32)
    assert float(jnp.max(jnp.abs(y1 - y2))) < 2e-4


# -- pattern summary -------------------------------------------------------------

def test_pattern_summary_basic(rng):
    E, n = 16, 256
    u = np.clip(rng.normal(0.5, 0.3, (E, n)), 0, 1)
    u[:, :40] = 0
    u[3, 100:180] = 0
    u[5] = 0
    out = np.asarray(ops.pattern_summary(jnp.asarray(u, jnp.float32)))
    exp = ref.pattern_summary_oracle(u)
    np.testing.assert_allclose(out, exp, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 12), st.integers(0, 3), st.data())
def test_pattern_summary_property(e_rows, zero_blocks, data):
    n = 128
    rng = np.random.default_rng(data.draw(st.integers(0, 10_000)))
    u = np.clip(rng.normal(0.4, 0.3, (e_rows, n)), 0, 1)
    for _ in range(zero_blocks):
        i = rng.integers(0, e_rows)
        a = rng.integers(0, n - 2)
        b = rng.integers(a + 1, n)
        u[i, a:b] = 0
    out = np.asarray(ops.pattern_summary(jnp.asarray(u, jnp.float32)))
    exp = ref.pattern_summary_oracle(u)
    np.testing.assert_allclose(out, exp, atol=2e-5)
    # mu/sigma/frac bounded
    assert (out[:, 0] >= -1e-6).all() and (out[:, 0] <= 1 + 1e-6).all()
    assert (out[:, 2] > 0).all() and (out[:, 2] <= 1 + 1e-6).all()
