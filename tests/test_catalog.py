"""ISSUE 8: the gated fault-scenario catalog (DESIGN.md §12).

  * every declared scenario runs under the standard deployment shape and
    meets its declared expectations (resolved with the right first plan
    and zero escalations, or — for the bad-standby family — honestly
    escalated);
  * the catalog is big enough: >= 21 scenarios spanning all five fault
    classes (ISSUE 9 adds the serving/SLO class);
  * the diagnosis path stays scenario-agnostic: no scenario name appears
    in any detector/localizer/report/planner/incident module — adding a
    scenario is adding DATA, never a special case.
"""
from pathlib import Path

import pytest

from repro.online.catalog import (FAULT_CLASSES, SCENARIOS, by_name,
                                  evaluate, run_scenario)

REPO = Path(__file__).resolve().parents[1]

#: the diagnosis path: everything between raw profiles and executed plans
DIAGNOSIS_PATH = [
    "src/repro/core/channels.py",
    "src/repro/core/detector.py",
    "src/repro/core/localizer.py",
    "src/repro/core/expectations.py",
    "src/repro/core/report.py",
    "src/repro/core/mitigation.py",
    "src/repro/online/pipeline.py",
    "src/repro/online/incident.py",
    "src/repro/online/mitigation.py",
    "src/repro/serve/playbook.py",
]


# -- the matrix ---------------------------------------------------------------

@pytest.mark.parametrize("sc", SCENARIOS, ids=[s.name for s in SCENARIOS])
def test_scenario_meets_expectations(sc):
    runner, res = run_scenario(sc)
    rows = evaluate(sc, runner, res)
    assert rows, sc.name
    for row in rows:
        assert row["ok"], row
        if row["resolved"]:
            assert row["escalations"] == 0
            assert row["wtr"] is not None and row["wtr"] >= 0
        else:
            # the honest-failure family: escalated, never green-washed
            assert row["escalated"] and row["wtr"] is None


# -- catalog shape ------------------------------------------------------------

def test_catalog_size_and_class_coverage():
    assert len(SCENARIOS) >= 21
    by_class = {c: [s for s in SCENARIOS if s.fault_class == c]
                for c in FAULT_CLASSES}
    assert set(by_class) == set(FAULT_CLASSES)
    assert len(by_class["perf"]) == 6            # the six paper originals
    assert len(by_class["numerics"]) >= 3
    assert len(by_class["host"]) >= 2
    assert len(by_class["environment"]) >= 3
    assert len(by_class["serve"]) >= 4           # the ISSUE 9 SLO family
    # the serving scenarios run the serve workload and expect slo-channel
    # incidents — the loop itself is shared (no per-class code paths)
    for s in by_class["serve"]:
        assert s.workload == "serve"
        assert all(e.channel == "slo" for e in s.expect)
    # every scenario's class is declared, names are unique
    assert all(s.fault_class in FAULT_CLASSES for s in SCENARIOS)
    assert len({s.name for s in SCENARIOS}) == len(SCENARIOS)
    # the bad-standby family exists and is declared escalated
    esc = [s for s in SCENARIOS
           if any(e.outcome == "escalated" for e in s.expect)]
    assert len(esc) >= 2
    assert all(s.fault_class == "environment" for s in esc)


def test_by_name():
    assert by_name("C1P1_gpu_throttle").fault_class == "perf"
    with pytest.raises(KeyError):
        by_name("no_such_scenario")


# -- the invariant: scenarios are data ----------------------------------------

def test_diagnosis_path_is_scenario_agnostic():
    """Grep the diagnosis-path modules for scenario names: a match means
    somebody special-cased a scenario instead of teaching the playbook a
    pattern, which is exactly how a 15-scenario matrix rots."""
    names = [s.name for s in SCENARIOS]
    offenders = []
    for rel in DIAGNOSIS_PATH:
        path = REPO / rel
        assert path.exists(), rel
        text = path.read_text()
        offenders += [(rel, n) for n in names if n in text]
    assert offenders == [], offenders


def test_diagnosis_path_does_not_import_catalog():
    for rel in DIAGNOSIS_PATH:
        text = (REPO / rel).read_text()
        assert "catalog" not in text, rel
