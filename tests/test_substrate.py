"""Optimizer / data / checkpoint / compression / MoE substrate tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import Checkpointer
from repro.configs.registry import ARCHS, reduced
from repro.data.pipeline import DataConfig, DataLoader, SyntheticLM
from repro.models import moe as M
from repro.optim.adamw import AdamW, OptConfig, lr_schedule
from repro.optim.compress import dequantize_int8, quantize_int8


# -- AdamW ---------------------------------------------------------------------

def test_adamw_quadratic_convergence():
    opt = AdamW(OptConfig(lr_peak=0.1, warmup_steps=1, total_steps=400,
                          weight_decay=0.0, clip_norm=0.0))
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(300):
        g = {"w": 2 * params["w"]}       # d/dw ||w||^2
        params, state, _ = opt.update(g, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adamw_decay_mask():
    opt = AdamW(OptConfig(weight_decay=0.5, lr_peak=0.1, warmup_steps=1))
    params = {"mlp": {"wi": jnp.ones((4, 4))},
              "ln": {"scale": jnp.ones((4,))}}
    state = opt.init(params)
    zero = jax.tree_util.tree_map(jnp.zeros_like, params)
    p2, _, _ = opt.update(zero, state, params)
    assert float(p2["mlp"]["wi"][0, 0]) < 1.0     # decayed
    assert float(p2["ln"]["scale"][0]) == 1.0     # masked


def test_grad_clip_and_metrics():
    opt = AdamW(OptConfig(clip_norm=1.0, lr_peak=0.1, warmup_steps=1))
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    _, _, m = opt.update({"w": jnp.full(3, 100.0)}, state, params)
    assert m["grad_norm"] > 100.0


def test_lr_schedule():
    c = OptConfig(lr_peak=1.0, warmup_steps=10, total_steps=100,
                  min_lr_ratio=0.1)
    assert float(lr_schedule(c, jnp.int32(5))) == pytest.approx(0.5)
    assert float(lr_schedule(c, jnp.int32(10))) == pytest.approx(1.0)
    assert float(lr_schedule(c, jnp.int32(100))) == pytest.approx(0.1)


def test_master_weights_bf16_params():
    cfg = reduced(ARCHS["granite-34b"]).with_overrides(
        param_dtype="bfloat16", dtype="bfloat16")
    from repro.models.transformer import Transformer
    model = Transformer(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW(OptConfig())
    state = opt.init(params)
    masters = jax.tree_util.tree_leaves(state["master"])
    assert all(m.dtype == jnp.float32 for m in masters)


# -- data -------------------------------------------------------------------------

def test_data_determinism_and_sharding():
    cfg = reduced(ARCHS["granite-34b"])
    d0 = SyntheticLM(cfg, DataConfig(batch=2, seq_len=32, shard=0))
    d0b = SyntheticLM(cfg, DataConfig(batch=2, seq_len=32, shard=0))
    d1 = SyntheticLM(cfg, DataConfig(batch=2, seq_len=32, shard=1))
    b0, b0b, b1 = d0.batch_at(5), d0b.batch_at(5), d1.batch_at(5)
    np.testing.assert_array_equal(b0["tokens"], b0b["tokens"])
    assert not np.array_equal(b0["tokens"], b1["tokens"])
    # labels are next tokens
    assert (b0["labels"] < cfg.vocab_size).all()


def test_dataloader_prefetch_and_anchor():
    cfg = reduced(ARCHS["granite-34b"])
    src = SyntheticLM(cfg, DataConfig(batch=2, seq_len=16))
    loader = DataLoader(src)
    b1 = loader.next()
    b2 = loader.next()
    assert b1["tokens"].shape == (2, 16)
    assert not np.array_equal(b1["tokens"], b2["tokens"])
    loader.close()


# -- checkpoint --------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    ck.save(10, tree, extra={"note": "x"}, async_=False)
    ck.save(20, tree, async_=True)
    ck.wait()
    assert ck.steps() == [10, 20]
    restored, meta = ck.restore(20, tree)
    assert meta["step"] == 20
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    assert restored["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, {"x": jnp.zeros(2)}, async_=False)
    assert ck.steps() == [3, 4]


# -- compression --------------------------------------------------------------------

def test_int8_quant_roundtrip():
    x = jnp.asarray(np.random.default_rng(0).normal(0, 1, 128),
                    jnp.float32)
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x)
    assert float(err.max()) <= float(s) * 0.5 + 1e-6


def test_error_feedback_unbiased():
    """With error feedback, the accumulated applied signal tracks the true
    gradient sum (compression noise does not accumulate)."""
    rng = np.random.default_rng(1)
    g_true = jnp.asarray(rng.normal(0, 1, 64), jnp.float32)
    residual = jnp.zeros(64)
    applied = jnp.zeros(64)
    for _ in range(50):
        gf = g_true + residual
        q, s = quantize_int8(gf)
        deq = dequantize_int8(q, s)
        residual = gf - deq
        applied += deq
    drift = jnp.abs(applied / 50 - g_true)
    assert float(drift.max()) < 0.01


# -- MoE local dispatch ----------------------------------------------------------------

def _moe_cfg():
    return reduced(ARCHS["deepseek-v2-lite-16b"])


def test_moe_gates_and_capacity():
    cfg = _moe_cfg().with_overrides(capacity_factor=float(8))
    key = jax.random.PRNGKey(0)
    p = M.init_moe(key, cfg)
    x = jax.random.normal(key, (2, 16, cfg.d_model))
    y, stats = M.apply_moe(p, x, cfg)
    assert y.shape == x.shape
    assert jnp.all(jnp.isfinite(y))
    E = cfg.num_experts
    counts = stats[:E]
    assert float(counts.sum()) == 2 * 16 * cfg.top_k   # no drops at cf=E


def test_moe_dropping_reduces_tokens():
    cfg = _moe_cfg().with_overrides(capacity_factor=0.25)
    key = jax.random.PRNGKey(0)
    p = M.init_moe(key, cfg)
    x = jax.random.normal(key, (2, 32, cfg.d_model))
    y, _ = M.apply_moe(p, x, cfg)
    assert jnp.all(jnp.isfinite(y))


def test_moe_grad_flows_to_router_and_experts():
    cfg = _moe_cfg()
    key = jax.random.PRNGKey(0)
    p = M.init_moe(key, cfg)
    x = jax.random.normal(key, (1, 16, cfg.d_model))

    def loss(p):
        y, _ = M.apply_moe(p, x, cfg)
        return jnp.sum(y ** 2)

    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["router"]).sum()) > 0
    assert float(jnp.abs(g["wi"]).sum()) > 0
    assert float(jnp.abs(g["wo"]).sum()) > 0
