"""ISSUE 4: real wire transport for the PerfTracker daemon (DESIGN.md §8).

Three layers of coverage:

  * framing/queue/collector units — length-prefixed reassembly at hostile
    recv boundaries, the bounded drop-oldest send queue, and window
    assembly under injected loss/duplication at the framing layer;
  * the service wire path — ``diagnose_profiles(mode="wire")`` over real
    Unix-socket connections, partial-window degradation, and transport
    counters surfaced in the report;
  * ``@pytest.mark.wire`` multi-process integration — ``n_procs`` spawned
    daemon processes reproduce the in-process fleet mode's confirmed
    culprit sets across the six-fault matrix, with and without 10%
    injected upload loss (the CI ``wire`` job runs exactly these).
"""
import threading
import time

import numpy as np
import pytest

from repro.core import faults as F
from repro.core.daemon import PerfTrackerDaemon, summarize_and_upload
from repro.core.events import FunctionEvent, Kind, SampleStream, WorkerProfile
from repro.core.localizer import Localizer
from repro.core.service import PerfTrackerService
from repro.core.simulation import (ALLGATHER, DATALOADER_STACK, FORWARD_STACK,
                                   GC_STACK, GEMM, FleetSimulator, SimConfig)
from repro.online import (EmaPatternAggregator, EscalationPolicy,
                          ScenarioRunner, ScheduledFault)
from repro.summarize import PatternAggregator, summarize_fleet
from repro.transport import (DaemonServer, FrameDecoder, LoopbackWire,
                             SendQueue, WindowCollector, WireClient,
                             decode_frames, encode_frame)
from repro.transport import framing


# -- framing ------------------------------------------------------------------

def test_frame_roundtrip():
    msgs = [framing.hello_msg(3),
            framing.window_start_msg(2, rates=[250.0, 2000.0]),
            {"t": "upload", "window": 1, "worker": 7, "seq": 0,
             "payload": b"\x00\x01\xffbinary", "summarize_s": 0.25,
             "raw_bytes": 12345}]
    blob = b"".join(encode_frame(m) for m in msgs)
    assert decode_frames(blob) == msgs


def test_frame_decoder_survives_any_recv_boundary():
    msgs = [framing.bye_msg(w) for w in range(5)]
    blob = b"".join(encode_frame(m) for m in msgs)
    # feed one byte at a time: every frame must pop exactly once, at the
    # arrival of its final byte
    dec = FrameDecoder()
    got = []
    for i in range(len(blob)):
        got += list(dec.feed(blob[i:i + 1]))
    assert got == msgs
    assert dec.pending_bytes == 0


def test_frame_decoder_multiple_frames_single_feed():
    msgs = [framing.hello_msg(w) for w in range(4)]
    dec = FrameDecoder()
    got = list(dec.feed(b"".join(encode_frame(m) for m in msgs)))
    assert got == msgs


def test_decode_frames_rejects_trailing_partial():
    blob = encode_frame(framing.hello_msg(0)) + b"\x00\x00"
    with pytest.raises(ValueError):
        decode_frames(blob)


def test_frame_decoder_rejects_oversized_length():
    bad = (framing.MAX_FRAME_BYTES + 1).to_bytes(4, "big") + b"x"
    with pytest.raises(ValueError):
        list(FrameDecoder().feed(bad))


def test_encode_frame_rejects_oversized_body():
    with pytest.raises(ValueError):
        encode_frame({"t": "upload",
                      "payload": b"x" * (framing.MAX_FRAME_BYTES + 1)})


# -- bounded send queue (backpressure policy) ---------------------------------

def test_send_queue_drops_oldest_upload():
    q = SendQueue(max_uploads=3)
    for i in range(5):
        q.put({"seq": i})
    assert q.dropped == 2
    got = [q.pop()[1]["seq"] for _ in range(3)]
    assert got == [2, 3, 4]          # oldest evicted, newest kept


def test_send_queue_never_drops_control_frames():
    q = SendQueue(max_uploads=2)
    q.put({"t": "hello"}, droppable=False)
    for i in range(6):
        q.put({"seq": i})
    q.put({"t": "window_end"}, droppable=False)
    kinds = []
    while (item := q.pop()) is not None:
        kinds.append(item[0])
    assert kinds == [False, True, True, False]
    assert q.dropped == 4


def _upload(worker, window_s=1.0, beta=0.5):
    """A tiny real PatternUpload."""
    n = 100
    prof = WorkerProfile(
        worker=worker, window=(0.0, window_s),
        events=[FunctionEvent("matmul", Kind.GPU, 0.0, beta * window_s,
                              worker)],
        streams={"gpu_sm": SampleStream(n / window_s, 0.0,
                                        np.full(n, 0.8))})
    return summarize_and_upload(prof, backend="numpy")


def test_client_backpressure_drops_oldest_counts_on_wire():
    """A stalled wire (blocking frame filter) fills the bounded queue; the
    oldest unsent windows drop, and the window_end frame — snapshotted at
    SEND time — carries the final counters to the collector."""
    gate = threading.Event()

    def stall(msg, frame):
        gate.wait(timeout=30.0)
        return None

    collector = WindowCollector([0])
    with DaemonServer(collector) as server:
        client = WireClient(server.address, worker=0, max_queue=2,
                            frame_filter=stall)
        try:
            for w in range(6):
                client.send_upload(w, _upload(0))
            # sender thread is stalled inside window 0's filter; of the 5
            # queued behind it, only the newest 2 survive
            deadline = time.monotonic() + 5.0
            while client.dropped < 3 and time.monotonic() < deadline:
                time.sleep(0.01)
            assert client.dropped == 3
            client.end_window(5)
            gate.set()
            assert client.flush(timeout=10.0)
            batch = collector.wait_window(5, timeout=10.0)
        finally:
            gate.set()
            client.close()
    assert batch.client_dropped == 3
    # the NEWEST windows survived the eviction: window 5's upload arrived
    assert batch.present == [0] and not batch.timed_out


# -- collector: loss, duplication, dedup --------------------------------------

def _loopback_batch(n_workers, frame_filter=None, window=0):
    uploads = [_upload(w) for w in range(n_workers)]
    with LoopbackWire(range(n_workers), frame_filter=frame_filter) as wire:
        return wire.send_round(uploads, window=window, timeout=15.0)


def test_collector_assembles_full_window():
    batch = _loopback_batch(6)
    assert batch.present == list(range(6))
    assert batch.complete and not batch.timed_out
    assert batch.duplicates == 0 and batch.missing == []


def test_collector_dedups_duplicated_frames():
    def dup(msg, frame):
        return [frame, frame, frame] if msg["worker"] == 2 else None
    batch = _loopback_batch(5, frame_filter=dup)
    assert batch.present == list(range(5))
    assert batch.duplicates == 2          # first copy kept, rest counted


def test_collector_tolerates_dropped_uploads():
    def drop(msg, frame):
        return [] if msg["worker"] in (1, 3) else None
    batch = _loopback_batch(5, frame_filter=drop)
    assert batch.missing == [1, 3]
    assert batch.present == [0, 2, 4]
    assert not batch.timed_out            # window_end frames still closed it
    mask = batch.present_mask(5)
    np.testing.assert_array_equal(mask, [True, False, True, False, True])


def test_anchors_frame_slo_parity():
    """ISSUE 10 satellite: the ``slo`` field rides the anchors frame with
    the same present-only-when-provided contract ``numerics`` has — a
    workload without the stream produces BYTE-identical frames to the
    historical format, and the collector parses it into ``batch.slo``."""
    durs = [0.5, 0.6]
    pairs = [(0.21, 0.013), (0.19, 0.011)]
    msg = framing.anchors_msg(3, 7, durs, slo=pairs)
    (back,) = decode_frames(encode_frame(msg))
    assert back["slo"] == [[0.21, 0.013], [0.19, 0.011]]
    assert "numerics" not in back
    # absent stream -> byte-identical legacy frame
    legacy = framing.anchors_msg(3, 7, durs)
    assert "slo" not in legacy and "numerics" not in legacy
    assert encode_frame(legacy) == encode_frame(
        {"t": "anchors", "window": 3, "worker": 7, "durs": durs})
    # collector side: slo lands beside anchors/numerics, first copy wins
    collector = WindowCollector([7])
    collector.on_message(msg)
    collector.on_message(framing.anchors_msg(3, 7, [9.9], slo=[(1.0, 1.0)]))
    collector.on_message({"t": "window_end", "window": 3, "worker": 7,
                          "sent": 1, "dropped": 0})
    batch = collector.wait_window(3, timeout=1.0)
    assert batch.anchors[7] == durs
    assert batch.slo == {7: pairs}
    assert batch.numerics == {}


def test_collector_timeout_reports_never_ended_worker():
    collector = WindowCollector([0, 1])
    collector.on_message({"t": "window_end", "window": 0, "worker": 0,
                          "sent": 0, "dropped": 0})
    t0 = time.monotonic()
    batch = collector.wait_window(0, timeout=0.2)
    assert time.monotonic() - t0 < 5.0
    assert batch.timed_out and batch.missing == [0, 1]


# -- service wire mode over the real transport --------------------------------

def _sim_profiles(W=16, faults=(), seed=7):
    sim = FleetSimulator(SimConfig(n_workers=W, window_s=1.0, rate_hz=1000,
                                   seed=seed), list(faults))
    return sim.profile_window()


def test_wire_mode_loss_degrades_instead_of_crashing():
    """Dropping healthy workers' uploads must not break localization of
    the real culprits — and the report must surface the transport holes."""
    profiles = _sim_profiles(W=16, faults=[F.GpuThrottle(workers=(3, 5))])

    def drop(msg, frame):
        return [] if msg["worker"] in (0, 9) else None
    svc = PerfTrackerService(summarize_backend="numpy",
                             wire_frame_filter=drop)
    res = svc.diagnose_profiles(profiles, mode="wire")
    d = next(d for d in res.diagnoses if d.abnormality.function == GEMM)
    assert {3, 5} <= set(d.abnormality.workers.tolist())
    assert res.transport["missing"] == [0, 9]
    assert res.transport["present"] == 14
    assert "transport: 14/16 workers reported" in res.report()
    assert "missing=[0, 9]" in res.report()


def test_wire_mode_drop_counter_in_report():
    profiles = _sim_profiles(W=4)
    svc = PerfTrackerService(summarize_backend="numpy")
    res = svc.diagnose_profiles(profiles, mode="wire")
    assert res.transport["client_dropped"] == 0
    assert "dropped=0" in res.report()


def test_daemon_process_window_uploads_over_wire():
    collector = WindowCollector([4])
    with DaemonServer(collector) as server:
        daemon = PerfTrackerDaemon(4, server.address, backend="numpy")
        try:
            prof = _sim_profiles(W=5)[4]
            up = daemon.process_window(0, prof)
            batch = collector.wait_window(0, timeout=10.0)
        finally:
            daemon.close()
    assert batch.present == [4]
    assert batch.uploads[4].payload == up.payload


# -- partial-fleet threading: aggregator / summarize_fleet / EMA / localizer --

def test_aggregator_set_row_places_partial_fleet():
    agg = PatternAggregator(expected_workers=4)
    agg.reserve_workers(4)
    agg.set_row(2, {"f": np.array([0.5, 0.6, 0.1], np.float32)},
                {"f": Kind.GPU})
    pats, kinds = agg.finalize()
    np.testing.assert_allclose(pats["f"][2], [0.5, 0.6, 0.1])
    np.testing.assert_allclose(pats["f"][[0, 1, 3]], 0.0)
    assert kinds["f"] == Kind.GPU
    with pytest.raises(ValueError):
        agg.set_row(7, {"f": np.zeros(3, np.float32)})


def test_summarize_fleet_partial_scatters_to_global_rows():
    profiles = _sim_profiles(W=6)
    full = summarize_fleet(profiles, backend="numpy").agg.finalize()[0]
    sub = [profiles[1], profiles[4]]
    fs = summarize_fleet(sub, backend="numpy", workers=[1, 4], fleet_size=6)
    part = fs.agg.finalize()[0]
    for name in full:
        np.testing.assert_array_equal(np.asarray(part[name])[[1, 4]],
                                      np.asarray(full[name])[[1, 4]])
        np.testing.assert_array_equal(np.asarray(part[name])[[0, 2, 3, 5]],
                                      0.0)
    with pytest.raises(ValueError):
        summarize_fleet(sub, backend="numpy", workers=[1, 9], fleet_size=6)
    # regression (review): a negative id must raise, not wrap into the
    # last worker's row via numpy negative indexing
    with pytest.raises(ValueError):
        summarize_fleet(sub, backend="numpy", workers=[-1, 4], fleet_size=6)


def test_ema_fold_present_freezes_absent_rows():
    def agg_of(vals):
        a = PatternAggregator(expected_workers=3)
        a.reserve_workers(3)
        a.intern("f", Kind.GPU)
        a.scatter_block(0, np.asarray(vals, np.float32).reshape(3, 1, 3))
        return a
    ema = EmaPatternAggregator(3, alpha=0.5)
    ema.fold(agg_of([[0.4, 0.8, 0.1]] * 3))
    ema.fold(agg_of([[0.8, 0.4, 0.3]] * 3),
             present=np.array([True, False, True]))
    pats, _ = ema.finalize()
    np.testing.assert_allclose(pats["f"][0], [0.6, 0.6, 0.2], rtol=1e-6)
    np.testing.assert_allclose(pats["f"][2], [0.6, 0.6, 0.2], rtol=1e-6)
    # absent worker 1: frozen at its last smoothed value, no decay
    np.testing.assert_allclose(pats["f"][1], [0.4, 0.8, 0.1], rtol=1e-6)


def test_ema_returning_worker_gets_full_value_not_ramp():
    """Regression (review): a worker absent when a column FIRST appeared
    must initialize at full value on its own first evidence — not an
    alpha-scaled ramp from the zero it never reported."""
    def agg_of(vals):
        a = PatternAggregator(expected_workers=2)
        a.reserve_workers(2)
        a.intern("g", Kind.GPU)
        a.scatter_block(0, np.asarray(vals, np.float32).reshape(2, 1, 3))
        return a
    ema = EmaPatternAggregator(2, alpha=0.3)
    # window 0: column g first appears, worker 1's upload was dropped
    ema.fold(agg_of([[0.9, 0.9, 0.1], [0.0, 0.0, 0.0]]),
             present=np.array([True, False]))
    # window 1: worker 1 reports g for the first time
    ema.fold(agg_of([[0.9, 0.9, 0.1], [0.9, 0.9, 0.1]]))
    pats, _ = ema.finalize()
    np.testing.assert_allclose(pats["g"][1], [0.9, 0.9, 0.1], rtol=1e-6)
    np.testing.assert_allclose(pats["g"][0], [0.9, 0.9, 0.1], rtol=1e-6)


def test_collector_drops_straggler_frames_for_popped_windows():
    """Regression (review): uploads arriving AFTER their window was handed
    out must not resurrect the batch (unbounded memory over a long run)."""
    collector = WindowCollector([0, 1])
    for w in (0, 1):
        collector.on_message({"t": "window_end", "window": 0, "worker": w,
                              "sent": 1, "dropped": 0})
    collector.wait_window(0, timeout=1.0)
    # straggler upload for the already-popped window 0
    collector.on_message(framing.upload_msg(0, _upload(1), seq=9))
    assert collector.stale_frames == 1
    assert collector._batches == {}


def test_ema_fold_all_present_mask_identical_to_default():
    def agg_of():
        a = PatternAggregator(expected_workers=2)
        a.reserve_workers(2)
        a.intern("f", Kind.GPU)
        a.scatter_block(0, np.full((2, 1, 3), 0.5, np.float32))
        return a
    a_ = EmaPatternAggregator(2, alpha=0.6)
    b_ = EmaPatternAggregator(2, alpha=0.6)
    for _ in range(3):
        a_.fold(agg_of())
        b_.fold(agg_of(), present=np.array([True, True]))
    np.testing.assert_array_equal(a_.matrix()[0], b_.matrix()[0])


def test_localizer_present_mask_reports_global_ids():
    W = 10
    pats = np.tile(np.array([0.5, 0.9, 0.05], np.float32), (W, 1))
    pats[7] = [0.9, 0.1, 0.05]        # the real outlier
    pats[2] = 0.0                     # absent worker: zero row
    pats[5] = 0.0
    present = np.ones(W, bool)
    present[[2, 5]] = False
    abn = Localizer().localize({"f": pats}, {"f": Kind.GPU},
                               present=present)
    assert len(abn) == 1
    assert abn[0].workers.tolist() == [7]     # global id survives masking
    # absent rows are excluded from the typical-pattern median
    np.testing.assert_allclose(abn[0].typical, [0.5, 0.9, 0.05])


def test_localizer_full_present_identical_to_default():
    pats = np.tile(np.array([0.5, 0.9, 0.05], np.float32), (8, 1))
    pats[3] = [0.95, 0.05, 0.01]
    a = Localizer().localize({"f": pats.copy()}, {"f": Kind.GPU})
    b = Localizer().localize({"f": pats.copy()}, {"f": Kind.GPU},
                             present=np.ones(8, bool))
    assert len(a) == len(b) == 1
    np.testing.assert_array_equal(a[0].workers, b[0].workers)
    np.testing.assert_array_equal(a[0].delta, b[0].delta)


# -- multi-process integration (the CI `wire` job: pytest -m wire) ------------

W_MP = 32
INJECT, REMOVE = 2, 6
N_WINDOWS = 9
BASE_HZ, FULL_HZ = 250.0, 2000.0

#: (fault, expected incident function, culprit workers or None=fleet-wide)
MP_SCENARIOS = [
    pytest.param(F.GpuThrottle(workers=(3, 11)), GEMM, {3, 11},
                 id="C1P1_gpu_throttle"),
    pytest.param(F.NvlinkDown(workers=[5], group_size=8), ALLGATHER, {5},
                 id="C1P2_nvlink_down"),
    pytest.param(F.RingSlowLink(slow_worker=9, rho=0.4), ALLGATHER, {9},
                 id="S3_ring_slow_link"),
    pytest.param(F.SlowDataloader(), DATALOADER_STACK, None,
                 id="C2P1_slow_dataloader"),
    pytest.param(F.CpuBoundForward(workers=range(6)), FORWARD_STACK,
                 set(range(6)), id="C2P2_cpu_forward"),
    pytest.param(F.AsyncGc(probability=0.5, pause_s=0.25), GC_STACK, None,
                 id="C2P3_async_gc"),
]


def _mp_runner(fault, seed=5):
    esc = EscalationPolicy(n_workers=W_MP, base_rate_hz=BASE_HZ,
                           full_rate_hz=FULL_HZ)
    return ScenarioRunner(
        SimConfig(n_workers=W_MP, window_s=1.0, rate_hz=FULL_HZ, seed=seed),
        [ScheduledFault(fault, INJECT, REMOVE)],
        n_windows=N_WINDOWS, escalation=esc)


def _culprit_sets(res):
    """{function: frozenset(workers)} over confirmed-or-later incidents."""
    return {i.function: frozenset(i.workers)
            for i in res.incidents if i.function}


def _wire_log_path(tmp_path):
    import os
    return os.environ.get("REPRO_WIRE_LOG",
                          str(tmp_path / "wire-collector.log"))


@pytest.mark.wire
@pytest.mark.timeout(300)
@pytest.mark.parametrize("fault,expect,culprits", MP_SCENARIOS)
def test_multiprocess_matches_inprocess_fleet(fault, expect, culprits,
                                              tmp_path):
    """Acceptance: >=4 real worker processes, W>=32, same confirmed
    culprit sets as the in-process mode="fleet" pipeline."""
    res_in = _mp_runner(fault).run()
    res_mp = _mp_runner(fault).run_multiprocess(
        n_procs=4, log_path=_wire_log_path(tmp_path))
    assert _culprit_sets(res_mp) == _culprit_sets(res_in)
    incs = [i for i in res_mp.incidents if i.function == expect]
    assert incs, (expect, [i.function for i in res_mp.incidents])
    if culprits is not None:
        assert culprits <= set(incs[0].workers)
    wire = res_mp.wire_summary()
    assert wire["delivered"] == wire["expected"]     # lossless loopback
    assert wire["partial_windows"] == 0


@pytest.mark.wire
@pytest.mark.timeout(300)
@pytest.mark.parametrize("fault,expect,culprits", MP_SCENARIOS)
def test_multiprocess_10pct_loss_still_localizes(fault, expect, culprits,
                                                 tmp_path):
    """Acceptance: 10% injected upload loss, every fault still localized
    with its culprits, and the holes surfaced in the window reports."""
    res = _mp_runner(fault).run_multiprocess(
        n_procs=4, loss=0.10, log_path=_wire_log_path(tmp_path))
    incs = [i for i in res.incidents if i.function == expect]
    assert incs, (expect, [i.function for i in res.incidents])
    if culprits is not None:
        assert culprits <= set(incs[0].workers)
    wire = res.wire_summary()
    assert wire["delivered"] < wire["expected"]      # loss actually bit
    assert wire["partial_windows"] > 0
    # drop counters surface in the per-window incident report text
    partial = next(r for r in res.reports if r.transport["missing"])
    txt = partial.report(W_MP)
    assert "transport:" in txt and "missing=" in txt


@pytest.mark.wire
@pytest.mark.timeout(300)
def test_multiprocess_escalation_rates_cross_process(tmp_path):
    """The parent's escalation decision rides the window_start broadcast:
    culprit workers' profiles come back sampled at the full rate."""
    res = _mp_runner(F.GpuThrottle(workers=(3, 11))).run_multiprocess(
        n_procs=4, log_path=_wire_log_path(tmp_path))
    mid = res.reports[INJECT + 1]
    assert {3, 11} <= set(mid.escalated)
    assert mid.rates[3] == FULL_HZ and mid.rates[0] == BASE_HZ
    # the raw bytes the children actually materialized reflect the split
    assert res.reports[0].raw_bytes < W_MP * FULL_HZ * 1.0 * 4 * 8
