"""§4.2: Algorithm 1 (critical execution duration), critical path, patterns."""
import numpy as np
import pytest

from _prop import given, settings, st   # hypothesis or graceful skip

from repro.core.critical_path import critical_time_by_function
from repro.core.events import FunctionEvent, Kind, SampleStream, WorkerProfile
from repro.core.patterns import MASS_FRACTION, critical_duration, \
    summarize_worker


# -- Algorithm 1 --------------------------------------------------------------

def region_ok(u, lo, hi, g):
    """No zero-run longer than g inside [lo, hi)."""
    run = 0
    for x in u[lo:hi]:
        run = run + 1 if x <= 0 else 0
        if run > g:
            return False
    return True


def test_contiguous_signal():
    u = np.zeros(100)
    u[20:60] = 1.0
    lo, hi = critical_duration(u)
    assert (lo, hi) == (20, 60)


def test_gap_included_when_needed():
    u = np.zeros(100)
    u[10:30] = 1.0
    u[40:60] = 1.0   # both bursts needed for 80% mass
    lo, hi = critical_duration(u)
    assert lo == 10 and hi == 60


def test_small_tail_excluded():
    u = np.zeros(200)
    u[10:110] = 1.0
    u[190:192] = 0.5  # 1% of mass, far away
    lo, hi = critical_duration(u)
    assert (lo, hi) == (10, 110)


def test_all_zero():
    assert critical_duration(np.zeros(10)) == (0, 10)


@settings(max_examples=40, deadline=None)
@given(st.lists(st.floats(0, 1, width=32), min_size=1, max_size=120),
       st.data())
def test_algorithm1_properties(vals, data):
    u = np.asarray(vals, np.float64)
    # sprinkle exact zeros
    if len(u) > 3:
        k = data.draw(st.integers(0, len(u) // 2))
        idx = data.draw(st.lists(st.integers(0, len(u) - 1), min_size=k,
                                 max_size=k, unique=True))
        u[idx] = 0.0
    lo, hi = critical_duration(u)
    total = u.sum()
    assert 0 <= lo <= hi <= len(u)
    if total > 0:
        seg = u[lo:hi]
        # (1) mass property
        assert seg.sum() >= MASS_FRACTION * total - 1e-9
        # (2) trimmed: boundaries are nonzero samples
        assert seg[0] > 0 and seg[-1] > 0
        # (3) minimal g: the interval's max interior zero-run g* is such
        # that no region at g*-1 reaches the mass target
        run = best = 0
        for x in seg:
            run = run + 1 if x <= 0 else 0
            best = max(best, run)
        if best > 0:
            lo2, hi2 = critical_duration(u)  # determinism
            assert (lo2, hi2) == (lo, hi)


# -- critical path -------------------------------------------------------------

def ev(name, kind, s, e, depth=0, thread="train"):
    return FunctionEvent(name, kind, s, e, 0, thread, depth)


def test_priority_shadows_lower():
    events = [ev("gpu", Kind.GPU, 1.0, 3.0),
              ev("comm", Kind.COMM, 0.0, 4.0),
              ev("py", Kind.PYTHON, 0.0, 5.0, depth=1)]
    ct = critical_time_by_function(events, (0.0, 5.0))
    assert ct["gpu"] == pytest.approx(2.0)
    assert ct["comm"] == pytest.approx(2.0)      # 0-1 and 3-4
    assert ct["py"] == pytest.approx(1.0)        # 4-5 only


def test_python_leaf_wins():
    events = [ev("parent", Kind.PYTHON, 0.0, 4.0, depth=1),
              ev("child", Kind.PYTHON, 1.0, 3.0, depth=2)]
    ct = critical_time_by_function(events, (0.0, 4.0))
    assert ct["child"] == pytest.approx(2.0)
    assert ct["parent"] == pytest.approx(2.0)


def test_non_train_thread_excluded():
    events = [ev("bg", Kind.PYTHON, 0.0, 4.0, thread="_bootstrap"),
              ev("fg", Kind.PYTHON, 1.0, 2.0)]
    ct = critical_time_by_function(events, (0.0, 4.0))
    assert "bg" not in ct
    assert ct["fg"] == pytest.approx(1.0)


def test_beta_bounded():
    events = [ev("a", Kind.GPU, 0.0, 10.0), ev("b", Kind.GPU, 0.0, 10.0)]
    ct = critical_time_by_function(events, (0.0, 2.0))
    assert sum(ct.values()) <= 2.0 * 2 + 1e-9


# -- worker summarization ---------------------------------------------------------

def test_summarize_worker_beta_mu():
    rate = 1000.0
    n = 2000
    gpu = np.zeros(n)
    gpu[0:1000] = 0.9
    prof = WorkerProfile(
        worker=0, window=(0.0, 2.0),
        events=[ev("k1", Kind.GPU, 0.0, 1.0)],
        streams={"gpu_sm": SampleStream(rate, 0.0, gpu)})
    pats = summarize_worker(prof)
    assert pats["k1"].beta == pytest.approx(0.5, abs=0.01)
    assert pats["k1"].mu == pytest.approx(0.9, abs=0.02)
    assert pats["k1"].sigma < 0.05
