"""ISSUE 3 satellite: every module wired into ``benchmarks/run.py`` must
import and run at minimum (env-shrunk) size under tier-1, so a broken
benchmark fails ``make test`` locally instead of only surfacing in the CI
bench job.
"""
import importlib
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent

#: minimum-size knobs per module (see each module's docstring)
SHRINK = {
    "REPRO_BENCH_FLEET_SIZES": "4",
    "REPRO_BENCH_LOC_SIZES": "200",
    "REPRO_BENCH_SUMMARIZE_GRID": "16x64",
    "REPRO_BENCH_OVERHEAD_CONFIGS": "granite-34b:32:1",
    "REPRO_BENCH_OVERHEAD_STEPS": "4",
    "REPRO_BENCH_RING_TRIALS": "2",
    "REPRO_BENCH_ONLINE_W": "8",
    "REPRO_BENCH_ONLINE_WINDOWS": "6",
    "REPRO_BENCH_ONLINE_CASES": "C1P1_gpu_throttle",
    "REPRO_BENCH_ABILITY_CASES": "C1P1_gpu_throttle",
    "REPRO_BENCH_ABILITY_SCENARIOS": "N1_loss_spike",
    "REPRO_BENCH_GOODPUT_SCENARIOS": "N1_loss_spike",
    "REPRO_BENCH_WIRE_W": "4",
    "REPRO_BENCH_WIRE_WINDOWS": "2",
    "REPRO_BENCH_MITIGATION_W": "8",
    "REPRO_BENCH_MITIGATION_WINDOWS": "10",
    "REPRO_BENCH_MITIGATION_CASES": "C2P1_slow_dataloader",
    "REPRO_BENCH_TREE_W": "12",
    "REPRO_BENCH_TREE_SHARDS": "3",
    "REPRO_BENCH_TREE_WINDOWS": "2",
    "REPRO_BENCH_TRAIN_OVERHEAD_ITERS": "4",
    "REPRO_TRAIN_D_MODEL": "32",          # layers stay 2 (gemma2 pairs)
    "REPRO_TRAIN_VOCAB": "256",
}


def _modules():
    sys.path.insert(0, str(REPO))
    try:
        from benchmarks.run import MODULES
    finally:
        sys.path.pop(0)
    return [name for name, _ in MODULES]


@pytest.mark.parametrize("name", _modules())
def test_benchmark_module_runs_at_min_size(name, monkeypatch):
    monkeypatch.syspath_prepend(str(REPO))
    for k, v in SHRINK.items():
        monkeypatch.setenv(k, v)
    # env knobs are read at import time: (re-)import fresh under the shrink
    for key in [k for k in sys.modules if k == f"benchmarks.{name}"]:
        del sys.modules[key]
    mod = importlib.import_module(f"benchmarks.{name}")
    rows = mod.run()
    assert rows, f"benchmarks/{name}.py returned no rows"
    for row in rows:
        n, v, d = row                       # the run.py row contract
        assert isinstance(n, str) and n
        float(v)                            # must be numeric (may be 0)
        str(d)


def test_run_py_json_and_metrics(tmp_path, monkeypatch):
    """The --json path and metric extraction the CI gate depends on."""
    monkeypatch.syspath_prepend(str(REPO))
    from benchmarks.run import metrics_from_rows
    rows = [("bench[fleet]_W8", 123.4, "2.5x_vs_wire;identical=Y"),
            ("bench/ratio", 5.7, "ratio=5.75x;accuracy=Y;note=free text"),
            ("plain", 1.0, "")]
    m = metrics_from_rows(rows)
    assert m["bench[fleet]_W8:speedup_vs_wire"] == 2.5
    assert m["bench[fleet]_W8:identical"] == "Y"
    assert m["bench/ratio:ratio"] == 5.75
    assert m["bench/ratio:accuracy"] == "Y"
    assert m["plain:us_per_call"] == 1.0


def test_check_regression_gate(tmp_path, monkeypatch):
    monkeypatch.syspath_prepend(str(REPO))
    import json
    import subprocess
    results = {"metrics": {"m:speedup": 2.0, "m:flag": "Y"}, "failures": 0}
    baselines = {"default_tolerance": 0.3, "metrics": {
        "m:speedup": {"value": 2.0, "direction": "higher"},
        "m:flag": {"equals": "Y"},
    }}
    rpath, bpath = tmp_path / "r.json", tmp_path / "b.json"
    rpath.write_text(json.dumps(results))
    bpath.write_text(json.dumps(baselines))
    script = str(REPO / "benchmarks" / "check_regression.py")

    def gate(res):
        rpath.write_text(json.dumps(res))
        return subprocess.run(
            [sys.executable, script, str(rpath), "--baselines", str(bpath),
             "--require-all"], capture_output=True, text=True).returncode

    assert gate(results) == 0
    # regression beyond tolerance fails
    assert gate({"metrics": {"m:speedup": 1.0, "m:flag": "Y"},
                 "failures": 0}) == 1
    # parity flag flip fails
    assert gate({"metrics": {"m:speedup": 2.0, "m:flag": "N"},
                 "failures": 0}) == 1
    # missing metric fails under --require-all
    assert gate({"metrics": {"m:speedup": 2.0}, "failures": 0}) == 1
    # errored benchmark module fails the gate
    assert gate({"metrics": {"m:speedup": 2.0, "m:flag": "Y"},
                 "failures": 1}) == 1
