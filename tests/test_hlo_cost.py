"""Trip-count-expanded HLO cost parser: verified against analytically known
programs (the measurement instrument for §Roofline must itself be tested)."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import expanded_cost, parse_module


def _cost_of(fn, *specs):
    comp = jax.jit(fn).lower(*specs).compile()
    return expanded_cost(comp.as_text(), 1)


def test_plain_matmul_flops():
    f = lambda a, b: a @ b
    c = _cost_of(f, jax.ShapeDtypeStruct((64, 64), jnp.float32),
                 jax.ShapeDtypeStruct((64, 64), jnp.float32))
    assert abs(c.flops - 2 * 64 ** 3) / (2 * 64 ** 3) < 0.05


def test_scanned_matmul_trip_expansion():
    def f(ws, x):
        def body(h, w):
            return h @ w, None
        h, _ = jax.lax.scan(body, x, ws)
        return h

    c = _cost_of(f, jax.ShapeDtypeStruct((10, 64, 64), jnp.float32),
                 jax.ShapeDtypeStruct((64, 64), jnp.float32))
    expect = 10 * 2 * 64 ** 3
    assert c.unknown_trip_loops == 0
    assert abs(c.flops - expect) / expect < 0.05


def test_nested_scan_trip_expansion():
    def f(ws, x):
        def outer(h, w):
            def inner(h2, _):
                return h2 @ w, None
            h2, _ = jax.lax.scan(inner, h, None, length=3)
            return h2, None
        h, _ = jax.lax.scan(outer, x, ws)
        return h

    c = _cost_of(f, jax.ShapeDtypeStruct((5, 32, 32), jnp.float32),
                 jax.ShapeDtypeStruct((32, 32), jnp.float32))
    expect = 5 * 3 * 2 * 32 ** 3
    assert c.unknown_trip_loops == 0
    assert abs(c.flops - expect) / expect < 0.05


def test_collective_formulas():
    from repro.launch.hlo_cost import _collective_traffic
    assert _collective_traffic("all-reduce", 100, 4) == pytest.approx(150.0)
    assert _collective_traffic("all-gather", 100, 4) == pytest.approx(75.0)
    assert _collective_traffic("reduce-scatter", 100, 4) == 300.0
    assert _collective_traffic("collective-permute", 100, 4) == 100.0


def test_parse_module_structure():
    txt = """
HloModule m

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (x: f32[8]) -> f32[] {
  %x = f32[8]{0} parameter(0)
  ROOT %r = f32[] reduce(%x, %c), dimensions={0}, to_apply=%add
}
"""
    comps, entry = parse_module(txt)
    assert entry == "%main"
    assert "%add" in comps
