"""§4.3: distances + MAD rule + the §3 ring classification."""
import numpy as np
import pytest

from repro.core.events import Kind
from repro.core.expectations import PYTHON_BOX, distance_from_expectation
from repro.core.localizer import Localizer
from repro.core.patterns import summarize_worker
from repro.core.events import FunctionEvent, SampleStream, WorkerProfile
from repro.core.ring import RingConfig, ring_utilization


def test_distance_from_expectation_box():
    assert distance_from_expectation(np.array([0.005, 0.5, 0.5]),
                                     PYTHON_BOX) == 0.0
    assert distance_from_expectation(np.array([0.11, 0.5, 0.5]),
                                     PYTHON_BOX) == pytest.approx(0.10)


def _mk(pats):
    return {"f": np.asarray(pats, np.float32)}, {"f": Kind.GPU}


def test_differential_outlier_flagged():
    W = 64
    pats = np.tile(np.array([0.5, 0.9, 0.05], np.float32), (W, 1))
    pats[7] = [0.9, 0.3, 0.05]     # slow worker: high beta, low util
    loc = Localizer()
    abn = loc.localize(*_mk(pats))
    assert len(abn) == 1
    assert abn[0].workers.tolist() == [7]
    assert "differential" in abn[0].reason


def test_homogeneous_fleet_clean():
    W = 64
    rng = np.random.default_rng(0)
    pats = np.tile(np.array([0.5, 0.9, 0.05], np.float32), (W, 1))
    pats += rng.normal(0, 0.005, pats.shape).astype(np.float32)
    loc = Localizer()
    assert loc.localize(*_mk(pats)) == []


def test_beta_gate():
    W = 32
    pats = np.tile(np.array([0.005, 0.9, 0.05], np.float32), (W, 1))
    pats[3] = [0.009, 0.1, 0.5]    # weird but negligible function
    loc = Localizer()
    assert loc.localize(*_mk(pats)) == []


def test_expectation_flagged_on_all_workers():
    W = 32
    pats = np.tile(np.array([0.2, 0.4, 0.05], np.float32), (W, 1))
    patterns = {"dataloader": pats}
    kinds = {"dataloader": Kind.PYTHON}
    abn = Localizer().localize(patterns, kinds)
    assert len(abn) == 1 and len(abn[0].workers) == W
    assert "expectation" in abn[0].reason


# -- §3 ring example: the three (mu, sigma) signatures -----------------------

def ring_patterns(slow_worker=None, rho=0.5):
    cfg = RingConfig(n_workers=8, n_rings=1, stage_s=0.02, noise=0.01)
    traces = ring_utilization(cfg, 2.0, 2000.0, slow_worker=slow_worker,
                              rho=rho, rng=np.random.default_rng(1))
    pats = []
    for w in range(cfg.n_workers):
        # comm occupies 25% of the window: inside the COMM expected box, so
        # only the DIFFERENTIAL path can flag workers
        prof = WorkerProfile(
            worker=w, window=(0.0, 2.0),
            events=[FunctionEvent("AllReduce_RING", Kind.COMM, 0.0, 0.5, w)],
            streams={"pcie_tx": SampleStream(2000.0, 0.0, traces[w])})
        pats.append(summarize_worker(prof)["AllReduce_RING"].as_array())
    return np.stack(pats)


def test_ring_healthy_full_throughput():
    pats = ring_patterns(None)
    assert (pats[:, 1] > 0.9).all()          # mu ~ max (Fig. 5a)


def test_ring_slow_link_signatures():
    rho = 0.5
    pats = ring_patterns(slow_worker=3, rho=rho)
    mu, sigma = pats[:, 1], pats[:, 2]
    # every worker's mean drops to ~rho (Fig. 5b/5c)
    assert (np.abs(mu - rho) < 0.15).all()
    # the slow-link worker is STABLE; everyone else fluctuates (Fig. 5)
    assert sigma[3] < 0.1
    others = np.delete(sigma, 3)
    assert (others > 3 * sigma[3]).all()


def test_ring_localizer_picks_slow_worker():
    pats = ring_patterns(slow_worker=3)
    patterns = {"AllReduce_RING": pats.astype(np.float32)}
    kinds = {"AllReduce_RING": Kind.COMM}
    abn = Localizer().localize(patterns, kinds)
    assert len(abn) == 1
    assert 3 in abn[0].workers.tolist()
    # paper §4.3: uniqueness, not raw distance — the stable slow worker is
    # the unique one even though fluctuating workers are "far" in L1 too
    assert len(abn[0].workers) <= 2
