"""ISSUE 6: sharded collector tree + wire control plane (DESIGN.md §10).

Coverage:

  * fleet-derived frame caps (``max_frame_bytes``) and oversize-frame
    rejection at the derived cap;
  * client reconnect-with-backoff across a collector restart, with the
    ``reconnects`` counter surfacing in transport reports;
  * the authenticated hello: matching tokens pass, mismatched/missing
    tokens are rejected, logged, and never reach the collector;
  * control-plane expected-set re-keying (``set_expected`` /
    ``window_start`` membership) down the tree;
  * shard-level failure modes at the root: duplicate shard frames deduped,
    a whole lost rack bounded by the window timeout and surfaced in
    ``missing_shards`` and the report;
  * byte-parity of tree-mode diagnosis against the flat wire mode across
    the six-fault matrix.
"""
import os

import numpy as np
import pytest

from repro.core.daemon import PerfTrackerDaemon, summarize_and_upload
from repro.core.events import FunctionEvent, Kind, SampleStream, WorkerProfile
from repro.core.report import format_transport
from repro.core.service import PerfTrackerService
from repro.core.simulation import FleetSimulator, SimConfig
from repro.transport import (CollectorTree, DaemonServer, FrameDecoder,
                             ShardCollector, WindowCollector, WireClient,
                             compact_shard, encode_frame, framing,
                             max_frame_bytes)
from tests.test_fleet import SCENARIOS, assert_identical


def _upload(worker, beta=0.5):
    """A tiny real PatternUpload."""
    n = 64
    prof = WorkerProfile(
        worker=worker, window=(0.0, 1.0),
        events=[FunctionEvent("matmul", Kind.GPU, 0.0, beta, worker)],
        streams={"gpu_sm": SampleStream(n / 1.0, 0.0, np.full(n, 0.8))})
    return summarize_and_upload(prof, backend="numpy")


def _end_msg(window, worker):
    return {"t": "window_end", "window": window, "worker": worker,
            "sent": 1, "dropped": 0}


def _profiles(W, faults=(), seed=7):
    sim = FleetSimulator(SimConfig(n_workers=W, window_s=1.0, rate_hz=1000,
                                   seed=seed), list(faults))
    return sim.profile_window()


# -- fleet-derived frame cap (satellite a) ------------------------------------

def test_max_frame_bytes_scales_with_fleet():
    # small fleets keep the 16 MB default floor
    assert max_frame_bytes(None) == framing.MAX_FRAME_BYTES
    assert max_frame_bytes(16) == framing.MAX_FRAME_BYTES
    # past ~960 workers a full-width shard frame outgrows the default:
    # the cap follows the fleet
    assert max_frame_bytes(1024) == (framing.FRAME_OVERHEAD_BYTES
                                     + 1024 * framing.PER_WORKER_FRAME_BYTES)
    assert max_frame_bytes(1024) > framing.MAX_FRAME_BYTES
    assert max_frame_bytes(2048) > max_frame_bytes(1024)


def test_oversized_frame_rejected_at_derived_cap():
    over_default = framing.MAX_FRAME_BYTES + 1
    # a length the DEFAULT cap rejects...
    with pytest.raises(ValueError):
        list(FrameDecoder().feed(over_default.to_bytes(4, "big") + b"x"))
    # ...is admitted once the cap is derived for a W=1024 fleet
    list(FrameDecoder(max_frame=max_frame_bytes(1024))
         .feed(over_default.to_bytes(4, "big")))
    # explicit caps reject at both ends of the wire
    with pytest.raises(ValueError):
        encode_frame({"t": "upload", "payload": b"x" * 2048},
                     max_frame=1024)
    big = encode_frame({"t": "upload", "payload": b"x" * 2048})
    with pytest.raises(ValueError):
        list(FrameDecoder(max_frame=1024).feed(big))


# -- reconnect with backoff (satellite b) -------------------------------------

@pytest.mark.timeout(60)
def test_client_reconnects_after_collector_restart(tmp_path):
    path = str(tmp_path / "collector.sock")
    collector = WindowCollector([0])
    server = DaemonServer(collector, address=path).start()
    client = WireClient(path, worker=0, reconnect_max=100,
                        reconnect_backoff_s=0.01,
                        reconnect_backoff_max_s=0.05)
    try:
        client.send_upload(0, _upload(0))
        client.end_window(0)
        assert collector.wait_window(0, timeout=10.0).present == [0]
        # collector restart: same path, fresh server
        server.stop()
        if os.path.exists(path):
            os.unlink(path)
        server2 = DaemonServer(collector, address=path).start()
        try:
            assert server2.wait_connections(1, timeout=20.0), \
                "client never re-dialed the restarted collector"
            client.send_upload(1, _upload(0))
            client.end_window(1)
            batch = collector.wait_window(1, timeout=10.0)
        finally:
            server2.stop()
    finally:
        client.close()
        server.stop()
    assert batch.present == [0] and not batch.timed_out
    assert client.reconnects == 1
    # the counter rides window_end into the batch stats and the report line
    assert batch.reconnects == 1
    assert "reconnects=1" in format_transport(batch.stats())


def test_client_reconnect_gives_up_after_max_attempts():
    collector = WindowCollector([0])
    server = DaemonServer(collector).start()
    client = WireClient(server.address, worker=0, reconnect_max=2,
                        reconnect_backoff_s=0.01,
                        reconnect_backoff_max_s=0.02)
    try:
        server.stop()                        # endpoint gone for good
        client.send_upload(0, _upload(0))
        client.end_window(0)
        client._thread.join(timeout=20.0)
        assert not client._thread.is_alive()
        assert any("reconnect failed after 2 attempts" in e
                   for e in client.errors)
        assert client.reconnects == 0
    finally:
        client.close()


# -- authenticated hello (satellite c) ----------------------------------------

def test_auth_token_matching_passes():
    collector = WindowCollector([0])
    with DaemonServer(collector, auth_token="s3cret") as server:
        client = WireClient(server.address, 0, auth_token="s3cret")
        try:
            client.send_upload(0, _upload(0))
            client.end_window(0)
            batch = collector.wait_window(0, timeout=10.0)
        finally:
            client.close()
        assert server.auth_rejected == 0
    assert batch.present == [0] and not batch.timed_out


def test_auth_token_mismatched_and_missing_rejected(tmp_path):
    log = str(tmp_path / "wire.log")
    collector = WindowCollector([0, 1])
    with DaemonServer(collector, auth_token="s3cret",
                      log_path=log) as server:
        bad = WireClient(server.address, 0, auth_token="wrong",
                         reconnect_max=1, reconnect_backoff_s=0.01,
                         reconnect_backoff_max_s=0.02)
        missing = WireClient(server.address, 1,
                             reconnect_max=1, reconnect_backoff_s=0.01,
                             reconnect_backoff_max_s=0.02)
        try:
            bad.send_upload(0, _upload(0))
            bad.end_window(0)
            missing.send_upload(0, _upload(1))
            missing.end_window(0)
            batch = collector.wait_window(0, timeout=1.0)
        finally:
            bad.close()
            missing.close()
        assert server.auth_rejected >= 2
    # nothing from either client ever reached the collector
    assert batch.timed_out and batch.present == []
    with open(log) as f:
        assert "auth rejected" in f.read()


# -- control plane: expected-set re-keying ------------------------------------

def test_set_expected_completes_open_batches():
    coll = WindowCollector([0, 1, 2])
    for w in (0, 1):
        coll.on_message(framing.upload_msg(0, _upload(w), 0))
        coll.on_message(_end_msg(0, w))
    # worker 2 was replaced out of the mesh: the OPEN window re-keys too
    coll.set_expected([0, 1])
    batch = coll.wait_window(0, timeout=5.0)
    assert not batch.timed_out and batch.complete
    assert batch.present == [0, 1] and batch.missing == []


@pytest.mark.timeout(120)
def test_window_start_membership_rekeys_tree():
    W, gone = 6, 3
    profiles = _profiles(W)
    members = [w for w in range(W) if w != gone]
    with CollectorTree(range(W), 2) as tree:
        daemons = {w: PerfTrackerDaemon(w, tree.address_of(w),
                                        backend="numpy") for w in members}
        try:
            tree.wait_connections(len(members))
            tree.broadcast(framing.window_start_msg(0, None,
                                                    membership=members))
            for w, d in daemons.items():
                d.process_window(0, profiles[w])
            batch = tree.wait_window(0, timeout=30.0)
        finally:
            for d in daemons.values():
                d.close()
    # the absent worker is OUT OF THE MESH, not missing: both the leaf
    # owning it and the root stopped expecting it
    assert not batch.timed_out and batch.complete
    assert batch.present == members and batch.missing == []
    assert gone not in batch.expected


# -- shard-level failure modes (satellite d) ----------------------------------

def _shard_frame(shard, workers, window=0):
    coll = WindowCollector(workers)
    for w in workers:
        coll.on_message(framing.upload_msg(window, _upload(w), 0))
        coll.on_message(_end_msg(window, w))
    return compact_shard(shard, coll.wait_window(window, timeout=5.0))


def test_shard_collector_dedups_duplicate_shard_frames():
    sc = ShardCollector({0: (0, 1), 1: (2, 3)})
    f0 = _shard_frame(0, (0, 1))
    sc.on_message(f0)
    sc.on_message(dict(f0))              # replayed shard frame
    sc.on_message(_shard_frame(1, (2, 3)))
    batch = sc.wait_window(0, timeout=5.0)
    assert not batch.timed_out
    assert batch.duplicate_shards == 1 and sc.total_duplicate_shards == 1
    assert len(batch.shards) == 2
    assert batch.present == [0, 1, 2, 3]
    assert "duplicate_shards=1" in format_transport(batch.stats())


def test_shard_collector_reports_lost_rack():
    sc = ShardCollector({0: (0, 1), 1: (2, 3)})
    sc.on_message(_shard_frame(0, (0, 1)))
    batch = sc.wait_window(0, timeout=0.3)
    assert batch.timed_out
    assert batch.missing_shards == [1]
    assert batch.present == [0, 1] and batch.missing == [2, 3]
    agg, present = batch.aggregate(4)
    np.testing.assert_array_equal(present, [True, True, False, False])
    pats, _ = agg.finalize()
    # the lost rack's rows stay zero (masked out of localization)
    assert pats and all(np.all(np.asarray(p)[[2, 3]] == 0)
                        for p in pats.values())


@pytest.mark.timeout(120)
def test_tree_survives_lost_rack_end_to_end():
    W = 9
    profiles = _profiles(W)
    with CollectorTree(range(W), 3, window_timeout=5.0) as tree:
        alive = [w for s in (0, 2) for w in tree.shard_workers[s]]
        tree.leaves[1].stop()            # the whole rack dies
        daemons = {w: PerfTrackerDaemon(w, tree.address_of(w),
                                        backend="numpy") for w in alive}
        try:
            tree.broadcast(framing.window_start_msg(0, None))
            for w, d in daemons.items():
                d.process_window(0, profiles[w])
            batch = tree.wait_window(0, timeout=3.0)
        finally:
            for d in daemons.values():
                d.close()
        lost = list(tree.shard_workers[1])
    assert batch.timed_out and batch.missing_shards == [1]
    assert batch.missing == lost and batch.present == alive
    res = PerfTrackerService().diagnose_batch(batch, fleet_size=W)
    assert "collector tree 2/3 shards reported" in res.report()
    assert "missing_shards=[1]" in res.report()


# -- six-fault matrix: tree mode is byte-identical to flat wire mode ----------

@pytest.mark.timeout(120)
@pytest.mark.parametrize("faults,expect,kind", SCENARIOS)
def test_tree_mode_matches_flat_wire_mode(faults, expect, kind):
    W = 16
    profiles = _profiles(W, faults)
    flat = PerfTrackerService(summarize_backend="numpy").diagnose_profiles(
        profiles, mode="wire")
    with CollectorTree(range(W), 4) as tree:
        daemons = [PerfTrackerDaemon(p.worker, tree.address_of(p.worker),
                                     backend="numpy") for p in profiles]
        try:
            tree.wait_connections(W)
            tree.broadcast(framing.window_start_msg(0, None))
            for d, p in zip(daemons, profiles):
                d.process_window(0, p)
            batch = tree.wait_window(0, timeout=30.0)
        finally:
            for d in daemons:
                d.close()
    assert not batch.timed_out
    assert batch.missing == [] and batch.missing_shards == []
    treed = PerfTrackerService().diagnose_batch(batch, fleet_size=W)
    assert any(expect in f for f in treed.functions())
    assert treed.diagnoses[0].abnormality.kind == kind
    assert_identical(treed, flat)
