PY ?= python

.PHONY: test deps bench bench-summarize bench-fleet

deps:
	$(PY) -m pip install -r requirements-dev.txt

# tier-1 verify (ROADMAP.md)
test:
	PYTHONPATH=src $(PY) -m pytest -x -q

bench:
	PYTHONPATH=src:. $(PY) benchmarks/run.py

bench-summarize:
	PYTHONPATH=src:. $(PY) benchmarks/run.py --only summarize_backends

bench-fleet:
	PYTHONPATH=src:. $(PY) benchmarks/run.py --only fleet_diagnosis
