PY ?= python

.PHONY: test deps lint bench bench-summarize bench-fleet bench-online \
        bench-gate bench-gate-update

deps:
	$(PY) -m pip install -r requirements-dev.txt

# tier-1 verify (ROADMAP.md)
test:
	PYTHONPATH=src $(PY) -m pytest -x -q

lint:
	ruff check .

bench:
	PYTHONPATH=src:. $(PY) benchmarks/run.py

bench-summarize:
	PYTHONPATH=src:. $(PY) benchmarks/run.py --only summarize_backends

bench-fleet:
	PYTHONPATH=src:. $(PY) benchmarks/run.py --only fleet_diagnosis

bench-online:
	PYTHONPATH=src:. $(PY) benchmarks/run.py --only online_pipeline

# the CI benchmark-regression gate: run the three gated benchmarks with the
# CI-pinned sizes, emit machine-readable results, compare against the
# committed baselines (benchmarks/baselines.json)
GATE_MODULES = summarize_backends,fleet_diagnosis,online_pipeline
GATE_ENV = REPRO_BENCH_FLEET_SIZES=8
GATE_JSON ?= reports/bench.json

bench-gate:
	mkdir -p $(dir $(GATE_JSON))
	$(GATE_ENV) PYTHONPATH=src:. $(PY) benchmarks/run.py \
	    --only $(GATE_MODULES) --json $(GATE_JSON)
	$(PY) benchmarks/check_regression.py $(GATE_JSON) --require-all

# after an INTENTIONAL perf change: refresh baseline values and commit
bench-gate-update:
	mkdir -p $(dir $(GATE_JSON))
	$(GATE_ENV) PYTHONPATH=src:. $(PY) benchmarks/run.py \
	    --only $(GATE_MODULES) --json $(GATE_JSON)
	$(PY) benchmarks/check_regression.py $(GATE_JSON) --update
