PY ?= python

.PHONY: test test-wire test-train test-serve test-cov deps lint bench \
        bench-summarize bench-fleet bench-online bench-wire \
        bench-mitigation bench-tree bench-overhead bench-scenarios \
        bench-serve bench-goodput bench-gate bench-gate-update

deps:
	$(PY) -m pip install -r requirements-dev.txt

# tier-1 verify (ROADMAP.md)
test:
	PYTHONPATH=src $(PY) -m pytest -x -q

# multi-process wire-transport integration tests only (the CI `wire` job);
# per-test timeouts via pytest-timeout so a hung socket cannot wedge CI
test-wire:
	PYTHONPATH=src $(PY) -m pytest -q -m wire --timeout=300

# real-trainer workload tests only (the CI `train` job): jit-compiled
# training loops, live fault scenarios, multi-process socket integration
test-train:
	PYTHONPATH=src $(PY) -m pytest -q -m train --timeout=600

# real-serving workload tests only (the CI `serve` job): jit-compiled
# decode loops + live latency-SLO fault scenarios (DESIGN.md §13)
test-serve:
	PYTHONPATH=src $(PY) -m pytest -q -m serve --timeout=600

# the committed coverage floor: `make test-cov` fails if total line
# coverage of src/repro drops below it.  Raise it when coverage improves;
# never lower it to make a PR pass.
COV_FLOOR ?= 60

test-cov:
	PYTHONPATH=src $(PY) -m pytest -q --cov=repro --cov-report=xml \
	    --cov-report=term-missing:skip-covered
	$(PY) -m coverage report --fail-under=$(COV_FLOOR) > /dev/null \
	    || { echo "FAIL: total coverage below floor ($(COV_FLOOR)%)"; \
	         exit 1; }

lint:
	ruff check .

bench:
	PYTHONPATH=src:. $(PY) benchmarks/run.py

bench-summarize:
	PYTHONPATH=src:. $(PY) benchmarks/run.py --only summarize_backends

bench-fleet:
	PYTHONPATH=src:. $(PY) benchmarks/run.py --only fleet_diagnosis

bench-online:
	PYTHONPATH=src:. $(PY) benchmarks/run.py --only online_pipeline

bench-wire:
	PYTHONPATH=src:. $(PY) benchmarks/run.py --only wire_transport

bench-mitigation:
	PYTHONPATH=src:. $(PY) benchmarks/run.py --only mitigation_loop

# sharded collector tree vs flat at W=1024 (ISSUE 6); needs a few minutes
# and ~3k file descriptors (the bench raises its own RLIMIT_NOFILE)
bench-tree:
	PYTHONPATH=src:. $(PY) benchmarks/run.py --only collector_tree

# tracer overhead on the real instrumented training loop (ISSUE 7); the
# gate is the declared budget (REPRO_TRAIN_OVERHEAD_BUDGET_PCT)
bench-overhead:
	PYTHONPATH=src:. $(PY) benchmarks/run.py --only train_overhead

# the full gated fault-scenario matrix (ISSUE 8, DESIGN.md §12): runs
# every catalog scenario through the closed loop, prints + writes the
# per-scenario markdown table (reports/scenario-matrix.md), exits
# non-zero when any scenario misses its declared expectations
bench-scenarios:
	PYTHONPATH=src:. $(PY) benchmarks/scenario_table.py

# the serving latency-SLO matrix (ISSUE 9, DESIGN.md §13): the serve
# fault class through the closed loop, per-expectation windows-to-resolve
bench-serve:
	PYTHONPATH=src:. $(PY) benchmarks/run.py --only serve_slo

# the goodput / recovery-economics matrix (ISSUE 10, DESIGN.md §14):
# every catalog scenario scored in windows of goodput lost from injection
# to verified recovery (rollback restore cost included) plus the chronic
# restart pair; writes the per-scenario table to reports/goodput.md
bench-goodput:
	PYTHONPATH=src:. $(PY) benchmarks/run.py --only goodput

# the CI benchmark-regression gate: run the gated benchmarks with the
# CI-pinned sizes, emit machine-readable results, compare against the
# committed baselines (benchmarks/baselines.json)
GATE_MODULES = summarize_backends,fleet_diagnosis,online_pipeline,wire_transport,mitigation_loop,serve_slo,collector_tree,train_overhead,ability_matrix,goodput
GATE_ENV = REPRO_BENCH_FLEET_SIZES=8
GATE_JSON ?= reports/bench.json

bench-gate:
	mkdir -p $(dir $(GATE_JSON))
	$(GATE_ENV) PYTHONPATH=src:. $(PY) benchmarks/run.py \
	    --only $(GATE_MODULES) --json $(GATE_JSON)
	$(PY) benchmarks/check_regression.py $(GATE_JSON) --require-all

# after an INTENTIONAL perf change: refresh baseline values and commit
bench-gate-update:
	mkdir -p $(dir $(GATE_JSON))
	$(GATE_ENV) PYTHONPATH=src:. $(PY) benchmarks/run.py \
	    --only $(GATE_MODULES) --json $(GATE_JSON)
	$(PY) benchmarks/check_regression.py $(GATE_JSON) --update
