"""ISSUE 10: goodput accounting — the recovery ECONOMICS of every catalog
scenario (DESIGN.md §14).

The ability matrix answers "was the fault resolved correctly"; this module
answers "what did the fault COST".  Every catalog scenario runs the closed
loop under the standard deployment shape and is scored in the currency
that matters to a training job: windows of goodput lost from fault
injection to verified recovery, the iterations that bought nothing
(degraded windows plus the steps a real rollback discarded), and the
wall-clock restore cost.  Rollback scenarios must restore REAL on-disk
state — a verified step installed from a checkpoint, never a label flip —
and the matrix row pins that.

The chronic pair measures the memory dividend: the same fault run twice
against one shared ``IncidentHistory`` store.  Run 1 learns the hard way
(wrong rung first, one escalation); run 2 — a "restarted job" — must
recognize the signature, start the ladder at the rung that worked, and
resolve with zero escalations (``rung_hit=Y``, the gated flag).

Row families for the regression gate (benchmarks/baselines.json):
  * ``goodput/<scenario>``   — value = mean windows lost (injection to
    verified recovery) over the scenario's resolved expectations (-1 when
    none resolve, e.g. the bad-standby family); derived carries
    class/lost_iters/lost_steps/restore_s/ok (+ restored for scenarios
    whose ladder executed a rollback);
  * ``goodput/class_<class>`` — per-class mean windows lost (the gated
    goodput ceiling, deterministic seeded quantities);
  * ``goodput/matrix``        — value = scenarios run; ``restored=Y`` iff
    every executed rollback across the catalog installed a verified
    on-disk step (and at least one ran); ``ok`` = every expectation met;
  * ``goodput/chronic``       — value = windows lost by the restarted
    run; ``rung_hit=Y`` iff it started at the remembered rung and
    resolved with zero escalations.

Env knobs (CI smoke shrink, see tests/test_benchmarks_smoke.py):
  * ``REPRO_BENCH_GOODPUT_SCENARIOS`` — comma-separated catalog scenario
    names (default: the whole catalog).

Writes the per-scenario goodput table to ``reports/goodput.md``.
"""
from __future__ import annotations

import os
import tempfile
from typing import Dict, List

import numpy as np


def _yn(flag: bool) -> str:
    return "Y" if flag else "N"


def _scenario_rows(md: List[str]) -> List[tuple]:
    from repro.core.mitigation import Action
    from repro.online.catalog import (FAULT_CLASSES, INJECT, SCENARIOS,
                                      by_name, evaluate, run_scenario)
    sel = [s.strip() for s in
           os.environ.get("REPRO_BENCH_GOODPUT_SCENARIOS", "").split(",")
           if s.strip()]
    scenarios = [by_name(n) for n in sel] if sel else list(SCENARIOS)

    rows: List[tuple] = []
    cls_lost: Dict[str, List[int]] = {}
    cls_ok: Dict[str, bool] = {}
    cls_n: Dict[str, int] = {}
    all_ok = True
    rollbacks_run = rollbacks_restored = 0
    for sc in scenarios:
        runner, res = run_scenario(sc)
        ev = evaluate(sc, runner, res)
        ok = all(r["ok"] for r in ev)
        all_ok &= ok
        # real-state cost of every rollback the ladder executed
        rb = [m for m in runner.engine.log
              if m.plan.action is Action.ROLLBACK_TO_CHECKPOINT]
        restored = [m for m in rb
                    if m.restored_step is not None and m.rollback_verified
                    and not m.rollback_failed]
        rollbacks_run += len(rb)
        rollbacks_restored += len(restored)
        lost_steps = sum(m.lost_steps for m in rb)
        restore_s = sum(m.restore_s for m in rb)
        # windows of goodput lost: fault injection -> verified recovery,
        # per resolved expectation (escalation contracts have no recovery)
        lost_w: List[int] = []
        for exp, r in zip(sc.expect, ev):
            if exp.outcome != "resolved" or not r["resolved"]:
                continue
            inc = next(i for i in res.incidents
                       if i.function == exp.function
                       and i.channel == exp.channel)
            lost_w.append(res.window_of(inc.resolved_at) - INJECT)
        value = float(np.mean(lost_w)) if lost_w else -1.0
        # iterations that bought nothing: every iteration of a degraded
        # window plus the steps the rollback honestly discarded
        lost_iters = int((sum(lost_w) + lost_steps)
                         * runner.iters_per_window)
        derived = (f"class={sc.fault_class};lost_iters={lost_iters};"
                   f"lost_steps={lost_steps};restore_s={restore_s:.4f};"
                   f"ok={_yn(ok)}")
        if rb:
            derived += f";restored={_yn(len(restored) == len(rb))}"
        rows.append((f"goodput/{sc.name}", value, derived))
        md.append(f"| {sc.name} | {sc.fault_class} | {value:.1f} "
                  f"| {lost_iters} | {lost_steps} | {restore_s:.4f} "
                  f"| {_yn(bool(rb) and len(restored) == len(rb))} "
                  f"| {_yn(ok)} |")
        cls_lost.setdefault(sc.fault_class, []).extend(lost_w)
        cls_ok[sc.fault_class] = cls_ok.get(sc.fault_class, True) and ok
        cls_n[sc.fault_class] = cls_n.get(sc.fault_class, 0) + 1
    for cls in FAULT_CLASSES:
        if cls not in cls_n:
            continue
        lw = cls_lost.get(cls, [])
        rows.append((
            f"goodput/class_{cls}",
            float(np.mean(lw)) if lw else -1.0,
            f"ok={_yn(cls_ok[cls])};scenarios={cls_n[cls]}"))
    # a rollback matrix with zero rollbacks would be a vacuous green
    restored_ok = rollbacks_run > 0 and rollbacks_restored == rollbacks_run
    rows.append((
        "goodput/matrix", float(len(scenarios)),
        f"ok={_yn(all_ok)};restored={_yn(restored_ok)};"
        f"rollbacks={rollbacks_run};scenarios={len(scenarios)}"))
    return rows


def _chronic_rows(md: List[str]) -> List[tuple]:
    """The same fault twice, one shared history store: the restarted run
    must start at the rung that worked and skip the failed-verification
    cycle run 1 paid for."""
    from repro.core import faults as F
    from repro.core.mitigation import Action
    from repro.core.simulation import GEMM, SimConfig
    from repro.online import (EscalationPolicy, ScenarioRunner,
                              ScheduledFault)
    from repro.online.catalog import (BASE_HZ, FULL_HZ, INJECT, N_STANDBY,
                                      N_WINDOWS, SEED, W, WINDOW_S)
    from repro.online.history import IncidentHistory

    def one_run(path):
        # the cure is FLAG_CODE, but the GEMM ladder tries REPLACE_HOSTS
        # first — run 1 must fail a verification cycle to learn that
        esc = EscalationPolicy(n_workers=W + N_STANDBY,
                               base_rate_hz=BASE_HZ, full_rate_hz=FULL_HZ,
                               max_escalated=max(4, W // 16))
        runner = ScenarioRunner(
            SimConfig(n_workers=W, window_s=WINDOW_S, rate_hz=FULL_HZ,
                      seed=SEED, n_standby=N_STANDBY),
            [ScheduledFault(F.GpuThrottle(workers=(3, W // 2 + 1)),
                            INJECT, N_WINDOWS,
                            cures=(Action.FLAG_CODE,))],
            n_windows=N_WINDOWS, escalation=esc, mitigation=True,
            history=IncidentHistory(path))
        res = runner.run()
        inc = next(i for i in res.incidents if i.function == GEMM)
        lost = (res.window_of(inc.resolved_at) - INJECT
                if inc.state == "resolved" else -1)
        first = next((m.plan.action for m in runner.engine.log
                      if m.incident_id == inc.id), None)
        return inc, lost, first

    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "history.jsonl")
        inc1, lost1, first1 = one_run(path)
        inc2, lost2, first2 = one_run(path)
    learned = (inc1.state == "resolved" and inc1.escalations >= 1
               and not inc1.chronic)
    rung_hit = (learned and inc2.state == "resolved" and inc2.chronic
                and inc2.escalations == 0
                and first2 is Action.FLAG_CODE)
    md.append(f"| chronic_restart | perf | {float(lost2):.1f} | - | - | - "
              f"| - | {_yn(rung_hit)} |")
    return [(
        "goodput/chronic", float(lost2),
        f"rung_hit={_yn(rung_hit)};chronic={_yn(inc2.chronic)};"
        f"escalations_run1={inc1.escalations};"
        f"escalations_run2={inc2.escalations};"
        f"windows_saved={lost1 - lost2 if lost1 >= 0 and lost2 >= 0 else 0}"
    )]


def run():
    md = [
        "### Goodput matrix (ISSUE 10, DESIGN.md §14)",
        "",
        "| scenario | class | lost windows | lost iters | lost steps "
        "| restore s | restored | ok |",
        "|---|---|---|---|---|---|---|---|",
    ]
    rows = _scenario_rows(md) + _chronic_rows(md)
    os.makedirs("reports", exist_ok=True)
    with open("reports/goodput.md", "w") as f:
        f.write("\n".join(md) + "\n")
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
