"""ISSUE 9: the serving latency-SLO matrix — windows-to-resolution across
the serve fault class (DESIGN.md §13).

Runs the catalog's serving slice (``fault_class == 'serve'``) under the
standard deployment shape with the mitigation loop closed: every SLO
incident must open on the ``slo`` channel, localize to the declared
serving function, and resolve through the serving playbook's ladder
(``SHED_LOAD`` / ``DRAIN_AND_REPLACE``) with zero escalations.  Per
scenario::

    serve_slo/<scenario>,  max windows from plan application to resolved
                           across the scenario's expectations,
                           ok=Y/N;expectations=n;plans=<actions>

plus an aggregate row::

    serve_slo/matrix,  mean windows-to-resolution,
                       ok=Y iff every expectation of every scenario met

Everything is deterministic (seeded simulator, fixed schedule), so the
CI gate pins a windows-to-resolution CEILING per scenario and the matrix
``ok`` flag (benchmarks/baselines.json).

Env knobs (CI smoke): ``REPRO_BENCH_SERVE_SCENARIOS`` (comma-separated
scenario names, default the whole serve class).
"""
from __future__ import annotations

import os


def _scenarios():
    from repro.online.catalog import SCENARIOS
    serve = [s for s in SCENARIOS if s.fault_class == "serve"]
    only = [c for c in os.environ.get("REPRO_BENCH_SERVE_SCENARIOS",
                                      "").split(",") if c]
    return [s for s in serve if not only or s.name in only]


def run():
    from repro.online.catalog import evaluate, run_scenario
    rows = []
    all_ok = True
    resolutions = []
    for sc in _scenarios():
        runner, res = run_scenario(sc)
        scored = evaluate(sc, runner, res)
        sc_ok = all(bool(r["ok"]) for r in scored) and bool(scored)
        wtrs = [r["wtr"] for r in scored if r["wtr"] is not None]
        resolutions += wtrs if sc_ok else []
        all_ok = all_ok and sc_ok
        rows.append((
            f"serve_slo/{sc.name}",
            max(wtrs) if sc_ok and wtrs else float("nan"),
            f"max_windows_to_resolve;ok={'Y' if sc_ok else 'N'};"
            f"expectations={len(scored)};"
            f"plans={'+'.join(r['first_action'] or 'none' for r in scored)}"))
    mean_wtr = (sum(resolutions) / len(resolutions)
                if resolutions else float("nan"))
    # an empty scenario filter (a typo in REPRO_BENCH_SERVE_SCENARIOS)
    # must not report a vacuous green matrix
    all_ok = all_ok and bool(resolutions)
    rows.append((
        "serve_slo/matrix", mean_wtr,
        f"mean_windows_to_resolve;ok={'Y' if all_ok else 'N'};"
        f"expectations={len(resolutions)}"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us},{derived}")
