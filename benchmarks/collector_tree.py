"""ISSUE 6: sharded collector tree vs flat single collector (DESIGN.md §10).

Measures the W=1024 upload path end-to-end to DIAGNOSIS-READY patterns —
window assembly plus aggregation into the full-width ``(W, F, 3)`` buffer
— under both topologies:

  * ``flat``: W ``WireClient``s -> one ``DaemonServer``/``WindowCollector``;
    the collector process ingests 2xW frames per window and unpacks W
    msgpack payloads serially after assembly;
  * ``tree``: W clients -> ``N_SHARDS`` leaf collectors, each a REAL
    spawned process (``leaf_process_main`` — the deployed shape: one
    rack-local collector per host) -> root ``ShardCollector``; racks
    decode + compact in parallel across the leaf processes and the root
    ingests N_SHARDS compacted frames per window and block-scatters them.

Rows::

    tree/collect_W<W>_S<S>,  us per diagnosis-ready window (tree),
        throughput_wps=<tree windows/s>;flat_wps=<flat windows/s>;
        ratio_vs_flat=<tree/flat>;root_frames_per_window=<frames>;
        root_ingress_kb=<compacted KB/window>;flat_ingress_kb=<KB/window>;
        ingress_ratio=<flat_kb/root_kb>;parity=Y|N;delivered=Y|N

Gated metrics: ``ingress_ratio`` and ``root_frames_per_window`` pin the
deterministic, load-independent scaling win — the root ingests O(shards)
compacted frames (~6x fewer bytes) instead of 2xW raw frames per window;
``parity`` pins tree-mode aggregation byte-identical to the flat
scatter; ``delivered`` pins losslessness (every worker, every window, no
dups, no timeouts).  ``ratio_vs_flat`` is gated only with a wide floor:
end-to-end windows/s is dominated by the single parent fanning 2xW
frames out through W clients, and on a 1-core runner the leaf processes
cannot run in parallel, so the extra rack hop costs latency that
multi-core hosts win back via parallel shard decode.

Env knobs (CI smoke): ``REPRO_BENCH_TREE_W`` (default 1024),
``REPRO_BENCH_TREE_SHARDS`` (default 8), ``REPRO_BENCH_TREE_WINDOWS``
(default 4).
"""
from __future__ import annotations

import os
import time

import numpy as np

W = int(os.environ.get("REPRO_BENCH_TREE_W", "1024"))
N_SHARDS = int(os.environ.get("REPRO_BENCH_TREE_SHARDS", "8"))
N_WINDOWS = int(os.environ.get("REPRO_BENCH_TREE_WINDOWS", "4"))
N_FUNCTIONS = 40          # ~KB payload per upload, like the paper's Fig. 11


def _raise_nofile() -> None:
    """W=1024 needs ~3 fds per client plus the server side; lift the soft
    RLIMIT_NOFILE to the hard cap so CI runners with a 1024 default don't
    die in accept()."""
    try:
        import resource
        soft, hard = resource.getrlimit(resource.RLIMIT_NOFILE)
        if soft < hard:
            resource.setrlimit(resource.RLIMIT_NOFILE, (hard, hard))
    except (ImportError, ValueError, OSError):
        pass


def _uploads():
    import msgpack
    from repro.core.daemon import PatternUpload
    rng = np.random.default_rng(0)
    out = []
    for w in range(W):
        payload = msgpack.packb({
            f"train.py:train_loop/module_{i}.py:forward_{i}": (
                float(rng.uniform(0, 0.5)), float(rng.uniform(0, 1)),
                float(rng.uniform(0, 0.2)), int(i % 4))
            for i in range(N_FUNCTIONS)})
        out.append(PatternUpload(worker=w, payload=payload,
                                 summarize_s=0.0, raw_bytes=1 << 20))
    return out


def _dial(address, worker, max_frame, timeout=30.0):
    """Connect to a leaf socket, retrying while its process finishes
    binding (the root handshake normally guarantees it already has)."""
    from repro.transport import WireClient
    deadline = time.monotonic() + timeout
    while True:
        try:
            return WireClient(address, worker, max_frame=max_frame)
        except OSError:
            if time.monotonic() > deadline:
                raise
            time.sleep(0.05)


def _send_window(clients, uploads, window):
    for c, u in zip(clients, uploads):
        c.send_upload(window, u)
        c.end_window(window)


def _flat_phase(uploads, max_frame):
    """Flat topology: windows/s to diagnosis-ready patterns, plus the
    reference finalize() output for the parity check."""
    from repro.core.service import PerfTrackerService
    from repro.transport import DaemonServer, WindowCollector, WireClient
    svc = PerfTrackerService()
    collector = WindowCollector(range(W))
    delivered = True
    times = []
    reference = None
    with DaemonServer(collector, max_frame=max_frame) as server:
        clients = [WireClient(server.address, u.worker,
                              max_frame=max_frame) for u in uploads]
        try:
            _send_window(clients, uploads, -1)            # warmup
            collector.wait_window(-1, timeout=60.0)
            for i in range(N_WINDOWS):
                t0 = time.perf_counter()
                _send_window(clients, uploads, i)
                batch = collector.wait_window(i, timeout=60.0)
                agg, present = svc.aggregate_batch(batch.sorted_uploads(),
                                                   W)
                pats, kinds = agg.finalize()
                times.append(time.perf_counter() - t0)
                delivered &= (len(batch.uploads) == W
                              and batch.duplicates == 0
                              and not batch.timed_out)
                if reference is None:
                    reference = (pats, kinds, present)
        finally:
            for c in clients:
                c.close()
    return times, delivered, reference


def _tree_phase(uploads, max_frame):
    """Sharded topology: the same measurement with every rack collector in
    its own spawned process feeding the in-process root."""
    import multiprocessing as mp
    import shutil
    import tempfile

    from repro.transport import DaemonServer, ShardCollector, framing
    from repro.transport.tree import leaf_process_main

    slices = np.array_split(np.arange(W), N_SHARDS)
    shard_workers = {s: tuple(map(int, sl)) for s, sl in enumerate(slices)}
    collector = ShardCollector(shard_workers)
    root = DaemonServer(collector, max_frame=max_frame).start()
    ctx = mp.get_context("spawn")
    sock_dir = tempfile.mkdtemp(prefix="repro-tree-bench-")
    addr_of = {}
    procs = []
    for s, ws in shard_workers.items():
        leaf_addr = f"{sock_dir}/leaf{s}.sock"
        p = ctx.Process(target=leaf_process_main,
                        args=(s, ws, root.address, leaf_addr),
                        kwargs={"max_frame": max_frame,
                                "window_timeout": 60.0},
                        daemon=True)
        p.start()
        procs.append(p)
        for w in ws:
            addr_of[w] = leaf_addr
    delivered = True
    times = []
    first = None
    ingress_bytes = []
    try:
        # every leaf uplink must be live before the first broadcast
        if not root.wait_connections(N_SHARDS, timeout=60.0):
            raise RuntimeError("leaf processes never dialed the root")
        clients = [_dial(addr_of[u.worker], u.worker, max_frame)
                   for u in uploads]
        try:
            root.broadcast(framing.window_start_msg(-1, None))  # warmup
            _send_window(clients, uploads, -1)
            collector.wait_window(-1, timeout=60.0)
            for i in range(N_WINDOWS):
                t0 = time.perf_counter()
                root.broadcast(framing.window_start_msg(i, None))
                _send_window(clients, uploads, i)
                batch = collector.wait_window(i, timeout=60.0)
                agg, present = batch.aggregate(W)
                pats, kinds = agg.finalize()
                times.append(time.perf_counter() - t0)
                delivered &= (len(batch.present) == W
                              and batch.duplicates == 0
                              and batch.duplicate_shards == 0
                              and not batch.timed_out
                              and len(batch.shards) == N_SHARDS)
                ingress_bytes.append(
                    sum(len(m["rows"]) + sum(len(n) for n in m["names"])
                        for m in batch.shards.values()))
                if first is None:
                    first = (pats, kinds, present)
        finally:
            for c in clients:
                c.close()
        frames_per_window = (collector.total_shards - N_SHARDS) \
            / max(1, N_WINDOWS)
    finally:
        root.broadcast(framing.stop_msg())
        for p in procs:
            p.join(timeout=10.0)
            if p.is_alive():
                p.terminate()
        root.stop()
        shutil.rmtree(sock_dir, ignore_errors=True)
    return times, delivered, first, frames_per_window, ingress_bytes


def _parity(a, b) -> bool:
    """Byte-identical finalize() outputs (names, kinds, values, mask)."""
    (pa, ka, ma), (pb, kb, mb) = a, b
    if list(pa) != list(pb) or ka != kb or not np.array_equal(ma, mb):
        return False
    return all(np.array_equal(pa[n], pb[n]) for n in pa)


def run():
    from repro.transport import framing
    _raise_nofile()
    uploads = _uploads()
    max_frame = framing.max_frame_bytes(W)
    flat_times, flat_ok, reference = _flat_phase(uploads, max_frame)
    (tree_times, tree_ok, first,
     frames_per_window, ingress_bytes) = _tree_phase(uploads, max_frame)
    parity = reference is not None and first is not None \
        and _parity(first, reference)
    flat_wps = N_WINDOWS / sum(flat_times)
    tree_wps = N_WINDOWS / sum(tree_times)
    flat_kb = sum(len(u.payload) for u in uploads) / 1024.0
    root_kb = float(np.mean(ingress_bytes)) / 1024.0 if ingress_bytes \
        else float("nan")
    return [(f"tree/collect_W{W}_S{N_SHARDS}",
             float(np.median(tree_times)) * 1e6,
             f"throughput_wps={tree_wps:.2f};flat_wps={flat_wps:.2f};"
             f"ratio_vs_flat={tree_wps / flat_wps:.2f};"
             f"root_frames_per_window={frames_per_window:.1f};"
             f"root_ingress_kb={root_kb:.1f};flat_ingress_kb={flat_kb:.1f};"
             f"ingress_ratio={flat_kb / root_kb:.2f};"
             f"parity={'Y' if parity else 'N'};"
             f"delivered={'Y' if (flat_ok and tree_ok) else 'N'}")]


if __name__ == "__main__":
    for r in run():
        print(f"{r[0]},{r[1]:.1f},{r[2]}")
