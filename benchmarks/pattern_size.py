"""Fig. 11: runtime-behavior-pattern size vs raw profiling data per worker.
Paper: ~30 KB patterns vs ~3 GB raw (1e5 x). Our window is shorter and the
synthetic model smaller, so the ratio is what matters; we also extrapolate
to the paper's 20 s / 10 kHz / full-model setting."""
from __future__ import annotations

from repro.core.daemon import summarize_and_upload
from repro.core.simulation import FleetSimulator, SimConfig


def run():
    cfg = SimConfig(n_workers=2, window_s=2.0, rate_hz=2000)
    sim = FleetSimulator(cfg, [])
    prof = sim.profile_window()[0]
    up = summarize_and_upload(prof)
    raw = up.raw_bytes
    pat = len(up.payload)
    # extrapolate to paper scale: 20 s window, 10 kHz, ~4e9/10k events/s
    scale = (20.0 / cfg.window_s) * (10_000 / cfg.rate_hz)
    raw_paper = raw * scale
    rows = [
        ("pattern_size/raw_bytes", raw, f"window={cfg.window_s}s"),
        ("pattern_size/pattern_bytes", pat,
         f"ratio={raw/max(1,pat):.0f}x"),
        ("pattern_size/extrapolated_20s_10khz_raw_mb", raw_paper / 1e6,
         f"ratio={raw_paper/max(1,pat):.0f}x (paper: ~1e5x)"),
    ]
    return [(n, v, d) for n, v, d in rows]


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
