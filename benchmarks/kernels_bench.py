"""Kernel micro-bench: wall us/call for the XLA reference paths on CPU (the
Pallas kernels run in interpret mode here, so wall numbers are reported for
the XLA oracle paths; TPU perf is covered by §Roofline in EXPERIMENTS.md)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.models.attention import AttnSpec, blocked_attention
from repro.models.ssm import ssd_chunked
from repro.kernels import ops


def _t(fn, *args, reps=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    rows = []
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    B, S, H, KV, D = 1, 512, 4, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, KV, D))
    v = jax.random.normal(ks[2], (B, S, KV, D))
    spec_u = AttnSpec(q_block=128, kv_block=128, folded=False)
    spec_f = AttnSpec(q_block=128, kv_block=128, folded=True)
    f_u = jax.jit(lambda q, k, v: blocked_attention(q, k, v, spec_u))
    f_f = jax.jit(lambda q, k, v: blocked_attention(q, k, v, spec_f))
    t_u = _t(f_u, q, k, v)
    t_f = _t(f_f, q, k, v)
    rows.append(("kernels/blocked_attention_unfolded", t_u,
                 f"B{B}xS{S}xH{H}xD{D}"))
    rows.append(("kernels/blocked_attention_folded", t_f,
                 f"speedup={t_u/t_f:.2f}x (causal folding)"))

    Bs, Ss, Hs, P, G, N = 1, 512, 4, 32, 2, 16
    x = jax.random.normal(ks[0], (Bs, Ss, Hs, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bs, Ss, Hs)))
    A = -jnp.exp(jax.random.uniform(ks[2], (Hs,)))
    Bm = jax.random.normal(ks[3], (Bs, Ss, G, N))
    Cm = jax.random.normal(ks[4], (Bs, Ss, G, N))
    f_ssd = jax.jit(lambda *a: ssd_chunked(*a, 64)[0])
    rows.append(("kernels/ssd_chunked_xla", _t(f_ssd, x, dt, A, Bm, Cm),
                 f"B{Bs}xS{Ss}xH{Hs}xP{P}"))

    import numpy as np
    u = jnp.asarray(np.clip(np.random.default_rng(0).normal(
        0.5, 0.3, (64, 512)), 0, 1), jnp.float32)
    rows.append(("kernels/pattern_summary_interpret",
                 _t(lambda u: ops.pattern_summary(u), u, reps=2),
                 "64 events x 512 samples (interpret mode)"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
