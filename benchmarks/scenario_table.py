"""ISSUE 8: run the full gated fault-scenario catalog and render the
per-scenario markdown table the CI ``scenario-matrix`` job publishes
(job summary + ``reports/scenario-matrix.md`` artifact).

Exit status is the gate: non-zero when any scenario misses its declared
expectations.  ``REPRO_BENCH_ABILITY_SCENARIOS`` shrinks the run (CI
smoke / local debugging), same knob as benchmarks/ability_matrix.py.
"""
from __future__ import annotations

import os
import sys
from pathlib import Path

from repro.online.catalog import SCENARIOS, by_name, evaluate, run_scenario

OUT = Path(os.environ.get("REPRO_SCENARIO_TABLE",
                          "reports/scenario-matrix.md"))

HEADER = ("| scenario | class | function | channel | outcome | first plan "
          "| escalations | wtr | ok |\n"
          "|---|---|---|---|---|---|---|---|---|")


def _outcome(row) -> str:
    if row["resolved"]:
        return "resolved"
    if row["escalated"]:
        return "escalated"
    return "MISSING"


def main() -> int:
    sel = os.environ.get("REPRO_BENCH_ABILITY_SCENARIOS", "")
    scenarios = ([by_name(s.strip()) for s in sel.split(",") if s.strip()]
                 if sel else list(SCENARIOS))
    lines = ["### Fault-scenario matrix (DESIGN.md §12)", "", HEADER]
    n_rows = n_ok = 0
    for sc in scenarios:
        runner, res = run_scenario(sc)
        for row in evaluate(sc, runner, res):
            n_rows += 1
            n_ok += bool(row["ok"])
            wtr = row["wtr"] if row["wtr"] is not None else "—"
            lines.append(
                f"| {row['scenario']} | {row['fault_class']} "
                f"| `{row['function']}` | {row['channel']} "
                f"| {_outcome(row)} | {row['first_action'] or '—'} "
                f"| {row['escalations']} | {wtr} "
                f"| {'✅' if row['ok'] else '❌'} |")
    ok = n_ok == n_rows
    lines += ["", f"**{n_ok}/{n_rows} expectations met across "
                  f"{len(scenarios)} scenarios — "
                  f"{'PASS' if ok else 'FAIL'}**", ""]
    text = "\n".join(lines)
    print(text)
    OUT.parent.mkdir(parents=True, exist_ok=True)
    OUT.write_text(text)
    print(f"wrote {OUT}", file=sys.stderr)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
