"""Benchmark harness — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows; ``--json`` additionally
writes machine-readable results (rows + extracted scalar metrics) for the
CI regression gate (``benchmarks/check_regression.py``)."""
from __future__ import annotations

import argparse
import json
import math
import re
import sys


MODULES = [
    ("detection", "Fig. 8/12: detection latency"),
    ("pattern_size", "Fig. 11: pattern vs raw data size"),
    ("ring_patterns", "Figs. 3/5: ring signatures"),
    ("ability_matrix", "Table 4: ability matrix vs baselines"),
    ("overhead", "Table 3 / Fig. 17a-b: profiling overhead"),
    ("localization_scaling", "Fig. 17c: localization scaling"),
    ("summarize_backends", "ISSUE 1: summarize backend shootout"),
    ("fleet_diagnosis", "ISSUE 2: fleet-batched vs per-worker diagnosis"),
    ("online_pipeline", "ISSUE 3: online pipeline / differential escalation"),
    ("wire_transport", "ISSUE 4: wire transport throughput / p99 latency"),
    ("mitigation_loop", "ISSUE 5: mitigation loop windows-to-resolution"),
    ("serve_slo", "ISSUE 9: serving latency-SLO matrix (serve fault class)"),
    ("goodput", "ISSUE 10: goodput / recovery-economics matrix"),
    ("collector_tree", "ISSUE 6: sharded collector tree vs flat at W=1024"),
    ("train_overhead", "ISSUE 7: tracer overhead on the real train loop"),
    ("kernels_bench", "kernel micro-bench"),
    ("roofline_table", "EXPERIMENTS §Roofline (from dry-run artifacts)"),
]

_SPEEDUP = re.compile(r"([0-9.eE+-]+)x_vs_([A-Za-z0-9_]+)")


def metrics_from_rows(rows):
    """Flatten benchmark rows into {metric: scalar-or-string}.

    Every row contributes ``<name>:us_per_call``; the free-form ``derived``
    field is split on ';' and each ``key=value`` token (values may carry a
    trailing 'x' or '%') and each ``<S>x_vs_<ref>`` speedup token becomes a
    metric.  Non-numeric values stay strings (e.g. parity flags 'Y'/'N')."""
    out = {}
    for name, us, derived in rows:
        out[f"{name}:us_per_call"] = float(us)
        for tok in str(derived).split(";"):
            tok = tok.strip()
            m = _SPEEDUP.fullmatch(tok)
            if m:
                out[f"{name}:speedup_vs_{m.group(2)}"] = float(m.group(1))
                continue
            if "=" not in tok:
                continue
            key, val = tok.split("=", 1)
            key, val = key.strip(), val.strip()
            try:
                out[f"{name}:{key}"] = float(val.rstrip("x%"))
            except ValueError:
                out[f"{name}:{key}"] = val
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="comma-separated module names")
    ap.add_argument("--skip", default="", help="comma-separated module names")
    ap.add_argument("--json", default="",
                    help="write machine-readable results to this path")
    args = ap.parse_args()
    only = set(filter(None, args.only.split(",")))
    skip = set(filter(None, args.skip.split(",")))

    print("name,us_per_call,derived")
    failures = 0
    all_rows = []
    for name, desc in MODULES:
        if only and name not in only:
            continue
        if name in skip:
            continue
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            for row in mod.run():
                n, v, d = row
                all_rows.append((n, float(v), str(d)))
                print(f"{n},{v:.1f},{d}", flush=True)
        except Exception as e:  # pragma: no cover
            failures += 1
            all_rows.append((name, math.nan, f"ERROR:{type(e).__name__}:{e}"))
            print(f"{name},nan,ERROR:{type(e).__name__}:{e}", flush=True)

    if args.json:
        with open(args.json, "w") as f:
            json.dump({
                "rows": [{"name": n, "us_per_call": v, "derived": d}
                         for n, v, d in all_rows],
                "metrics": metrics_from_rows(all_rows),
                "failures": failures,
            }, f, indent=2, sort_keys=True)
        print(f"wrote {args.json} ({len(all_rows)} rows)", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
