"""Benchmark harness — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows."""
from __future__ import annotations

import argparse
import sys


MODULES = [
    ("detection", "Fig. 8/12: detection latency"),
    ("pattern_size", "Fig. 11: pattern vs raw data size"),
    ("ring_patterns", "Figs. 3/5: ring signatures"),
    ("ability_matrix", "Table 4: ability matrix vs baselines"),
    ("overhead", "Table 3 / Fig. 17a-b: profiling overhead"),
    ("localization_scaling", "Fig. 17c: localization scaling"),
    ("summarize_backends", "ISSUE 1: summarize backend shootout"),
    ("fleet_diagnosis", "ISSUE 2: fleet-batched vs per-worker diagnosis"),
    ("kernels_bench", "kernel micro-bench"),
    ("roofline_table", "EXPERIMENTS §Roofline (from dry-run artifacts)"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="comma-separated module names")
    ap.add_argument("--skip", default="", help="comma-separated module names")
    args = ap.parse_args()
    only = set(filter(None, args.only.split(",")))
    skip = set(filter(None, args.skip.split(",")))

    print("name,us_per_call,derived")
    failures = 0
    for name, desc in MODULES:
        if only and name not in only:
            continue
        if name in skip:
            continue
        try:
            mod = __import__(f"benchmarks.{name}", fromlist=["run"])
            for row in mod.run():
                n, v, d = row
                print(f"{n},{v:.1f},{d}", flush=True)
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"{name},nan,ERROR:{type(e).__name__}:{e}", flush=True)
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
