"""ISSUE 2: fleet-batched diagnosis vs the per-worker loop.

End-to-end ``PerfTrackerService.diagnose_profiles`` wall-time over the same
raw profiling windows in both modes:

  * ``wire``  — the per-worker daemon loop: W ``summarize_and_upload``
    calls, each packing/summarizing/serializing one worker;
  * ``fleet`` — one packed summarization pass across all W workers
    (``repro.summarize.fleet``), msgpack skipped.

Acceptance (ISSUE 2): fleet >= 5x wire at W=512 on the numpy backend, with
identical diagnoses.  Rows::

    fleet_diagnosis[<mode>]_W<W>, us_per_call, <speedup;parity>

``REPRO_BENCH_FLEET_SIZES`` (comma-separated) overrides the fleet sizes —
CI smoke runs W=8 only.
"""
from __future__ import annotations

import os
import time

import numpy as np


SIZES = tuple(int(x) for x in os.environ.get(
    "REPRO_BENCH_FLEET_SIZES", "8,32,128,512").split(",") if x)

#: profiling-window shape: 1 s window sampled at 500 Hz — scaled down from
#: the paper's 20 s x 10 kHz the same way the rest of the sim suite is
WINDOW_S = 1.0
RATE_HZ = 500.0


def _profiles(W: int, seed: int = 7):
    from repro.core import faults as F
    from repro.core.simulation import FleetSimulator, SimConfig
    sim = FleetSimulator(
        SimConfig(n_workers=W, window_s=WINDOW_S, rate_hz=RATE_HZ,
                  seed=seed),
        [F.GpuThrottle(workers=range(max(1, W // 64)))])
    return sim.profile_window()


def _same_diagnoses(a, b) -> bool:
    if len(a.diagnoses) != len(b.diagnoses):
        return False
    for da, db in zip(a.diagnoses, b.diagnoses):
        aa, bb = da.abnormality, db.abnormality
        if aa.function != bb.function or da.hint != db.hint \
                or aa.workers.tolist() != bb.workers.tolist() \
                or not np.array_equal(aa.patterns, bb.patterns):
            return False
    return True


def run():
    from repro.core.service import PerfTrackerService
    rows = []
    for W in SIZES:
        profiles = _profiles(W)
        svc = PerfTrackerService(summarize_backend="numpy")
        best = {}
        result = {}
        for mode in ("wire", "fleet"):
            svc.diagnose_profiles(profiles, mode=mode)      # warmup
            reps = 3 if W >= 128 else 5
            ts = []
            for _ in range(reps):
                t0 = time.perf_counter()
                result[mode] = svc.diagnose_profiles(profiles, mode=mode)
                ts.append(time.perf_counter() - t0)
            best[mode] = min(ts)
        parity = _same_diagnoses(result["wire"], result["fleet"])
        speedup = best["wire"] / best["fleet"]
        rows.append((f"fleet_diagnosis[wire]_W{W}", best["wire"] * 1e6, ""))
        rows.append((f"fleet_diagnosis[fleet]_W{W}", best["fleet"] * 1e6,
                     f"{speedup:.1f}x_vs_wire;"
                     f"identical={'Y' if parity else 'N'}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r[0]},{r[1]:.1f},{r[2]}")
