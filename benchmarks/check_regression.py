"""Benchmark-regression gate (ISSUE 3 satellite).

Compares a ``benchmarks/run.py --json`` results file against the committed
``benchmarks/baselines.json`` and exits non-zero on any regression, so CI
fails before a PR silently gives back the speed the perf work bought.

Baseline schema::

    {
      "default_tolerance": 0.3,
      "metrics": {
        "<metric>": {"value": 5.7, "direction": "higher",
                     "tolerance": 0.3, "note": "..."},
        "<metric>": {"equals": "Y", "note": "..."}
      }
    }

Per-metric semantics:

  * ``equals``             — exact match (parity / accuracy flags);
  * ``direction: higher``  — bigger is better (speedups, byte ratios);
                             fail when value < baseline * (1 - tolerance);
  * ``direction: lower``   — smaller is better (latencies);
                             fail when value > baseline * (1 + tolerance);
  * ``direction: both``    — deterministic quantities; fail outside
                             baseline * (1 -/+ tolerance).

Only RELATIVE metrics (speedup ratios, byte ratios, deterministic counts,
parity flags) belong in the committed baselines: absolute wall-clock moves
with the CI machine, ratios of two runs on the same machine mostly don't.

``--update`` rewrites the ``value`` of every numeric baseline entry from
the given results file (tolerances, directions, and notes are kept) —
run it locally after an intentional perf change and commit the diff.
"""
from __future__ import annotations

import argparse
import json
import math
import sys
from pathlib import Path

DEFAULT_BASELINES = Path(__file__).parent / "baselines.json"


def load(path):
    with open(path) as f:
        return json.load(f)


def check_metric(name, spec, value, default_tol):
    """Returns (ok, detail)."""
    if "equals" in spec:
        ok = str(value) == str(spec["equals"])
        return ok, f"expected == {spec['equals']!r}, got {value!r}"
    base = float(spec["value"])
    tol = float(spec.get("tolerance", default_tol))
    direction = spec.get("direction", "both")
    try:
        v = float(value)
    except (TypeError, ValueError):
        return False, f"non-numeric result {value!r}"
    if math.isnan(v):
        return False, "result is NaN (benchmark errored?)"
    lo, hi = base * (1 - tol), base * (1 + tol)
    if direction == "higher":
        ok = v >= lo
        bound = f">= {lo:.4g}"
    elif direction == "lower":
        ok = v <= hi
        bound = f"<= {hi:.4g}"
    else:
        ok = lo <= v <= hi
        bound = f"in [{lo:.4g}, {hi:.4g}]"
    return ok, f"baseline {base:.4g} (tol {tol:.0%}, {direction}): " \
               f"need {bound}, got {v:.4g}"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("results", help="benchmarks/run.py --json output")
    ap.add_argument("--baselines", default=str(DEFAULT_BASELINES))
    ap.add_argument("--require-all", action="store_true",
                    help="missing baseline metrics fail (CI mode; default "
                         "skips metrics absent from the results)")
    ap.add_argument("--update", action="store_true",
                    help="rewrite numeric baseline values from the results")
    args = ap.parse_args()

    results = load(args.results)
    metrics = results.get("metrics", {})
    baselines = load(args.baselines)
    default_tol = float(baselines.get("default_tolerance", 0.3))
    specs = baselines.get("metrics", {})

    if args.update:
        updated = 0
        for name, spec in specs.items():
            if "value" in spec and name in metrics:
                spec["value"] = round(float(metrics[name]), 4)
                updated += 1
        with open(args.baselines, "w") as f:
            json.dump(baselines, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"updated {updated}/{len(specs)} baseline values "
              f"in {args.baselines}")
        return

    failures = []
    skipped = []
    for name, spec in sorted(specs.items()):
        if name not in metrics:
            (failures if args.require_all else skipped).append(
                (name, "metric missing from results"))
            continue
        ok, detail = check_metric(name, spec, metrics[name], default_tol)
        status = "OK  " if ok else "FAIL"
        print(f"{status} {name}: {detail}")
        if not ok:
            failures.append((name, detail))
    for name, why in skipped:
        print(f"SKIP {name}: {why}")
    if results.get("failures"):
        failures.append(("(harness)",
                         f"{results['failures']} benchmark module(s) errored"))

    if failures:
        print(f"\n{len(failures)} benchmark regression(s):", file=sys.stderr)
        for name, detail in failures:
            print(f"  {name}: {detail}", file=sys.stderr)
        sys.exit(1)
    print(f"\nall {len(specs) - len(skipped)} gated metrics within "
          f"tolerance ({len(skipped)} skipped)")


if __name__ == "__main__":
    main()
