"""ISSUE 7: tracer overhead on the REAL instrumented training loop.

``Trainer.train_iteration`` runs the same fenced split-step whether or not
a ``Tracer`` observes it, so the tracer-on / tracer-off delta isolates
exactly what instrumentation costs: the phase context managers, the
HLO-cost sub-event records, and the background ``ProcessSampler`` thread
at the production 100 Hz rate.  The gate is the declared budget, not an
absolute time: ``within_budget=Y`` iff the median-step inflation stays
under ``REPRO_TRAIN_OVERHEAD_BUDGET_PCT`` (default 25%, roomy enough for
shared-runner noise on a sub-10ms step; the honest figure is ~1-3%).

Shrink knobs: ``REPRO_BENCH_TRAIN_OVERHEAD_ITERS`` plus the
``REPRO_TRAIN_*`` model-size knobs ``tiny_train_setup`` reads.
"""
from __future__ import annotations

import os
import time

import numpy as np

ITERS = int(os.environ.get("REPRO_BENCH_TRAIN_OVERHEAD_ITERS", "30"))
BUDGET_PCT = float(os.environ.get("REPRO_TRAIN_OVERHEAD_BUDGET_PCT", "25"))


def _block_s(trainer, state, n, tracer=None):
    params, opt_state = state
    if tracer is not None:
        tracer.start_window()
    durs = []
    for _ in range(n):
        t0 = time.perf_counter()
        params, opt_state, _ = trainer.train_iteration(params, opt_state,
                                                       tracer=tracer)
        durs.append(time.perf_counter() - t0)
    if tracer is not None:
        tracer.stop_window()
    state[0], state[1] = params, opt_state
    return durs


def run():
    from repro.instrument.tracer import ProcessSampler, Tracer
    from repro.train.loop import Trainer
    from repro.train.workload import tiny_train_setup

    mc, dc, oc, tc = tiny_train_setup()
    tr = Trainer(mc, dc, oc, tc)
    params, opt_state, _ = tr.init_state()
    state = [params, opt_state]
    _block_s(tr, state, 3)                             # compile + warm caches
    tracer = Tracer(worker=0, samplers={"cpu": ProcessSampler(rate_hz=100.0)})
    # interleave off/on blocks so machine-load drift hits both sides alike
    block = max(2, min(5, ITERS))
    off, on = [], []
    while len(off) < ITERS:
        off += _block_s(tr, state, block)
        on += _block_s(tr, state, block, tracer=tracer)
    t_off = float(np.median(off))
    t_on = float(np.median(on))
    tr.loader.close()

    inflation = 100.0 * (t_on / t_off - 1.0)
    within = "Y" if inflation <= BUDGET_PCT else "N"
    return [(
        "train_overhead/tiny", t_on * 1e6,
        f"off_us={t_off * 1e6:.1f};on_us={t_on * 1e6:.1f};"
        f"inflation_pct={inflation:.2f};budget_pct={BUDGET_PCT:.1f};"
        f"within_budget={within}")]


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
