"""Table 4: troubleshooting-ability matrix — PerfTracker vs the
state-of-the-art baselines, all IMPLEMENTED and run on the same simulated
faults (C1P1, C1P2, C2P1, C2P2, C2P3 + the §3 ring case) — plus the full
gated fault-scenario catalog (ISSUE 8, DESIGN.md §12): every declared
scenario runs the closed act->verify->escalate loop end-to-end and its
outcome is scored against the catalog's expectations.

Baselines (per the paper's descriptions):
  * hw-monitor (Minder/DCGM-class): per-worker coarse hardware means only
    (1 Hz), cross-worker z-score outlier rule; no function attribution.
  * comm-monitor (C4/MegaScale-class): collective-transport stats only.

Env knobs (CI smoke shrink, see tests/test_benchmarks_smoke.py):
  * ``REPRO_BENCH_ABILITY_CASES``      — comma-separated one-shot cases;
  * ``REPRO_BENCH_ABILITY_SCENARIOS``  — comma-separated catalog scenario
    names (default: the whole catalog).

Row families for the regression gate (benchmarks/baselines.json):
  * ``ability/<case>``            — one-shot detection vs baselines;
  * ``ability/scenario_<name>``   — value = mean windows-to-resolution
    over the scenario's resolved expectations (-1 when none resolve,
    e.g. the bad-standby family), derived carries
    class/resolved/escalated/first_action/ok;
  * ``ability/class_<class>``     — value = mean windows-to-resolution
    over the class's resolved expectations (the gated per-class ceiling);
  * ``ability/matrix``            — value = scenarios run, ``ok`` = the
    whole matrix met its declared expectations.
"""
from __future__ import annotations

import os
import time
from typing import Dict, List

import numpy as np

from repro.core import faults as F
from repro.core.service import PerfTrackerService
from repro.core.simulation import (ALLGATHER, GEMM, FleetSimulator,
                                   SimConfig)

CASES = {
    "C1P1_gpu_throttle": ([F.GpuThrottle(workers=range(4))], GEMM),
    "C1P2_nvlink_down": ([F.NvlinkDown(workers=[5])], ALLGATHER),
    "S3_ring_slow_link": ([F.RingSlowLink(slow_worker=9, rho=0.4)],
                          ALLGATHER),
    "C2P1_slow_dataloader": ([F.SlowDataloader()], "socket"),
    "C2P2_cpu_forward": ([F.CpuBoundForward(workers=range(6))], "forward"),
    "C2P3_async_gc": ([F.AsyncGc(probability=0.5)], "gradmode"),
}


def _mean_streams(profiles):
    """1 Hz coarse means per worker per stream (what DCGM-class monitors
    export)."""
    out = {}
    for s in ("gpu_sm", "cpu", "pcie_tx"):
        out[s] = np.array([p.streams[s].values.mean() for p in profiles])
    return out


def hw_monitor(profiles) -> bool:
    """DCGM/Minder-class: cross-worker outlier on GPU/PCIe hardware MEANS
    (no function attribution, no CPU/code visibility). Alerts on hardware
    asymmetries; blind to code-level issues and to WHAT is slow."""
    means = _mean_streams(profiles)
    for name in ("gpu_sm", "pcie_tx"):
        v = means[name]
        med = np.median(v)
        mad = np.median(np.abs(v - med)) + 1e-9
        if mad > 0.005 and (np.abs(v - med) > 6 * mad).any():
            return True
        # bimodal hardware populations (e.g. a rack of throttled GPUs)
        if v.std() > 0.15:
            return True
    return False


def comm_monitor(profiles) -> bool:
    """C4/MegaScale-class: collective-transport stats only."""
    v = _mean_streams(profiles)["pcie_tx"]
    med = np.median(v)
    mad = np.median(np.abs(v - med)) + 1e-9
    return bool(mad > 0.005 and (np.abs(v - med) > 6 * mad).any()
                or v.std() > 0.15)


def perftracker(profiles, expect) -> bool:
    svc = PerfTrackerService()
    res = svc.diagnose_profiles(profiles)
    return any(expect in f for f in res.functions())


def _selected(env: str, names):
    sel = os.environ.get(env, "")
    if not sel:
        return None if names is None else list(names)
    return [s.strip() for s in sel.split(",") if s.strip()]


def _yn(flag: bool) -> str:
    return "Y" if flag else "N"


def scenario_rows(scenario_names=None) -> List[tuple]:
    """Run the catalog matrix; one row per scenario + per-class and
    aggregate rollups (see module docstring for the row contract)."""
    from repro.online.catalog import (FAULT_CLASSES, by_name, evaluate,
                                      run_scenario)
    names = (_selected("REPRO_BENCH_ABILITY_SCENARIOS", None)
             if scenario_names is None else list(scenario_names))
    if names is None:
        from repro.online.catalog import SCENARIOS
        scenarios = list(SCENARIOS)
    else:
        scenarios = [by_name(n) for n in names]

    rows: List[tuple] = []
    cls_wtr: Dict[str, List[int]] = {}
    cls_ok: Dict[str, bool] = {}
    cls_n: Dict[str, int] = {}
    all_ok = True
    for sc in scenarios:
        runner, res = run_scenario(sc)
        ev = evaluate(sc, runner, res)
        ok = all(r["ok"] for r in ev)
        all_ok &= ok
        wtrs = [r["wtr"] for r in ev if r["wtr"] is not None]
        resolved = all(r["resolved"] for r in ev)
        escalated = any(r["escalated"] for r in ev)
        first = "+".join(r["first_action"] or "none" for r in ev)
        value = float(np.mean(wtrs)) if wtrs else -1.0
        rows.append((
            f"ability/scenario_{sc.name}", value,
            f"class={sc.fault_class};resolved={_yn(resolved)};"
            f"escalated={_yn(escalated)};first_action={first};"
            f"ok={_yn(ok)}"))
        cls_wtr.setdefault(sc.fault_class, []).extend(wtrs)
        cls_ok[sc.fault_class] = cls_ok.get(sc.fault_class, True) and ok
        cls_n[sc.fault_class] = cls_n.get(sc.fault_class, 0) + 1
    for cls in FAULT_CLASSES:
        if cls not in cls_n:
            continue
        wtrs = cls_wtr.get(cls, [])
        rows.append((
            f"ability/class_{cls}",
            float(np.mean(wtrs)) if wtrs else -1.0,
            f"ok={_yn(cls_ok[cls])};scenarios={cls_n[cls]}"))
    rows.append(("ability/matrix", float(len(scenarios)),
                 f"ok={_yn(all_ok)};scenarios={len(scenarios)}"))
    return rows


def run():
    rows = []
    matrix: Dict[str, List[str]] = {}
    for case in _selected("REPRO_BENCH_ABILITY_CASES", CASES):
        faults, expect = CASES[case]
        sim = FleetSimulator(SimConfig(n_workers=32, window_s=2.0,
                                       rate_hz=2000, seed=7), faults)
        profiles = sim.profile_window()
        t0 = time.perf_counter()
        pt = perftracker(profiles, expect)
        t_pt = time.perf_counter() - t0
        hw = hw_monitor(profiles)
        cm = comm_monitor(profiles)
        rows.append((f"ability/{case}", t_pt * 1e6,
                     f"perftracker={'Y' if pt else 'N'};"
                     f"hw_monitor={'Y' if hw else 'N'};"
                     f"comm_monitor={'Y' if cm else 'N'}"))
    return rows + scenario_rows()


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
