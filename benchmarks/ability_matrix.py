"""Table 4: troubleshooting-ability matrix — PerfTracker vs the
state-of-the-art baselines, all IMPLEMENTED and run on the same simulated
faults (C1P1, C1P2, C2P1, C2P2, C2P3 + the §3 ring case).

Baselines (per the paper's descriptions):
  * hw-monitor (Minder/DCGM-class): per-worker coarse hardware means only
    (1 Hz), cross-worker z-score outlier rule; no function attribution.
  * comm-monitor (C4/MegaScale-class): collective-transport stats only.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import faults as F
from repro.core.service import PerfTrackerService
from repro.core.simulation import (ALLGATHER, GEMM, FleetSimulator,
                                   SimConfig)

CASES = {
    "C1P1_gpu_throttle": ([F.GpuThrottle(workers=range(4))], GEMM),
    "C1P2_nvlink_down": ([F.NvlinkDown(workers=[5])], ALLGATHER),
    "S3_ring_slow_link": ([F.RingSlowLink(slow_worker=9, rho=0.4)],
                          ALLGATHER),
    "C2P1_slow_dataloader": ([F.SlowDataloader()], "socket"),
    "C2P2_cpu_forward": ([F.CpuBoundForward(workers=range(6))], "forward"),
    "C2P3_async_gc": ([F.AsyncGc(probability=0.5)], "gradmode"),
}


def _mean_streams(profiles):
    """1 Hz coarse means per worker per stream (what DCGM-class monitors
    export)."""
    out = {}
    for s in ("gpu_sm", "cpu", "pcie_tx"):
        out[s] = np.array([p.streams[s].values.mean() for p in profiles])
    return out


def hw_monitor(profiles) -> bool:
    """DCGM/Minder-class: cross-worker outlier on GPU/PCIe hardware MEANS
    (no function attribution, no CPU/code visibility). Alerts on hardware
    asymmetries; blind to code-level issues and to WHAT is slow."""
    means = _mean_streams(profiles)
    for name in ("gpu_sm", "pcie_tx"):
        v = means[name]
        med = np.median(v)
        mad = np.median(np.abs(v - med)) + 1e-9
        if mad > 0.005 and (np.abs(v - med) > 6 * mad).any():
            return True
        # bimodal hardware populations (e.g. a rack of throttled GPUs)
        if v.std() > 0.15:
            return True
    return False


def comm_monitor(profiles) -> bool:
    """C4/MegaScale-class: collective-transport stats only."""
    v = _mean_streams(profiles)["pcie_tx"]
    med = np.median(v)
    mad = np.median(np.abs(v - med)) + 1e-9
    return bool(mad > 0.005 and (np.abs(v - med) > 6 * mad).any()
                or v.std() > 0.15)


def perftracker(profiles, expect) -> bool:
    svc = PerfTrackerService()
    res = svc.diagnose_profiles(profiles)
    return any(expect in f for f in res.functions())


def run():
    rows = []
    matrix: Dict[str, List[str]] = {}
    for case, (faults, expect) in CASES.items():
        sim = FleetSimulator(SimConfig(n_workers=32, window_s=2.0,
                                       rate_hz=2000, seed=7), faults)
        profiles = sim.profile_window()
        t0 = time.perf_counter()
        pt = perftracker(profiles, expect)
        t_pt = time.perf_counter() - t0
        hw = hw_monitor(profiles)
        cm = comm_monitor(profiles)
        rows.append((f"ability/{case}", t_pt * 1e6,
                     f"perftracker={'Y' if pt else 'N'};"
                     f"hw_monitor={'Y' if hw else 'N'};"
                     f"comm_monitor={'Y' if cm else 'N'}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
