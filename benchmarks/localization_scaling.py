"""Fig. 17c: centralized localization time vs fleet size (single CPU core).
The paper reports ~3 minutes at 1,000,000 workers; the vectorized numpy
localizer here is benchmarked on the same simulated-pattern methodology."""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core import faults as F
from repro.core.service import PerfTrackerService
from repro.core.simulation import GEMM, FleetSimulator, SimConfig

#: smoke override (tests/test_benchmarks_smoke.py): comma-separated sizes
SIZES = tuple(int(x) for x in os.environ.get(
    "REPRO_BENCH_LOC_SIZES", "1000,10000,100000,1000000").split(",") if x)


def run(sizes=SIZES, n_functions=20):
    rows = []
    for w in sizes:
        sim = FleetSimulator(
            SimConfig(n_workers=w, seed=1),
            [F.GpuThrottle(workers=np.random.default_rng(0).choice(
                w, size=max(1, w // 100), replace=False))])
        patterns, kinds = sim.synth_patterns(n_functions)
        svc = PerfTrackerService()
        t0 = time.perf_counter()
        res = svc.diagnose_patterns(patterns, kinds)
        dt = time.perf_counter() - t0
        found = any(f == GEMM for f in res.functions())
        rows.append((f"localization_scaling/w={w}", dt * 1e6,
                     f"localize_s={dt:.3f};found={found}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
