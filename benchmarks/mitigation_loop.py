"""ISSUE 5: the closed mitigation loop — windows-to-resolution across the
six-fault matrix (DESIGN.md §9).

Each case schedules one fault that the SCHEDULE NEVER REMOVES: the only
way the incident resolves is the mitigation engine executing the correct
plan (replace hosts + re-mesh onto standbys, migrate the dataloader,
synchronize GC, flag code) and verification watching the signature clear.
Per case::

    mitigation/<case>_W<W>,  windows from plan application to resolved,
                             resolved=Y/N;escalations=k;plan=<action>;
                             windows_to_detect=d

plus an aggregate row::

    mitigation/matrix_W<W>,  mean windows-to-resolution,
                             resolved=Y iff every case resolved with the
                             expected first plan and zero escalations

Everything is deterministic (seeded simulator, fixed schedule), so the CI
gate pins a windows-to-resolution CEILING per fault and the matrix
``resolved`` flag (benchmarks/baselines.json).

Env knobs (CI smoke): ``REPRO_BENCH_MITIGATION_W`` (default 24),
``REPRO_BENCH_MITIGATION_WINDOWS`` (default 12),
``REPRO_BENCH_MITIGATION_CASES`` (comma-separated case names, default all
six).
"""
from __future__ import annotations

import os

W = int(os.environ.get("REPRO_BENCH_MITIGATION_W", "24"))
N_WINDOWS = int(os.environ.get("REPRO_BENCH_MITIGATION_WINDOWS", "12"))
N_STANDBY = 4
INJECT = 2
WINDOW_S = 1.0
BASE_HZ, FULL_HZ = 250.0, 2000.0


def _cases():
    from repro.core import faults as F
    from repro.core.mitigation import Action
    from repro.core.simulation import (ALLGATHER, DATALOADER_STACK,
                                       FORWARD_STACK, GC_STACK, GEMM)
    cases = {
        "C1P1_gpu_throttle": (F.GpuThrottle(workers=(3, W // 2 + 1)),
                              GEMM, Action.REPLACE_HOSTS),
        "C1P2_nvlink_down": (F.NvlinkDown(workers=[5], group_size=8),
                             ALLGATHER, Action.REPLACE_HOSTS),
        "S3_ring_slow_link": (F.RingSlowLink(slow_worker=9, rho=0.4),
                              ALLGATHER, Action.REPLACE_HOSTS),
        "C2P1_slow_dataloader": (F.SlowDataloader(), DATALOADER_STACK,
                                 Action.MIGRATE_DATALOADER),
        "C2P2_cpu_forward": (F.CpuBoundForward(workers=range(6)),
                             FORWARD_STACK, Action.FLAG_CODE),
        "C2P3_async_gc": (F.AsyncGc(probability=0.5, pause_s=0.25),
                          GC_STACK, Action.SYNCHRONIZE_GC),
    }
    only = [c for c in os.environ.get("REPRO_BENCH_MITIGATION_CASES",
                                      "").split(",") if c]
    return {k: v for k, v in cases.items() if not only or k in only}


def _run_case(fault):
    from repro.core.simulation import SimConfig
    from repro.online import (EscalationPolicy, ScenarioRunner,
                              ScheduledFault)
    esc = EscalationPolicy(n_workers=W + N_STANDBY, base_rate_hz=BASE_HZ,
                           full_rate_hz=FULL_HZ,
                           max_escalated=max(4, W // 16))
    runner = ScenarioRunner(
        SimConfig(n_workers=W, window_s=WINDOW_S, rate_hz=FULL_HZ, seed=5,
                  n_standby=N_STANDBY),
        [ScheduledFault(fault, INJECT, N_WINDOWS)],   # never removed
        n_windows=N_WINDOWS, escalation=esc, mitigation=True)
    return runner, runner.run()


def run():
    rows = []
    all_ok = True
    resolutions = []
    for name, (fault, expect, action) in _cases().items():
        runner, res = _run_case(fault)
        incs = [i for i in res.incidents if i.function == expect]
        inc = incs[0] if incs else None
        mine = ([m for m in runner.engine.log
                 if inc is not None and m.incident_id == inc.id]
                if inc is not None else [])
        ok = (inc is not None and inc.state == "resolved"
              and mine and mine[0].plan.action is action
              and inc.escalations == 0)
        if ok:
            apply_w = mine[0].window
            resolved_w = res.window_of(inc.resolved_at)
            wtr = resolved_w - apply_w
            detect = res.window_of(inc.opened_at) - INJECT
            resolutions.append(wtr)
        else:
            wtr, detect = float("nan"), float("nan")
        all_ok = all_ok and ok
        rows.append((
            f"mitigation/{name}_W{W}", wtr,
            f"windows_to_resolve;resolved={'Y' if ok else 'N'};"
            f"escalations={inc.escalations if inc else -1};"
            f"plan={mine[0].plan.action.value if mine else 'none'};"
            f"windows_to_detect={detect}"))
    mean_wtr = (sum(resolutions) / len(resolutions)
                if resolutions else float("nan"))
    # an empty case filter (e.g. a typo in REPRO_BENCH_MITIGATION_CASES)
    # must not report a vacuous green matrix
    all_ok = all_ok and bool(resolutions)
    rows.append((
        f"mitigation/matrix_W{W}", mean_wtr,
        f"mean_windows_to_resolve;resolved={'Y' if all_ok else 'N'};"
        f"cases={len(resolutions)}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r[0]},{r[1]:.1f},{r[2]}")
