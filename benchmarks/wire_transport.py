"""ISSUE 4: wire-transport throughput and latency (DESIGN.md §8).

Measures the transport layer in isolation (uploads are pre-summarized once
— socket framing, per-worker connections, and window assembly are the
variables): W persistent ``WireClient`` connections to one ``DaemonServer``
over a Unix-domain socket push ``N_WINDOWS`` full windows of ~KB pattern
uploads; the collector assembles each.

Rows::

    wire/upload_W<W>,  us per assembled window,
        throughput_wps=<windows/s>;p99_upload_us=<per-upload enqueue->
        assemble latency>;delivered=Y|N;payload_kb=<per-window KB>

``delivered`` is the deterministic gate flag: every upload of every window
must arrive (loopback is lossless — a drop here is a transport bug).
Throughput is gated with a generous tolerance (absolute wall-clock moves
with the CI machine); p99 latency is reported ungated.

Env knobs (CI smoke): ``REPRO_BENCH_WIRE_W`` (default 64),
``REPRO_BENCH_WIRE_WINDOWS`` (default 8).
"""
from __future__ import annotations

import os
import time

import numpy as np

W = int(os.environ.get("REPRO_BENCH_WIRE_W", "64"))
N_WINDOWS = int(os.environ.get("REPRO_BENCH_WIRE_WINDOWS", "8"))
N_FUNCTIONS = 40          # ~KB payload per upload, like the paper's Fig. 11


def _uploads():
    """One fleet of realistic ~KB uploads (pre-summarized once)."""
    import msgpack
    from repro.core.daemon import PatternUpload
    rng = np.random.default_rng(0)
    out = []
    for w in range(W):
        payload = msgpack.packb({
            f"train.py:train_loop/module_{i}.py:forward_{i}": (
                float(rng.uniform(0, 0.5)), float(rng.uniform(0, 1)),
                float(rng.uniform(0, 0.2)), int(i % 4))
            for i in range(N_FUNCTIONS)})
        out.append(PatternUpload(worker=w, payload=payload,
                                 summarize_s=0.0, raw_bytes=1 << 20))
    return out


def run():
    from repro.transport import DaemonServer, WindowCollector, WireClient
    uploads = _uploads()
    payload_kb = sum(len(u.payload) for u in uploads) / 1024.0
    collector = WindowCollector(range(W))
    latencies = []
    delivered = True
    with DaemonServer(collector) as server:
        clients = [WireClient(server.address, u.worker) for u in uploads]
        try:
            # warmup window (connection setup, allocator)
            for c, u in zip(clients, uploads):
                c.send_upload(-1, u)
                c.end_window(-1)
            collector.wait_window(-1, timeout=30.0)

            t_start = time.perf_counter()
            window_times = []
            for i in range(N_WINDOWS):
                t0 = time.perf_counter()
                enq = {}
                for c, u in zip(clients, uploads):
                    enq[u.worker] = time.perf_counter()
                    c.send_upload(i, u)
                    c.end_window(i)
                batch = collector.wait_window(i, timeout=30.0)
                t1 = time.perf_counter()
                window_times.append(t1 - t0)
                # per-upload latency: enqueue -> window assembled (upper
                # bound; the collector does not timestamp each frame)
                latencies += [t1 - enq[w] for w in batch.present]
                delivered &= (len(batch.uploads) == W
                              and batch.duplicates == 0
                              and not batch.timed_out)
            total = time.perf_counter() - t_start
        finally:
            for c in clients:
                c.close()
    wps = N_WINDOWS / total
    p99 = float(np.percentile(latencies, 99)) * 1e6 if latencies else 0.0
    return [(f"wire/upload_W{W}",
             float(np.median(window_times)) * 1e6,
             f"throughput_wps={wps:.1f};p99_upload_us={p99:.0f};"
             f"delivered={'Y' if delivered else 'N'};"
             f"payload_kb={payload_kb:.1f}")]


if __name__ == "__main__":
    for r in run():
        print(f"{r[0]},{r[1]:.1f},{r[2]}")
