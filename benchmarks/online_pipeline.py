"""ISSUE 3: online incident pipeline — steady-state cost of differential
escalation (DESIGN.md §7).

Runs the multi-window fault matrix through ``ScenarioRunner`` twice per
case:

  * ``escalated`` — fleet at the cheap base rate, only workers implicated
    by the previous window's localization at the full rate;
  * ``full``      — every worker at the full rate every window (what a
    naive always-on profiler costs).

Acceptance (ISSUE 3): at W=128 the escalated run profiles >= 4x fewer raw
bytes than always-full-rate, with no loss of localization accuracy on the
fault matrix (every case's expected incident found, naming the culprit
workers, in BOTH runs).  Rows::

    online/bytes_ratio_W<W>,   total_full_bytes/total_escalated_bytes
    online/window_latency_us,  median per-window summarize+localize wall

Env knobs (CI smoke): ``REPRO_BENCH_ONLINE_W`` (default 128),
``REPRO_BENCH_ONLINE_WINDOWS`` (default 8), ``REPRO_BENCH_ONLINE_CASES``
(comma-separated case names, default all six).
"""
from __future__ import annotations

import os
import statistics

W = int(os.environ.get("REPRO_BENCH_ONLINE_W", "128"))
N_WINDOWS = int(os.environ.get("REPRO_BENCH_ONLINE_WINDOWS", "8"))
INJECT, REMOVE = 2, max(3, N_WINDOWS - 2)
WINDOW_S = 1.0
BASE_HZ, FULL_HZ = 250.0, 2000.0


def _cases():
    from repro.core import faults as F
    from repro.core.simulation import (ALLGATHER, DATALOADER_STACK,
                                       FORWARD_STACK, GC_STACK, GEMM)
    cases = {
        "C1P1_gpu_throttle": (F.GpuThrottle(workers=(3, W // 2 + 1)),
                              GEMM, {3, W // 2 + 1}),
        "C1P2_nvlink_down": (F.NvlinkDown(workers=[5], group_size=8),
                             ALLGATHER, {5}),
        "S3_ring_slow_link": (F.RingSlowLink(slow_worker=9, rho=0.4),
                              ALLGATHER, {9}),
        "C2P1_slow_dataloader": (F.SlowDataloader(), DATALOADER_STACK, None),
        "C2P2_cpu_forward": (F.CpuBoundForward(workers=range(6)),
                             FORWARD_STACK, set(range(6))),
        "C2P3_async_gc": (F.AsyncGc(probability=0.5, pause_s=0.25),
                          GC_STACK, None),
    }
    only = [c for c in os.environ.get("REPRO_BENCH_ONLINE_CASES",
                                      "").split(",") if c]
    return {k: v for k, v in cases.items() if not only or k in only}


def _run_case(fault, escalated: bool):
    from repro.core.simulation import SimConfig
    from repro.online import (EscalationPolicy, ScenarioRunner,
                              ScheduledFault)
    esc = EscalationPolicy(n_workers=W, base_rate_hz=BASE_HZ,
                           full_rate_hz=FULL_HZ,
                           max_escalated=max(4, W // 16)) \
        if escalated else None
    runner = ScenarioRunner(
        SimConfig(n_workers=W, window_s=WINDOW_S, rate_hz=FULL_HZ, seed=5),
        [ScheduledFault(fault, INJECT, REMOVE)],
        n_windows=N_WINDOWS, escalation=esc)
    return runner.run()


def _case_ok(res, expect, culprits) -> bool:
    incs = [i for i in res.incidents if i.function == expect]
    if not incs:
        return False
    if culprits is not None and not culprits <= set(incs[0].workers):
        return False
    return True


def run():
    rows = []
    bytes_esc = bytes_full = 0
    latencies = []
    ok = True
    for name, (fault, expect, culprits) in _cases().items():
        res_esc = _run_case(fault, escalated=True)
        res_full = _run_case(fault, escalated=False)
        case_ok = (_case_ok(res_esc, expect, culprits)
                   and _case_ok(res_full, expect, culprits))
        ok = ok and case_ok
        b_esc = sum(r.raw_bytes for r in res_esc.reports)
        b_full = sum(r.raw_bytes for r in res_full.reports)
        bytes_esc += b_esc
        bytes_full += b_full
        latencies += [r.summarize_s + r.localize_s
                      for r in res_esc.reports]
        rows.append((f"online/{name}_W{W}", b_full / max(1, b_esc),
                     f"bytes_ratio;accuracy={'Y' if case_ok else 'N'}"))
    ratio = bytes_full / max(1, bytes_esc)
    rows.append((f"online/bytes_ratio_W{W}", ratio,
                 f"ratio={ratio:.2f}x;accuracy={'Y' if ok else 'N'};"
                 f"escalated_mb={bytes_esc/1e6:.1f};"
                 f"full_mb={bytes_full/1e6:.1f}"))
    rows.append(("online/window_latency_us",
                 statistics.median(latencies) * 1e6,
                 f"median_steady_state_tick;W={W}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r[0]},{r[1]:.1f},{r[2]}")
