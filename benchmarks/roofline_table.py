"""§Roofline: emit the per-(arch x shape x mesh) roofline table from the
dry-run artifacts in reports/dryrun/ (run launch.dryrun first)."""
from __future__ import annotations

import json
from pathlib import Path

DRYRUN = Path("reports/dryrun")


def rows_from(mesh_dir: Path, tag_filter=""):
    out = []
    for f in sorted(mesh_dir.glob("*.json")):
        r = json.loads(f.read_text())
        if tag_filter and tag_filter not in f.stem:
            continue
        rl = r["roofline"]
        out.append((
            f"roofline/{r['mesh']}/{r['arch']}/{r['shape']}",
            rl["step_time_bound_s"] * 1e6,
            f"dom={rl['dominant']};tc={rl['t_compute_s']:.3f};"
            f"tm={rl['t_memory_s']:.3f};tcoll={rl['t_collective_s']:.3f};"
            f"useful={rl['useful_flops_ratio']:.3f};"
            f"frac={rl['roofline_fraction']:.4f}"))
    return out


def run():
    rows = []
    for mesh in ("16x16", "2x16x16"):
        d = DRYRUN / mesh
        if d.exists():
            rows.extend(rows_from(d))
    if not rows:
        rows.append(("roofline/missing", 0.0,
                     "run: python -m repro.launch.dryrun --all --mesh both"))
    return rows


def markdown_table() -> str:
    lines = ["| mesh | arch | shape | dominant | t_comp (s) | t_mem (s) "
             "| t_coll (s) | useful | roofline |",
             "|---|---|---|---|---|---|---|---|---|"]
    for mesh in ("16x16", "2x16x16"):
        d = DRYRUN / mesh
        if not d.exists():
            continue
        for f in sorted(d.glob("*.json")):
            r = json.loads(f.read_text())
            rl = r["roofline"]
            lines.append(
                f"| {r['mesh']} | {r['arch']} | {r['shape']} "
                f"| {rl['dominant']} | {rl['t_compute_s']:.3f} "
                f"| {rl['t_memory_s']:.3f} | {rl['t_collective_s']:.3f} "
                f"| {rl['useful_flops_ratio']:.3f} "
                f"| {rl['roofline_fraction']:.4f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
