"""Fig. 8/12: degradation-detection latency — iterations until trigger as a
function of slowdown magnitude (threshold is 5% per §4.1)."""
from __future__ import annotations

from repro.core.detector import DetectorConfig, IterationDetector


def iterations_to_trigger(slowdown: float, n_recent=50) -> int:
    det = IterationDetector(DetectorConfig(n_recent=n_recent))
    t = 0.0
    for i in range(2000):
        dur = 1.0 if i < 100 else slowdown
        det.feed("dataloader.next", t)
        trig = det.feed("optimizer.step", t + dur * 0.97)
        t += dur
        if trig is not None:
            return i - 100 + 1
    return -1


def run():
    rows = []
    for slowdown in (1.02, 1.05, 1.08, 1.2, 1.5, 2.0):
        it = iterations_to_trigger(slowdown)
        rows.append((f"detection/slowdown_{slowdown:.2f}", float(it),
                     "iterations-to-trigger (-1 = none; <=1.05 stays "
                     "under threshold)"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
