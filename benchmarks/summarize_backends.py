"""Summarize-backend shootout over (E, n) grids (ISSUE 1 acceptance: the
numpy backend must be >= 5x the python oracle at E >= 256).

Rows: ``summarize[<backend>]_E<E>_n<n>, us_per_call, speedup-vs-python``.
"""
from __future__ import annotations

import os
import time

import numpy as np

#: smoke override (tests/test_benchmarks_smoke.py): "ExN,ExN" pairs
GRID = [tuple(int(v) for v in pair.split("x"))
        for pair in os.environ.get(
            "REPRO_BENCH_SUMMARIZE_GRID",
            "64x256,256x256,256x512,1024x256").split(",") if pair]
BACKENDS = ["python", "numpy", "pallas"]


def _matrix(E: int, n: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    u = np.clip(rng.normal(0.45, 0.3, (E, n)), 0, 1).astype(np.float32)
    for _ in range(E // 4):
        i = int(rng.integers(0, E))
        a = int(rng.integers(0, n))
        u[i, a:min(n, a + int(rng.integers(1, n // 3 + 2)))] = 0
    u[:: max(1, E // 16)] = 0.0          # some all-zero rows
    return u


def _time(fn, reps: int) -> float:
    fn()                                  # warmup (jit/trace)
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    from repro.summarize import get_backend
    rows = []
    for E, n in GRID:
        u = _matrix(E, n)
        base_us = None
        for name in BACKENDS:
            be = get_backend(name)
            if be.name != name:           # unavailable, fell back
                continue
            reps = 1 if name == "python" else (3 if name == "pallas" else 20)
            us = _time(lambda: be.batch_stats(u), reps)
            if name == "python":
                base_us = us
            speedup = f"{base_us / us:.1f}x_vs_python" if base_us else ""
            rows.append((f"summarize[{name}]_E{E}_n{n}", us, speedup))
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r[0]},{r[1]:.1f},{r[2]}")
