"""Table 3 / Fig. 17a-b: profiling overhead on the real training loop.

Measures iteration time with PerfTracker off / attached-idle / actively
profiling, across model configs, plus the off-thread pattern-summarization
and localization times (Fig. 17b)."""
from __future__ import annotations

import os
import time

import numpy as np

from repro.configs.registry import ARCHS, reduced
from repro.data.pipeline import DataConfig
from repro.optim.adamw import OptConfig
from repro.train.loop import TrainConfig, Trainer

#: smoke override (tests/test_benchmarks_smoke.py): "arch:d_model:layers"
#: triples, comma-separated
CONFIGS = [(a, int(d), int(layers)) for a, d, layers in
           (spec.split(":") for spec in os.environ.get(
               "REPRO_BENCH_OVERHEAD_CONFIGS",
               "granite-34b:64:2,granite-34b:128:4,"
               "deepseek-v2-lite-16b:64:3").split(","))]
STEPS = int(os.environ.get("REPRO_BENCH_OVERHEAD_STEPS", "12"))


def _iter_time(trainer, steps=STEPS, warmup=None):
    if warmup is None:
        warmup = min(3, steps - 1)
    params, opt_state, _ = trainer.init_state(resume=False)
    import jax.numpy as jnp
    times = []
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in trainer._next().items()}
        t0 = time.perf_counter()
        params, opt_state, m = trainer._jit_step(params, opt_state, b)
        float(m["loss"])
        if i >= warmup:
            times.append(time.perf_counter() - t0)
    trainer.loader.close()
    return float(np.mean(times))


def run():
    rows = []
    for arch, d_model, layers in CONFIGS:
        cfg = reduced(ARCHS[arch], d_model=d_model, layers=layers)
        data = DataConfig(batch=4, seq_len=64)
        base = Trainer(cfg, data, OptConfig(), TrainConfig(
            steps=1, perftracker=False))
        t_off = _iter_time(base)
        with_pt = Trainer(cfg, data, OptConfig(), TrainConfig(
            steps=1, perftracker=True, pt_window_s=0.5))
        t_idle = _iter_time(with_pt)
        # force a profiling window open during measurement
        with_pt2 = Trainer(cfg, data, OptConfig(), TrainConfig(
            steps=1, perftracker=True, pt_window_s=30.0))
        with_pt2.pt.tracer.start_window()
        t_prof = _iter_time(with_pt2)
        prof = with_pt2.pt.tracer.stop_window()
        t0 = time.perf_counter()
        from repro.core.daemon import summarize_and_upload
        up = summarize_and_upload(prof)
        t_sum = time.perf_counter() - t0
        tag = f"{arch}/d{d_model}xL{layers}"
        rows.append((f"overhead/{tag}/train_s_iter", t_off * 1e6,
                     f"baseline={t_off:.4f}s"))
        rows.append((f"overhead/{tag}/attached_s_iter", t_idle * 1e6,
                     f"delta={100*(t_idle/t_off-1):+.1f}%"))
        rows.append((f"overhead/{tag}/profiling_s_iter", t_prof * 1e6,
                     f"delta={100*(t_prof/t_off-1):+.1f}%"))
        rows.append((f"overhead/{tag}/summarize_s", t_sum * 1e6,
                     f"off-thread; {len(up.payload)}B patterns"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
