"""Figs. 3/5: ring-communication (mu, sigma) signatures and localization
accuracy over randomized slow-link positions/severities."""
from __future__ import annotations

import os

import numpy as np

from repro.core.events import FunctionEvent, Kind, SampleStream, WorkerProfile
from repro.core.localizer import Localizer
from repro.core.patterns import summarize_worker
from repro.core.ring import RingConfig, ring_utilization


def _patterns(n, slow, rho, seed):
    cfg = RingConfig(n_workers=n, n_rings=1, stage_s=0.02, noise=0.01)
    tr = ring_utilization(cfg, 2.0, 2000.0, slow_worker=slow, rho=rho,
                          rng=np.random.default_rng(seed))
    pats = []
    for w in range(n):
        prof = WorkerProfile(
            worker=w, window=(0.0, 2.0),
            events=[FunctionEvent("AllReduce_RING", Kind.COMM, 0.0, 0.5, w)],
            streams={"pcie_tx": SampleStream(2000.0, 0.0, tr[w])})
        pats.append(summarize_worker(prof)["AllReduce_RING"].as_array())
    return np.stack(pats)


def run():
    rows = []
    # healthy vs degraded signature magnitudes (Fig. 3 / Fig. 5)
    healthy = _patterns(16, None, 1.0, 0)
    deg = _patterns(16, 5, 0.5, 0)
    rows.append(("ring/healthy_mu", float(healthy[:, 1].mean()),
                 "Fig3: ~max throughput"))
    rows.append(("ring/slow_worker_mu", float(deg[5, 1]),
                 "Fig5c: ~rho, stable"))
    rows.append(("ring/slow_worker_sigma", float(deg[5, 2]), "low"))
    rows.append(("ring/peer_sigma", float(np.delete(deg[:, 2], 5).mean()),
                 "Fig5b: high fluctuation"))
    # localization accuracy over trials
    hits = trials = 0
    for seed in range(int(os.environ.get("REPRO_BENCH_RING_TRIALS", "10"))):
        rng = np.random.default_rng(seed)
        slow = int(rng.integers(0, 16))
        rho = float(rng.uniform(0.3, 0.7))
        pats = _patterns(16, slow, rho, seed)
        abn = Localizer(seed=seed).localize(
            {"AllReduce_RING": pats.astype(np.float32)},
            {"AllReduce_RING": Kind.COMM})
        trials += 1
        if abn and slow in abn[0].workers.tolist() \
                and len(abn[0].workers) <= 3:
            hits += 1
    rows.append(("ring/localization_accuracy", 100.0 * hits / trials,
                 f"{hits}/{trials} randomized slow links"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(map(str, r)))
