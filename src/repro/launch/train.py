"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --reduced \
      --steps 100 --ckpt-dir /tmp/ckpt

Production posture: ``--mesh single|multi`` builds the 256/512-chip mesh
(placeholder host devices in this container; on real TPU pods the same code
runs under jax.distributed with megascale DCN transport). XLA flags for
compute/comm overlap (latency-hiding scheduler, async collectives) are set
here for TPU targets.
"""
from __future__ import annotations

import argparse
import os

TPU_XLA_FLAGS = " ".join([
    # compute/comm overlap on TPU targets (no-ops on CPU)
    "--xla_tpu_enable_async_collective_fusion=true",
    "--xla_tpu_overlap_compute_collective_tc=true",
    "--xla_tpu_enable_async_all_gather=true",
    "--xla_enable_async_all_reduce=true",
])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--remat", default="none",
                    choices=["none", "dots", "full"])
    ap.add_argument("--mesh", default="none",
                    choices=["none", "single", "multi"])
    ap.add_argument("--grad-compress", default="none",
                    choices=["none", "bf16", "int8"])
    ap.add_argument("--no-perftracker", action="store_true")
    ap.add_argument("--inject-slow-dataloader", type=float, default=0.0,
                    help="seconds of injected storage latency per batch "
                         "after step N/2 (reproduces case C2P1 online)")
    args = ap.parse_args()

    if args.mesh != "none":
        os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count"
                                   "=512 " + os.environ.get("XLA_FLAGS", ""))

    import jax
    from repro.configs.registry import ARCHS, reduced
    from repro.data.pipeline import DataConfig
    from repro.dist.sharding import DistCtx
    from repro.launch.mesh import make_production_mesh
    from repro.optim.adamw import OptConfig
    from repro.train.loop import TrainConfig, Trainer

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = reduced(cfg)

    dist = None
    if args.mesh != "none":
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
        dist = DistCtx.from_mesh(mesh)

    data = DataConfig(batch=args.batch, seq_len=args.seq)
    tc = TrainConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                     ckpt_every=args.ckpt_every, remat=args.remat,
                     perftracker=not args.no_perftracker)
    opt = OptConfig(lr_peak=args.lr, warmup_steps=max(10, args.steps // 20),
                    total_steps=args.steps)
    trainer = Trainer(cfg, data, opt, tc, dist=dist)

    if args.inject_slow_dataloader:
        half = args.steps // 2
        orig_next = trainer.loader.next

        def degrading_next():
            if trainer.loader.step >= half:
                trainer.loader.source.data.delay_s = \
                    args.inject_slow_dataloader
            return orig_next()
        trainer.loader.next = degrading_next
        if trainer.pt:
            trainer._next, _ = trainer.pt.wrap(degrading_next, lambda: None)

    trainer.run()
    if trainer.pt:
        res = trainer.pt.flush()
        if res is not None:
            print(res.report())


if __name__ == "__main__":
    main()
