"""Roofline-term derivation from compiled dry-run artifacts.

compute term    = HLO_FLOPs / (chips x peak FLOP/s)
memory term     = HLO_bytes / (chips x HBM bw)
collective term = collective_bytes / (chips x link bw)

``cost_analysis()`` provides per-device FLOPs/bytes (the compiled module is
the per-device SPMD program). collective_bytes is parsed from
``compiled.as_text()`` — we sum ring-model per-device traffic for every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

Hardware constants: TPU v5e — 197 TFLOP/s bf16 per chip; 819 GB/s HBM;
~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0,
}

_COLL_RE = re.compile(
    r"=\s*(?P<shape>[^\s=]+)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\(",
)
_SHAPE_RE = re.compile(r"([a-z0-9_]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    """Parse e.g. 'bf16[16,128]{1,0}' or tuple '(bf16[..], f32[..])'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # iota form [num_groups, group_size]
        return int(m.group(2))
    return default


@dataclass
class CollectiveStats:
    bytes_by_op: Dict[str, float] = field(default_factory=dict)
    count_by_op: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_op.values())


def parse_collectives(hlo_text: str, num_devices: int) -> CollectiveStats:
    """Per-device ring-model traffic for every collective in the module.

    all-reduce:   2 * size * (n-1)/n      (size = result bytes)
    all-gather:   size * (n-1)/n          (size = result bytes)
    reduce-scatter: size_result * (n-1)   (operand = result * n)
    all-to-all:   size * (n-1)/n
    collective-permute: size
    """
    stats = CollectiveStats()
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        # avoid double counting async -start/-done pairs: skip -done lines
        if f"{op}-done(" in line:
            continue
        size = _shape_bytes(m.group("shape"))
        n = max(2, _group_size(line, num_devices))
        if op == "all-reduce":
            traffic = 2.0 * size * (n - 1) / n
        elif op == "all-gather":
            traffic = size * (n - 1) / n
        elif op == "reduce-scatter":
            traffic = size * (n - 1)
        elif op == "all-to-all":
            traffic = size * (n - 1) / n
        else:  # collective-permute
            traffic = float(size)
        stats.bytes_by_op[op] = stats.bytes_by_op.get(op, 0.0) + traffic
        stats.count_by_op[op] = stats.count_by_op.get(op, 0) + 1
    return stats


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (train, N=active params) / 2*N*D (prefill) /
    2*N*B (decode, one token per sequence)."""
    counts = cfg.param_counts()
    n_active = counts["active"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: one token / seq


def roofline(cost: Dict[str, float], coll: CollectiveStats,
             num_devices: int, model_fl: float) -> Dict[str, float]:
    dev_flops = float(cost.get("flops", 0.0))
    dev_bytes = float(cost.get("bytes accessed", 0.0))
    t_compute = dev_flops / PEAK_FLOPS
    t_memory = dev_bytes / HBM_BW
    t_coll = coll.total_bytes / ICI_BW
    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    hlo_global = dev_flops * num_devices
    # CPU-backend FloatNormalization promotes bf16 math to f32, so f32
    # activation collectives would be bf16 on TPU: adjusted estimate
    # halves f32 collective traffic (documented in EXPERIMENTS §Dry-run).
    bound = max(t_compute, t_memory, t_coll)
    ideal = model_fl / (num_devices * PEAK_FLOPS)
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "hlo_flops_per_dev": dev_flops,
        "hlo_bytes_per_dev": dev_bytes,
        "collective_bytes_per_dev": coll.total_bytes,
        "collective_breakdown": dict(coll.bytes_by_op),
        "collective_counts": dict(coll.count_by_op),
        "model_flops": model_fl,
        "useful_flops_ratio": (model_fl / hlo_global) if hlo_global else 0.0,
        "roofline_fraction": (ideal / bound) if bound else 0.0,
        "step_time_bound_s": bound,
    }
