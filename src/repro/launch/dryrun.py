"""Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
on the production meshes and derive roofline terms from the compiled
artifacts. See DESIGN.md §4/§6 and EXPERIMENTS.md §Dry-run.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-2b \
      --shape train_4k --mesh single
"""
# The very first two lines (before ANY other import): 512 placeholder host
# devices so jax.make_mesh can build the production mesh.
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import shapes_for
from repro.configs.registry import ARCHS, get_arch, get_shape
from repro.dist.sharding import DistCtx
from repro.launch import analysis as an
from repro.launch import hlo_cost
from repro.launch.mesh import make_production_mesh
from repro.models import io as mio
from repro.models.transformer import Transformer
from repro.optim.adamw import AdamW, OptConfig
from repro.train.step import make_prefill_step, make_serve_step, \
    make_train_step

DEFAULT_OUT = Path("reports/dryrun")


def _replicated(mesh):
    return NamedSharding(mesh, P())


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               remat: str = "none", folded: bool = False,
               pad_heads: bool = False, zero1_moe: bool = False,
               serve_no_fsdp: bool = False, accum: int = 1):
    """Builds and lowers the cell's program. Returns (lowered, meta)."""
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    dist = DistCtx.from_mesh(mesh)
    if zero1_moe:
        dist.zero1_moe = True
    if serve_no_fsdp and shape.kind == "decode":
        # serving: weights are read-only — replicate over DP, shard over TP
        # only (llama4's 400B stays FSDP: 50 GB/chip replicated won't fit)
        dist.fsdp = False
    model = Transformer(cfg, dist=dist,
                        remat=remat if shape.kind == "train" else "none",
                        folded=folded, pad_heads=pad_heads)
    specs = mio.input_specs(cfg, shape)
    params_spec = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    ps = dist.params_shardings(params_spec)
    bs = dist.batch_shardings(specs)

    with mesh:
        if shape.kind == "train":
            opt = AdamW(OptConfig())
            opt_spec = jax.eval_shape(opt.init, params_spec)
            # opt state always fully sharded (ZeRO); with zero1_moe the
            # PARAMS are dp-replicated but m/v/master stay dp-sharded
            opt_dist = DistCtx.from_mesh(mesh)
            osh = opt.state_shardings(opt_dist.params_shardings(params_spec),
                                      _replicated(mesh))
            step = make_train_step(model, opt, accum_steps=accum)
            jitted = jax.jit(step, in_shardings=(ps, osh, bs),
                             out_shardings=(ps, osh, None))
            lowered = jitted.lower(params_spec, opt_spec, specs)
        elif shape.kind == "prefill":
            step = make_prefill_step(model)
            jitted = jax.jit(step, in_shardings=(ps, bs))
            lowered = jitted.lower(params_spec, specs)
        else:  # decode
            B = shape.global_batch
            cache_spec = jax.eval_shape(
                lambda: model.init_cache(B, shape.seq_len))
            cs = dist.cache_shardings(cache_spec, B)
            pos_spec = jax.ShapeDtypeStruct((), jnp.int32)
            step = make_serve_step(model)
            jitted = jax.jit(step, in_shardings=(ps, cs, bs,
                                                 _replicated(mesh)),
                             out_shardings=(None, cs))
            lowered = jitted.lower(params_spec, cache_spec, specs, pos_spec)

    meta = {"cfg": cfg, "shape": shape, "mesh": mesh,
            "devices": mesh.size, "params_spec": params_spec}
    return lowered, meta


def analyse(lowered, meta, compile_s: float):
    compiled = lowered.compile()
    cfg, shape = meta["cfg"], meta["shape"]
    n_dev = meta["devices"]

    raw_cost = {}
    try:
        raw_cost = dict(compiled.cost_analysis())
    except Exception as e:  # pragma: no cover
        raw_cost = {"error": str(e)}

    mem = {}
    try:
        ma = compiled.memory_analysis()
        mem = {k: int(getattr(ma, k)) for k in (
            "argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")}
        mem["total_per_device"] = (mem["argument_size_in_bytes"]
                                   + mem["temp_size_in_bytes"])
    except Exception as e:  # pragma: no cover
        mem = {"error": str(e)}

    text = compiled.as_text()
    cost = hlo_cost.expanded_cost(text, n_dev)
    coll = an.CollectiveStats(bytes_by_op=dict(cost.coll_bytes),
                              count_by_op={k: int(v) for k, v in
                                           cost.coll_counts.items()})
    mf = an.model_flops(cfg, shape)
    terms = an.roofline({"flops": cost.flops, "bytes accessed": cost.bytes},
                        coll, n_dev, mf)
    counts = cfg.param_counts()
    return {
        "arch": cfg.name,
        "shape": shape.name,
        "kind": shape.kind,
        "mesh": "x".join(str(s) for s in meta["mesh"].devices.shape),
        "devices": n_dev,
        "compile_s": round(compile_s, 1),
        "hlo_text_bytes": len(text),
        "unknown_trip_loops": cost.unknown_trip_loops,
        "params_total": counts["total"],
        "params_active": counts["active"],
        "memory": mem,
        "raw_cost_flops": float(raw_cost.get("flops", -1.0)),
        "raw_cost_bytes": float(raw_cost.get("bytes accessed", -1.0)),
        "roofline": terms,
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: Path,
             remat: str, folded: bool, force: bool, tag: str = "",
             pad_heads: bool = False, zero1_moe: bool = False,
             serve_no_fsdp: bool = False, accum: int = 1) -> dict:
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    suffix = f"__{tag}" if tag else ""
    out = out_dir / mesh_tag / f"{arch}__{shape_name}{suffix}.json"
    if out.exists() and not force:
        res = json.loads(out.read_text())
        print(f"[skip] {mesh_tag} {arch} {shape_name} (cached)")
        return res
    out.parent.mkdir(parents=True, exist_ok=True)
    t0 = time.time()
    lowered, meta = lower_cell(arch, shape_name, multi_pod, remat, folded,
                               pad_heads, zero1_moe, serve_no_fsdp, accum)
    t_lower = time.time() - t0
    t1 = time.time()
    res = analyse(lowered, meta, t_lower)
    res["lower_s"] = round(t_lower, 1)
    res["compile_s"] = round(time.time() - t1, 1)
    res["remat"] = remat
    res["folded"] = folded
    res["pad_heads"] = pad_heads
    res["zero1_moe"] = zero1_moe
    res["serve_no_fsdp"] = serve_no_fsdp
    res["accum"] = accum
    out.write_text(json.dumps(res, indent=1))
    r = res["roofline"]
    print(f"[ok] {mesh_tag} {arch} {shape_name}{suffix}: "
          f"dominant={r['dominant']} "
          f"tc={r['t_compute_s']:.4f}s tm={r['t_memory_s']:.4f}s "
          f"tcoll={r['t_collective_s']:.4f}s "
          f"useful={r['useful_flops_ratio']:.3f} "
          f"roofline={r['roofline_fraction']:.3f} "
          f"(lower {res['lower_s']}s compile {res['compile_s']}s)",
          flush=True)
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=str(DEFAULT_OUT))
    ap.add_argument("--remat", default="full",
                    choices=["none", "dots", "full"])
    ap.add_argument("--folded", action="store_true",
                    help="balanced causal folding in blocked attention")
    ap.add_argument("--pad-heads", action="store_true",
                    help="phantom-head TP padding (uneven head counts)")
    ap.add_argument("--zero1-moe", action="store_true",
                    help="ZeRO-1 expert weights (no per-layer FSDP gathers)")
    ap.add_argument("--serve-no-fsdp", action="store_true",
                    help="decode cells: replicate weights over DP")
    ap.add_argument("--accum", type=int, default=1,
                    help="gradient-accumulation micro-batches (train)")
    ap.add_argument("--tag", default="", help="result filename suffix")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    out_dir = Path(args.out)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    cells = []
    if args.all:
        for name, cfg in ARCHS.items():
            for shp in shapes_for(cfg):
                cells.append((name, shp.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    failures = []
    for multi in meshes:
        for arch, shp in cells:
            try:
                run_cell(arch, shp, multi, out_dir, args.remat, args.folded,
                         args.force, args.tag, args.pad_heads,
                         args.zero1_moe, args.serve_no_fsdp, args.accum)
            except Exception as e:
                mesh_tag = "2x16x16" if multi else "16x16"
                print(f"[FAIL] {mesh_tag} {arch} {shp}: {e}", flush=True)
                failures.append((mesh_tag, arch, shp, traceback.format_exc()))
    if failures:
        flog = out_dir / "failures.log"
        flog.parent.mkdir(parents=True, exist_ok=True)
        with open(flog, "a") as f:
            for mesh_tag, arch, shp, tb in failures:
                f.write(f"==== {mesh_tag} {arch} {shp}\n{tb}\n")
        print(f"{len(failures)} failures -> {flog}")
        raise SystemExit(1)
    print("dry-run complete")


if __name__ == "__main__":
    main()
