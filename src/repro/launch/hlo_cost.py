"""Trip-count-expanded HLO cost analysis.

``compiled.cost_analysis()`` visits while-loop bodies ONCE, so a model lowered
with lax.scan over L layers under-reports FLOPs/bytes/collectives by ~L x
(verified experimentally — see EXPERIMENTS.md §Dry-run). This module parses
``compiled.as_text()`` and expands costs through the call graph:

  cost(ENTRY) with  cost(while) = trip * cost(body) + trip * cost(cond)
                    cost(fusion/call) = cost at call site (+ dot/conv FLOPs
                                        recursively from the fused comp)

Counted:
  * FLOPs: dot (2*result_numel*K from lhs_contracting_dims), convolution
    (2*result*kernel_spatial*Cin/groups); elementwise ignored (sub-1%).
  * bytes (HBM-traffic model): result bytes once (the write) for every
    counted op, plus operand reads for dot/conv/fusion-boundaries/collectives
    (weights+activations striped from HBM); parameter/constant/tuple/gte/
    bitcast excluded; dynamic-update-slice counted as 2x update (in-place).
    Unfused elementwise chains overcount ~1.5x vs ideal TPU fusion — the
    model is kept consistent across all cells so §Perf deltas are valid.
  * collectives: ring-model per-device traffic by op type.

Trip counts: the while's condition computation contains
``constant(N)`` + ``compare direction=LT`` (lax.scan's canonical form);
fallback trip=1 with a warning flag.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1, "s16": 2, "u16": 2, "bf16": 2,
    "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(\([^)]*\)|[^\s]+)\s+([\w\-]+)(\(.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?(%[\w.\-]+)\s*\(.*\)\s*->")
_CALLS_RE = re.compile(r"(?:calls|body|condition|to_apply)=(%[\w.\-]+)")
_BODY_RE = re.compile(r"body=(%[\w.\-]+)")
_COND_RE = re.compile(r"condition=(%[\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERANDS_RE = re.compile(r"(%[\w.\-]+)")
_CONST_RE = re.compile(r"=\s*s32\[\]\s+constant\((\d+)\)")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_WINDOW_SIZE_RE = re.compile(r"window=\{size=([0-9x]+)")
_FEATURE_GROUPS_RE = re.compile(r"feature_group_count=(\d+)")

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "after-all", "iota", "partition-id",
                   "replica-id"}


def _parse_shape(shape_str: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for m in _SHAPE_RE.finditer(shape_str):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = tuple(int(d) for d in m.group(2).split(",")) \
            if m.group(2) else ()
        out.append((dt, dims))
    return out


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _parse_shape(shape_str):
        total += _DTYPE_BYTES[dt] * (math.prod(dims) if dims else 1)
    return total


def _shape_numel(shape_str: str) -> int:
    total = 0
    for _, dims in _parse_shape(shape_str):
        total += math.prod(dims) if dims else 1
    return total


@dataclass
class Instr:
    name: str
    shape: str
    op: str
    rest: str


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)

    @property
    def has_dus(self) -> bool:
        return any(i.op == "dynamic-update-slice" for i in self.instrs)

    @property
    def has_slice_read(self) -> bool:
        return any(i.op in ("dynamic-slice", "gather") for i in self.instrs)

    def slice_read_bytes(self) -> float:
        return float(sum(_shape_bytes(i.shape) for i in self.instrs
                         if i.op in ("dynamic-slice", "gather")))

    def dus_update_bytes(self) -> float:
        """2x the update-slice bytes of every interior dynamic-update-slice
        (read update + write slice; the carried buffer itself never moves)."""
        total = 0.0
        for i in self.instrs:
            if i.op != "dynamic-update-slice":
                continue
            ops = _OPERANDS_RE.findall(i.rest.split("),")[0] + ")")
            if len(ops) >= 2 and ops[1] in self.shapes:
                total += 2.0 * _shape_bytes(self.shapes[ops[1]])
        return total


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: Dict[str, float] = field(default_factory=dict)
    coll_counts: Dict[str, float] = field(default_factory=dict)
    unknown_trip_loops: int = 0
    # per-(op, shape) aggregated bytes / flops for §Perf debugging
    detail_bytes: Dict[str, float] = field(default_factory=dict)
    detail_flops: Dict[str, float] = field(default_factory=dict)

    def _dadd(self, d: Dict[str, float], key: str, v: float):
        d[key] = d.get(key, 0.0) + v

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0.0) + v * mult
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0.0) + v * mult
        for k, v in other.detail_bytes.items():
            self.detail_bytes[k] = self.detail_bytes.get(k, 0.0) + v * mult
        for k, v in other.detail_flops.items():
            self.detail_flops[k] = self.detail_flops.get(k, 0.0) + v * mult
        self.unknown_trip_loops += other.unknown_trip_loops

    def top_bytes(self, n=15):
        return sorted(self.detail_bytes.items(), key=lambda kv: -kv[1])[:n]

    def top_flops(self, n=15):
        return sorted(self.detail_flops.items(), key=lambda kv: -kv[1])[:n]

    @property
    def collective_total(self) -> float:
        return sum(self.coll_bytes.values())


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.strip()
        if not stripped:
            continue
        hdr = _COMP_HDR_RE.match(stripped)
        if hdr and stripped.endswith("{"):
            cur = Computation(hdr.group(1))
            comps[cur.name] = cur
            if stripped.startswith("ENTRY"):
                entry = cur.name
            continue
        if stripped.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if m:
            ins = Instr(m.group(1), m.group(2), m.group(3), m.group(4))
            cur.instrs.append(ins)
            cur.shapes[ins.name] = ins.shape
    return comps, entry


def _dot_flops(ins: Instr, comp: Computation) -> float:
    result_numel = _shape_numel(ins.shape)
    cm = _CONTRACT_RE.search(ins.rest)
    ops = _OPERANDS_RE.findall(ins.rest.split("),")[0] + ")")
    lhs_shape = None
    for o in ops:
        if o in comp.shapes:
            lhs_shape = comp.shapes[o]
            break
    if lhs_shape is None or cm is None:
        return 2.0 * result_numel  # degenerate fallback
    parsed = _parse_shape(lhs_shape)
    if not parsed:
        return 2.0 * result_numel
    dims = parsed[0][1]
    k = 1
    if cm.group(1):
        for d in cm.group(1).split(","):
            di = int(d)
            if di < len(dims):
                k *= dims[di]
    return 2.0 * result_numel * k


def _conv_flops(ins: Instr, comp: Computation) -> float:
    result_numel = _shape_numel(ins.shape)
    wm = _WINDOW_SIZE_RE.search(ins.rest)
    spatial = 1
    if wm:
        for d in wm.group(1).split("x"):
            spatial *= int(d)
    fg = _FEATURE_GROUPS_RE.search(ins.rest)
    groups = int(fg.group(1)) if fg else 1
    # input feature per group: from rhs shape (kernel) if available
    ops = _OPERANDS_RE.findall(ins.rest)
    cin_per_group = 1
    if len(ops) >= 2 and ops[1] in comp.shapes:
        parsed = _parse_shape(comp.shapes[ops[1]])
        if parsed:
            kd = parsed[0][1]
            if len(kd) >= 2:
                cin_per_group = max(1, math.prod(kd) // (spatial * max(
                    1, kd[-1])))
    return 2.0 * result_numel * spatial * cin_per_group


def _group_size(rest: str, default: int) -> int:
    m = _GROUPS_RE.search(rest)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(rest)
    if m:
        return int(m.group(2))
    return default


def _collective_traffic(op: str, size: float, n: int) -> float:
    if op == "all-reduce":
        return 2.0 * size * (n - 1) / n
    if op == "all-gather":
        return size * (n - 1) / n
    if op == "reduce-scatter":
        return size * (n - 1)
    if op == "all-to-all":
        return size * (n - 1) / n
    return float(size)  # collective-permute


def _trip_count(cond: Computation) -> Optional[int]:
    consts = []
    for ins in cond.instrs:
        if ins.op == "constant" and ins.shape == "s32[]":
            mm = re.match(r"\((\d+)\)", ins.rest)
            if mm:
                consts.append(int(mm.group(1)))
    if consts:
        return max(consts)
    return None


class ModuleCost:
    def __init__(self, text: str, num_devices: int):
        self.comps, self.entry = parse_module(text)
        self.num_devices = num_devices
        self._memo: Dict[str, Cost] = {}

    def compute(self) -> Cost:
        if self.entry is None:
            return Cost()
        return self._cost(self.entry)

    def _cost(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        comp = self.comps.get(name)
        total = Cost()
        if comp is None:
            self._memo[name] = total
            return total
        self._memo[name] = total  # guard cycles
        for ins in comp.instrs:
            op = ins.op
            base = op.replace("-start", "").replace("-done", "")
            if base in COLLECTIVE_OPS:
                if op.endswith("-done"):
                    continue
                size = _shape_bytes(ins.shape)
                n = max(2, _group_size(ins.rest, self.num_devices))
                traffic = _collective_traffic(base, size, n)
                total.coll_bytes[base] = total.coll_bytes.get(base, 0.) \
                    + traffic
                total.coll_counts[base] = total.coll_counts.get(base, 0.) + 1
                total.bytes += 2 * size
                total._dadd(total.detail_bytes, f"{base} {ins.shape}",
                            2 * size)
                continue
            if op == "while":
                body = _BODY_RE.search(ins.rest)
                cond = _COND_RE.search(ins.rest)
                trip = None
                if cond and cond.group(1) in self.comps:
                    trip = _trip_count(self.comps[cond.group(1)])
                if trip is None:
                    trip = 1
                    total.unknown_trip_loops += 1
                if body and body.group(1) in self.comps:
                    total.add(self._cost(body.group(1)), trip)
                if cond and cond.group(1) in self.comps:
                    total.add(self._cost(cond.group(1)), trip)
                continue
            if op in ("fusion", "call", "conditional", "map", "reduce",
                      "reduce-window", "sort", "scatter", "custom-call",
                      "select-and-scatter"):
                # FLOPs (and collectives) from fused dots/convs recursively
                in_place = False
                for cm in _CALLS_RE.finditer(ins.rest):
                    called = self.comps.get(cm.group(1))
                    sub = self._cost(cm.group(1))
                    total.flops += sub.flops
                    for k, v in sub.coll_bytes.items():
                        total.coll_bytes[k] = total.coll_bytes.get(k, 0.) + v
                    for k, v in sub.coll_counts.items():
                        total.coll_counts[k] = total.coll_counts.get(k, 0.) + v
                    if called is not None and called.has_dus:
                        # in-place loop-carried buffer update: count interior
                        # slice traffic only (2x DUS update + slice reads +
                        # fused dot io); the pass-through buffer and full-size
                        # interior selects/copies never move on hardware.
                        in_place = True
                        b = (called.dus_update_bytes()
                             + called.slice_read_bytes())
                        for di in called.instrs:
                            if di.op == "dot":
                                b += self._io_bytes(di, called)
                        total.bytes += b
                        total._dadd(total.detail_bytes,
                                    f"{op}(dus) {ins.shape}", b)
                if not in_place:
                    io = self._fusion_io_bytes(ins, comp)
                    total.bytes += io
                    total._dadd(total.detail_bytes, f"{op} {ins.shape}", io)
                continue
            if op == "dot":
                fl = _dot_flops(ins, comp)
                io = self._io_bytes(ins, comp)
                total.flops += fl
                total.bytes += io
                total._dadd(total.detail_flops, f"dot {ins.shape}", fl)
                total._dadd(total.detail_bytes, f"dot {ins.shape}", io)
                continue
            if op == "convolution":
                total.flops += _conv_flops(ins, comp)
                total.bytes += self._io_bytes(ins, comp)
                continue
            if op == "dynamic-update-slice":
                # in-place: read update + write slice
                ops = _OPERANDS_RE.findall(ins.rest)
                upd = 0
                if len(ops) >= 2 and ops[1] in comp.shapes:
                    upd = _shape_bytes(comp.shapes[ops[1]])
                total.bytes += 2 * upd
                continue
            if op in _SKIP_BYTES_OPS:
                continue
            b = _shape_bytes(ins.shape)  # write-once model
            total.bytes += b
            total._dadd(total.detail_bytes, f"{op} {ins.shape}", b)
        return total

    def _fusion_io_bytes(self, ins: Instr, comp: Computation) -> float:
        """Fusion-boundary traffic. Operands that are read through an
        interior dynamic-slice/gather (e.g. one layer's slice of a stacked
        scan buffer) are counted at the SLICE size, not the full buffer —
        only the slice moves on hardware."""
        rb = _shape_bytes(ins.shape)
        called = None
        for cm in _CALLS_RE.finditer(ins.rest):
            called = self.comps.get(cm.group(1)) or called
        slice_read = called is not None and called.has_slice_read
        b = float(rb)
        arg_str = ins.rest.split("),")[0]
        for o in _OPERANDS_RE.findall(arg_str):
            if o in comp.shapes:
                ob = _shape_bytes(comp.shapes[o])
                if slice_read and ob > 4 * max(rb, 1):
                    continue  # counted via interior slice results below
                b += ob
        if slice_read:
            b += called.slice_read_bytes()
        return b

    def _io_bytes(self, ins: Instr, comp: Computation) -> float:
        b = _shape_bytes(ins.shape)
        arg_str = ins.rest.split("),")[0]
        for o in _OPERANDS_RE.findall(arg_str):
            if o in comp.shapes:
                b += _shape_bytes(comp.shapes[o])
        return b


def expanded_cost(hlo_text: str, num_devices: int) -> Cost:
    return ModuleCost(hlo_text, num_devices).compute()
