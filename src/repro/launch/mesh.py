"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod: (pod=2, data=16, model=16) = 512 chips (pod = DCN axis)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Elastic-scaling entry point: any (shape, axes) the device pool allows."""
    return jax.make_mesh(tuple(shape), tuple(axes))
