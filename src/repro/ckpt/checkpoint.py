"""Sharded checkpointing with async save and elastic restore (no orbax).

Layout:  <dir>/step_<n>/
           meta.json          — tree structure, shapes, dtypes, step, cfg
           <flat_key>.npy     — one array per leaf (gathered logical value)

* ``save`` gathers each (possibly sharded) array and writes it off-thread
  (async) so the training loop is never blocked (paper Fig. 16's off-thread
  summarization is the same pattern).
* ``restore`` reads logical arrays and ``jax.device_put``s them with the
  CURRENT mesh's shardings — the mesh may be a different shape/size than at
  save time (elastic re-mesh after dropping hosts; DESIGN.md §4/§7).
* On a real multi-host pod each host writes only its addressable shards;
  the single-process container writes the full logical value. The format
  (one file per leaf + JSON meta) is host-count independent.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

_EXT_DTYPES = {"bfloat16": ml_dtypes.bfloat16,
               "float8_e4m3fn": ml_dtypes.float8_e4m3fn,
               "float8_e5m2": ml_dtypes.float8_e5m2}


class CheckpointError(RuntimeError):
    """A checkpoint step directory is unusable: missing ``meta.json``,
    unreadable metadata, or a leaf file absent (partial write)."""


def _to_savable(v: np.ndarray) -> np.ndarray:
    if v.dtype.name in _EXT_DTYPES:
        return v.view(np.uint16 if v.dtype.itemsize == 2 else np.uint8)
    return v


def _from_savable(v: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _EXT_DTYPES:
        return v.view(_EXT_DTYPES[dtype_name])
    return v


def _flatten(tree) -> Dict[str, Any]:
    flat = {}

    def one(kp, leaf):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in kp)
        flat[key] = leaf
    jax.tree_util.tree_map_with_path(one, tree)
    return flat


def _unflatten_into(template, flat: Dict[str, np.ndarray]):
    def one(kp, leaf):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in kp)
        arr = flat[key]
        assert tuple(arr.shape) == tuple(leaf.shape), (key, arr.shape,
                                                       leaf.shape)
        return arr
    return jax.tree_util.tree_map_with_path(one, template)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.keep = keep
        self.dir.mkdir(parents=True, exist_ok=True)
        self._pending: Optional[threading.Thread] = None
        self.last_save_s = 0.0
        # a crashed process may leave .tmp_step_* behind; they were never
        # renamed so they are not checkpoints — reclaim the disk
        for p in self.dir.glob(".tmp_step_*"):
            shutil.rmtree(p, ignore_errors=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree, extra: Optional[dict] = None,
             async_: bool = True):
        """Gather + write. With async_, device->host copy happens inline
        (cheap) and file IO goes to a background thread."""
        self.wait()
        flat = {k: np.asarray(jax.device_get(v))
                for k, v in _flatten(tree).items()}
        meta = {"step": step,
                "extra": extra or {},
                "leaves": {k: {"shape": list(v.shape),
                               "dtype": str(v.dtype)}
                           for k, v in flat.items()}}

        def write():
            t0 = time.perf_counter()
            tmp = self.dir / f".tmp_step_{step}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            for k, v in flat.items():
                np.save(tmp / (k.replace("/", "__") + ".npy"),
                        _to_savable(v))
            (tmp / "meta.json").write_text(json.dumps(meta))
            final = self.dir / f"step_{step}"
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)
            self._gc()
            self.last_save_s = time.perf_counter() - t0

        if async_:
            self._pending = threading.Thread(target=write, daemon=True)
            self._pending.start()
        else:
            write()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def _read_meta(self, d: Path) -> dict:
        """Read and validate one step dir's metadata; raises
        ``CheckpointError`` on a torn or corrupt directory."""
        meta_path = d / "meta.json"
        try:
            meta = json.loads(meta_path.read_text())
        except OSError as e:
            raise CheckpointError(f"{d.name}: missing meta.json ({e})")
        except ValueError as e:
            raise CheckpointError(f"{d.name}: corrupt meta.json ({e})")
        for k in meta.get("leaves", {}):
            if not (d / (k.replace("/", "__") + ".npy")).exists():
                raise CheckpointError(
                    f"{d.name}: partial write, leaf {k!r} missing")
        return meta

    def _is_valid(self, d: Path) -> bool:
        try:
            self._read_meta(d)
        except CheckpointError:
            return False
        return True

    def steps(self):
        """Step numbers of the VALID on-disk checkpoints, ascending.  A
        torn ``step_<n>/`` (missing/corrupt meta.json or a leaf .npy gone)
        is never counted, so it can never be selected as "latest"."""
        out = []
        for p in self.dir.glob("step_*"):
            try:
                s = int(p.name.split("_")[1])
            except ValueError:
                continue
            if self._is_valid(p):
                out.append(s)
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, step: int, template, shardings=None
                ) -> Tuple[Any, dict]:
        """Restore into the current mesh: ``shardings`` (pytree matching
        template) may come from a DIFFERENT mesh than at save time.
        Raises ``CheckpointError`` when the step dir is torn/corrupt."""
        self.wait()
        d = self.dir / f"step_{step}"
        meta = self._read_meta(d)
        flat = {}
        for k, info in meta["leaves"].items():
            try:
                arr = np.load(d / (k.replace("/", "__") + ".npy"))
            except (OSError, ValueError) as e:
                raise CheckpointError(f"{d.name}: unreadable leaf "
                                      f"{k!r} ({e})")
            flat[k] = _from_savable(arr, info["dtype"])
        tree = _unflatten_into(template, flat)
        if shardings is not None:
            tree = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s) if s is not None
                else jax.device_put(x), tree, shardings)
        else:
            tree = jax.tree_util.tree_map(jax.device_put, tree)
        return tree, meta
