from repro.ckpt.checkpoint import Checkpointer, CheckpointError  # noqa: F401
from repro.ckpt.recovery import (RecoveryManager,  # noqa: F401
                                 RestoreOutcome, SimTrainState)
