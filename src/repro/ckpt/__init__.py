from repro.ckpt.checkpoint import Checkpointer  # noqa: F401
