"""Checkpoint-aware recovery: the bridge between mitigation plans and
REAL on-disk training state (DESIGN.md §14).

``CHECKPOINT_NOW`` and ``ROLLBACK_TO_CHECKPOINT`` were plan labels until
this module: a ``RecoveryManager`` owns a ``Checkpointer`` plus two hooks
into the live workload —

  * ``snapshot()  -> (step, tree)``   — gather the current training state;
  * ``install(step, tree)``           — push a restored state back in;

so the ``MitigationEngine`` can drive an actual async save for
``CHECKPOINT_NOW`` and, for ``ROLLBACK_TO_CHECKPOINT``, restore the
latest VALID on-disk step into the running workload.  Every rollback is
verified by parameter equality against the saved arrays and reported as a
``RestoreOutcome``; when no usable checkpoint exists the outcome is an
honest failure (``ok=False``) — the engine then cures nothing, the
signature survives verification, and the incident escalates instead of
faking a cure.

Two workload bindings:

  * ``RecoveryManager.for_workload`` — a real workload exposing
    ``snapshot_state``/``install_state`` (``TrainerWorkload``: the live
    params/opt_state of every ``Trainer``);
  * ``RecoveryManager.for_sim`` — simulator scenarios carry a
    ``SimTrainState`` side-car: a small REAL jax pytree advanced one
    optimizer step per profiling window, so catalog rollbacks exercise
    genuine save/restore/verify against disk rather than a label.
"""
from __future__ import annotations

import tempfile
import time
from dataclasses import dataclass
from typing import Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.checkpoint import Checkpointer, CheckpointError


@dataclass
class RestoreOutcome:
    """What one rollback actually did (the goodput accounting unit)."""
    ok: bool
    step: Optional[int] = None
    #: wall-clock restore cost (read + install + verify), seconds
    restore_s: float = 0.0
    #: training steps discarded by rolling back (current - restored)
    lost_steps: int = 0
    #: installed state compared equal, leaf by leaf, to the on-disk arrays
    verified: bool = False
    error: str = ""


def _trees_equal(a, b) -> bool:
    leaves_a = jax.tree_util.tree_leaves(a)
    leaves_b = jax.tree_util.tree_leaves(b)
    if len(leaves_a) != len(leaves_b):
        return False
    return all(np.array_equal(np.asarray(jax.device_get(x)),
                              np.asarray(jax.device_get(y)))
               for x, y in zip(leaves_a, leaves_b))


class SimTrainState:
    """Minimal REAL training state for simulator scenarios: a jax pytree
    (params + first-moment accumulator) advanced one deterministic
    pseudo-SGD step per profiling window.  It is what simulator-backed
    rollbacks save, restore, and verify against disk — the fault world
    stays simulated, the checkpoint path does not."""

    def __init__(self, seed: int = 0, n: int = 64):
        self.step = 0
        rng = np.random.default_rng((int(seed), 0x51))
        self.params = {
            "w": jnp.asarray(rng.standard_normal(n), jnp.float32),
            "mu": jnp.zeros((n,), jnp.float32),
        }

    def advance(self) -> None:
        self.step += 1
        g = jnp.sin(self.params["w"] * float(self.step))
        mu = 0.9 * self.params["mu"] + 0.1 * g
        self.params = {"w": self.params["w"] - 0.01 * mu, "mu": mu}

    def snapshot(self) -> Tuple[int, dict]:
        return self.step, dict(self.params)

    def install(self, step: int, tree: dict) -> None:
        self.step = int(step)
        self.params = {"w": tree["w"], "mu": tree["mu"]}


class RecoveryManager:
    """Owns the checkpoint directory and the live-state hooks for one run.

    ``on_window`` is the cadence hook (periodic saves every ``save_every``
    windows, plus the side-car's step for sim runs); ``checkpoint`` and
    ``rollback`` are the two verbs the ``MitigationEngine`` executes.
    ``save_every=0`` disables periodic saves entirely — the honest-failure
    path: a rollback before any explicit save finds an empty directory.
    """

    def __init__(self, checkpointer: Checkpointer,
                 snapshot: Callable[[], Tuple[int, object]],
                 install: Callable[[int, object], None],
                 advance: Optional[Callable[[], None]] = None,
                 save_every: int = 3):
        self.ckpt = checkpointer
        self._snapshot = snapshot
        self._install = install
        self._advance = advance
        self.save_every = int(save_every)
        self.saved_steps: List[int] = []
        self.outcomes: List[RestoreOutcome] = []
        self._tmp: Optional[tempfile.TemporaryDirectory] = None

    # -- constructors --------------------------------------------------------
    @classmethod
    def for_sim(cls, seed: int = 0, directory: Optional[str] = None,
                save_every: int = 3) -> "RecoveryManager":
        tmp = None
        if directory is None:
            tmp = tempfile.TemporaryDirectory(prefix="repro-ckpt-")
            directory = tmp.name
        st = SimTrainState(seed)
        mgr = cls(Checkpointer(directory), st.snapshot, st.install,
                  advance=st.advance, save_every=save_every)
        mgr.state = st
        mgr._tmp = tmp            # keeps the temp dir alive for the run
        return mgr

    @classmethod
    def for_workload(cls, workload, directory: Optional[str] = None,
                     save_every: int = 3) -> "RecoveryManager":
        """Bind to a live workload exposing ``snapshot_state`` /
        ``install_state`` (e.g. ``TrainerWorkload``)."""
        tmp = None
        if directory is None:
            tmp = tempfile.TemporaryDirectory(prefix="repro-ckpt-")
            directory = tmp.name
        mgr = cls(Checkpointer(directory), workload.snapshot_state,
                  workload.install_state, advance=None,
                  save_every=save_every)
        mgr._tmp = tmp
        return mgr

    # -- cadence -------------------------------------------------------------
    def on_window(self, window: int) -> None:
        """Called once at the top of every profiling window: periodic
        baseline saves, then (for sim runs) one training step."""
        if self.save_every > 0 and window % self.save_every == 0:
            self.checkpoint()
        if self._advance is not None:
            self._advance()

    # -- verbs ---------------------------------------------------------------
    def checkpoint(self, async_: bool = True) -> int:
        """CHECKPOINT_NOW: snapshot the live state and save it (async:
        file IO off-thread, the workload is never blocked)."""
        step, tree = self._snapshot()
        self.ckpt.save(int(step), tree, async_=async_)
        self.saved_steps.append(int(step))
        return int(step)

    def rollback(self) -> RestoreOutcome:
        """ROLLBACK_TO_CHECKPOINT: restore the latest VALID on-disk step
        into the live workload and verify parameter equality against the
        saved arrays.  Never raises — a missing/corrupt checkpoint is an
        honest ``ok=False`` outcome for the engine to act on."""
        t0 = time.perf_counter()
        self.ckpt.wait()
        cur_step, template = self._snapshot()
        step = self.ckpt.latest_step()
        if step is None:
            out = RestoreOutcome(ok=False,
                                 error="no valid checkpoint on disk")
        else:
            try:
                tree, meta = self.ckpt.restore(step, template)
            except CheckpointError as e:
                out = RestoreOutcome(ok=False, step=step, error=str(e))
            else:
                restored_step = int(meta["step"])
                self._install(restored_step, tree)
                _, now = self._snapshot()
                out = RestoreOutcome(
                    ok=True, step=restored_step,
                    restore_s=time.perf_counter() - t0,
                    lost_steps=max(0, int(cur_step) - restored_step),
                    verified=_trees_equal(now, tree))
        self.outcomes.append(out)
        return out

    # -- accounting ----------------------------------------------------------
    @property
    def total_restore_s(self) -> float:
        return sum(o.restore_s for o in self.outcomes)

    @property
    def total_lost_steps(self) -> int:
        return sum(o.lost_steps for o in self.outcomes if o.ok)
