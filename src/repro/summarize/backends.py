"""The three summarize backends (DESIGN.md §3).

``python``  — the reference implementation: a per-row loop around the exact
              Algorithm-1 binary search in ``repro.core.patterns`` (the
              oracle every other backend is tested against).
``numpy``   — batched: all E rows advance one shared binary-search step per
              pass, in *segment space* (one entry per nonzero run instead of
              per sample).  Same selection rules as the Pallas kernel
              (max-mass feasible region, leftmost tie).
``pallas``  — the TPU kernel ``repro.kernels.pattern_summary`` wired into the
              daemon pipeline; interpret mode off-TPU (see ENV_INTERPRET),
              compiled on real hardware.
"""
from __future__ import annotations

import os

import numpy as np

from repro.summarize.base import ENV_INTERPRET, register_backend


class PythonBackend:
    """Row-at-a-time oracle (the pre-refactor hot loop, kept as ground truth)."""

    name = "python"

    def available(self) -> bool:
        return True

    def batch_stats(self, u: np.ndarray) -> np.ndarray:
        from repro.core.patterns import critical_duration
        u = np.asarray(u)
        out = np.zeros((u.shape[0], 3), np.float64)
        for i, row in enumerate(u):
            if float(row.sum()) <= 0.0:
                out[i] = (0.0, 0.0, len(row))
                continue
            lo, hi = critical_duration(row)
            seg = row[lo:hi].astype(np.float64)
            out[i] = (seg.mean(), seg.std(), hi - lo)
        return out


class NumpyBackend:
    """Vectorized Algorithm 1 in *segment space*.

    Each row is compressed once into its nonzero runs (segments): per
    segment, the prefix sum at its end, the prefix sum just before its
    start, and the zero-gap separating it from the previous segment.  The
    binary search over gap bounds then runs entirely on the ``(E, S)``
    segment arrays (S = max segments per row — usually a small fraction of
    n), with each region-start prefix sum recovered gather-free by a cummax
    over the monotone per-segment prefix sums.  Region masses are exactly
    the f32 prefix-sum differences the sample-space formulation computes,
    and segment boundaries are nonzero samples, so region trimming is free.
    Galloping probes (0, then ~doubling from below, capped by the bisection
    midpoint) finish dense rows — whose optimal gap bound is 0-2 — in one
    or two passes."""

    name = "numpy"

    def __init__(self, mass_fraction: float | None = None):
        self.mass_fraction = mass_fraction

    def _mass_fraction(self) -> float:
        if self.mass_fraction is None:
            # single source of truth; late import (patterns imports us back)
            from repro.core.patterns import MASS_FRACTION
            self.mass_fraction = MASS_FRACTION
        return self.mass_fraction

    def available(self) -> bool:
        return True

    def batch_stats(self, u: np.ndarray) -> np.ndarray:
        u = np.ascontiguousarray(u, np.float32)
        E, n = u.shape
        if E == 0 or n == 0:
            return np.zeros((E, 3))
        nz = u > 0.0
        csum = np.cumsum(u, axis=1, dtype=np.float32)
        # float64 row sum, NOT csum[:, -1]: the python oracle's target
        # comes from the same f64 sum (exact for f32 addends, so identical
        # under any zero-padding width), while sequential-f32 cumsum drifts
        # from it by enough to flip borderline feasibility on long rows
        total = u.sum(axis=1, dtype=np.float64)
        target = self._mass_fraction() * total - 1e-9
        empty = total <= 0.0
        all_empty = np.stack([np.zeros(E), np.zeros(E),
                              np.full(E, float(n))], axis=1)

        # -- one-time segmentation: nonzero runs as (row, start, end) -----
        prev = np.empty_like(nz)
        prev[:, 0] = False
        prev[:, 1:] = nz[:, :-1]
        nxt = np.empty_like(nz)
        nxt[:, -1] = False
        nxt[:, :-1] = nz[:, 1:]
        r_st, c_st = np.nonzero(nz & ~prev)          # row-major order
        c_en = np.nonzero(nz & ~nxt)[1]              # pairs with c_st
        if r_st.size == 0:
            return all_empty
        K = np.bincount(r_st, minlength=E)           # segments per row
        S = int(K.max())
        off = np.concatenate([[0], np.cumsum(K)[:-1]])
        o = np.arange(r_st.size) - off[r_st]         # segment ordinal

        BIG = np.int32(n + 1)
        gapb = np.full((E, S), BIG, np.int32)        # zero-gap before seg k
        cs_end = np.full((E, S), -1.0, np.float32)   # csum at segment end
        cs_st0 = np.zeros((E, S), np.float32)        # csum before seg start
        st_col = np.zeros((E, S), np.int32)
        en_col = np.zeros((E, S), np.int32)
        st_col[r_st, o] = c_st
        en_col[r_st, o] = c_en
        cs_end[r_st, o] = csum[r_st, c_en]
        cs_st0[r_st, o] = np.where(
            c_st > 0, csum[r_st, np.maximum(c_st - 1, 0)], np.float32(0.0))
        j = np.flatnonzero(o > 0)  # row-major: entry j-1 is segment o-1
        gapb[r_st[j], o[j]] = c_st[j] - c_en[j - 1] - 1

        # -- binary search over gap bounds, all rows in parallel ----------
        # g* <= the row's largest interior gap (no splits there => one
        # region holding all mass); single-segment rows need no search
        max_gap = np.where(gapb == BIG, 0, gapb).max(axis=1).astype(np.int32)
        best_g = max_gap.copy()
        lo_g = np.zeros((E,), np.int32)
        hi_g = np.where(empty, np.int32(-1), max_gap - 1)

        while True:
            act = lo_g <= hi_g
            if not act.any():
                break
            g = np.minimum((lo_g + hi_g) >> 1,
                           np.where(lo_g == 0, 0, 2 * lo_g))
            split = gapb > g[:, None]                # k=0 always splits
            base = np.maximum.accumulate(
                np.where(split, cs_st0, np.float32(0.0)), axis=1)
            mass = cs_end - base                     # padded entries <= -1
            feas = act & (mass.max(axis=1).astype(np.float64) >= target)
            miss = act & ~feas
            best_g[feas] = g[feas]
            hi_g[feas] = g[feas] - 1
            lo_g[miss] = g[miss] + 1

        # -- best region at g*: max-mass group, leftmost on ties ----------
        split = gapb > best_g[:, None]
        kidx = np.broadcast_to(np.arange(S, dtype=np.int32), (E, S))
        first_k = np.maximum.accumulate(
            np.where(split, kidx, np.int32(0)), axis=1)
        base = np.maximum.accumulate(
            np.where(split, cs_st0, np.float32(0.0)), axis=1)
        best_k = np.argmax(cs_end - base, axis=1)
        ar = np.arange(E)
        lo = st_col[ar, first_k[ar, best_k]]         # already zero-trimmed
        hi = en_col[ar, best_k] + 1

        # -- duration-weighted moments over [lo, hi) ----------------------
        idx = np.broadcast_to(np.arange(n, dtype=np.int32), (E, n))
        inside = (idx >= lo[:, None]) & (idx < hi[:, None])
        cnt = np.maximum((hi - lo).astype(np.float64), 1.0)
        mean = np.where(inside, u, 0).sum(axis=1, dtype=np.float64) / cnt
        var = np.where(inside,
                       np.square(u - mean[:, None].astype(np.float32)),
                       0).sum(axis=1, dtype=np.float64) / cnt
        return np.where(empty[:, None], all_empty,
                        np.stack([mean, np.sqrt(var),
                                  (hi - lo).astype(np.float64)], axis=1))


class PallasBackend:
    """Batches rows through the TPU kernel; interpret mode everywhere else."""

    name = "pallas"

    def __init__(self, block_events: int = 8):
        self.block_events = block_events
        self._jnp = None

    def _modules(self):
        if self._jnp is None:
            import jax.numpy as jnp
            from repro.kernels.ops import pattern_summary
            self._jnp = jnp
            self._kernel = pattern_summary
        return self._jnp, self._kernel

    def available(self) -> bool:
        if self._jnp is not None:
            return True
        # spec lookup first: a jax-free process (no jax installed) must be
        # able to ask 'is pallas available?' without paying the jax import
        import importlib.util
        if importlib.util.find_spec("jax") is None:
            return False
        try:
            self._modules()
            return True
        except Exception:
            return False

    def auto_ok(self) -> bool:
        """Only the ``auto`` default: compiled-on-TPU pallas is fast, the
        interpreter is not — don't auto-pick it on CPU hosts.  Declines
        without importing jax when nothing else has (a TPU training
        process always has jax loaded; a CPU-only daemon may not, and
        probing would cost it the whole jax import)."""
        import sys
        if "jax" not in sys.modules:
            return False
        if not self.available():
            return False
        import jax
        return jax.default_backend() == "tpu"

    def interpret(self) -> bool:
        env = os.environ.get(ENV_INTERPRET)
        if env is not None:
            return env not in ("0", "false", "False")
        import jax
        return jax.default_backend() != "tpu"

    def batch_stats(self, u: np.ndarray) -> np.ndarray:
        jnp, kernel = self._modules()
        E, n = u.shape
        out = np.asarray(kernel(jnp.asarray(u, jnp.float32),
                                block_events=self.block_events,
                                interpret=self.interpret()))
        # kernel reports critical-duration *fraction* of the row width;
        # the protocol wants sample counts
        out = out.astype(np.float64)
        out[:, 2] = np.rint(out[:, 2] * n)
        return out


register_backend("python", PythonBackend)
register_backend("numpy", NumpyBackend)
register_backend("pallas", PallasBackend)
