"""Pack one worker's executions into a batched utilization matrix.

The per-event Python loop in the old ``summarize_worker`` touched one stream
slice at a time; every summarize backend instead wants *all* executions of a
worker as a single zero-padded ``(E, n)`` matrix so Algorithm 1 runs as
row-parallel feasibility passes (DESIGN.md §3).  Trailing zero-padding is
safe: candidate regions are trimmed to nonzero boundaries, so padded tails
never change the selected critical duration — only the engine's weighting
needs the true per-row lengths, which we carry alongside.

``pack_profile`` is also the single place where a function's *kind* decides
which resource stream an execution reads (``kind_of`` overrides beat the
event's own kind — the unified kind-resolution path used by daemon uploads).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from repro.core.events import Kind, RESOURCE_FOR_KIND, WorkerProfile


@dataclass
class PackedEvents:
    """Batched view of one worker's executions (E rows, n_max samples)."""
    u: np.ndarray          # (E, n) float32, zero-padded rows
    lengths: np.ndarray    # (E,) int32 true sample counts per row
    rates: np.ndarray      # (E,) float64 sample rate of each row's stream
    fn_ids: np.ndarray     # (E,) int32 index into ``names``
    names: List[str]       # function id -> identity (first-seen order)

    @property
    def n_events(self) -> int:
        return int(self.u.shape[0])


def resolve_kinds(profile: WorkerProfile,
                  kind_of: Optional[Dict[str, Kind]] = None
                  ) -> Dict[str, Kind]:
    """One kind per function: explicit ``kind_of`` overrides win, otherwise
    the kind of the function's first event. The single source of truth for
    both stream selection (here) and upload payloads (daemon)."""
    kinds: Dict[str, Kind] = dict(kind_of or {})
    for e in profile.events:
        kinds.setdefault(e.name, e.kind)
    return kinds


def pack_profile(profile: WorkerProfile,
                 kind_of: Optional[Dict[str, Kind]] = None
                 ) -> PackedEvents:
    """Build the (E, n) matrix for one worker.

    Events whose stream is missing or whose window is empty are dropped
    (exactly the executions the python oracle skipped).  Reuses a matrix the
    tracer pre-packed onto ``profile.packed`` when no kind overrides are in
    play (overrides can reroute an event to a different stream).

    Stream routing precedence, per event: the event's explicit ``resource``
    field wins outright; else a ``kind_of`` override for its function; else
    the event's own kind (so a name recorded under mixed kinds keeps the
    pre-refactor per-event semantics).  The one-kind-per-function map the
    daemon uploads is ``resolve_kinds`` — same override precedence.
    """
    if not kind_of and getattr(profile, "packed", None) is not None:
        return profile.packed
    override = dict(kind_of or {})

    rows: List[np.ndarray] = []
    rates: List[float] = []
    fn_ids: List[int] = []
    names: List[str] = []
    index: Dict[str, int] = {}
    for e in profile.events:
        kind = override.get(e.name, e.kind)
        stream_name = e.resource or RESOURCE_FOR_KIND[kind]
        stream = profile.streams.get(stream_name)
        if stream is None:
            continue
        u = stream.window(e.start, e.end)
        if len(u) == 0:
            continue
        if e.name not in index:
            index[e.name] = len(names)
            names.append(e.name)
        rows.append(np.asarray(u, np.float32))
        rates.append(stream.rate_hz)
        fn_ids.append(index[e.name])

    E = len(rows)
    n = max((len(r) for r in rows), default=0)
    u = np.zeros((E, n), np.float32)
    lengths = np.zeros((E,), np.int32)
    for i, r in enumerate(rows):
        u[i, :len(r)] = r
        lengths[i] = len(r)
    return PackedEvents(u=u, lengths=lengths,
                        rates=np.asarray(rates, np.float64),
                        fn_ids=np.asarray(fn_ids, np.int32), names=names)
