"""Backend protocol for batched behavior-pattern summarization (DESIGN.md §3).

A *summarize backend* consumes one zero-padded ``(E, n)`` utilization matrix
(one row per function execution, see ``repro.summarize.packing``) and returns
an ``(E, 3)`` float array of per-row critical-duration statistics::

    out[e] = (mean, std, count)

where ``[lo, hi)`` is the Algorithm-1 critical execution duration of row
``e``, ``mean``/``std`` are the population statistics of ``u[e, lo:hi]`` and
``count = hi - lo`` (samples, including interior zeros kept by the gap
bound).  All-zero rows may return any ``count``; the engine overrides them
with the row's true (unpadded) length, so backends need not know padding.

Backends are registered by name and selected per call, per service, or
globally via the ``REPRO_SUMMARIZE_BACKEND`` environment variable
(``python`` | ``numpy`` | ``pallas`` | ``auto``).  ``auto`` (the default)
prefers the fastest backend that can run in this process: ``pallas`` when a
TPU is attached, else ``numpy``.  Unavailable backends fall back down the
chain ``pallas -> numpy -> python`` rather than raising, so a fleet daemon
never dies because its accelerator went away.
"""
from __future__ import annotations

import os
from typing import Callable, Dict, List, Optional, Protocol, runtime_checkable

import numpy as np

ENV_BACKEND = "REPRO_SUMMARIZE_BACKEND"
ENV_INTERPRET = "REPRO_PALLAS_INTERPRET"

#: fallback order used by ``auto`` and by unavailable explicit choices
FALLBACK_CHAIN = ("pallas", "numpy", "python")


@runtime_checkable
class SummarizeBackend(Protocol):
    """Batched Algorithm-1 executor."""

    name: str

    def batch_stats(self, u: np.ndarray) -> np.ndarray:
        """u: (E, n) utilization in [0, 1]. Returns (E, 3) [mean, std, count]."""
        ...

    def available(self) -> bool:
        """Whether this backend can run in the current process."""
        ...


_REGISTRY: Dict[str, Callable[[], SummarizeBackend]] = {}
_INSTANCES: Dict[str, SummarizeBackend] = {}


def register_backend(name: str, factory: Callable[[], SummarizeBackend]) -> None:
    _REGISTRY[name] = factory


def available_backends() -> List[str]:
    """Names of registered backends that report themselves runnable."""
    return [n for n in _REGISTRY if _instance(n).available()]


def _instance(name: str) -> SummarizeBackend:
    if name not in _INSTANCES:
        if name not in _REGISTRY:
            raise KeyError(
                f"unknown summarize backend {name!r}; "
                f"registered: {sorted(_REGISTRY)}")
        _INSTANCES[name] = _REGISTRY[name]()
    return _INSTANCES[name]


def get_backend(name: Optional[str] = None) -> SummarizeBackend:
    """Resolve a backend by explicit name, env var, or ``auto`` fallback.

    An explicit/env choice that is registered but unavailable (e.g. ``pallas``
    with no jax) degrades down FALLBACK_CHAIN instead of raising.
    """
    choice = name or os.environ.get(ENV_BACKEND, "auto")
    if choice != "auto":
        be = _instance(choice)           # unknown names still raise
        if be.available():
            return be
        start = (FALLBACK_CHAIN.index(choice) + 1
                 if choice in FALLBACK_CHAIN else 0)
        chain = FALLBACK_CHAIN[start:]
    else:
        chain = FALLBACK_CHAIN
    for cand in chain:
        if cand not in _REGISTRY:
            continue
        be = _instance(cand)
        # fallback candidates must both claim to be a good default (auto_ok:
        # pallas declines off-TPU, where interpret mode is orders of
        # magnitude slower than numpy) AND run here — auto_ok first, so a
        # declining backend never pays its availability probe (pallas's
        # would import jax into an otherwise jax-free daemon process); an
        # explicit name is only honored verbatim above, never via fallback
        if getattr(be, "auto_ok", be.available)() and be.available():
            return be
    return _instance("python")           # always available
