"""Batched per-worker summarization (DESIGN.md §3): pack -> backend -> reduce.

Replaces the per-event loop of the old ``core.patterns.summarize_worker``:
every execution of every function becomes one row of a single ``(E, n)``
matrix, the selected backend computes all critical-duration statistics in one
batched call, and the duration-weighted per-function reduction (Eq. 4-5) is a
pair of ``bincount`` scatters.  Beta (Eq. 2-3) still comes from the critical
path sweep, which is already event-parallel-free and cheap.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple, Union

import numpy as np

from repro.core.critical_path import critical_time_by_function
from repro.core.events import Kind, WorkerProfile
from repro.summarize.base import SummarizeBackend, get_backend
from repro.summarize.packing import pack_profile, resolve_kinds

BackendLike = Union[str, SummarizeBackend, None]


def _resolve_backend(backend: BackendLike) -> SummarizeBackend:
    if backend is None or isinstance(backend, str):
        return get_backend(backend)
    return backend


def row_weights(u: np.ndarray, stats: np.ndarray, lengths: np.ndarray,
                rates) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-row ``(mean, std, weight)`` under the padding-independence
    conventions every summarization path must share float-exactly (the
    fleet==wire byte-identity invariant hangs on it): all-zero rows weigh
    their true (unpadded) window with zeroed moments, no row can outweigh
    its own window, and the weight is ``|L(e)|`` seconds (count / rate).
    ``rates`` may be a per-row array or a scalar."""
    mean, std, cnt = stats[:, 0], stats[:, 1], stats[:, 2]
    lengths = lengths.astype(np.float64)
    empty = u.sum(axis=1) <= 0.0
    cnt = np.where(empty, lengths, np.minimum(cnt, lengths))
    mean = np.where(empty, 0.0, mean)
    std = np.where(empty, 0.0, std)
    return mean, std, cnt / rates


def summarize_profile(profile: WorkerProfile,
                      kind_of: Optional[Dict[str, Kind]] = None,
                      backend: BackendLike = None,
                      ) -> Tuple[Dict[str, "Pattern"], Dict[str, Kind]]:
    """Per-function behavior patterns + resolved kinds for one worker.

    This is the one summarization entry point: kinds resolve once
    (``kind_of`` overrides beat event kinds) and steer both stream selection
    and the returned kind map the daemon uploads.
    """
    from repro.core.patterns import Pattern   # late: patterns delegates here

    be = _resolve_backend(backend)
    kinds = resolve_kinds(profile, kind_of)
    t0, t1 = profile.window
    # degenerate (zero-width) windows: beta is 0/tiny = 0, matching the
    # fleet-batched path instead of dying on a ZeroDivisionError
    T = max(t1 - t0, np.finfo(float).tiny)
    beta = critical_time_by_function(profile.events, profile.window)

    # every function named by an event gets a pattern, even if all its
    # executions were dropped at pack time (missing stream / empty window)
    names = []
    index: Dict[str, int] = {}
    for e in profile.events:
        if e.name not in index:
            index[e.name] = len(names)
            names.append(e.name)
    F = len(names)
    num_mu = np.zeros((F,))
    num_sig = np.zeros((F,))
    den = np.zeros((F,))

    packed = pack_profile(profile, kind_of)
    if packed.n_events and packed.u.shape[1]:
        stats = np.asarray(be.batch_stats(packed.u), np.float64)
        mean, std, w = row_weights(packed.u, stats, packed.lengths,
                                   packed.rates)
        gid = np.asarray([index[nm] for nm in packed.names],
                         np.int64)[packed.fn_ids]
        num_mu = np.bincount(gid, weights=w * mean, minlength=F)
        num_sig = np.bincount(gid, weights=w * std, minlength=F)
        den = np.bincount(gid, weights=w, minlength=F)

    out: Dict[str, Pattern] = {}
    for j, nm in enumerate(names):
        mu = num_mu[j] / den[j] if den[j] else 0.0
        sigma = num_sig[j] / den[j] if den[j] else 0.0
        out[nm] = Pattern(beta=min(1.0, beta.get(nm, 0.0) / T),
                          mu=min(1.0, mu), sigma=min(1.0, sigma))
    return out, kinds
