"""Batched per-worker summarization (DESIGN.md §3): pack -> backend -> reduce.

Replaces the per-event loop of the old ``core.patterns.summarize_worker``:
every execution of every function becomes one row of a single ``(E, n)``
matrix, the selected backend computes all critical-duration statistics in one
batched call, and the duration-weighted per-function reduction (Eq. 4-5) is a
pair of ``bincount`` scatters.  Beta (Eq. 2-3) still comes from the critical
path sweep, which is already event-parallel-free and cheap.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple, Union

import numpy as np

from repro.core.critical_path import critical_time_by_function
from repro.core.events import Kind, WorkerProfile
from repro.summarize.base import SummarizeBackend, get_backend
from repro.summarize.packing import pack_profile, resolve_kinds

BackendLike = Union[str, SummarizeBackend, None]


def _resolve_backend(backend: BackendLike) -> SummarizeBackend:
    if backend is None or isinstance(backend, str):
        return get_backend(backend)
    return backend


def summarize_profile(profile: WorkerProfile,
                      kind_of: Optional[Dict[str, Kind]] = None,
                      backend: BackendLike = None,
                      ) -> Tuple[Dict[str, "Pattern"], Dict[str, Kind]]:
    """Per-function behavior patterns + resolved kinds for one worker.

    This is the one summarization entry point: kinds resolve once
    (``kind_of`` overrides beat event kinds) and steer both stream selection
    and the returned kind map the daemon uploads.
    """
    from repro.core.patterns import Pattern   # late: patterns delegates here

    be = _resolve_backend(backend)
    kinds = resolve_kinds(profile, kind_of)
    t0, t1 = profile.window
    T = t1 - t0
    beta = critical_time_by_function(profile.events, profile.window)

    # every function named by an event gets a pattern, even if all its
    # executions were dropped at pack time (missing stream / empty window)
    names = []
    index: Dict[str, int] = {}
    for e in profile.events:
        if e.name not in index:
            index[e.name] = len(names)
            names.append(e.name)
    F = len(names)
    num_mu = np.zeros((F,))
    num_sig = np.zeros((F,))
    den = np.zeros((F,))

    packed = pack_profile(profile, kind_of)
    if packed.n_events and packed.u.shape[1]:
        stats = np.asarray(be.batch_stats(packed.u), np.float64)
        mean, std, cnt = stats[:, 0], stats[:, 1], stats[:, 2]
        lengths = packed.lengths.astype(np.float64)
        # padding-independent conventions: all-zero rows weigh their true
        # (unpadded) window; no row can outweigh its own window
        empty = packed.u.sum(axis=1) <= 0.0
        cnt = np.where(empty, lengths, np.minimum(cnt, lengths))
        mean = np.where(empty, 0.0, mean)
        std = np.where(empty, 0.0, std)
        w = cnt / packed.rates                             # |L(e)| seconds
        gid = np.asarray([index[nm] for nm in packed.names],
                         np.int64)[packed.fn_ids]
        num_mu = np.bincount(gid, weights=w * mean, minlength=F)
        num_sig = np.bincount(gid, weights=w * std, minlength=F)
        den = np.bincount(gid, weights=w, minlength=F)

    out: Dict[str, Pattern] = {}
    for j, nm in enumerate(names):
        mu = num_mu[j] / den[j] if den[j] else 0.0
        sigma = num_sig[j] / den[j] if den[j] else 0.0
        out[nm] = Pattern(beta=min(1.0, beta.get(nm, 0.0) / T),
                          mu=min(1.0, mu), sigma=min(1.0, sigma))
    return out, kinds
