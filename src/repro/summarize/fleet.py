"""Fleet-batched summarization: one packed pass across all workers.

``summarize_and_upload`` runs Algorithm 1 per worker — W backend calls, W
msgpack round-trips, W transient pattern dicts — which is the right shape
when every worker's daemon summarizes on its own host and only ~KB payloads
cross the network (DESIGN.md §1).  When the whole fleet's raw profiles are
already in one process (simulation, replay, single-host scaling runs), that
per-worker loop is pure overhead: this module instead

  1. extracts every worker's events into one flat, worker-major table
     (one pass over ΣE events — the only per-event Python left);
  2. packs all executions into ragged ``(ΣE, n)`` batches grouped by stream
     rate (and length-bucketed inside a group to bound padding waste) with
     a single gather from the fleet's concatenated sample streams;
  3. runs the selected backend's ``batch_stats`` once per group;
  4. extracts every worker's critical path in one padded ``(W, E, S)``
     sweep (``repro.core.critical_path``);
  5. scatter-reduces per ``(worker, function)`` straight into the
     ``PatternAggregator``'s columnar ``(W, F, 3)`` buffer — msgpack never
     runs.

The fast path is float-exact against the per-worker loop: backends are
padding-inert, every reduction accumulates sequentially in the same
(worker, event) order via ``bincount``, and moment sums use float64
accumulators (exact for float32 addends at these magnitudes), so diagnoses
are byte-identical between the two paths (tested).  The one documented
exception: a function whose executions land in *different* rate groups or
length buckets (events on differently-sampled or wildly different-duration
streams) accumulates per group first, which can differ from strict event
order in the last ulp.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.critical_path import batched_event_times
from repro.core.events import Kind, RESOURCE_FOR_KIND, WorkerProfile
from repro.summarize.aggregate import PatternAggregator
from repro.summarize.base import SummarizeBackend

#: length-bucket upper bounds inside one rate group (geometric, x4): rows
#: pad to the smallest bucket holding them instead of the group max
_BUCKETS = (32, 128, 512, 2048, 8192, 32768)

_N_KINDS = len(RESOURCE_FOR_KIND)
_KIND_BY_VALUE = [Kind(k) for k in range(_N_KINDS)]


@dataclass
class FleetEvents:
    """Flat worker-major event table for a whole fleet (ΣE rows)."""
    worker: np.ndarray       # (ΣE,) int64 profile index
    starts: np.ndarray       # (ΣE,) float64 raw (unclipped) start
    ends: np.ndarray         # (ΣE,) float64
    kinds: np.ndarray        # (ΣE,) int8
    depth: np.ndarray        # (ΣE,) int16
    train: np.ndarray        # (ΣE,) bool (thread == 'train')
    fid: np.ndarray          # (ΣE,) int64 first-seen id within the worker
    counts: np.ndarray       # (W,) events per worker
    names_w: List[List[str]]           # per worker first-seen names
    windows: np.ndarray      # (W, 2) float64
    resource_fix: List[Tuple[int, str]]  # flat idx -> explicit resource

    @property
    def n_events(self) -> int:
        return int(self.worker.shape[0])


@dataclass
class RateGroup:
    """One ``batch_stats`` batch: rows of one rate and length bucket."""
    rate: float
    u: np.ndarray            # (R, n) float32 zero-padded
    lengths: np.ndarray      # (R,) int64 true sample counts
    rows: np.ndarray         # (R,) int64 index into the flat event table


@dataclass
class FleetBatch:
    """Everything ``summarize_fleet`` needs after the one packing pass."""
    events: FleetEvents
    groups: List[RateGroup]
    col: np.ndarray          # (ΣE,) int64 aggregator column per event
    cols_w: List[np.ndarray]  # per worker: local fid -> aggregator column
    agg: PatternAggregator
    base: int                # first aggregator row of this fleet
    rows: Optional[np.ndarray] = None  # explicit aggregator rows (partial
    #                                    fleets: profile i -> row rows[i])


@dataclass
class FleetSummary:
    """Result of one fleet-batched summarization pass."""
    agg: PatternAggregator
    n_rows: int              # ΣE executions batched across the fleet
    n_groups: int            # (rate, length-bucket) batches
    pattern_bytes: int       # serialized size had the patterns crossed the wire


def extract_events(profiles: Sequence[WorkerProfile]) -> FleetEvents:
    """One pass over every event of every worker into flat numpy columns."""
    W = len(profiles)
    counts = np.fromiter((len(p.events) for p in profiles), np.int64, W)
    total = int(counts.sum())
    all_ev = [e for p in profiles for e in p.events]
    starts = np.array([e.start for e in all_ev], np.float64)
    ends = np.array([e.end for e in all_ev], np.float64)
    kinds = np.array([int(e.kind) for e in all_ev], np.int8)
    depth = np.array([e.depth for e in all_ev], np.int16)
    train = np.array([e.thread == "train" for e in all_ev], bool)
    resource_fix = [(i, e.resource) for i, e in enumerate(all_ev)
                    if e.resource]

    fid_l: List[int] = []
    names_w: List[List[str]] = []
    for p in profiles:
        index: Dict[str, int] = {}
        fid_l += [index.setdefault(e.name, len(index)) for e in p.events]
        names_w.append(list(index))
    fid = np.array(fid_l, np.int64) if total else np.zeros(0, np.int64)
    windows = np.array([p.window for p in profiles], np.float64) \
        if W else np.zeros((0, 2))
    return FleetEvents(
        worker=np.repeat(np.arange(W, dtype=np.int64), counts),
        starts=starts, ends=ends, kinds=kinds, depth=depth, train=train,
        fid=fid, counts=counts, names_w=names_w, windows=windows,
        resource_fix=resource_fix)


def _route_rows(profiles: Sequence[WorkerProfile], ev: FleetEvents,
                kind_of: Optional[Dict[str, Kind]]
                ) -> Tuple[np.ndarray, ...]:
    """Resolve each execution to its stream and sample range.

    Returns flat ``(offset, length, rate, valid)`` arrays — ``offset``
    indexes the fleet-wide concatenation of all sample streams — plus that
    concatenation itself.  Routing precedence matches ``pack_profile``:
    explicit ``resource`` field, else ``kind_of`` override, else the
    event's own kind.
    """
    W = len(profiles)
    resources = [RESOURCE_FOR_KIND[Kind(k)] for k in range(_N_KINDS)]
    # per (worker, kind): the stream a kind-routed event reads — built as
    # flat scalar lists (cheaper than W x K numpy item assignments)
    m_rate: List[float] = []
    m_len: List[int] = []
    m_t0: List[float] = []
    m_base: List[int] = []
    m_ok: List[bool] = []
    chunks: List[np.ndarray] = []
    base = 0
    bases: List[Dict[str, Tuple[int, float, int, float]]] = []
    for p in profiles:
        by_name: Dict[str, Tuple[int, float, int, float]] = {}
        for name, st in p.streams.items():
            by_name[name] = (base, st.rate_hz, len(st.values), st.t0)
            v = np.asarray(st.values)
            chunks.append(v)
            base += len(v)
        bases.append(by_name)
        for sname in resources:
            meta = by_name.get(sname)
            if meta is None:
                m_base.append(0)
                m_rate.append(1.0)
                m_len.append(0)
                m_t0.append(0.0)
                m_ok.append(False)
            else:
                b, r, n, t0 = meta
                m_base.append(b)
                m_rate.append(r)
                m_len.append(n)
                m_t0.append(t0)
                m_ok.append(True)
    s_rate = np.array(m_rate).reshape(W, _N_KINDS)
    s_len = np.array(m_len, np.int64).reshape(W, _N_KINDS)
    s_t0 = np.array(m_t0).reshape(W, _N_KINDS)
    s_base = np.array(m_base, np.int64).reshape(W, _N_KINDS)
    s_ok = np.array(m_ok, bool).reshape(W, _N_KINDS)
    flat = np.concatenate(chunks) if chunks else np.zeros(0)
    if flat.dtype != np.float32:   # one fleet-wide cast (f64->f32 is the
        flat = flat.astype(np.float32)   # same rounding rows get per-worker)

    route = ev.kinds.astype(np.int64)
    if kind_of:
        off = 0
        for w in range(W):
            E = int(ev.counts[w])
            over = np.fromiter(
                (int(kind_of.get(nm, -1)) for nm in ev.names_w[w]),
                np.int64, len(ev.names_w[w]))
            if (over >= 0).any():
                o = over[ev.fid[off:off + E]]
                sl = route[off:off + E]
                route[off:off + E] = np.where(o >= 0, o, sl)
            off += E
    wk = ev.worker
    rate = s_rate[wk, route]
    n_len = s_len[wk, route]
    t0 = s_t0[wk, route]
    offset0 = s_base[wk, route]
    ok = s_ok[wk, route]
    for i, rname in ev.resource_fix:       # explicit resource field wins
        meta = bases[int(wk[i])].get(rname)
        if meta is None:
            ok[i] = False
        else:
            offset0[i], rate[i], n_len[i], t0[i] = meta
            ok[i] = True

    # SampleStream.window semantics, vectorized: i0 = max(0, int(...)),
    # i1 = min(len, int(ceil(...))) — int() truncates toward zero
    i0 = np.maximum(0, np.trunc((ev.starts - t0) * rate).astype(np.int64))
    i1 = np.minimum(n_len,
                    np.ceil((ev.ends - t0) * rate).astype(np.int64))
    lengths = np.maximum(0, i1 - i0)
    valid = ok & (lengths > 0)
    return offset0 + i0, lengths, rate, valid, flat


def pack_fleet(profiles: Sequence[WorkerProfile],
               kind_of: Optional[Dict[str, Kind]] = None,
               agg: Optional[PatternAggregator] = None,
               workers: Optional[Sequence[int]] = None,
               fleet_size: Optional[int] = None) -> FleetBatch:
    """Pack all W workers into per-(rate, length-bucket) ragged batches and
    intern every function into ``agg``'s columns (worker order, so
    first-seen kinds match the streaming upload path).

    ``workers``/``fleet_size`` is the partial-fleet path (wire transport,
    DESIGN.md §8): ``profiles`` covers only the workers whose windows
    arrived, ``workers[i]`` is profile i's GLOBAL worker id, and the
    aggregator reserves the full ``fleet_size`` rows — absent workers keep
    zero rows instead of renumbering the fleet."""
    W = len(profiles)
    rows: Optional[np.ndarray] = None
    if workers is not None:
        rows = np.asarray(list(workers), np.int64)
        if rows.shape != (W,):
            raise ValueError(f"workers {rows.shape} must map each of the "
                             f"{W} profiles to its fleet row")
        n_rows = int(fleet_size if fleet_size is not None
                     else (rows.max() + 1 if W else 0))
        if W and not (0 <= int(rows.min())
                      and int(rows.max()) < n_rows):
            raise ValueError(
                f"worker ids [{int(rows.min())}, {int(rows.max())}] "
                f"outside fleet [0, {n_rows}) — negative ids would "
                "silently wrap into another worker's row")
    else:
        n_rows = W
    if agg is None:
        agg = PatternAggregator(expected_workers=max(1, n_rows))
    base = agg.reserve_workers(n_rows)
    if rows is not None:
        rows = base + rows
    ev = extract_events(profiles)

    # resolve_kinds semantics without a per-event pass: one reversed flat
    # assignment leaves each function's FIRST event kind in place
    n_names = np.fromiter((len(n) for n in ev.names_w), np.int64, W)
    name_off = np.concatenate([[0], np.cumsum(n_names)])
    gidx = (ev.fid + name_off[ev.worker]) if ev.n_events \
        else np.zeros(0, np.int64)
    kfirst = np.zeros(int(name_off[-1]), np.int8)
    kfirst[gidx[::-1]] = ev.kinds[::-1]
    kof = kind_of or {}
    kfirst_l = kfirst.tolist()
    off_l = name_off.tolist()
    cols_flat = np.array(
        [agg.intern(nm, kof[nm] if nm in kof
                    else _KIND_BY_VALUE[kfirst_l[off_l[w] + j]])
         for w, names in enumerate(ev.names_w)
         for j, nm in enumerate(names)], np.int64)
    col = cols_flat[gidx] if ev.n_events else gidx
    cols_w = [cols_flat[name_off[w]:name_off[w + 1]] for w in range(W)]

    offsets, lengths, rates, valid, flat = _route_rows(profiles, ev, kind_of)
    groups: List[RateGroup] = []
    vrows = np.flatnonzero(valid)
    if vrows.size:
        for rate in np.unique(rates[vrows]):
            in_rate = vrows[rates[vrows] == rate]
            glen = lengths[in_rate]
            g_max = int(glen.max())
            caps = [c for c in _BUCKETS if c < g_max] + [g_max]
            lo = 0
            for cap in caps:
                sel = in_rate[(glen > lo) & (glen <= cap)]
                if sel.size == 0:
                    lo = cap
                    continue
                n_b = int(lengths[sel].max())
                ar = np.arange(n_b, dtype=np.int64)
                mask = ar[None, :] < lengths[sel, None]
                idx = (offsets[sel, None] + ar[None, :]) * mask
                u = np.where(mask, flat[idx], np.float32(0.0))
                groups.append(RateGroup(rate=float(rate), u=u,
                                        lengths=lengths[sel], rows=sel))
                lo = cap
    return FleetBatch(events=ev, groups=groups, col=col, cols_w=cols_w,
                      agg=agg, base=base, rows=rows)


def summarize_fleet(profiles: Sequence[WorkerProfile],
                    kind_of: Optional[Dict[str, Kind]] = None,
                    backend=None,
                    agg: Optional[PatternAggregator] = None,
                    workers: Optional[Sequence[int]] = None,
                    fleet_size: Optional[int] = None) -> FleetSummary:
    """The fleet-batched equivalent of W ``summarize_and_upload`` calls.

    Returns a ``FleetSummary`` whose aggregator holds the same ``(W, F, 3)``
    pattern block the streaming upload path would have produced, without
    serializing anything.  ``workers``/``fleet_size`` place a PARTIAL
    fleet's profiles at their global rows (see ``pack_fleet``) so a wire
    window with missing workers aggregates without renumbering.
    """
    from repro.summarize.engine import _resolve_backend, row_weights
    be: SummarizeBackend = _resolve_backend(backend)
    W = len(profiles)
    fb = pack_fleet(profiles, kind_of, agg, workers=workers,
                    fleet_size=fleet_size)
    ev, agg, base = fb.events, fb.agg, fb.base
    F = agg.n_functions
    if W == 0 or F == 0:
        return FleetSummary(agg=agg, n_rows=0, n_groups=0, pattern_bytes=0)

    # -- one batch_stats per group, scatter-reduced over (w, f) bins -------
    num_mu = np.zeros(W * F)
    num_sig = np.zeros(W * F)
    den = np.zeros(W * F)
    n_rows = 0
    for g in fb.groups:
        n_rows += g.u.shape[0]
        stats = np.asarray(be.batch_stats(g.u), np.float64)
        mean, std, wgt = row_weights(g.u, stats, g.lengths, g.rate)
        bins = ev.worker[g.rows] * F + fb.col[g.rows]
        num_mu += np.bincount(bins, weights=wgt * mean, minlength=W * F)
        num_sig += np.bincount(bins, weights=wgt * std, minlength=W * F)
        den += np.bincount(bins, weights=wgt, minlength=W * F)

    den = den.reshape(W, F)
    mu = np.divide(num_mu.reshape(W, F), den,
                   out=np.zeros((W, F)), where=den != 0)
    sig = np.divide(num_sig.reshape(W, F), den,
                    out=np.zeros((W, F)), where=den != 0)
    np.minimum(mu, 1.0, out=mu)
    np.minimum(sig, 1.0, out=sig)

    # -- beta: the whole fleet's critical paths in one padded sweep --------
    eligible = (ev.kinds != int(Kind.PYTHON)) | ev.train
    times = batched_event_times(ev.starts, ev.ends, ev.kinds, ev.depth,
                                eligible, ev.worker, ev.counts, ev.windows)
    T = ev.windows[:, 1] - ev.windows[:, 0]
    beta = np.bincount(ev.worker * F + fb.col, weights=times,
                       minlength=W * F).reshape(W, F)
    beta /= np.maximum(T, np.finfo(float).tiny)[:, None]
    np.minimum(beta, 1.0, out=beta)

    pattern_bytes = _wire_payload_bytes(ev.names_w)
    block = np.stack([beta, mu, sig], axis=2)
    if fb.rows is not None:
        agg.scatter_rows(fb.rows, block)
    else:
        agg.scatter_block(base, block)
    return FleetSummary(agg=agg, n_rows=n_rows, n_groups=len(fb.groups),
                        pattern_bytes=pattern_bytes)


def _wire_payload_bytes(names_w: List[List[str]]) -> int:
    """Exact size of the msgpack uploads the wire path would have sent:
    per worker a map of {name: (float64 beta, mu, sigma, fixint kind)} —
    fixarray(4) + 3 x (0xcb + 8) + 1 = 29 value bytes per function."""
    total = 0
    for names in names_w:
        n = len(names)
        total += 1 if n < 16 else (3 if n < 65536 else 5)   # map header
        for nm in names:
            ln = len(nm.encode())
            total += ln + (1 if ln < 32 else (2 if ln < 256 else 3))
            total += 29
    return total
