"""Incremental columnar pattern aggregation (DESIGN.md §4).

The old ``PerfTrackerService.aggregate`` unpacked *every* worker's msgpack
payload into a Python dict, held all W dicts alive at once, then scattered
them into per-function ``(W, 3)`` arrays allocated per name.  At the paper's
fleet scale (~100k workers x hundreds of functions) that is W transient
dicts plus F separate arrays touched W times each.

``PatternAggregator`` streams instead: each upload is unpacked, scattered
into one growing ``(W_cap, F_cap, 3)`` buffer, and dropped before the next
one is touched.  Function identities are interned once into a column index;
both axes grow geometrically so adding a worker or discovering a new
function is amortized O(1).  ``finalize`` hands the localizer zero-copy
per-function views into the same buffer.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.core.events import Kind


class PatternAggregator:
    """Streaming {function -> (W, 3)} builder over per-worker uploads."""

    def __init__(self, expected_workers: int = 16, expected_functions: int = 32):
        self._names: List[str] = []
        self._col: Dict[str, int] = {}          # function name -> column
        self._kinds: Dict[str, Kind] = {}
        self._buf = np.zeros((max(1, expected_workers),
                              max(1, expected_functions), 3), np.float32)
        self._n_workers = 0

    # -- growth ------------------------------------------------------------
    def _ensure(self, rows: int, cols: int) -> None:
        W_cap, F_cap, _ = self._buf.shape
        if rows <= W_cap and cols <= F_cap:
            return
        new = np.zeros((max(rows, 2 * W_cap) if rows > W_cap else W_cap,
                        max(cols, 2 * F_cap) if cols > F_cap else F_cap, 3),
                       np.float32)
        new[:self._n_workers, :len(self._names)] = \
            self._buf[:self._n_workers, :len(self._names)]
        self._buf = new

    def _intern(self, name: str, kind: Optional[Kind]) -> int:
        j = self._col.get(name)
        if j is None:
            j = len(self._names)
            self._ensure(self._n_workers, j + 1)
            self._col[name] = j
            self._names.append(name)
        if kind is not None and name not in self._kinds:
            self._kinds[name] = kind
        return j

    # -- columnar fast path (fleet-batched summarization) -------------------
    def reserve_workers(self, count: int) -> int:
        """Pre-assign ``count`` worker rows for a block scatter; returns the
        first row id.  Used by the fleet-batched path, which fills whole
        (W, F, 3) blocks at once instead of streaming per-worker dicts."""
        base = self._n_workers
        self._ensure(base + count, len(self._names))
        self._n_workers = base + count
        return base

    def intern(self, name: str, kind: Optional[Kind] = None) -> int:
        """Public column interning: same first-seen-kind semantics the
        streaming path applies upload by upload."""
        return self._intern(name, kind)

    def scatter_block(self, row0: int, block: np.ndarray) -> None:
        """Write a dense (Wb, Fb, 3) pattern block at rows ``row0..`` into
        the first ``Fb`` columns — the direct scatter-reduce target of the
        fleet-batched path (no per-worker dicts, no msgpack)."""
        Wb, Fb = block.shape[0], block.shape[1]
        if row0 + Wb > self._buf.shape[0] or Fb > self._buf.shape[1]:
            raise ValueError("scatter_block outside reserved buffer: call "
                             "reserve_workers/intern first")
        self._buf[row0:row0 + Wb, :Fb] = block

    def scatter_rows(self, rows: np.ndarray, block: np.ndarray) -> None:
        """Write a dense (Wb, Fb, 3) block at explicit (non-contiguous)
        reserved rows — the partial-fleet scatter target: a wire window
        missing workers lands its present rows without renumbering them."""
        rows = np.asarray(rows, np.int64)
        Wb, Fb = block.shape[0], block.shape[1]
        if rows.shape != (Wb,):
            raise ValueError(f"rows {rows.shape} must match block rows {Wb}")
        if (rows.size and (int(rows.min()) < 0
                           or int(rows.max()) >= self._n_workers)) \
                or Fb > self._buf.shape[1]:
            raise ValueError("scatter_rows outside reserved buffer (rows "
                             "must be non-negative — negative indices would "
                             "wrap): call reserve_workers/intern first")
        self._buf[rows, :Fb] = block

    def scatter_cols(self, rows: np.ndarray, cols: np.ndarray,
                     block: np.ndarray) -> None:
        """Write a dense (Wb, Fb, 3) block at explicit reserved rows AND
        explicit interned columns — the collector-tree root's scatter
        target (DESIGN.md §10): each shard frame carries its rack's rows
        over its own function subset, so neither axis is a prefix of the
        root buffer."""
        rows = np.asarray(rows, np.int64)
        cols = np.asarray(cols, np.int64)
        Wb, Fb = block.shape[0], block.shape[1]
        if rows.shape != (Wb,) or cols.shape != (Fb,):
            raise ValueError(f"rows {rows.shape}/cols {cols.shape} must "
                             f"match block ({Wb}, {Fb}, 3)")
        if rows.size and (int(rows.min()) < 0
                          or int(rows.max()) >= self._n_workers):
            raise ValueError("scatter_cols rows outside reserved "
                             f"[0, {self._n_workers})")
        if cols.size and (int(cols.min()) < 0
                          or int(cols.max()) >= len(self._names)):
            raise ValueError("scatter_cols cols outside interned "
                             f"[0, {len(self._names)})")
        self._buf[np.ix_(rows, cols)] = block

    def set_row(self, row: int, pats: Dict[str, np.ndarray],
                kinds: Optional[Dict[str, Kind]] = None) -> int:
        """Scatter one worker's patterns at an explicit reserved row (the
        wire collector's entry: uploads address rows by worker id, and a
        partial window simply leaves absent rows at zero)."""
        if not 0 <= row < self._n_workers:
            raise ValueError(f"row {row} outside reserved "
                             f"[0, {self._n_workers})")
        kinds = kinds or {}
        for name, p in pats.items():
            j = self._intern(name, kinds.get(name))
            self._buf[row, j] = p
        return row

    def add_upload_at(self, upload, row: int) -> int:
        """Unpack one ``PatternUpload`` into an explicit reserved row."""
        pats, kinds = upload.unpack()
        return self.set_row(row, pats, kinds)

    # -- streaming ---------------------------------------------------------
    def add_patterns(self, pats: Dict[str, np.ndarray],
                     kinds: Optional[Dict[str, Kind]] = None) -> int:
        """Scatter one worker's patterns; returns its row id. Functions this
        worker never reported keep the zero pattern (never on its critical
        path) — exactly the old stacking semantics."""
        w = self._n_workers
        self._ensure(w + 1, len(self._names))
        self._n_workers = w + 1
        kinds = kinds or {}
        for name, p in pats.items():
            j = self._intern(name, kinds.get(name))
            self._buf[w, j] = p
        return w

    def add_upload(self, upload) -> int:
        """Unpack one ``PatternUpload`` and fold it in; the transient dict
        dies here — W uploads never coexist as Python objects."""
        pats, kinds = upload.unpack()
        return self.add_patterns(pats, kinds)

    def extend(self, uploads: Iterable) -> "PatternAggregator":
        for u in uploads:
            self.add_upload(u)
        return self

    # -- results -----------------------------------------------------------
    @property
    def n_workers(self) -> int:
        return self._n_workers

    @property
    def n_functions(self) -> int:
        return len(self._names)

    def matrix(self) -> Tuple[np.ndarray, List[str]]:
        """The raw columnar view: ((W, F, 3) float32, column names)."""
        return (self._buf[:self._n_workers, :len(self._names)],
                list(self._names))

    def kinds(self) -> Dict[str, Kind]:
        """First-seen kind per interned function (copy)."""
        return dict(self._kinds)

    def finalize(self, sort_names: bool = True
                 ) -> Tuple[Dict[str, np.ndarray], Dict[str, Kind]]:
        """Localizer-shaped result: {name: (W, 3) zero-copy view}, kinds.

        The views alias the internal buffer: they are valid until the next
        ``add_*`` call (growth may reallocate, freezing old views at stale
        data).  Treat finalize as terminal, or re-call it after adding."""
        mat, names = self.matrix()
        order = sorted(names) if sort_names else names
        return ({n: mat[:, self._col[n], :] for n in order},
                dict(self._kinds))
