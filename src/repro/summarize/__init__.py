"""Backend-pluggable batched summarization (DESIGN.md §3-4).

One pipeline from tracer to fleet-scale localization:

    tracer (pre-packed events) -> pack_profile -> SummarizeBackend
        -> summarize_profile -> daemon upload -> PatternAggregator
        -> Localizer

Backends: ``python`` (oracle loop), ``numpy`` (vectorized feasibility
passes), ``pallas`` (TPU kernel).  Select per call, per service, or via the
``REPRO_SUMMARIZE_BACKEND`` env var.
"""
from repro.summarize.base import (ENV_BACKEND, SummarizeBackend,  # noqa: F401
                                  available_backends, get_backend,
                                  register_backend)
from repro.summarize import backends as _backends  # noqa: F401 (registers)
from repro.summarize.packing import (PackedEvents, pack_profile,  # noqa: F401
                                     resolve_kinds)
from repro.summarize.engine import summarize_profile  # noqa: F401
from repro.summarize.aggregate import PatternAggregator  # noqa: F401
from repro.summarize.fleet import (FleetSummary, pack_fleet,  # noqa: F401
                                   summarize_fleet)
