"""jax version compatibility for SPMD APIs.

``shard_map`` moved from ``jax.experimental.shard_map`` to top-level
``jax.shard_map`` (and its replication-check kwarg was renamed
``check_rep`` -> ``check_vma``) across jax releases; the container pins an
older jax, so call sites go through this shim.
"""
from __future__ import annotations

import inspect

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    if hasattr(jax, "shard_map"):
        sm = jax.shard_map
    else:
        from jax.experimental.shard_map import shard_map as sm
    # the module move and the check_rep -> check_vma rename happened in
    # different releases: probe the actual signature, not the location
    params = inspect.signature(sm).parameters
    kw = ("check_vma" if "check_vma" in params
          else "check_rep" if "check_rep" in params else None)
    kwargs = {kw: check_vma} if kw else {}
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              **kwargs)
