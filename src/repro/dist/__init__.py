from repro.dist.sharding import DistCtx  # noqa: F401
