"""Sharding context for production meshes (DESIGN.md §4).

``DistCtx`` is the one object the model/optimizer/serving layers consult for
placement decisions, derived from the mesh's axis names:

  - ``model`` (a.k.a. tensor-parallel) axis: expert/TP sharding;
  - every other axis ("pod", "data", ...): data-parallel, and — with
    ``fsdp`` on (the default) — parameter sharding a la ZeRO-3: each leaf is
    sharded over the DP axes along its largest divisible dimension and
    gathered on use by XLA's SPMD partitioner.

Numerics never depend on these choices (SPMD resharding is exact); they only
set where bytes live, so the rules below stay deliberately simple and total:
anything indivisible is replicated rather than rejected.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

#: axis names treated as the tensor/model-parallel axis
MODEL_AXIS_NAMES = ("model", "tp")


@dataclass
class DistCtx:
    mesh: Optional[Mesh] = None
    fsdp: bool = True             # ZeRO-3 params over the DP axes
    zero1_moe: bool = False       # experts resident (no per-layer gathers)

    @classmethod
    def from_mesh(cls, mesh: Mesh) -> "DistCtx":
        return cls(mesh=mesh)

    # -- axis bookkeeping --------------------------------------------------
    @property
    def axis_names(self) -> Tuple[str, ...]:
        return tuple(self.mesh.axis_names) if self.mesh is not None else ()

    @property
    def tp_axis(self) -> Optional[str]:
        for a in self.axis_names:
            if a in MODEL_AXIS_NAMES:
                return a
        return None

    @property
    def dp_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in self.axis_names
                     if a not in MODEL_AXIS_NAMES)

    def _size(self, axes) -> int:
        if self.mesh is None:
            return 1
        s = 1
        for a in axes:
            s *= self.mesh.shape[a]
        return s

    @property
    def dp_size(self) -> int:
        return self._size(self.dp_axes)

    @property
    def tp_size(self) -> int:
        return self._size((self.tp_axis,)) if self.tp_axis else 1

    # -- sharding rules ----------------------------------------------------
    def _named(self, spec) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def replicated(self) -> Optional[NamedSharding]:
        """Fully-replicated placement on this mesh (None when unmeshed).

        Needed wherever a shardings PYTREE is built leaf-by-leaf: a None
        leaf inside the tree breaks ``jax.tree_util.tree_map`` structure
        matching (None is an empty subtree, not a leaf), so scalar state
        like the optimizer step must carry a real replicated sharding."""
        if self.mesh is None:
            return None
        return self._named(P())

    def _dp_entry(self):
        dp = self.dp_axes
        return dp if len(dp) > 1 else dp[0]

    def _shard_leaf_fsdp(self, leaf) -> NamedSharding:
        shape = tuple(getattr(leaf, "shape", ()))
        dpn = self.dp_size
        if not self.fsdp or dpn <= 1 or not shape:
            return self._named(P())
        divisible = [i for i, s in enumerate(shape) if s and s % dpn == 0]
        if not divisible:
            return self._named(P())
        ax = max(divisible, key=lambda i: shape[i])
        spec = [None] * len(shape)
        spec[ax] = self._dp_entry()
        return self._named(P(*spec))

    def params_shardings(self, params):
        """ZeRO-3 layout: every leaf sharded over DP along its largest
        divisible dim (replicated when fsdp is off or nothing divides)."""
        return jax.tree_util.tree_map(self._shard_leaf_fsdp, params)

    def _shard_batch_leaf(self, leaf) -> NamedSharding:
        shape = tuple(getattr(leaf, "shape", ()))
        dpn = self.dp_size
        if dpn > 1 and shape and shape[0] % dpn == 0:
            spec = [self._dp_entry()] + [None] * (len(shape) - 1)
            return self._named(P(*spec))
        return self._named(P())

    def batch_shardings(self, batch):
        """Inputs: leading (global-batch) dim over the DP axes."""
        return jax.tree_util.tree_map(self._shard_batch_leaf, batch)

    def cache_shardings(self, cache, batch_size: int):
        """KV caches: the batch dim (whichever axis equals ``batch_size``)
        over DP; everything else replicated."""
        dpn = self.dp_size

        def shard(leaf):
            shape = tuple(getattr(leaf, "shape", ()))
            spec = [None] * len(shape)
            if dpn > 1:
                for i, s in enumerate(shape):
                    if s == batch_size and s % dpn == 0:
                        spec[i] = self._dp_entry()
                        break
            return self._named(P(*spec))
        return jax.tree_util.tree_map(shard, cache)

    # -- activation constraints -------------------------------------------
    def _constrain(self, x, last_axis_tp: bool):
        if self.mesh is None or not getattr(x, "ndim", 0):
            return x
        spec = [None] * x.ndim
        if self.dp_size > 1 and x.shape[0] % self.dp_size == 0:
            spec[0] = self._dp_entry()
        tp = self.tp_axis
        if (last_axis_tp and tp and self.tp_size > 1
                and x.shape[-1] % self.tp_size == 0):
            spec[-1] = tp
        return jax.lax.with_sharding_constraint(x, self._named(P(*spec)))

    def constrain_act(self, x):
        """Activations: batch over DP, feature dim replicated."""
        return self._constrain(x, last_axis_tp=False)

    def constrain_logits(self, x):
        """Logits: batch over DP, vocab over the model axis."""
        return self._constrain(x, last_axis_tp=True)

    def constrain_heads(self, x):
        """Attention tensors (B, S, H, D): batch over DP, heads over the
        model axis (the head counts are padded upstream to divide tp)."""
        if self.mesh is None or getattr(x, "ndim", 0) < 4:
            return x
        spec = [None] * x.ndim
        if self.dp_size > 1 and x.shape[0] % self.dp_size == 0:
            spec[0] = self._dp_entry()
        tp = self.tp_axis
        if tp and self.tp_size > 1 and x.shape[2] % self.tp_size == 0:
            spec[2] = tp
        return jax.lax.with_sharding_constraint(x, self._named(P(*spec)))
