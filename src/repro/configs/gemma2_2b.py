"""gemma2-2b — dense decoder, local+global alternating attention, logit
softcaps. [arXiv:2408.00118; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    source="arXiv:2408.00118; hf",
    num_layers=26,
    d_model=2304,
    vocab_size=256_000,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    mlp="geglu",
    norm="rms",
    post_norms=True,
    scale_embed=True,
    tie_embeddings=True,
    rope_theta=10_000.0,
    sliding_window=4096,
    local_global=True,
    attn_softcap=50.0,
    logit_softcap=30.0,
    attn_scale=256 ** -0.5,
    long_context_ok=False,
    notes=("long_500k skipped: alternating *global* layers are full attention "
           "and need a dense 500k KV cache (see DESIGN.md §6)."),
)
