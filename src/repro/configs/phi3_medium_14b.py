"""phi3-medium-14b — dense decoder, RoPE + SwiGLU + GQA (kv=10).
[arXiv:2404.14219; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3-medium-14b",
    family="dense",
    source="arXiv:2404.14219; unverified",
    num_layers=40,
    d_model=5120,
    vocab_size=100_352,
    num_heads=40,
    num_kv_heads=10,
    head_dim=128,
    d_ff=17_920,
    mlp="swiglu",
    norm="rms",
    tie_embeddings=False,
    rope_theta=10_000.0,
    long_context_ok=False,
    notes="long_500k skipped: pure full attention.",
)
