"""Architecture registry: ``--arch <id>`` resolution + reduced configs for
CPU smoke tests."""
from __future__ import annotations

from typing import Dict

from repro.configs.base import ModelConfig, ShapeConfig, ALL_SHAPES, shapes_for

from repro.configs import (  # noqa: F401
    gemma2_2b,
    granite_34b,
    phi3_medium_14b,
    starcoder2_3b,
    mamba2_2p7b,
    deepseek_v2_lite_16b,
    llama4_maverick_400b_a17b,
    internvl2_1b,
    musicgen_medium,
    zamba2_7b,
)

ARCHS: Dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        gemma2_2b,
        granite_34b,
        phi3_medium_14b,
        starcoder2_3b,
        mamba2_2p7b,
        deepseek_v2_lite_16b,
        llama4_maverick_400b_a17b,
        internvl2_1b,
        musicgen_medium,
        zamba2_7b,
    )
}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    for s in ALL_SHAPES:
        if s.name == name:
            return s
    raise KeyError(f"unknown shape {name!r}")


def reduced(cfg: ModelConfig, *, layers: int = 2, d_model: int = 64,
            vocab: int = 512) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests: small layers/width, few
    experts, tiny embedding tables — structure preserved."""
    head_dim = 16
    n_heads = max(2, min(4, cfg.num_heads)) if cfg.num_heads else 0
    n_kv = max(1, min(n_heads, cfg.num_kv_heads)) if cfg.num_kv_heads else 0
    kw = dict(
        num_layers=layers,
        d_model=d_model,
        vocab_size=vocab,
        d_ff=4 * d_model if cfg.d_ff else 0,
        dense_d_ff=4 * d_model if cfg.dense_d_ff else 0,
        num_heads=n_heads,
        num_kv_heads=n_kv,
        head_dim=head_dim,
        dtype="float32",
        param_dtype="float32",
    )
    if cfg.attention == "mla":
        kw.update(kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
                  v_head_dim=16, head_dim=24)
    if cfg.is_moe:
        kw.update(num_experts=8, top_k=min(2, cfg.top_k),
                  num_shared_experts=min(1, cfg.num_shared_experts),
                  first_dense=min(cfg.first_dense, 1),
                  moe_every=cfg.moe_every,
                  d_ff=2 * d_model)
        if cfg.moe_every > 1 or cfg.first_dense:
            kw["num_layers"] = max(layers, 2 * cfg.moe_every)
    if cfg.ssm_state:
        kw.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=32,
                  ssm_groups=min(cfg.ssm_groups, 2))
    if cfg.shared_attn_every:
        kw.update(num_layers=max(4, layers), shared_attn_every=2)
    if cfg.sliding_window:
        kw.update(sliding_window=64)
    if cfg.frontend_tokens:
        kw.update(frontend_tokens=8)
    return cfg.with_overrides(**kw)


__all__ = ["ARCHS", "get_arch", "get_shape", "reduced", "shapes_for"]
