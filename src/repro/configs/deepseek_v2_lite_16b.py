"""deepseek-v2-lite-16b — MLA (kv_lora=512) + MoE (2 shared + 64 routed,
top-6), first layer dense. [arXiv:2405.04434; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    source="arXiv:2405.04434; hf",
    num_layers=27,
    d_model=2048,
    vocab_size=102_400,
    attention="mla",
    num_heads=16,
    num_kv_heads=16,   # MLA: latent-shared; head count for attention core
    head_dim=192,      # qk_nope + qk_rope
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    d_ff=1408,                 # per routed/shared expert
    dense_d_ff=10_944,         # layer-0 dense MLP
    first_dense=1,
    num_experts=64,
    num_shared_experts=2,
    top_k=6,
    moe_every=1,
    capacity_factor=1.25,
    mlp="swiglu",
    norm="rms",
    tie_embeddings=False,
    rope_theta=10_000.0,
    long_context_ok=False,
    notes="long_500k skipped: full attention (MLA compresses KV but is still "
          "quadratic).",
)
