"""zamba2-7b — hybrid: Mamba2 backbone + shared-weight attention block applied
periodically. [arXiv:2411.15242; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    source="arXiv:2411.15242; unverified",
    num_layers=81,              # mamba2 layers
    d_model=3584,
    vocab_size=32_000,
    attention="gqa",            # the shared attention block
    num_heads=32,
    num_kv_heads=32,
    head_dim=112,               # 3584 / 32
    d_ff=14_336,                # shared block's MLP
    shared_attn_every=6,        # one shared-weight attn block per 6 ssm layers
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_groups=2,
    ssm_chunk=256,
    conv_width=4,
    mlp="swiglu",
    norm="rms",
    tie_embeddings=True,
    rope_theta=10_000.0,
    long_context_ok=True,
    notes="long_500k runs: SSM state is O(1); the shared attention blocks use "
          "a sliding KV window of 4096 in long-context serving (Zamba2-style "
          "hybrid serving; full KV at 500k would defeat the SSM).",
    sliding_window=4096,
)
