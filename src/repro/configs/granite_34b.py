"""granite-34b-code — llama-architecture dense decoder with MQA (kv=1).
[arXiv:2405.04324; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    source="arXiv:2405.04324; hf",
    num_layers=88,
    d_model=6144,
    vocab_size=49_152,
    num_heads=48,
    num_kv_heads=1,
    head_dim=128,
    d_ff=24_576,
    mlp="swiglu",
    norm="rms",
    tie_embeddings=True,
    rope_theta=10_000.0,
    long_context_ok=False,
    notes="long_500k skipped: pure full attention.",
)
