"""internvl2-1b — VLM: InternViT frontend (STUB: precomputed patch
embeddings) + Qwen2-0.5B language trunk (GQA kv=2, qkv bias).
[arXiv:2404.16821; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    source="arXiv:2404.16821; hf",
    num_layers=24,
    d_model=896,
    vocab_size=151_655,
    num_heads=14,
    num_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    mlp="swiglu",
    norm="rms",
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
    frontend="vision",
    frontend_tokens=256,   # one 448x448 tile -> 256 patch embeddings (stub)
    long_context_ok=False,
    notes="vocab 151655 padded to 151808 for 16-way TP (DESIGN.md §4). "
          "long_500k skipped: full attention.",
)
