"""mamba2-2.7b — attention-free SSM with SSD (state-space duality).
[arXiv:2405.21060; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    source="arXiv:2405.21060; unverified",
    num_layers=64,
    d_model=2560,
    vocab_size=50_280,
    attention="none",
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_groups=1,
    ssm_chunk=256,
    conv_width=4,
    norm="rms",
    tie_embeddings=True,
    long_context_ok=True,
    notes="long_500k runs: recurrent state is O(1) in sequence length.",
)
