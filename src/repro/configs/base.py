"""Model / shape configuration system.

Every assigned architecture is expressed as a ``ModelConfig``; input-shape
cells are ``ShapeConfig``. Configs are plain frozen dataclasses so they can be
hashed into jit static args and serialized into checkpoints / dry-run reports.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class ModelConfig:
    # -- identity -----------------------------------------------------------
    name: str = "model"
    family: str = "dense"  # dense | ssm | moe | hybrid | vlm | audio
    source: str = ""       # citation tag, e.g. "arXiv:2408.00118; hf"

    # -- trunk --------------------------------------------------------------
    num_layers: int = 2
    d_model: int = 128
    vocab_size: int = 512
    norm: str = "rms"          # rms | layer
    norm_eps: float = 1e-6
    mlp: str = "swiglu"        # swiglu | geglu | gelu (non-gated)
    d_ff: int = 512
    use_bias: bool = False
    tie_embeddings: bool = True
    scale_embed: bool = False  # gemma: embeddings scaled by sqrt(d_model)
    post_norms: bool = False   # gemma2: post-attn / post-ffn norms
    qkv_bias: bool = False     # qwen2/internvl
    logit_softcap: float = 0.0 # gemma2 final logit soft-capping

    # -- attention ----------------------------------------------------------
    attention: str = "gqa"     # gqa | mla | none
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 32
    rope_theta: float = 10_000.0
    attn_softcap: float = 0.0          # gemma2 attention logit soft-capping
    sliding_window: int = 0            # 0 = full attention
    local_global: bool = False         # gemma2: alternate local(sliding)/global
    attn_scale: float = 0.0            # 0 -> default 1/sqrt(head_dim)

    # -- MLA (deepseek) ------------------------------------------------------
    kv_lora_rank: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128

    # -- MoE ------------------------------------------------------------------
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    moe_every: int = 1          # every k-th layer is MoE (llama4: 2)
    first_dense: int = 0        # first k layers use a dense MLP (deepseek: 1)
    dense_d_ff: int = 0         # d_ff of interleaved/first dense MLPs
    capacity_factor: float = 1.25
    router_noise: float = 0.0
    aux_loss_weight: float = 0.001

    # -- SSM (mamba2 / zamba2) -------------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    ssm_chunk: int = 256
    conv_width: int = 4
    shared_attn_every: int = 0  # zamba2: shared-weight attn block every k ssm layers

    # -- modality frontend stubs -------------------------------------------
    frontend: str = ""          # "" | vision | audio
    frontend_tokens: int = 0    # number of precomputed embedding positions

    # -- numerics -----------------------------------------------------------
    dtype: str = "bfloat16"       # activation/compute dtype
    param_dtype: str = "bfloat16"

    # -- notes / applicability ----------------------------------------------
    long_context_ok: bool = False  # True => supports long_500k cell
    notes: str = ""

    # ---------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 (Megatron-standard) so the
        embedding / LM head shard evenly over a 16-way model axis."""
        return _round_up(self.vocab_size, 256)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    def with_overrides(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # Parameter count (analytic; verified against jax.eval_shape in tests) --
    def param_counts(self) -> dict:
        """Returns dict with total / active / embedding parameter counts."""
        d, ff, V = self.d_model, self.d_ff, self.padded_vocab
        counts = {"embed": V * d}
        L = self.num_layers
        per_layer_attn = 0
        if self.attention == "gqa":
            q = d * self.num_heads * self.head_dim
            kv = 2 * d * self.num_kv_heads * self.head_dim
            o = self.num_heads * self.head_dim * d
            per_layer_attn = q + kv + o
        elif self.attention == "mla":
            qk = self.qk_nope_dim + self.qk_rope_dim
            q = d * self.num_heads * qk
            kv_down = d * (self.kv_lora_rank + self.qk_rope_dim)
            kv_up = self.kv_lora_rank * self.num_heads * (self.qk_nope_dim + self.v_head_dim)
            o = self.num_heads * self.v_head_dim * d
            per_layer_attn = q + kv_down + kv_up + o

        def mlp_params(dff: int) -> int:
            gates = 2 if self.mlp in ("swiglu", "geglu") else 1
            return d * dff * gates + dff * d

        # layer layout
        n_moe, n_dense, n_ssm, n_shared_attn = 0, 0, 0, 0
        if self.family in ("ssm",):
            n_ssm = L
        elif self.family == "hybrid":
            n_ssm = L
            if self.shared_attn_every:
                n_shared_attn = 1  # shared weights, applied many times
        elif self.is_moe:
            for i in range(L):
                if i < self.first_dense or (i % self.moe_every) != (self.moe_every - 1):
                    n_dense += 1
                else:
                    n_moe += 1
        else:
            n_dense = L

        total = counts["embed"]
        active = counts["embed"]
        if not self.tie_embeddings:
            total += V * d
            active += V * d
        # ssm layers
        if n_ssm:
            di, G, S = self.d_inner, self.ssm_groups, self.ssm_state
            conv_ch = di + 2 * G * S
            per_ssm = (d * (2 * di + 2 * G * S + self.ssm_heads)  # in_proj
                       + conv_ch * self.conv_width                 # conv
                       + self.ssm_heads * 2                        # A_log, D
                       + di * d)                                   # out_proj
            total += n_ssm * per_ssm
            active += n_ssm * per_ssm
        if n_shared_attn:
            sa = per_layer_attn if per_layer_attn else (
                d * self.num_heads * self.head_dim * 2
                + 2 * d * self.num_kv_heads * self.head_dim)
            sa += mlp_params(ff)
            total += sa
            # applied L // shared_attn_every times; active counts once per app
            napp = L // max(1, self.shared_attn_every)
            active += sa * 0 + sa  # weights exist once; FLOPs counted separately
        dense_ff = self.dense_d_ff or ff
        total += n_dense * (per_layer_attn + mlp_params(dense_ff))
        active += n_dense * (per_layer_attn + mlp_params(dense_ff))
        if n_moe:
            router = d * self.num_experts
            experts = self.num_experts * mlp_params(ff)
            shared = self.num_shared_experts * mlp_params(ff)
            total += n_moe * (per_layer_attn + router + experts + shared)
            active += n_moe * (per_layer_attn + router
                               + (self.top_k * mlp_params(ff))
                               + shared)
        counts["total"] = total
        counts["active"] = active
        return counts


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode
    notes: str = ""

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode",
                         "one new token against a 32k KV/state cache")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode",
                        "long-context decode; sub-quadratic archs only")

ALL_SHAPES: Tuple[ShapeConfig, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shapes_for(cfg: ModelConfig) -> Tuple[ShapeConfig, ...]:
    """Shape cells that apply to this architecture (long_500k is restricted
    to SSM/hybrid archs; see DESIGN.md §6)."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.long_context_ok:
        out.append(LONG_500K)
    return tuple(out)
