"""llama4-maverick-400b-a17b — MoE 128 routed experts top-1 + shared expert,
interleaved dense/MoE layers (every 2nd layer MoE), GQA kv=8.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

Parameter budget check (ModelConfig.param_counts): 24 MoE layers x 128
experts x 3*5120*8192 ~= 386B routed + dense/attn/shared ~= 400B total,
~17B active (top-1 + shared expert + interleaved dense) — matches 400b-a17b.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
    num_layers=48,
    d_model=5120,
    vocab_size=202_048,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,              # per expert
    dense_d_ff=16_384,      # interleaved dense layers
    num_experts=128,
    num_shared_experts=1,
    top_k=1,
    moe_every=2,            # layers 1,3,5,... are MoE
    capacity_factor=1.25,
    mlp="swiglu",
    norm="rms",
    tie_embeddings=False,
    rope_theta=500_000.0,
    long_context_ok=False,
    notes="long_500k skipped: full attention. Early-fusion multimodal "
          "frontend out of scope (text trunk only, per assignment).",
)
