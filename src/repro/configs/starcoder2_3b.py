"""starcoder2-3b — dense decoder, GQA (kv=2) + RoPE, non-gated GELU MLP with
biases and LayerNorm (BigCode family). [arXiv:2402.19173; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    source="arXiv:2402.19173; hf",
    num_layers=30,
    d_model=3072,
    vocab_size=49_152,
    num_heads=24,
    num_kv_heads=2,
    head_dim=128,
    d_ff=12_288,
    mlp="gelu",
    norm="layer",
    use_bias=True,
    tie_embeddings=True,
    rope_theta=100_000.0,
    sliding_window=4096,
    long_context_ok=False,
    notes="long_500k skipped: full/sliding attention hybrid trained at 16k.",
)
