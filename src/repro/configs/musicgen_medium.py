"""musicgen-medium — decoder-only transformer over EnCodec tokens (frontend
STUB: precomputed frame embeddings), MHA (kv=24), LayerNorm + GELU.
[arXiv:2306.05284; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    source="arXiv:2306.05284; hf",
    num_layers=48,
    d_model=1536,
    vocab_size=2048,
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    mlp="gelu",
    norm="layer",
    use_bias=True,
    tie_embeddings=False,
    rope_theta=10_000.0,   # positional handling simplified to RoPE trunk-side
    frontend="audio",
    frontend_tokens=0,     # frame embeddings replace token embeddings 1:1
    long_context_ok=False,
    notes="EnCodec codebook interleaving handled by the stub frontend; trunk "
          "sees one embedding per frame. long_500k skipped: full attention.",
)
