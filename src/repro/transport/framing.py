"""Wire framing for PerfTracker pattern uploads (DESIGN.md §8).

One frame = a 4-byte big-endian unsigned length prefix followed by exactly
that many bytes of msgpack.  Length-prefixing (rather than delimiters) is
what lets ~KB binary payloads — the msgpack pattern dicts the daemon
already produces — cross the socket untouched, and what makes partial
reads trivial to resume: a ``FrameDecoder`` buffers bytes from *any* recv
boundary and yields only complete frames.

Every frame body is a msgpack map with a ``"t"`` type tag:

  ``hello``        client -> server   {worker}
  ``upload``       client -> server   {window, worker, seq, payload,
                                       summarize_s, raw_bytes}
  ``window_end``   client -> server   {window, worker, sent, dropped}
                   (cumulative counters; ``dropped`` is the client-side
                   backpressure drop count — the collector's loss
                   accounting rides on this frame, which is never dropped)
  ``window_start`` server -> client   {window, rates | None, stop: False}
  ``stop``         server -> client   {}
  ``bye``          client -> server   {worker}

The per-frame size cap rejects corrupt prefixes before they turn into a
multi-GB allocation; real pattern uploads are ~KB (paper Fig. 11).
"""
from __future__ import annotations

import struct
from typing import Dict, Iterator, List, Optional, Tuple

import msgpack

#: frames above this are a protocol error (pattern uploads are ~KB; the
#: largest legitimate frame is a window_start carrying one float per worker)
MAX_FRAME_BYTES = 16 * 1024 * 1024

_LEN = struct.Struct(">I")


def encode_frame(msg: Dict) -> bytes:
    """Serialize one protocol message into a length-prefixed frame."""
    body = msgpack.packb(msg, use_bin_type=True)
    if len(body) > MAX_FRAME_BYTES:
        raise ValueError(f"frame body {len(body)}B exceeds "
                         f"MAX_FRAME_BYTES={MAX_FRAME_BYTES}")
    return _LEN.pack(len(body)) + body


def decode_frames(data: bytes) -> List[Dict]:
    """Decode a byte string holding zero or more COMPLETE frames (tests /
    one-shot paths; streaming callers use ``FrameDecoder``)."""
    dec = FrameDecoder()
    out = list(dec.feed(data))
    if dec.pending_bytes:
        raise ValueError(f"{dec.pending_bytes} trailing bytes do not form "
                         "a complete frame")
    return out


class FrameDecoder:
    """Incremental frame reassembly over an arbitrary byte stream.

    ``feed`` accepts whatever one ``recv`` returned — half a length prefix,
    three frames and a torn fourth — and yields each message exactly once,
    as soon as its final byte arrives.
    """

    def __init__(self) -> None:
        self._buf = bytearray()
        self._need: Optional[int] = None     # body length once prefix parsed

    @property
    def pending_bytes(self) -> int:
        return len(self._buf)

    def feed(self, data: bytes) -> Iterator[Dict]:
        self._buf += data
        while True:
            if self._need is None:
                if len(self._buf) < _LEN.size:
                    return
                (self._need,) = _LEN.unpack_from(self._buf)
                if self._need > MAX_FRAME_BYTES:
                    raise ValueError(
                        f"frame length {self._need}B exceeds "
                        f"MAX_FRAME_BYTES={MAX_FRAME_BYTES} "
                        "(corrupt stream?)")
                del self._buf[:_LEN.size]
            if len(self._buf) < self._need:
                return
            body = bytes(self._buf[:self._need])
            del self._buf[:self._need]
            self._need = None
            yield msgpack.unpackb(body, raw=False, strict_map_key=False)


# -- message constructors (one place defines the schema) ----------------------

def hello_msg(worker: int) -> Dict:
    return {"t": "hello", "worker": int(worker)}


def upload_msg(window: int, upload, seq: int) -> Dict:
    """Wrap a ``repro.core.daemon.PatternUpload`` for the wire."""
    return {"t": "upload", "window": int(window), "worker": int(upload.worker),
            "seq": int(seq), "payload": upload.payload,
            "summarize_s": float(upload.summarize_s),
            "raw_bytes": int(upload.raw_bytes)}


def msg_to_upload(msg: Dict) -> Tuple[int, "PatternUpload"]:
    """Inverse of ``upload_msg``: (window, PatternUpload)."""
    from repro.core.daemon import PatternUpload   # late: avoid import cycle
    return int(msg["window"]), PatternUpload(
        worker=int(msg["worker"]), payload=msg["payload"],
        summarize_s=float(msg["summarize_s"]),
        raw_bytes=int(msg["raw_bytes"]))


def window_end_msg(window: int, worker: int, sent: int, dropped: int) -> Dict:
    return {"t": "window_end", "window": int(window), "worker": int(worker),
            "sent": int(sent), "dropped": int(dropped)}


def window_start_msg(window: int, rates=None, stop: bool = False) -> Dict:
    return {"t": "window_start", "window": int(window),
            "rates": (None if rates is None
                      else [float(r) for r in rates]),
            "stop": bool(stop)}


def stop_msg() -> Dict:
    return {"t": "stop"}


def bye_msg(worker: int) -> Dict:
    return {"t": "bye", "worker": int(worker)}
