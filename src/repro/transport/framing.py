"""Wire framing for PerfTracker pattern uploads (DESIGN.md §8, §10).

One frame = a 4-byte big-endian unsigned length prefix followed by exactly
that many bytes of msgpack.  Length-prefixing (rather than delimiters) is
what lets ~KB binary payloads — the msgpack pattern dicts the daemon
already produces — cross the socket untouched, and what makes partial
reads trivial to resume: a ``FrameDecoder`` buffers bytes from *any* recv
boundary and yields only complete frames.

Every frame body is a msgpack map with a ``"t"`` type tag:

  ``hello``        client -> server   {worker, role?, token?}
                   (``token`` is the optional shared-secret for an
                   authenticated collector; ``role`` distinguishes leaf
                   uplinks of a collector tree from worker daemons)
  ``upload``       client -> server   {window, worker, seq, payload,
                                       summarize_s, raw_bytes}
  ``window_end``   client -> server   {window, worker, sent, dropped,
                                       reconnects}
                   (cumulative counters; ``dropped`` is the client-side
                   backpressure drop count and ``reconnects`` the number
                   of times the client re-dialed the collector — loss
                   accounting rides on this frame, which is never dropped)
  ``anchors``      client -> server   {window, worker, durs, numerics?,
                   slo?} (a REAL workload's measured per-iteration
                   durations for the window — the parent merges them into
                   the job-level detector stream; control grade, never
                   dropped.  ``numerics`` optionally carries per-iteration
                   (loss, grad_norm) pairs for the numerics channel;
                   ``slo`` carries (p99_ttft, p99_tbt) pairs for the
                   serving latency-SLO channel)
  ``shard``        leaf -> root       one COMPACTED rack window: packed
                   columnar patterns (float32 rows), present workers,
                   missing/dup/drop counters (DESIGN.md §10)
  ``window_start`` server -> client   {window, rates | None, stop: False,
                                       membership?, plans?}
                   (``membership`` is the current training-mesh worker
                   set and ``plans`` the mitigation actions applied since
                   the previous window — the control-plane deltas worker
                   processes replay onto their own simulators)
  ``stop``         server -> client   {}
  ``bye``          client -> server   {worker}

The per-frame size cap rejects corrupt prefixes before they turn into a
multi-GB allocation.  Most frames are ~KB (paper Fig. 11), but the cap is
DERIVED from fleet size when known (``max_frame_bytes``): a ``window_start``
carries one rate per worker plus membership and mitigation deltas, and a
per-shard compaction frame carries a whole rack's columnar pattern block —
at W=1024+ those legitimately outgrow any fixed small bound.
"""
from __future__ import annotations

import struct
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import msgpack

#: default per-frame cap when the fleet size is unknown (pattern uploads
#: are ~KB; this bound only exists to reject corrupt length prefixes)
MAX_FRAME_BYTES = 16 * 1024 * 1024

#: per-worker budget for fleet-shaped frames: a worker's share of a shard
#: frame (F functions x 3 float32 + interned names) plus its entries in
#: window_start rates/membership/plan deltas, with generous headroom
PER_WORKER_FRAME_BYTES = 16 * 1024

#: fleet-size-independent headroom (frame schema, names, counters)
FRAME_OVERHEAD_BYTES = 1024 * 1024


def max_frame_bytes(fleet_size: Optional[int] = None) -> int:
    """The per-frame size cap for a deployment of ``fleet_size`` workers.

    ``None`` (unknown fleet) keeps the fixed default; otherwise the cap
    grows linearly with the fleet so the legitimate big frames — a
    ``window_start`` carrying per-worker rates + membership + mitigation
    deltas, a per-shard columnar compaction frame — are never rejected at
    scale, while corrupt prefixes still die quickly."""
    if fleet_size is None:
        return MAX_FRAME_BYTES
    return max(MAX_FRAME_BYTES,
               FRAME_OVERHEAD_BYTES
               + PER_WORKER_FRAME_BYTES * int(fleet_size))


_LEN = struct.Struct(">I")


def encode_frame(msg: Dict, max_frame: Optional[int] = None) -> bytes:
    """Serialize one protocol message into a length-prefixed frame."""
    cap = MAX_FRAME_BYTES if max_frame is None else int(max_frame)
    body = msgpack.packb(msg, use_bin_type=True)
    if len(body) > cap:
        raise ValueError(f"frame body {len(body)}B exceeds "
                         f"max frame size {cap}B")
    return _LEN.pack(len(body)) + body


def decode_frames(data: bytes, max_frame: Optional[int] = None) -> List[Dict]:
    """Decode a byte string holding zero or more COMPLETE frames (tests /
    one-shot paths; streaming callers use ``FrameDecoder``)."""
    dec = FrameDecoder(max_frame=max_frame)
    out = list(dec.feed(data))
    if dec.pending_bytes:
        raise ValueError(f"{dec.pending_bytes} trailing bytes do not form "
                         "a complete frame")
    return out


class FrameDecoder:
    """Incremental frame reassembly over an arbitrary byte stream.

    ``feed`` accepts whatever one ``recv`` returned — half a length prefix,
    three frames and a torn fourth — and yields each message exactly once,
    as soon as its final byte arrives.  ``max_frame`` bounds a single
    frame (``max_frame_bytes(fleet_size)`` for fleet-shaped streams)."""

    def __init__(self, max_frame: Optional[int] = None) -> None:
        self.max_frame = MAX_FRAME_BYTES if max_frame is None \
            else int(max_frame)
        self._buf = bytearray()
        self._need: Optional[int] = None     # body length once prefix parsed

    @property
    def pending_bytes(self) -> int:
        return len(self._buf)

    def feed(self, data: bytes) -> Iterator[Dict]:
        self._buf += data
        while True:
            if self._need is None:
                if len(self._buf) < _LEN.size:
                    return
                (self._need,) = _LEN.unpack_from(self._buf)
                if self._need > self.max_frame:
                    raise ValueError(
                        f"frame length {self._need}B exceeds "
                        f"max frame size {self.max_frame}B "
                        "(corrupt stream?)")
                del self._buf[:_LEN.size]
            if len(self._buf) < self._need:
                return
            body = bytes(self._buf[:self._need])
            del self._buf[:self._need]
            self._need = None
            yield msgpack.unpackb(body, raw=False, strict_map_key=False)


# -- message constructors (one place defines the schema) ----------------------

def hello_msg(worker: int, token: Optional[str] = None,
              role: str = "worker") -> Dict:
    msg: Dict = {"t": "hello", "worker": int(worker)}
    if role != "worker":
        msg["role"] = str(role)
    if token is not None:
        msg["token"] = str(token)
    return msg


def upload_msg(window: int, upload, seq: int) -> Dict:
    """Wrap a ``repro.core.daemon.PatternUpload`` for the wire."""
    return {"t": "upload", "window": int(window), "worker": int(upload.worker),
            "seq": int(seq), "payload": upload.payload,
            "summarize_s": float(upload.summarize_s),
            "raw_bytes": int(upload.raw_bytes)}


def msg_to_upload(msg: Dict) -> Tuple[int, "PatternUpload"]:
    """Inverse of ``upload_msg``: (window, PatternUpload)."""
    from repro.core.daemon import PatternUpload   # late: avoid import cycle
    return int(msg["window"]), PatternUpload(
        worker=int(msg["worker"]), payload=msg["payload"],
        summarize_s=float(msg["summarize_s"]),
        raw_bytes=int(msg["raw_bytes"]))


def window_end_msg(window: int, worker: int, sent: int, dropped: int,
                   reconnects: int = 0) -> Dict:
    return {"t": "window_end", "window": int(window), "worker": int(worker),
            "sent": int(sent), "dropped": int(dropped),
            "reconnects": int(reconnects)}


def anchors_msg(window: int, worker: int, durations: Sequence[float],
                numerics: Optional[Sequence[Tuple[float, float]]] = None,
                slo: Optional[Sequence[Tuple[float, float]]] = None
                ) -> Dict:
    """Per-window anchor report of a REAL workload (DESIGN.md §11): the
    worker's measured iteration durations, in iteration order.  Control
    grade — sent undroppable, because the job-level iteration detector's
    (D, O) stream is merged from these.

    ``numerics`` optionally rides along: per-iteration (loss, grad_norm)
    pairs for the numerics channel (DESIGN.md §12a).  ``slo`` does the
    same for serving workloads: per-iteration (p99_ttft, p99_tbt) pairs
    for the latency-SLO channel (DESIGN.md §13).  Each field is only
    present when provided, so workloads without those streams produce
    byte-identical frames to the earlier wire formats."""
    msg = {"t": "anchors", "window": int(window), "worker": int(worker),
           "durs": [float(d) for d in durations]}
    if numerics is not None:
        msg["numerics"] = [[float(a), float(b)] for a, b in numerics]
    if slo is not None:
        msg["slo"] = [[float(a), float(b)] for a, b in slo]
    return msg


def window_start_msg(window: int, rates=None, stop: bool = False,
                     membership: Optional[Sequence[int]] = None,
                     plans: Optional[List[Dict]] = None) -> Dict:
    """Per-window control frame.  ``membership`` (current training-mesh
    worker ids) and ``plans`` (mitigation deltas applied since the last
    window, see ``repro.online.mitigation.plan_to_wire``) are the §10
    control plane: worker processes replay them onto their own simulators
    and collectors re-key their expected sets."""
    msg: Dict = {"t": "window_start", "window": int(window),
                 "rates": (None if rates is None
                           else [float(r) for r in rates]),
                 "stop": bool(stop)}
    if membership is not None:
        msg["membership"] = [int(w) for w in membership]
    if plans:
        msg["plans"] = list(plans)
    return msg


def shard_msg(window: int, shard: int, workers: Sequence[int],
              names: Sequence[str], kinds: Sequence[int], rows: bytes,
              missing: Sequence[int], duplicates: int, client_dropped: int,
              reconnects: int, raw_bytes: int, pattern_bytes: int,
              summarize_s: float, timed_out: bool) -> Dict:
    """One compacted rack window, leaf -> root (DESIGN.md §10).

    ``rows`` is the packed columnar pattern block: float32 little-endian
    ``(len(workers), len(names), 3)``, row ``i`` belonging to
    ``workers[i]`` (ascending).  One shard frame replaces the rack's
    2xW_rack upload/window_end frames at the root, so root ingress is
    O(shards) frames per window."""
    return {"t": "shard", "window": int(window), "shard": int(shard),
            "workers": [int(w) for w in workers],
            "missing": [int(w) for w in missing],
            "names": [str(n) for n in names],
            "kinds": [int(k) for k in kinds],
            "rows": bytes(rows),
            "duplicates": int(duplicates),
            "client_dropped": int(client_dropped),
            "reconnects": int(reconnects),
            "raw_bytes": int(raw_bytes),
            "pattern_bytes": int(pattern_bytes),
            "summarize_s": float(summarize_s),
            "timed_out": bool(timed_out)}


def stop_msg() -> Dict:
    return {"t": "stop"}


def bye_msg(worker: int) -> Dict:
    return {"t": "bye", "worker": int(worker)}
