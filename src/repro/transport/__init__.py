"""Real wire transport for PerfTracker pattern uploads (DESIGN.md §8,
§10): length-prefixed msgpack framing over Unix/TCP sockets, per-worker
clients with bounded drop-oldest send queues and reconnect-with-backoff,
a multiplexing collector server with optional shared-secret auth,
partial-window assembly with dedup and loss accounting, and a two-tier
collector tree (leaf racks compacting shard frames into a root)."""
from repro.transport.client import SendQueue, WireClient, connect
from repro.transport.collector import WindowBatch, WindowCollector
from repro.transport.framing import (FrameDecoder, MAX_FRAME_BYTES,
                                     decode_frames, encode_frame,
                                     max_frame_bytes)
from repro.transport.loopback import LoopbackWire
from repro.transport.server import DaemonServer
from repro.transport.tree import (CollectorTree, LeafNode, ShardCollector,
                                  TreeWindowBatch, compact_shard,
                                  leaf_process_main)

__all__ = [
    "FrameDecoder", "MAX_FRAME_BYTES", "max_frame_bytes",
    "decode_frames", "encode_frame",
    "SendQueue", "WireClient", "connect",
    "WindowBatch", "WindowCollector",
    "DaemonServer", "LoopbackWire",
    "CollectorTree", "LeafNode", "ShardCollector", "TreeWindowBatch",
    "compact_shard", "leaf_process_main",
]
