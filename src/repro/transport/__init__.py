"""Real wire transport for PerfTracker pattern uploads (DESIGN.md §8):
length-prefixed msgpack framing over Unix/TCP sockets, per-worker clients
with bounded drop-oldest send queues, a multiplexing collector server, and
partial-window assembly with dedup and loss accounting."""
from repro.transport.client import SendQueue, WireClient, connect
from repro.transport.collector import WindowBatch, WindowCollector
from repro.transport.framing import (FrameDecoder, MAX_FRAME_BYTES,
                                     decode_frames, encode_frame)
from repro.transport.loopback import LoopbackWire
from repro.transport.server import DaemonServer

__all__ = [
    "FrameDecoder", "MAX_FRAME_BYTES", "decode_frames", "encode_frame",
    "SendQueue", "WireClient", "connect",
    "WindowBatch", "WindowCollector",
    "DaemonServer", "LoopbackWire",
]
