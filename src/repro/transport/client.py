"""Per-worker upload client (DESIGN.md §8).

``WireClient`` is the daemon side of the wire: it owns one socket to the
``DaemonServer``, a *bounded* send queue, and a background sender thread,
so the training/daemon thread never blocks on a slow collector.

Backpressure policy: the queue bounds the number of UNSENT upload frames.
When a new upload arrives at a full queue, the OLDEST unsent upload is
dropped and counted — stale windows are worth strictly less than fresh
ones (the collector tolerates the hole; the EMA keeps the worker's last
evidence), so the newest window always gets a seat.  Control frames
(``hello``/``window_end``/``bye``) are never dropped: loss accounting and
window assembly ride on them.

Reconnect policy: a lost connection (collector restart, transient accept
failure) is re-dialed with bounded exponential backoff.  A successful
reconnect re-sends the hello (with the auth token, when configured),
discards the torn half-sent frame, and resumes draining the queue; the
``reconnects`` counter rides every subsequent ``window_end`` so the
collector's transport accounting surfaces it in reports.

Loss/reorder injection for tests happens at the framing layer: a
``frame_filter(msg, frame) -> [frames]`` hook sees every encoded upload
frame and may drop it (``[]``), duplicate it (``[frame, frame]``), or pass
it through (``None`` / ``[frame]``).  Control frames bypass the filter,
exactly like the drop policy.
"""
from __future__ import annotations

import os
import queue as _queue
import selectors
import socket
import threading
from collections import deque
from typing import Callable, Dict, Iterable, List, Optional, Tuple, Union

from repro.transport import framing

Address = Union[str, Tuple[str, int]]

#: frame_filter signature: (decoded msg, encoded frame) -> frames to send
FrameFilter = Callable[[Dict, bytes], Optional[Iterable[bytes]]]


def connect(address: Address, timeout: float = 10.0) -> socket.socket:
    """Dial a ``DaemonServer``: a str address is a Unix-domain socket path,
    a (host, port) tuple is TCP."""
    if isinstance(address, str):
        sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    else:
        sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    sock.settimeout(timeout)
    try:
        sock.connect(address if isinstance(address, str) else tuple(address))
    except BaseException:
        sock.close()
        raise
    sock.settimeout(None)
    return sock


class SendQueue:
    """Bounded FIFO of protocol messages with drop-oldest overflow.

    Only *droppable* entries (uploads) count toward — and are evicted by —
    the bound; control frames always enqueue.  Thread-safe.
    """

    def __init__(self, max_uploads: int = 64):
        if max_uploads < 1:
            raise ValueError(f"max_uploads must be >= 1, got {max_uploads}")
        self.max_uploads = int(max_uploads)
        self._q: deque = deque()              # (droppable, msg)
        self._n_droppable = 0
        self.dropped = 0                      # cumulative drop-oldest count
        self._lock = threading.Lock()

    def put(self, msg: Dict, droppable: bool = True) -> None:
        with self._lock:
            if droppable and self._n_droppable >= self.max_uploads:
                # evict the OLDEST unsent upload (never a control frame)
                for i, (d, _m) in enumerate(self._q):
                    if d:
                        del self._q[i]
                        self._n_droppable -= 1
                        self.dropped += 1
                        break
            self._q.append((droppable, msg))
            if droppable:
                self._n_droppable += 1

    def pop(self) -> Optional[Tuple[bool, Dict]]:
        with self._lock:
            if not self._q:
                return None
            droppable, msg = self._q.popleft()
            if droppable:
                self._n_droppable -= 1
            return droppable, msg

    def __len__(self) -> int:
        with self._lock:
            return len(self._q)


class WireClient:
    """One worker's (or leaf uplink's) connection to a collector."""

    def __init__(self, address: Address, worker: int,
                 max_queue: int = 64,
                 frame_filter: Optional[FrameFilter] = None,
                 connect_timeout: float = 10.0,
                 auth_token: Optional[str] = None,
                 role: str = "worker",
                 max_frame: Optional[int] = None,
                 reconnect_max: int = 5,
                 reconnect_backoff_s: float = 0.05,
                 reconnect_backoff_max_s: float = 1.0):
        self.address = address
        self.worker = int(worker)
        self.frame_filter = frame_filter
        self.auth_token = auth_token
        self.role = role
        self.max_frame = max_frame
        self.queue = SendQueue(max_uploads=max_queue)
        self.sent = 0                       # upload frames handed to the OS
        self.enqueued = 0                   # upload frames accepted
        self.reconnects = 0                 # successful re-dials
        self.reconnect_max = int(reconnect_max)
        self.reconnect_backoff_s = float(reconnect_backoff_s)
        self.reconnect_backoff_max_s = float(reconnect_backoff_max_s)
        self.errors: List[str] = []
        self._seq = 0
        self._connect_timeout = float(connect_timeout)
        self._controls: "_queue.Queue[Dict]" = _queue.Queue()
        self._sock = connect(address, timeout=connect_timeout)
        self._sock.setblocking(False)
        self._wake_r, self._wake_w = os.pipe()
        self._outbuf = bytearray()
        self._decoder = framing.FrameDecoder(max_frame=max_frame)
        self._stop = threading.Event()
        self._idle = threading.Event()      # set while queue+outbuf empty
        self._idle.set()
        self.queue.put(self._hello(), droppable=False)
        self._thread = threading.Thread(
            target=self._run, name=f"wire-client-{worker}", daemon=True)
        self._thread.start()

    def _hello(self) -> Dict:
        return framing.hello_msg(self.worker, token=self.auth_token,
                                 role=self.role)

    # -- daemon-facing API --------------------------------------------------
    @property
    def dropped(self) -> int:
        """Cumulative uploads evicted by backpressure (drop-oldest)."""
        return self.queue.dropped

    def send_upload(self, window: int, upload) -> int:
        """Enqueue one window's pattern upload; returns its seq number.
        Never blocks: a full queue evicts the oldest unsent upload."""
        seq = self._seq
        self._seq += 1
        self.enqueued += 1
        self.queue.put(framing.upload_msg(window, upload, seq))
        self._notify()
        return seq

    def send_msg(self, msg: Dict, droppable: bool = False) -> None:
        """Enqueue one pre-built protocol message (leaf uplinks forward
        compacted shard frames with this; shard frames are control-grade:
        never dropped by backpressure)."""
        self.queue.put(msg, droppable=droppable)
        self._notify()

    def end_window(self, window: int) -> None:
        """Close one window on the wire.  The frame's counters are
        snapshotted at SEND time (sender thread), so drops that happen
        while it is queued are still reported."""
        self.queue.put({"t": "_window_end", "window": int(window)},
                       droppable=False)
        self._notify()

    def recv_control(self, timeout: Optional[float] = None) -> Optional[Dict]:
        """Next server->client control frame (window_start/stop), or None
        on timeout."""
        try:
            return self._controls.get(timeout=timeout)
        except _queue.Empty:
            return None

    def flush(self, timeout: float = 30.0) -> bool:
        """Block until every queued frame reached the OS (or timeout).
        Returns False when frames remain undelivered — timeout, or a
        sender thread that died mid-drain (its exit sets the idle event
        to wake waiters, so the verdict comes from the actual queue and
        buffer state, never from the event alone)."""
        if self._thread.is_alive():
            self._idle.wait(timeout=timeout)
        return len(self.queue) == 0 and not self._outbuf

    def close(self, timeout: float = 10.0) -> None:
        if not self._stop.is_set() and self._thread.is_alive():
            self.queue.put(framing.bye_msg(self.worker), droppable=False)
            self._notify()
            self.flush(timeout=timeout)
        self._stop.set()
        self._notify()
        self._thread.join(timeout=timeout)
        for fd in (self._wake_r, self._wake_w):
            try:
                os.close(fd)
            except OSError:
                pass
        try:
            self._sock.close()
        except OSError:
            pass

    # -- sender/receiver loop ------------------------------------------------
    def _notify(self) -> None:
        self._idle.clear()
        try:
            os.write(self._wake_w, b"\0")
        except OSError:
            pass

    def _encode_next(self) -> None:
        """Drain queued messages into the outbuf, applying the framing-layer
        fault filter to upload frames."""
        while len(self._outbuf) < 1 << 20:
            item = self.queue.pop()
            if item is None:
                return
            droppable, msg = item
            if msg.get("t") == "_window_end":
                msg = framing.window_end_msg(
                    msg["window"], self.worker,
                    sent=self.sent, dropped=self.queue.dropped,
                    reconnects=self.reconnects)
            frame = framing.encode_frame(msg, max_frame=self.max_frame)
            if droppable:
                self.sent += 1
                if self.frame_filter is not None:
                    frames = self.frame_filter(msg, frame)
                    frames = [frame] if frames is None else list(frames)
                else:
                    frames = [frame]
                for f in frames:
                    self._outbuf += f
            else:
                self._outbuf += frame

    def _reconnect(self, sel: selectors.BaseSelector) -> bool:
        """Bounded-exponential-backoff re-dial after a lost connection.
        On success: fresh socket registered, decoder reset, torn outbuf
        replaced by a new hello.  Returns False when out of attempts or
        stopping — the caller exits the sender loop."""
        try:
            sel.unregister(self._sock)
        except (KeyError, ValueError):
            pass
        try:
            self._sock.close()
        except OSError:
            pass
        delay = self.reconnect_backoff_s
        for attempt in range(self.reconnect_max):
            if self._stop.is_set():
                return False
            self._stop.wait(delay)
            delay = min(2 * delay, self.reconnect_backoff_max_s)
            if self._stop.is_set():
                return False
            try:
                self._sock = connect(self.address,
                                     timeout=self._connect_timeout)
            except OSError:
                continue
            self._sock.setblocking(False)
            self._decoder = framing.FrameDecoder(max_frame=self.max_frame)
            # the half-sent frame is torn — restarting it would corrupt the
            # stream; re-introduce ourselves instead and resume the queue
            self._outbuf = bytearray(
                framing.encode_frame(self._hello(),
                                     max_frame=self.max_frame))
            self.reconnects += 1
            sel.register(self._sock, selectors.EVENT_READ
                         | selectors.EVENT_WRITE)
            return True
        self.errors.append(
            f"reconnect failed after {self.reconnect_max} attempts")
        return False

    def _run(self) -> None:
        sel = selectors.DefaultSelector()
        sel.register(self._sock, selectors.EVENT_READ)
        sel.register(self._wake_r, selectors.EVENT_READ)
        registered = selectors.EVENT_READ
        try:
            while not self._stop.is_set():
                if not self._outbuf:
                    self._encode_next()
                want = selectors.EVENT_READ | (
                    selectors.EVENT_WRITE if self._outbuf else 0)
                if want != registered:
                    sel.modify(self._sock, want)
                    registered = want
                if not self._outbuf and not len(self.queue):
                    self._idle.set()
                    if len(self.queue):   # raced with a concurrent put
                        self._idle.clear()
                lost = False
                for key, events in sel.select(timeout=0.2):
                    if key.fd == self._wake_r:
                        try:
                            os.read(self._wake_r, 4096)
                        except OSError:
                            pass
                        continue
                    if events & selectors.EVENT_READ:
                        if not self._read():
                            lost = True
                            break
                    if events & selectors.EVENT_WRITE and self._outbuf:
                        if not self._write():
                            lost = True
                            break
                if lost:
                    if self._stop.is_set() or not self._reconnect(sel):
                        return
                    registered = selectors.EVENT_READ \
                        | selectors.EVENT_WRITE
        except Exception as e:                      # pragma: no cover
            self.errors.append(f"{type(e).__name__}: {e}")
        finally:
            self._idle.set()
            sel.close()

    def _read(self) -> bool:
        try:
            data = self._sock.recv(65536)
        except BlockingIOError:
            return True
        except OSError as e:
            self.errors.append(f"recv: {e}")
            return False
        if not data:
            self.errors.append("server closed connection")
            return False
        for msg in self._decoder.feed(data):
            self._controls.put(msg)
        return True

    def _write(self) -> bool:
        try:
            n = self._sock.send(self._outbuf)
        except BlockingIOError:
            return True
        except OSError as e:
            self.errors.append(f"send: {e}")
            return False
        del self._outbuf[:n]
        return True
