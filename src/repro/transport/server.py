"""Central collector endpoint (DESIGN.md §8).

``DaemonServer`` owns the listening socket (Unix-domain by default, TCP
when given a (host, port) address), multiplexes every per-worker daemon
connection through one ``selectors`` IO thread, reassembles frames with
``FrameDecoder``, and hands decoded messages to a ``WindowCollector``.

It is also the control plane: ``broadcast`` pushes ``window_start`` /
``stop`` frames to every connected daemon (the multi-process scenario
runner drives worker processes with it).

A plaintext event log (connections, window summaries, errors) goes to
``log_path`` when given — the CI ``wire`` job uploads it as an artifact on
failure, so a hung socket leaves evidence.
"""
from __future__ import annotations

import os
import selectors
import socket
import tempfile
import threading
import time
from typing import Dict, List, Optional, Tuple, Union

from repro.transport import framing
from repro.transport.collector import WindowCollector

Address = Union[str, Tuple[str, int]]


class _Conn:
    def __init__(self, sock: socket.socket, max_frame: Optional[int] = None):
        self.sock = sock
        self.decoder = framing.FrameDecoder(max_frame=max_frame)
        self.outbuf = bytearray()
        self.worker: Optional[int] = None    # set by the hello frame
        self.authed = False                  # hello accepted (token checked)
        self.registered = selectors.EVENT_READ   # current epoll interest set


class DaemonServer:
    """Accepts per-worker daemon connections and feeds the collector.

    ``auth_token`` (optional shared secret) gates the hello handshake:
    a connection whose hello carries a missing or mismatched token is
    logged and closed before any of its frames reach the collector.

    The set of client->server frame types forwarded to the collector is
    the collector's ``HANDLED`` attribute (default: upload/window_end),
    so the same server fronts both flat ``WindowCollector``s and the
    root ``ShardCollector`` of a collector tree (DESIGN.md §10).
    """

    def __init__(self, collector: WindowCollector,
                 address: Optional[Address] = None,
                 log_path: Optional[str] = None,
                 auth_token: Optional[str] = None,
                 max_frame: Optional[int] = None):
        self.collector = collector
        self.log_path = log_path
        self.auth_token = auth_token
        self.max_frame = max_frame
        self.auth_rejected = 0               # connections refused at hello
        self._log_lock = threading.Lock()
        self._owns_socket_dir: Optional[str] = None
        if address is None:
            self._owns_socket_dir = tempfile.mkdtemp(prefix="repro-wire-")
            address = os.path.join(self._owns_socket_dir, "daemon.sock")
        self.address: Address = address
        if isinstance(address, str):
            self._listener = socket.socket(socket.AF_UNIX,
                                           socket.SOCK_STREAM)
            self._listener.bind(address)
        else:
            self._listener = socket.socket(socket.AF_INET,
                                           socket.SOCK_STREAM)
            self._listener.setsockopt(socket.SOL_SOCKET,
                                      socket.SO_REUSEADDR, 1)
            self._listener.bind(tuple(address))
            self.address = self._listener.getsockname()
        self._listener.listen(1024)
        self._listener.setblocking(False)
        self._wake_r, self._wake_w = os.pipe()
        self._conns: Dict[int, _Conn] = {}          # fd -> conn
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "DaemonServer":
        self.log(f"listening on {self.address}")
        self._thread = threading.Thread(target=self._run,
                                        name="wire-server", daemon=True)
        self._thread.start()
        return self

    def stop(self, timeout: float = 10.0) -> None:
        if self._stop.is_set():
            return
        self._stop.set()
        self._notify()
        if self._thread is not None:
            self._thread.join(timeout=timeout)
        with self._lock:
            conns = list(self._conns.values())
            self._conns.clear()
        for c in conns:
            try:
                c.sock.close()
            except OSError:
                pass
        try:
            self._listener.close()
        except OSError:
            pass
        for fd in (self._wake_r, self._wake_w):
            try:
                os.close(fd)
            except OSError:
                pass
        if self._owns_socket_dir:
            try:
                os.unlink(self.address)          # type: ignore[arg-type]
                os.rmdir(self._owns_socket_dir)
            except OSError:
                pass
        self.log("stopped")

    def __enter__(self) -> "DaemonServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- control plane -------------------------------------------------------
    @property
    def n_connections(self) -> int:
        with self._lock:
            return len(self._conns)

    def connected_workers(self) -> List[int]:
        with self._lock:
            return sorted(c.worker for c in self._conns.values()
                          if c.worker is not None)

    def wait_connections(self, n: int, timeout: float = 30.0) -> bool:
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            if self.n_connections >= n:
                return True
            time.sleep(0.01)
        return self.n_connections >= n

    def broadcast(self, msg: Dict) -> int:
        """Queue one control frame to every connected daemon; returns the
        number of recipients."""
        frame = framing.encode_frame(msg, max_frame=self.max_frame)
        with self._lock:
            for conn in self._conns.values():
                conn.outbuf += frame
            n = len(self._conns)
        self._notify()
        return n

    def log(self, line: str) -> None:
        if not self.log_path:
            return
        with self._log_lock:
            with open(self.log_path, "a") as f:
                f.write(f"[{time.strftime('%H:%M:%S')}] {line}\n")

    # -- IO loop -------------------------------------------------------------
    def _notify(self) -> None:
        try:
            os.write(self._wake_w, b"\0")
        except OSError:
            pass

    def _run(self) -> None:
        sel = selectors.DefaultSelector()
        sel.register(self._listener, selectors.EVENT_READ, "accept")
        sel.register(self._wake_r, selectors.EVENT_READ, "wake")
        try:
            while not self._stop.is_set():
                # only touch connections whose interest set actually
                # changed — at W=1024 a blanket sel.modify sweep is O(W)
                # epoll_ctl syscalls per wakeup and dominates the loop
                with self._lock:
                    for fd, conn in self._conns.items():
                        want = selectors.EVENT_READ | (
                            selectors.EVENT_WRITE if conn.outbuf else 0)
                        if want != conn.registered:
                            sel.modify(conn.sock, want, "conn")
                            conn.registered = want
                for key, events in sel.select(timeout=0.2):
                    if key.data == "wake":
                        try:
                            os.read(self._wake_r, 4096)
                        except OSError:
                            pass
                    elif key.data == "accept":
                        self._accept(sel)
                    else:
                        self._service(sel, key.fileobj, events)
        except Exception as e:                       # pragma: no cover
            self.log(f"server loop error: {type(e).__name__}: {e}")
        finally:
            sel.close()

    def _accept(self, sel) -> None:
        while True:
            try:
                sock, _ = self._listener.accept()
            except (BlockingIOError, OSError):
                return
            sock.setblocking(False)
            conn = _Conn(sock, max_frame=self.max_frame)
            with self._lock:
                self._conns[sock.fileno()] = conn
            sel.register(sock, selectors.EVENT_READ, "conn")
            self.log(f"accepted connection fd={sock.fileno()}")

    def _close_conn(self, sel, sock) -> None:
        with self._lock:
            conn = self._conns.pop(sock.fileno(), None)
        try:
            sel.unregister(sock)
        except (KeyError, ValueError):
            pass
        try:
            sock.close()
        except OSError:
            pass
        if conn is not None:
            self.log(f"closed connection worker={conn.worker}")

    def _service(self, sel, sock, events) -> None:
        with self._lock:
            conn = self._conns.get(sock.fileno())
        if conn is None:
            return
        if events & selectors.EVENT_READ:
            try:
                data = sock.recv(1 << 20)
            except BlockingIOError:
                data = None
            except OSError as e:
                self.log(f"recv error worker={conn.worker}: {e}")
                self._close_conn(sel, sock)
                return
            if data == b"":
                self._close_conn(sel, sock)
                return
            if data:
                try:
                    for msg in conn.decoder.feed(data):
                        if not self._dispatch(conn, msg):
                            self._close_conn(sel, sock)
                            return
                except ValueError as e:
                    self.log(f"framing error worker={conn.worker}: {e}")
                    self._close_conn(sel, sock)
                    return
        if events & selectors.EVENT_WRITE:
            # snapshot under the lock: broadcast() appends to outbuf from
            # other threads, and resizing a bytearray while send() exports
            # its buffer raises BufferError
            with self._lock:
                data = bytes(conn.outbuf)
            if not data:
                return
            try:
                n = sock.send(data)
                with self._lock:
                    del conn.outbuf[:n]
            except BlockingIOError:
                pass
            except OSError as e:
                self.log(f"send error worker={conn.worker}: {e}")
                self._close_conn(sel, sock)

    def _dispatch(self, conn: _Conn, msg: Dict) -> bool:
        """Handle one decoded frame; False closes the connection."""
        t = msg.get("t")
        if t == "hello":
            if self.auth_token is not None \
                    and msg.get("token") != self.auth_token:
                self.auth_rejected += 1
                self.log(f"auth rejected worker={msg.get('worker')} "
                         f"(missing or mismatched token)")
                return False
            conn.worker = int(msg["worker"])
            conn.authed = True
            role = msg.get("role", "worker")
            self.log(f"hello worker={conn.worker} role={role}")
            return True
        if self.auth_token is not None and not conn.authed:
            # nothing but a valid hello may precede authenticated traffic
            self.auth_rejected += 1
            self.log(f"auth rejected: {t!r} frame before hello")
            return False
        handled = getattr(self.collector, "HANDLED", ("upload", "window_end"))
        if t in handled:
            if t == "window_end":
                self.log(f"window_end window={msg.get('window')} "
                         f"worker={msg.get('worker')} "
                         f"sent={msg.get('sent')} "
                         f"dropped={msg.get('dropped')}")
            self.collector.on_message(msg)
        elif t == "bye":
            self.log(f"bye worker={msg.get('worker')}")
        else:
            self.log(f"unknown frame type {t!r} from worker={conn.worker}")
        return True
