"""Two-tier collector tree (DESIGN.md §10).

One flat ``DaemonServer`` stops scaling around the point where a single
accept loop must decode 2xW frames per window.  The tree splits the fleet
into N "rack" slices, each fronted by a ``LeafNode`` — its own selectors
loop + ``WindowCollector`` assembling just that slice — and a root that
only ever sees N compacted *shard frames* per window:

    workers ──upload/window_end──> LeafNode ──shard──> root ShardCollector
    workers <──window_start/stop── LeafNode <──window_start/stop── root

Hierarchical partial-window assembly: a leaf waits for its slice (same
partial-window semantics as the flat collector — missing workers bounded
by the leaf timeout), folds the slice's uploads into a leaf-local
``PatternAggregator``, and ships ONE frame upstream: the packed columnar
float32 block, the present worker list, interned names/kinds, and the
rack's loss counters.  The root scatters each block straight into the
fleet-wide aggregator (``scatter_cols``) — root ingest is O(shards)
frames per window instead of O(workers), and the expensive msgpack
unpacking runs in parallel across the leaves.

Byte-parity with the flat path is preserved by construction: shard blocks
are scattered in ascending shard-id order (shards are contiguous
ascending worker ranges), so function interning and first-seen kind
resolution happen in exactly the ascending-worker order the flat
``aggregate_batch`` uses, and the float32 pattern values cross the wire
verbatim.

Control plane: ``CollectorTree.broadcast`` pushes ``window_start`` /
``stop`` frames to the leaves' uplink connections; each leaf applies the
membership delta to its own collector's expected set (its rack ∩ the
current training mesh) and re-broadcasts the frame to its rack, so mesh
changes (``replace_hosts`` re-mesh, scenario cures) flow down the tree to
every worker process.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core.events import Kind
from repro.summarize.aggregate import PatternAggregator
from repro.transport import framing
from repro.transport.client import WireClient
from repro.transport.collector import WindowBatch, WindowCollector
from repro.transport.server import DaemonServer


def compact_shard(shard: int, batch: WindowBatch) -> Dict:
    """Fold one assembled rack window into a single shard frame.

    The rack's uploads are unpacked into a leaf-local aggregator in
    ascending worker order (the parity-critical order), then shipped as a
    packed little-endian float32 ``(n_present, F, 3)`` block plus the
    interned names/kinds — the root never touches the rack's msgpack."""
    uploads = batch.sorted_uploads()
    agg = PatternAggregator(expected_workers=max(1, len(uploads)))
    base = agg.reserve_workers(len(uploads))
    for i, u in enumerate(uploads):
        agg.add_upload_at(u, base + i)
    mat, names = agg.matrix()
    kinds = agg.kinds()
    rows = np.ascontiguousarray(mat, dtype="<f4").tobytes()
    return framing.shard_msg(
        window=batch.window, shard=shard,
        workers=batch.present, names=names,
        kinds=[int(kinds[n].value) for n in names], rows=rows,
        missing=batch.missing, duplicates=batch.duplicates,
        client_dropped=batch.client_dropped, reconnects=batch.reconnects,
        raw_bytes=sum(u.raw_bytes for u in uploads),
        pattern_bytes=sum(len(u.payload) for u in uploads),
        summarize_s=sum(u.summarize_s for u in uploads),
        timed_out=batch.timed_out)


@dataclass
class TreeWindowBatch:
    """One fleet window assembled from per-shard compaction frames.

    Quacks like ``WindowBatch`` where diagnosis needs it (present /
    missing / present_mask / stats) but aggregates by scattering shard
    blocks instead of unpacking per-worker uploads — ``aggregate()`` is
    the tree-mode replacement for ``aggregate_batch``."""
    window: int
    expected: Tuple[int, ...]                 # fleet-level expected workers
    expected_shards: Tuple[int, ...]
    shards: Dict[int, Dict] = field(default_factory=dict)  # shard id -> msg
    duplicate_shards: int = 0                 # deduped shard frames
    timed_out: bool = False                   # root wait hit its deadline

    @property
    def present(self) -> List[int]:
        out: List[int] = []
        for s in sorted(self.shards):
            out.extend(self.shards[s]["workers"])
        return sorted(out)

    @property
    def missing(self) -> List[int]:
        return sorted(set(self.expected) - set(self.present))

    @property
    def missing_shards(self) -> List[int]:
        return sorted(set(self.expected_shards) - set(self.shards))

    @property
    def complete(self) -> bool:
        return not self.missing

    def _sum(self, key: str) -> int:
        return sum(m[key] for m in self.shards.values())

    @property
    def duplicates(self) -> int:
        return self._sum("duplicates")

    @property
    def client_dropped(self) -> int:
        return self._sum("client_dropped")

    @property
    def reconnects(self) -> int:
        return self._sum("reconnects")

    @property
    def raw_bytes(self) -> int:
        return self._sum("raw_bytes")

    @property
    def pattern_bytes(self) -> int:
        return self._sum("pattern_bytes")

    @property
    def summarize_s(self) -> float:
        return sum(m["summarize_s"] for m in self.shards.values())

    def present_mask(self, fleet_size: int) -> np.ndarray:
        mask = np.zeros(int(fleet_size), bool)
        mask[self.present] = True
        return mask

    def stats(self) -> Dict[str, object]:
        """WindowBatch-compatible transport counters + tree shape."""
        return {"window": self.window,
                "expected": len(self.expected),
                "present": len(self.present),
                "missing": self.missing,
                "duplicates": self.duplicates,
                "client_dropped": self.client_dropped,
                "reconnects": self.reconnects,
                "timed_out": self.timed_out,
                "shards": len(self.shards),
                "expected_shards": len(self.expected_shards),
                "missing_shards": self.missing_shards,
                "duplicate_shards": self.duplicate_shards}

    def aggregate(self, fleet_size: int
                  ) -> Tuple[PatternAggregator, np.ndarray]:
        """Scatter every shard block into one full-width aggregator.

        Ascending shard-id order == ascending worker order (shards are
        contiguous slices), so interning and first-seen kinds match the
        flat ``aggregate_batch`` exactly; absent rows stay zero and are
        masked out of localization."""
        agg = PatternAggregator(expected_workers=max(1, int(fleet_size)))
        agg.reserve_workers(int(fleet_size))
        present = np.zeros(int(fleet_size), bool)
        for s in sorted(self.shards):
            m = self.shards[s]
            names = m["names"]
            cols = np.array([agg.intern(n, Kind(k))
                             for n, k in zip(names, m["kinds"])], np.int64)
            rows = np.array(m["workers"], np.int64)
            if rows.size:
                present[rows] = True
                if cols.size:
                    block = np.frombuffer(m["rows"], dtype="<f4").reshape(
                        len(rows), len(names), 3)
                    agg.scatter_cols(rows, cols, block)
        return agg, present


class ShardCollector:
    """Root-side reassembly of per-shard compaction frames.

    Same contract as ``WindowCollector`` (on_message from the server's IO
    thread, wait_window from the consumer) but keyed by shard id: a window
    is complete when every expected SHARD reported, duplicate shard frames
    keep the first copy, and a whole missing rack is bounded by the
    wait_window timeout and surfaced in ``missing_shards``."""

    HANDLED = ("shard",)                     # frame types the server forwards

    def __init__(self, shard_workers: Dict[int, Sequence[int]]):
        #: static rack topology: shard id -> full worker slice
        self.shard_workers = {int(s): tuple(sorted(int(w) for w in ws))
                              for s, ws in shard_workers.items()}
        self.expected_shards = tuple(sorted(self.shard_workers))
        #: current training mesh (None = everyone in the topology)
        self._membership: Optional[Set[int]] = None
        self._batches: Dict[int, TreeWindowBatch] = {}
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._popped_through: float = float("-inf")
        self.total_shards = 0
        self.total_duplicate_shards = 0
        self.stale_frames = 0

    def _expected_workers(self) -> Tuple[int, ...]:
        all_ws = [w for ws in self.shard_workers.values() for w in ws]
        if self._membership is None:
            return tuple(sorted(all_ws))
        return tuple(sorted(set(all_ws) & self._membership))

    def set_membership(self, workers: Sequence[int]) -> None:
        """Control-plane mesh delta: expected workers become the rack
        topology ∩ the current training mesh (open windows included)."""
        with self._cv:
            self._membership = {int(w) for w in workers}
            exp = self._expected_workers()
            for b in self._batches.values():
                b.expected = exp
            self._cv.notify_all()

    def _batch(self, window: int) -> TreeWindowBatch:
        b = self._batches.get(window)
        if b is None:
            b = self._batches[window] = TreeWindowBatch(
                window=window, expected=self._expected_workers(),
                expected_shards=self.expected_shards)
        return b

    def on_message(self, msg: Dict) -> None:
        if msg.get("t") != "shard":
            return
        window, shard = int(msg["window"]), int(msg["shard"])
        with self._cv:
            if window <= self._popped_through:
                self.stale_frames += 1
                return
            b = self._batch(window)
            if shard in b.shards:
                b.duplicate_shards += 1
                self.total_duplicate_shards += 1
                return
            b.shards[shard] = msg
            self.total_shards += 1
            if set(b.shards) >= set(self.expected_shards):
                self._cv.notify_all()

    def wait_window(self, window: int, timeout: float = 30.0
                    ) -> TreeWindowBatch:
        """Block until every expected shard reported ``window`` (or
        timeout); the batch is partial when racks are missing."""
        import time as _time
        deadline = _time.monotonic() + timeout
        with self._cv:
            while True:
                b = self._batch(window)
                if set(b.shards) >= set(self.expected_shards):
                    break
                remaining = deadline - _time.monotonic()
                if remaining <= 0:
                    b.timed_out = True
                    break
                self._cv.wait(timeout=min(remaining, 0.5))
            self._batches.pop(window, None)
            self._popped_through = max(self._popped_through, window)
            return b


class LeafNode:
    """One rack: a ``DaemonServer`` + ``WindowCollector`` for a worker
    slice, plus an uplink ``WireClient`` (role="leaf") to the root.

    The pump thread is driven entirely by the control plane: each
    ``window_start`` from the root is re-broadcast to the rack, the leaf
    assembles its slice (expected = rack ∩ membership), compacts it, and
    forwards one shard frame upstream.  ``stop`` is re-broadcast and ends
    the pump."""

    def __init__(self, shard: int, workers: Sequence[int],
                 root_address, auth_token: Optional[str] = None,
                 max_frame: Optional[int] = None,
                 window_timeout: float = 30.0,
                 log_path: Optional[str] = None,
                 address=None):
        self.shard = int(shard)
        self.workers = tuple(sorted(int(w) for w in workers))
        self.window_timeout = float(window_timeout)
        self.collector = WindowCollector(self.workers)
        self.server = DaemonServer(self.collector, address=address,
                                   auth_token=auth_token,
                                   max_frame=max_frame, log_path=log_path)
        self.uplink = WireClient(root_address, worker=self.shard,
                                 auth_token=auth_token, role="leaf",
                                 max_frame=max_frame)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    @property
    def address(self):
        return self.server.address

    def start(self) -> "LeafNode":
        self.server.start()
        self._thread = threading.Thread(
            target=self._pump, name=f"leaf-{self.shard}", daemon=True)
        self._thread.start()
        return self

    def stop(self, timeout: float = 10.0) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=timeout)
        self.uplink.close()
        self.server.stop()

    def _pump(self) -> None:
        while not self._stop.is_set():
            msg = self.uplink.recv_control(timeout=0.5)
            if msg is None:
                continue
            t = msg.get("t")
            if t == "stop" or (t == "window_start" and msg.get("stop")):
                self.server.broadcast(msg)
                return
            if t != "window_start":
                self.server.broadcast(msg)
                continue
            members = msg.get("membership")
            if members is not None:
                mine = sorted(set(self.workers) & {int(w) for w in members})
                self.collector.set_expected(mine)
            self.server.broadcast(msg)
            window = int(msg["window"])
            batch = self.collector.wait_window(
                window, timeout=self.window_timeout)
            self.uplink.send_msg(compact_shard(self.shard, batch),
                                 droppable=False)


def leaf_process_main(shard: int, workers: Sequence[int], root_address,
                      address, auth_token: Optional[str] = None,
                      max_frame: Optional[int] = None,
                      window_timeout: float = 30.0,
                      log_path: Optional[str] = None) -> None:
    """Entry point for one ``LeafNode`` as a STANDALONE process — the
    deployed shape, where each rack's collector runs on its own host and
    the root only ever pays for O(shards) frames per window.  ``address``
    must be a pre-agreed socket path/endpoint so workers can dial the leaf
    without a discovery round-trip.  Runs until the root broadcasts
    ``stop`` (picklable args only: multiprocessing spawn target)."""
    leaf = LeafNode(shard, workers, root_address, auth_token=auth_token,
                    max_frame=max_frame, window_timeout=window_timeout,
                    log_path=log_path, address=address).start()
    try:
        if leaf._thread is not None:
            leaf._thread.join()              # pump exits on the stop frame
    finally:
        leaf.uplink.close()
        leaf.server.stop()


class CollectorTree:
    """The assembled tree: N leaves over contiguous worker slices + the
    root ``DaemonServer``/``ShardCollector`` pair.

    Drop-in for the flat (collector, server) pair in scenario drivers:
    ``broadcast`` pushes control frames down the tree, ``wait_window``
    returns a ``TreeWindowBatch``, and ``address_of(worker)`` tells each
    worker process which LEAF to dial."""

    def __init__(self, workers: Sequence[int], n_shards: int,
                 auth_token: Optional[str] = None,
                 max_frame: Optional[int] = None,
                 window_timeout: float = 30.0,
                 log_path: Optional[str] = None):
        ws = sorted(int(w) for w in workers)
        n_shards = int(n_shards)
        if not 1 <= n_shards <= max(1, len(ws)):
            raise ValueError(f"n_shards={n_shards} must be in "
                             f"[1, {max(1, len(ws))}] for {len(ws)} workers")
        slices = [list(map(int, s)) for s in np.array_split(ws, n_shards)]
        self.shard_workers = {s: tuple(sl) for s, sl in enumerate(slices)}
        self.collector = ShardCollector(self.shard_workers)
        self.root = DaemonServer(self.collector, auth_token=auth_token,
                                 max_frame=max_frame, log_path=log_path)
        self._leaf_args = dict(auth_token=auth_token, max_frame=max_frame,
                               window_timeout=window_timeout,
                               log_path=log_path)
        self.leaves: List[LeafNode] = []
        self._addr_of: Dict[int, object] = {}

    @property
    def address(self):
        return self.root.address

    @property
    def n_shards(self) -> int:
        return len(self.shard_workers)

    def address_of(self, worker: int):
        """The LEAF address worker ``worker``'s daemon should dial."""
        return self._addr_of[int(worker)]

    def start(self) -> "CollectorTree":
        self.root.start()
        for s, ws in self.shard_workers.items():
            leaf = LeafNode(s, ws, self.root.address,
                            **self._leaf_args).start()
            self.leaves.append(leaf)
            for w in ws:
                self._addr_of[w] = leaf.address
        # every leaf uplink must be connected before the first broadcast,
        # or early window_start frames miss racks entirely
        self.root.wait_connections(len(self.leaves))
        return self

    def stop(self, timeout: float = 10.0) -> None:
        for leaf in self.leaves:
            leaf.stop(timeout=timeout)
        self.root.stop()

    def __enter__(self) -> "CollectorTree":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def wait_connections(self, n: int, timeout: float = 30.0) -> bool:
        """Block until ``n`` WORKER connections exist across the leaves."""
        import time as _time
        deadline = _time.monotonic() + timeout
        while True:
            total = sum(leaf.server.n_connections for leaf in self.leaves)
            if total >= n or _time.monotonic() >= deadline:
                return total >= n
            _time.sleep(0.01)

    def set_membership(self, workers: Sequence[int]) -> None:
        """Re-key the ROOT's expected set immediately (leaves re-key their
        own slices from the membership field of the next broadcast)."""
        self.collector.set_membership(workers)

    def broadcast(self, msg: Dict) -> int:
        """Push one control frame to every leaf (leaves forward it to
        their racks); returns the number of leaves reached."""
        if msg.get("t") == "window_start" and "membership" in msg:
            self.collector.set_membership(msg["membership"])
        return self.root.broadcast(msg)

    def wait_window(self, window: int, timeout: float = 30.0
                    ) -> TreeWindowBatch:
        return self.collector.wait_window(window, timeout=timeout)
