"""Single-host loopback wiring (DESIGN.md §8).

``LoopbackWire`` stands up a real ``DaemonServer`` + ``WindowCollector``
on a Unix-domain socket and round-trips per-worker uploads through actual
``WireClient`` connections — the same framing, backpressure, and
partial-window machinery the distributed deployment uses, in one process.
``PerfTrackerService.diagnose_profiles(mode="wire")`` runs on it, so the
wire path in every test and benchmark exercises the real transport instead
of an in-process msgpack round-trip.

Connections are chunked (``max_conns`` at a time) so a 512-worker
benchmark fleet doesn't hold 512 sockets open at once; the collector's
window assembly is indifferent to connection lifetime.
"""
from __future__ import annotations

from typing import Iterable, Optional, Sequence

from repro.transport.client import FrameFilter, WireClient
from repro.transport.collector import WindowBatch, WindowCollector
from repro.transport.server import Address, DaemonServer


class LoopbackWire:
    """A server + collector pair for one fleet of worker ids."""

    def __init__(self, workers: Sequence[int],
                 address: Optional[Address] = None,
                 max_conns: int = 64,
                 frame_filter: Optional[FrameFilter] = None,
                 log_path: Optional[str] = None):
        self.workers = [int(w) for w in workers]
        self.max_conns = int(max_conns)
        self.frame_filter = frame_filter
        self.collector = WindowCollector(self.workers)
        self.server = DaemonServer(self.collector, address=address,
                                   log_path=log_path)

    def __enter__(self) -> "LoopbackWire":
        self.server.start()
        return self

    def __exit__(self, *exc) -> None:
        self.server.stop()

    def send_round(self, uploads: Iterable, window: int = 0,
                   timeout: float = 30.0) -> WindowBatch:
        """Push one window's uploads through real per-worker connections
        and assemble the (possibly partial) batch."""
        uploads = list(uploads)
        for lo in range(0, len(uploads), self.max_conns):
            chunk = uploads[lo:lo + self.max_conns]
            clients = [WireClient(self.server.address, u.worker,
                                  frame_filter=self.frame_filter)
                       for u in chunk]
            try:
                for c, u in zip(clients, chunk):
                    c.send_upload(window, u)
                    c.end_window(window)
                for c in clients:
                    c.flush(timeout=timeout)
            finally:
                for c in clients:
                    c.close(timeout=timeout)
        # workers with no upload at all still owe a window_end: report them
        # closed so the wait below keys on upload arrival, not liveness
        sent_workers = {u.worker for u in uploads}
        for w in self.workers:
            if w not in sent_workers:
                self.collector.on_message(
                    {"t": "window_end", "window": window, "worker": w,
                     "sent": 0, "dropped": 0})
        return self.collector.wait_window(window, timeout=timeout)
