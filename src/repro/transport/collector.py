"""Window assembly on the collector side (DESIGN.md §8).

Uploads arrive per (window, worker) over per-worker connections, in
whatever order the wire delivers them — possibly duplicated (a retrying
client, an injected fault) and possibly never (client-side backpressure
drop, injected loss).  ``WindowCollector`` reassembles them into
``WindowBatch``es with *partial-window semantics*:

  * a window is COMPLETE when every expected worker has closed it with a
    ``window_end`` frame — not when every upload arrived.  A worker whose
    upload was dropped still ends the window (the end frame is
    undroppable), so the collector learns about the hole immediately
    instead of timing out on it;
  * duplicate (window, worker) uploads keep the FIRST copy and count the
    rest (``duplicates``);
  * workers that never even end the window (dead process, wedged socket)
    are bounded by the ``wait_window`` timeout and reported in
    ``missing`` alongside the dropped ones.

The batch carries everything downstream diagnosis needs to degrade
gracefully: the present-worker set, the missing set, duplicate and
client-side drop counters.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Set, Tuple

import numpy as np

from repro.transport import framing


@dataclass
class WindowBatch:
    """One assembled (possibly partial) profiling window."""
    window: int
    expected: Tuple[int, ...]                 # worker ids owed this window
    uploads: Dict[int, "PatternUpload"] = field(default_factory=dict)
    #: per-worker measured iteration durations (REAL workloads only; empty
    #: for simulator runs, whose parents own the anchor stream)
    anchors: Dict[int, List[float]] = field(default_factory=dict)
    #: per-worker per-iteration (loss, grad_norm) pairs for the numerics
    #: channel (only when the workload ships them on its anchors frames)
    numerics: Dict[int, List[Tuple[float, float]]] = field(
        default_factory=dict)
    #: per-worker per-iteration (p99_ttft, p99_tbt) pairs for the serving
    #: latency-SLO channel (same ride-along contract as ``numerics``)
    slo: Dict[int, List[Tuple[float, float]]] = field(
        default_factory=dict)
    ended: Set[int] = field(default_factory=set)
    duplicates: int = 0                       # deduped (window, worker) copies
    client_dropped: int = 0                   # cumulative backpressure drops
    reconnects: int = 0                       # cumulative client re-dials
    timed_out: bool = False                   # wait_window hit its deadline

    @property
    def present(self) -> List[int]:
        """Workers whose upload arrived, ascending."""
        return sorted(self.uploads)

    @property
    def missing(self) -> List[int]:
        """Expected workers with no upload this window."""
        return sorted(set(self.expected) - set(self.uploads))

    @property
    def complete(self) -> bool:
        return not self.missing

    def present_mask(self, fleet_size: int) -> np.ndarray:
        mask = np.zeros(int(fleet_size), bool)
        mask[self.present] = True
        return mask

    def sorted_uploads(self) -> List["PatternUpload"]:
        return [self.uploads[w] for w in self.present]

    def stats(self) -> Dict[str, object]:
        """Transport counters for reports (DESIGN.md §8)."""
        return {"window": self.window,
                "expected": len(self.expected),
                "present": len(self.uploads),
                "missing": self.missing,
                "duplicates": self.duplicates,
                "client_dropped": self.client_dropped,
                "reconnects": self.reconnects,
                "timed_out": self.timed_out}


class WindowCollector:
    """Thread-safe (window, worker) -> upload reassembly."""

    #: frame types the DaemonServer forwards here (anchors frames carry a
    #: real workload's iteration durations, DESIGN.md §11)
    HANDLED = ("upload", "window_end", "anchors")

    def __init__(self, expected_workers: Sequence[int]):
        self.expected = tuple(sorted(int(w) for w in expected_workers))
        self._batches: Dict[int, WindowBatch] = {}
        #: latest cumulative drop/reconnect counters per worker
        #: (from window_end)
        self._drops: Dict[int, int] = {}
        self._reconnects: Dict[int, int] = {}
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        #: highest window index already handed out by wait_window; frames
        #: for it (or older windows) are stragglers — counted and dropped,
        #: never resurrected into _batches (which would leak one batch per
        #: late upload over a long-running pipeline).  Assumes windows are
        #: consumed in ascending order, which every driver does.
        self._popped_through: float = float("-inf")
        self.total_uploads = 0
        self.total_duplicates = 0
        self.stale_frames = 0

    def _batch(self, window: int) -> WindowBatch:
        b = self._batches.get(window)
        if b is None:
            b = self._batches[window] = WindowBatch(
                window=window, expected=self.expected)
        return b

    # -- frame ingestion (called from the server's IO thread) ---------------
    def on_message(self, msg: Dict) -> None:
        t = msg.get("t")
        if t == "upload":
            window, upload = framing.msg_to_upload(msg)
            with self._cv:
                if window <= self._popped_through:
                    self.stale_frames += 1
                    return
                b = self._batch(window)
                if upload.worker in b.uploads:
                    b.duplicates += 1
                    self.total_duplicates += 1
                else:
                    b.uploads[upload.worker] = upload
                    self.total_uploads += 1
        elif t == "anchors":
            with self._cv:
                if int(msg["window"]) <= self._popped_through:
                    self.stale_frames += 1
                    return
                b = self._batch(int(msg["window"]))
                # first copy wins, like uploads (the frame is undroppable,
                # so a duplicate is a retransmit after reconnect)
                w = int(msg["worker"])
                b.anchors.setdefault(w,
                                     [float(d) for d in msg.get("durs", [])])
                if msg.get("numerics") is not None:
                    b.numerics.setdefault(
                        w, [(float(p[0]), float(p[1]))
                            for p in msg["numerics"]])
                if msg.get("slo") is not None:
                    b.slo.setdefault(
                        w, [(float(p[0]), float(p[1]))
                            for p in msg["slo"]])
        elif t == "window_end":
            with self._cv:
                if int(msg["window"]) <= self._popped_through:
                    self.stale_frames += 1
                    return
                b = self._batch(int(msg["window"]))
                b.ended.add(int(msg["worker"]))
                self._drops[int(msg["worker"])] = int(msg.get("dropped", 0))
                self._reconnects[int(msg["worker"])] = \
                    int(msg.get("reconnects", 0))
                if b.ended >= set(self.expected):
                    self._cv.notify_all()

    # -- consumer side -------------------------------------------------------
    def client_dropped(self) -> int:
        with self._lock:
            return sum(self._drops.values())

    def set_expected(self, workers: Sequence[int]) -> None:
        """Re-key the expected worker set when the training mesh changes
        (control-plane membership delta, DESIGN.md §10).  Applies to all
        OPEN batches too: a window opened under the old mesh but not yet
        popped completes under the new one — mitigated-away workers stop
        being owed, replacements start being owed."""
        with self._cv:
            self.expected = tuple(sorted(int(w) for w in workers))
            for b in self._batches.values():
                b.expected = self.expected
            self._cv.notify_all()

    def wait_window(self, window: int, timeout: float = 30.0) -> WindowBatch:
        """Block until every expected worker ended ``window`` (or timeout);
        returns the batch — partial if uploads were dropped or workers
        never reported."""
        deadline = time.monotonic() + timeout
        with self._cv:
            while True:
                b = self._batch(window)
                if b.ended >= set(self.expected):
                    break
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    b.timed_out = True
                    break
                self._cv.wait(timeout=min(remaining, 0.5))
            self._batches.pop(window, None)
            self._popped_through = max(self._popped_through, window)
            b.client_dropped = sum(self._drops.values())
            b.reconnects = sum(self._reconnects.values())
            return b
