"""Blocked online-softmax attention core with a flash-style custom VJP.

Forward saves only (q, k, v, out, lse); the backward recomputes block
probabilities — O(B*S*d) residual memory instead of O(S^2) scan residuals
(verified against naive autodiff in tests/test_attention.py).

Causal folding (``spec.folded``): q blocks are paired (i, NQ-1-i); each pair
runs an inner scan of exactly NQ+1 block-updates where iteration t updates
  - pair-low  with kv block t          while t <= i_lo,
  - pair-high with kv block t-i_lo-1   otherwise,
so attention-core FLOPs drop from NQ*NK to ~(NQ+1)*NQ/2 block-updates
(the exact S^2/2 + O(S*BK) causal lower bound) with uniform per-iteration
work. The backward uses the transposed pairing over kv blocks.
"""
from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array
NEG_INF = -1.0e30


class AttnSpec(NamedTuple):
    causal: bool = True
    window: int = 0          # 0 = full
    softcap: float = 0.0
    scale: float = 0.0       # 0 -> 1/sqrt(D)
    q_block: int = 512
    kv_block: int = 512
    folded: bool = False     # balanced causal folding


def _mask(qpos: Array, kpos: Array, spec: AttnSpec, kv_len) -> Array:
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if spec.causal:
        m &= qpos[:, None] >= kpos[None, :]
    if spec.window:
        m &= qpos[:, None] - kpos[None, :] < spec.window
    if kv_len is not None:
        m &= kpos[None, :] < kv_len
    return m


def _scores(q, k, qpos, kpos, spec, kv_len):
    """s: (B, BQ, KV, G, BK) fp32, masked."""
    scale = spec.scale or 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("btkgd,bskd->btkgs", q, k,
                   preferred_element_type=jnp.float32) * scale
    if spec.softcap:
        s = jnp.tanh(s / spec.softcap) * spec.softcap
    mask = _mask(qpos, kpos, spec, kv_len)
    return jnp.where(mask[None, :, None, None, :], s, NEG_INF), mask


def _block_update(carry, q, k, v, qpos, kpos, spec, kv_len):
    m, l, acc = carry
    s, mask = _scores(q, k, qpos, kpos, spec, kv_len)
    m_new = jnp.maximum(m, s.max(axis=-1))
    alpha = jnp.exp(m - m_new)
    p = jnp.exp(s - m_new[..., None])
    p = jnp.where(mask[None, :, None, None, :], p, 0.0)
    l_new = l * alpha + p.sum(axis=-1)
    pv = jnp.einsum("btkgs,bskd->btkgd", p.astype(v.dtype), v,
                    preferred_element_type=jnp.float32)
    acc_new = acc * alpha[..., None] + pv
    return m_new, l_new, acc_new


def _split_blocks(x, n, bs):
    # (B, S, ...) -> (n, B, bs, ...)
    B = x.shape[0]
    return x.reshape((B, n, bs) + x.shape[2:]).swapaxes(0, 1)


def _forward(q, k, v, spec: AttnSpec, q_offset, kv_len):
    """Returns (out (B,Sq,H,Dv), lse (B,Sq,KV,G))."""
    B, Sq, H, D = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // KV
    BQ, BK = min(spec.q_block, Sq), min(spec.kv_block, Skv)
    assert Sq % BQ == 0 and Skv % BK == 0, (Sq, BQ, Skv, BK)
    NQ, NK = Sq // BQ, Skv // BK

    qg = _split_blocks(q.reshape(B, Sq, KV, G, D), NQ, BQ)
    kb = _split_blocks(k, NK, BK)
    vb = _split_blocks(v, NK, BK)

    fold = (spec.folded and spec.causal and not spec.window
            and kv_len is None and Sq == Skv and BQ == BK and NQ >= 2
            and NQ % 2 == 0)

    qpos_of = lambda i: q_offset + i * BQ + jnp.arange(BQ)
    kpos_of = lambda j: j * BK + jnp.arange(BK)

    def zinit():
        return (jnp.full((B, BQ, KV, G), NEG_INF, jnp.float32),
                jnp.zeros((B, BQ, KV, G), jnp.float32),
                jnp.zeros((B, BQ, KV, G, Dv), jnp.float32))

    if not fold:
        def outer(_, qi):
            qblk, i = qi
            qpos = qpos_of(i)

            def inner(c, kj):
                kblk, vblk, j = kj
                c2 = _block_update(c, qblk, kblk, vblk, qpos, kpos_of(j),
                                   spec, kv_len)
                if spec.causal:
                    # skip fully-masked future blocks (cheap select)
                    valid = (j * BK) <= (q_offset + i * BQ + BQ - 1)
                    c2 = jax.tree_util.tree_map(
                        lambda n, o: jnp.where(valid, n, o), c2, c)
                return c2, None

            (m, l, acc), _ = jax.lax.scan(inner, zinit(),
                                          (kb, vb, jnp.arange(NK)))
            out = acc / jnp.maximum(l, 1e-30)[..., None]
            lse = m + jnp.log(jnp.maximum(l, 1e-30))
            return None, (out, lse)

        _, (outs, lses) = jax.lax.scan(outer, None, (qg, jnp.arange(NQ)))
    else:
        # ---- balanced causal folding: one block-update per iteration ----
        Pn = NQ // 2
        ilo = jnp.arange(Pn)
        ihi = NQ - 1 - ilo
        q_lo, q_hi = qg[:Pn], qg[::-1][:Pn]

        def outer(_, qi):
            qlo, qhi, lo, hi = qi
            plo, phi = qpos_of(lo), qpos_of(hi)

            def inner(c, t):
                clo, chi = c
                use_lo = t <= lo
                j = jnp.where(use_lo, t, t - lo - 1)
                kblk = jax.lax.dynamic_index_in_dim(kb, j, 0, False)
                vblk = jax.lax.dynamic_index_in_dim(vb, j, 0, False)
                qblk = jnp.where(use_lo, qlo, qhi)
                qpos = jnp.where(use_lo, plo, phi)
                cin = jax.tree_util.tree_map(
                    lambda a, b: jnp.where(use_lo, a, b), clo, chi)
                cout = _block_update(cin, qblk, kblk, vblk, qpos,
                                     kpos_of(j), spec, kv_len)
                clo = jax.tree_util.tree_map(
                    lambda n, o: jnp.where(use_lo, n, o), cout, clo)
                chi = jax.tree_util.tree_map(
                    lambda n, o: jnp.where(use_lo, o, n), cout, chi)
                return (clo, chi), None

            (clo, chi), _ = jax.lax.scan(inner, (zinit(), zinit()),
                                         jnp.arange(NQ + 1))

            def fin(c):
                out = c[2] / jnp.maximum(c[1], 1e-30)[..., None]
                lse = c[0] + jnp.log(jnp.maximum(c[1], 1e-30))
                return out, lse
            (olo, llo), (ohi, lhi) = fin(clo), fin(chi)
            return None, ((olo, llo), (ohi, lhi))

        _, ((out_lo, lse_lo), (out_hi, lse_hi)) = jax.lax.scan(
            outer, None, (q_lo, q_hi, ilo, ihi))
        outs = jnp.concatenate([out_lo, out_hi[::-1]], axis=0)
        lses = jnp.concatenate([lse_lo, lse_hi[::-1]], axis=0)

    out = outs.swapaxes(0, 1).reshape(B, Sq, KV * G, Dv)
    lse = lses.swapaxes(0, 1).reshape(B, Sq, KV, G)
    return out.astype(q.dtype), lse


def _recompute_p(q, k, lse, qpos, kpos, spec, kv_len):
    """p (B,BQ,KV,G,BK) fp32 and pre-softcap scores t (for softcap grad)."""
    scale = spec.scale or 1.0 / math.sqrt(q.shape[-1])
    t = jnp.einsum("btkgd,bskd->btkgs", q, k,
                   preferred_element_type=jnp.float32) * scale
    if spec.softcap:
        z = jnp.tanh(t / spec.softcap) * spec.softcap
    else:
        z = t
    mask = _mask(qpos, kpos, spec, kv_len)
    z = jnp.where(mask[None, :, None, None, :], z, NEG_INF)
    p = jnp.exp(z - lse[..., None])
    p = jnp.where(mask[None, :, None, None, :], p, 0.0)
    return p, t, mask


def _backward(res, dout, spec: AttnSpec, q_offset, kv_len):
    q, k, v, out, lse = res
    B, Sq, H, D = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // KV
    BQ, BK = min(spec.q_block, Sq), min(spec.kv_block, Skv)
    NQ, NK = Sq // BQ, Skv // BK
    scale = spec.scale or 1.0 / math.sqrt(D)

    qg = _split_blocks(q.reshape(B, Sq, KV, G, D), NQ, BQ)
    kb = _split_blocks(k, NK, BK)
    vb = _split_blocks(v, NK, BK)
    dog = _split_blocks(dout.reshape(B, Sq, KV, G, Dv), NQ, BQ)
    lseg = _split_blocks(lse, NQ, BQ)
    # delta = rowsum(dout * out)
    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1).reshape(B, Sq, KV, G)
    dg = _split_blocks(delta, NQ, BQ)

    qpos_of = lambda i: q_offset + i * BQ + jnp.arange(BQ)
    kpos_of = lambda j: j * BK + jnp.arange(BK)

    f32 = lambda x: x.astype(jnp.float32)

    def _block_bwd(qblk, kblk, vblk, doblk, lseblk, dblk, qpos, kpos):
        """One (q, kv) block backward update; returns (dk, dv, dq) blocks."""
        p, t, mask = _recompute_p(qblk, kblk, lseblk, qpos, kpos,
                                  spec, kv_len)
        dp = jnp.einsum("btkgd,bskd->btkgs", doblk, vblk,
                        preferred_element_type=jnp.float32)
        dz = p * (dp - dblk[..., None])
        if spec.softcap:
            th = jnp.tanh(t / spec.softcap)
            dz = dz * (1.0 - jnp.square(th))
        dz = dz * scale
        dvb = jnp.einsum("btkgs,btkgd->bskd", p, f32(doblk))
        dkb = jnp.einsum("btkgs,btkgd->bskd", dz, f32(qblk))
        dqb = jnp.einsum("btkgs,bskd->btkgd", dz, f32(kblk))
        return dkb, dvb, dqb

    fold = (spec.folded and spec.causal and not spec.window
            and kv_len is None and Sq == Skv and BQ == BK and NQ >= 2
            and NQ % 2 == 0)

    if not fold:
        def kv_step(dq_acc, kj):
            kblk, vblk, j = kj
            kpos = kpos_of(j)

            def q_step(carry, qi):
                dk, dv = carry
                qblk, doblk, lseblk, dblk, i = qi
                dkb, dvb, dqb = _block_bwd(qblk, kblk, vblk, doblk, lseblk,
                                           dblk, qpos_of(i), kpos)
                if spec.causal:
                    valid = (j * BK) <= (q_offset + i * BQ + BQ - 1)
                    dvb = jnp.where(valid, dvb, 0.0)
                    dkb = jnp.where(valid, dkb, 0.0)
                    dqb = jnp.where(valid, dqb, 0.0)
                return (dk + dkb, dv + dvb), dqb

            zk = jnp.zeros((B, BK, KV, D), jnp.float32)
            zv = jnp.zeros((B, BK, KV, Dv), jnp.float32)
            (dk, dv), dq_contrib = jax.lax.scan(
                q_step, (zk, zv), (qg, dog, lseg, dg, jnp.arange(NQ)))
            return dq_acc + dq_contrib, (dk, dv)

        dq0 = jnp.zeros((NQ, B, BQ, KV, G, D), jnp.float32)
        dq_acc, (dks, dvs) = jax.lax.scan(kv_step, dq0,
                                          (kb, vb, jnp.arange(NK)))
    else:
        # Balanced causal folding, transposed for the backward: kv blocks
        # pair (j, NK-1-j); iteration t of NQ+1 updates
        #   pair-high kv with q block  j_hi + t        while t <= j_lo,
        #   pair-low  kv with q block  j_lo + t-j_lo-1 otherwise —
        # exactly one block-backward per iteration (S^2/2 lower bound).
        Pn = NK // 2
        jlo = jnp.arange(Pn)
        jhi = NK - 1 - jlo
        k_lo, k_hi = kb[:Pn], kb[::-1][:Pn]
        v_lo, v_hi = vb[:Pn], vb[::-1][:Pn]

        def kv_pair_step(dq_acc, kj):
            klo, vlo, khi, vhi, lo, hi = kj

            def t_step(carry, t):
                dk_lo, dv_lo, dk_hi, dv_hi, dq_acc = carry
                use_hi = t <= lo
                i = jnp.where(use_hi, hi + t, lo + (t - lo - 1))
                j = jnp.where(use_hi, hi, lo)
                kblk = jnp.where(use_hi, khi, klo)
                vblk = jnp.where(use_hi, vhi, vlo)
                qblk = jax.lax.dynamic_index_in_dim(qg, i, 0, False)
                doblk = jax.lax.dynamic_index_in_dim(dog, i, 0, False)
                lseblk = jax.lax.dynamic_index_in_dim(lseg, i, 0, False)
                dblk = jax.lax.dynamic_index_in_dim(dg, i, 0, False)
                dkb, dvb, dqb = _block_bwd(qblk, kblk, vblk, doblk, lseblk,
                                           dblk, qpos_of(i), kpos_of(j))
                dk_lo = jnp.where(use_hi, dk_lo, dk_lo + dkb)
                dv_lo = jnp.where(use_hi, dv_lo, dv_lo + dvb)
                dk_hi = jnp.where(use_hi, dk_hi + dkb, dk_hi)
                dv_hi = jnp.where(use_hi, dv_hi + dvb, dv_hi)
                dq_acc = jax.lax.dynamic_update_index_in_dim(
                    dq_acc,
                    jax.lax.dynamic_index_in_dim(dq_acc, i, 0, False) + dqb,
                    i, 0)
                return (dk_lo, dv_lo, dk_hi, dv_hi, dq_acc), None

            zk = jnp.zeros((B, BK, KV, D), jnp.float32)
            zv = jnp.zeros((B, BK, KV, Dv), jnp.float32)
            (dk_lo, dv_lo, dk_hi, dv_hi, dq_acc), _ = jax.lax.scan(
                t_step, (zk, zv, zk, zv, dq_acc), jnp.arange(NQ + 1))
            return dq_acc, ((dk_lo, dv_lo), (dk_hi, dv_hi))

        dq0 = jnp.zeros((NQ, B, BQ, KV, G, D), jnp.float32)
        dq_acc, ((dk_lo, dv_lo), (dk_hi, dv_hi)) = jax.lax.scan(
            kv_pair_step, dq0, (k_lo, v_lo, k_hi, v_hi, jlo, jhi))
        dks = jnp.concatenate([dk_lo, dk_hi[::-1]], axis=0)
        dvs = jnp.concatenate([dv_lo, dv_hi[::-1]], axis=0)
    dq = dq_acc.swapaxes(0, 1).reshape(B, Sq, H, D).astype(q.dtype)
    dk = dks.swapaxes(0, 1).reshape(B, Skv, KV, D).astype(k.dtype)
    dv = dvs.swapaxes(0, 1).reshape(B, Skv, KV, Dv).astype(v.dtype)
    return dq, dk, dv


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def blocked_attention(q: Array, k: Array, v: Array, spec: AttnSpec,
                      q_offset: int = 0, kv_len=None) -> Array:
    out, _ = _forward(q, k, v, spec, q_offset, kv_len)
    return out


def _fwd(q, k, v, spec, q_offset, kv_len):
    out, lse = _forward(q, k, v, spec, q_offset, kv_len)
    return out, (q, k, v, out, lse)


def _bwd(spec, q_offset, kv_len, res, dout):
    return _backward(res, dout, spec, q_offset, kv_len)


blocked_attention.defvjp(_fwd, _bwd)
