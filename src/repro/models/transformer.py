"""Model assembly: every assigned architecture family (dense / moe / ssm /
hybrid / vlm / audio) built from the blocks in this package, with
scan-over-layers (stacked params — keeps HLO O(1 layer)), KV/SSM caches, and
single-token decode. Pure-functional; distribution enters only through the
optional ``dist`` context (sharding constraints + MoE shard_map).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import attention as A
from repro.models import moe as M
from repro.models import ssm as S

Array = jax.Array


def _dt(cfg):
    return L._dtype(cfg.dtype)


def _pdt(cfg):
    return L._dtype(cfg.param_dtype)


def attn_spec(cfg, window: int, folded: bool = False) -> A.AttnSpec:
    return A.AttnSpec(causal=True, window=window, softcap=cfg.attn_softcap,
                      scale=cfg.attn_scale, folded=folded)


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _init_norms(key, cfg, stack):
    p = {"ln1": L.init_norm(cfg.norm, cfg.d_model, stack, _pdt(cfg)),
         "ln2": L.init_norm(cfg.norm, cfg.d_model, stack, _pdt(cfg))}
    if cfg.post_norms:
        p["ln1p"] = L.init_norm(cfg.norm, cfg.d_model, stack, _pdt(cfg))
        p["ln2p"] = L.init_norm(cfg.norm, cfg.d_model, stack, _pdt(cfg))
    return p


def init_attn_block(key, cfg, stack=(), d_ff=None, moe=False):
    ks = jax.random.split(key, 3)
    p = _init_norms(ks[0], cfg, stack)
    if cfg.attention == "mla":
        p["attn"] = A.init_mla(ks[1], cfg, stack, _pdt(cfg))
    else:
        p["attn"] = A.init_gqa(ks[1], cfg, stack, _pdt(cfg))
    if moe:
        p["moe"] = M.init_moe(ks[2], cfg, stack, _pdt(cfg))
    else:
        p["mlp"] = L.init_mlp(ks[2], cfg.d_model, d_ff or cfg.d_ff, cfg.mlp,
                              cfg.use_bias, stack, _pdt(cfg))
    return p


def init_mamba_block(key, cfg, stack=()):
    k1, k2 = jax.random.split(key)
    return {"ln": L.init_norm(cfg.norm, cfg.d_model, stack, _pdt(cfg)),
            "mamba": S.init_mamba2(k2, cfg, stack, _pdt(cfg))}


def apply_attn_block(bp, x, cfg, positions, spec, dist=None,
                     impl=A.blocked_attention, pad_heads=False):
    """Returns (x, aux_stats or None, (k, v)-like cache entries)."""
    h = L.apply_norm(bp["ln1"], x, cfg.norm, cfg.norm_eps)
    if cfg.attention == "mla":
        a, kv = A.apply_mla(bp["attn"], h, cfg, positions, spec, impl, dist)
    else:
        a, kv = A.apply_gqa(bp["attn"], h, cfg, positions, spec, impl, dist,
                            pad_heads)
    if cfg.post_norms:
        a = L.apply_norm(bp["ln1p"], a, cfg.norm, cfg.norm_eps)
    x = _constrain(x + a, dist)
    h = L.apply_norm(bp["ln2"], x, cfg.norm, cfg.norm_eps)
    stats = None
    if "moe" in bp:
        m, stats = M.apply_moe(bp["moe"], h, cfg, dist)
    else:
        m = L.apply_mlp(bp["mlp"], h, cfg.mlp)
    if cfg.post_norms:
        m = L.apply_norm(bp["ln2p"], m, cfg.norm, cfg.norm_eps)
    return _constrain(x + m, dist), stats, kv


def apply_mamba_block(bp, x, cfg, dist=None, impl=S.ssd_chunked,
                      return_cache=False):
    h = L.apply_norm(bp["ln"], x, cfg.norm, cfg.norm_eps)
    y = S.apply_mamba2(bp["mamba"], h, cfg, impl)
    return _constrain(x + y, dist)


def decode_attn_block(bp, x, cfg, pos, cache, spec, dist=None, ring=False):
    h = L.apply_norm(bp["ln1"], x, cfg.norm, cfg.norm_eps)
    if cfg.attention == "mla":
        a, lat, kr = A.mla_decode(bp["attn"], h, cfg, pos, cache["latent"],
                                  cache["krope"], spec)
        new_cache = {"latent": lat, "krope": kr}
    else:
        a, kc, vc = A.gqa_decode(bp["attn"], h, cfg, pos, cache["k"],
                                 cache["v"], spec, ring=ring)
        new_cache = {"k": kc, "v": vc}
    if cfg.post_norms:
        a = L.apply_norm(bp["ln1p"], a, cfg.norm, cfg.norm_eps)
    x = x + a
    h = L.apply_norm(bp["ln2"], x, cfg.norm, cfg.norm_eps)
    if "moe" in bp:
        m, _ = M.apply_moe(bp["moe"], h, cfg, dist)
    else:
        m = L.apply_mlp(bp["mlp"], h, cfg.mlp)
    if cfg.post_norms:
        m = L.apply_norm(bp["ln2p"], m, cfg.norm, cfg.norm_eps)
    return x + m, new_cache


def decode_mamba_block(bp, x, cfg, cache, dist=None):
    h = L.apply_norm(bp["ln"], x, cfg.norm, cfg.norm_eps)
    y, new_cache = S.mamba2_decode(bp["mamba"], h, cfg, cache)
    return x + y, new_cache


def _constrain(x, dist):
    return dist.constrain_act(x) if dist is not None else x


# ---------------------------------------------------------------------------
# Transformer (all families)
# ---------------------------------------------------------------------------

class Transformer:
    """Functional model wrapper for one ModelConfig."""

    def __init__(self, cfg, dist=None, attn_impl=None, remat: str = "none",
                 folded: bool = False, pad_heads: bool = False):
        self.cfg = cfg
        self.dist = dist
        self.attn_impl = attn_impl or A.blocked_attention
        self.remat = remat
        self.folded = folded  # balanced causal folding (EXPERIMENTS §Perf)
        self.pad_heads = pad_heads  # phantom-head TP padding (§Perf)

    # -- init ---------------------------------------------------------------
    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        ks = jax.random.split(key, 8)
        p: Dict[str, Any] = {
            "embed": L.init_embed(ks[0], cfg.padded_vocab, cfg.d_model,
                                  _pdt(cfg)),
            "final_norm": L.init_norm(cfg.norm, cfg.d_model, (), _pdt(cfg)),
        }
        if not cfg.tie_embeddings:
            p["lm_head"] = L.dense_init(ks[1], (cfg.padded_vocab, cfg.d_model),
                                        (), _pdt(cfg))
        if cfg.frontend:
            p["frontend"] = L.dense_init(ks[2], (cfg.d_model, cfg.d_model),
                                         (), _pdt(cfg))
        fam = cfg.family
        if fam in ("dense", "vlm", "audio"):
            if cfg.local_global:  # gemma2: stacked (L/2, 2) pairs
                assert cfg.num_layers % 2 == 0
                p["blocks"] = init_attn_block(
                    ks[3], cfg, (cfg.num_layers // 2, 2))
            else:
                p["blocks"] = init_attn_block(ks[3], cfg, (cfg.num_layers,))
        elif fam == "moe":
            if cfg.moe_every == 2:  # llama4: (dense, moe) pairs
                n_pair = cfg.num_layers // 2
                p["pair_dense"] = init_attn_block(
                    ks[3], cfg, (n_pair,), d_ff=cfg.dense_d_ff)
                p["pair_moe"] = init_attn_block(ks[4], cfg, (n_pair,),
                                                moe=True)
            else:  # deepseek: first layer dense, rest MoE
                nd = cfg.first_dense
                if nd:
                    p["dense0"] = init_attn_block(ks[3], cfg, (nd,),
                                                  d_ff=cfg.dense_d_ff)
                p["blocks"] = init_attn_block(
                    ks[4], cfg, (cfg.num_layers - nd,), moe=True)
        elif fam == "ssm":
            p["blocks"] = init_mamba_block(ks[3], cfg, (cfg.num_layers,))
        elif fam == "hybrid":
            k = cfg.shared_attn_every
            ngroups, tail = divmod(cfg.num_layers, k)
            p["groups"] = init_mamba_block(ks[3], cfg, (ngroups, k))
            if tail:
                p["tail"] = init_mamba_block(ks[4], cfg, (tail,))
            p["shared_attn"] = init_attn_block(ks[5], cfg, ())
        else:
            raise ValueError(fam)
        return p

    # -- embedding ------------------------------------------------------------
    def _embed_inputs(self, p, batch):
        cfg = self.cfg
        parts = []
        if cfg.frontend and "embeds" in batch:
            fe = jnp.einsum("bsd,de->bse",
                            batch["embeds"].astype(_dt(cfg)), p["frontend"])
            parts.append(fe)
        if batch.get("tokens") is not None:
            parts.append(L.embed_lookup(p["embed"], batch["tokens"],
                                        cfg.scale_embed, cfg.d_model)
                         .astype(_dt(cfg)))
        x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
        return _constrain(x, self.dist)

    def _maybe_remat(self, fn):
        if self.remat == "none":
            return fn
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if self.remat == "dots" else None)
        return jax.checkpoint(fn, policy=policy, prevent_cse=False)

    # -- forward (train / prefill) -------------------------------------------
    def forward(self, p, batch, collect_cache: bool = False):
        """Returns (hidden (B,S,d), aux_stats or None, cache or None)."""
        cfg, dist, impl = self.cfg, self.dist, self.attn_impl
        x = self._embed_inputs(p, batch)
        B, Sq, _ = x.shape
        positions = jnp.arange(Sq)[None, :]
        sw_spec = attn_spec(cfg, cfg.sliding_window, self.folded)
        full_spec = attn_spec(cfg, 0, self.folded)
        stats_sum = None
        cache = {}

        fam = cfg.family
        if fam in ("dense", "vlm", "audio"):
            spec = sw_spec if cfg.sliding_window and not cfg.local_global \
                else full_spec
            if cfg.local_global:
                def pair_body(h, bp):
                    h, _, kv_l = apply_attn_block(
                        jax.tree_util.tree_map(lambda a: a[0], bp), h, cfg,
                        positions, sw_spec, dist, impl, self.pad_heads)
                    h, _, kv_g = apply_attn_block(
                        jax.tree_util.tree_map(lambda a: a[1], bp), h, cfg,
                        positions, full_spec, dist, impl, self.pad_heads)
                    kv = jax.tree_util.tree_map(
                        lambda a, b: jnp.stack([a, b]), kv_l, kv_g)
                    return h, (kv if collect_cache else None)
                x, kvs = jax.lax.scan(self._maybe_remat(pair_body), x,
                                      p["blocks"])
            else:
                def body(h, bp):
                    h, _, kv = apply_attn_block(bp, h, cfg, positions, spec,
                                                dist, impl, self.pad_heads)
                    return h, (kv if collect_cache else None)
                x, kvs = jax.lax.scan(self._maybe_remat(body), x, p["blocks"])
            if collect_cache:
                cache["kv"] = kvs

        elif fam == "moe":
            if cfg.moe_every == 2:
                def pair_body(h, bps):
                    bpd, bpm = bps
                    h, _, kv_d = apply_attn_block(bpd, h, cfg, positions,
                                                  full_spec, dist, impl,
                                                  self.pad_heads)
                    h, st, kv_m = apply_attn_block(bpm, h, cfg, positions,
                                                   full_spec, dist, impl,
                                                   self.pad_heads)
                    kv = jax.tree_util.tree_map(
                        lambda a, b: jnp.stack([a, b]), kv_d, kv_m)
                    return h, (st, kv if collect_cache else None)
                x, (stats, kvs) = jax.lax.scan(
                    self._maybe_remat(pair_body), x,
                    (p["pair_dense"], p["pair_moe"]))
                stats_sum = stats.sum(axis=0)
            else:
                if "dense0" in p:
                    def d0_body(h, bp):
                        h, _, kv = apply_attn_block(bp, h, cfg, positions,
                                                    full_spec, dist, impl,
                                                    self.pad_heads)
                        return h, (kv if collect_cache else None)
                    x, kv0 = jax.lax.scan(self._maybe_remat(d0_body), x,
                                          p["dense0"])
                    if collect_cache:
                        cache["kv0"] = kv0

                def moe_body(h, bp):
                    h, st, kv = apply_attn_block(bp, h, cfg, positions,
                                                 full_spec, dist, impl,
                                                 self.pad_heads)
                    return h, (st, kv if collect_cache else None)
                x, (stats, kvs) = jax.lax.scan(self._maybe_remat(moe_body),
                                               x, p["blocks"])
                stats_sum = stats.sum(axis=0)
            if collect_cache:
                cache["kv"] = kvs

        elif fam == "ssm":
            def body(h, bp):
                return apply_mamba_block(bp, h, cfg, dist), None
            x, _ = jax.lax.scan(self._maybe_remat(body), x, p["blocks"])

        elif fam == "hybrid":
            sa = p["shared_attn"]

            def group_body(h, bp):
                def inner(h2, bpi):
                    return apply_mamba_block(bpi, h2, cfg, dist), None
                h, _ = jax.lax.scan(inner, h, bp)
                h, _, kv = apply_attn_block(sa, h, cfg, positions, sw_spec,
                                            dist, impl, self.pad_heads)
                return h, (kv if collect_cache else None)
            x, kvs = jax.lax.scan(self._maybe_remat(group_body), x,
                                  p["groups"])
            if collect_cache:
                cache["kv"] = kvs
            if "tail" in p:
                def tail_body(h, bp):
                    return apply_mamba_block(bp, h, cfg, dist), None
                x, _ = jax.lax.scan(self._maybe_remat(tail_body), x,
                                    p["tail"])
        else:
            raise ValueError(fam)

        x = L.apply_norm(p["final_norm"], x, cfg.norm, cfg.norm_eps)
        return x, stats_sum, (cache if collect_cache else None)

    def logits(self, p, hidden):
        cfg = self.cfg
        head = p["embed"]["table"] if cfg.tie_embeddings else p["lm_head"]
        out = L.lm_logits(head, hidden, cfg.logit_softcap)
        return _constrain_logits(out, self.dist)

    # -- losses ---------------------------------------------------------------
    def loss(self, p, batch):
        cfg = self.cfg
        hidden, stats, _ = self.forward(p, batch)
        logits = self.logits(p, hidden)
        labels = batch["labels"]
        nll, ntok = L.cross_entropy(logits, labels, cfg.vocab_size)
        aux = jnp.zeros((), jnp.float32)
        if stats is not None and cfg.is_moe:
            n_moe = (cfg.num_layers // cfg.moe_every if cfg.moe_every > 1
                     else cfg.num_layers - cfg.first_dense)
            total_tokens = labels.shape[0] * labels.shape[1] * max(1, n_moe)
            aux = M.aux_loss_from_stats(stats, cfg, float(total_tokens))
        metrics = {"nll": nll, "aux": aux, "ntok": ntok}
        return nll + aux, metrics

    # -- decode ---------------------------------------------------------------
    def kv_len(self, max_len: int) -> int:
        cfg = self.cfg
        if cfg.sliding_window and max_len > cfg.sliding_window \
                and not cfg.local_global:
            return cfg.sliding_window
        return max_len

    def init_cache(self, batch: int, max_len: int, make=jnp.zeros):
        """Concrete (or abstract via make=jax.ShapeDtypeStruct-compatible)
        decode cache pytree."""
        cfg = self.cfg
        dt = _dt(cfg)
        kvl = self.kv_len(max_len)

        def kv(stack):
            if cfg.attention == "mla":
                return {
                    "latent": make(stack + (batch, max_len,
                                            cfg.kv_lora_rank), dt),
                    "krope": make(stack + (batch, max_len,
                                           cfg.qk_rope_dim), dt),
                }
            return {
                "k": make(stack + (batch, kvl, cfg.num_kv_heads,
                                   cfg.head_dim), dt),
                "v": make(stack + (batch, kvl, cfg.num_kv_heads,
                                   cfg.head_dim), dt),
            }

        def kv_full(stack):  # gemma2 global layers need full length
            return {
                "k": make(stack + (batch, max_len, cfg.num_kv_heads,
                                   cfg.head_dim), dt),
                "v": make(stack + (batch, max_len, cfg.num_kv_heads,
                                   cfg.head_dim), dt),
            }

        def ssm(stack):
            di, N, G = cfg.d_inner, cfg.ssm_state, cfg.ssm_groups
            W = cfg.conv_width
            return {
                "conv_x": make(stack + (batch, W - 1, di), dt),
                "conv_B": make(stack + (batch, W - 1, G * N), dt),
                "conv_C": make(stack + (batch, W - 1, G * N), dt),
                "state": make(stack + (batch, cfg.ssm_heads, N,
                                       cfg.ssm_head_dim), jnp.float32),
            }

        fam = cfg.family
        if fam in ("dense", "vlm", "audio"):
            if cfg.local_global:
                return {"local": kv((cfg.num_layers // 2,)),
                        "global": kv_full((cfg.num_layers // 2,))}
            return {"kv": kv((cfg.num_layers,))}
        if fam == "moe":
            if cfg.moe_every == 2:
                return {"kv": kv((cfg.num_layers // 2, 2))}
            c = {"kv": kv((cfg.num_layers - cfg.first_dense,))}
            if cfg.first_dense:
                c["kv0"] = kv((cfg.first_dense,))
            return c
        if fam == "ssm":
            return {"ssm": ssm((cfg.num_layers,))}
        if fam == "hybrid":
            k = cfg.shared_attn_every
            ngroups, tail = divmod(cfg.num_layers, k)
            c = {"ssm": ssm((ngroups, k)), "attn": kv((ngroups,))}
            if tail:
                c["ssm_tail"] = ssm((tail,))
            return c
        raise ValueError(fam)

    def decode_step(self, p, cache, batch, pos):
        """One token for the whole batch. batch: {'tokens': (B,1)} or
        {'embeds': (B,1,d)}; pos: scalar int32 (current position).
        Returns (logits (B,1,V), new_cache)."""
        cfg, dist = self.cfg, self.dist
        x = self._embed_inputs(p, batch)
        sw_spec = attn_spec(cfg, cfg.sliding_window)
        full_spec = attn_spec(cfg, 0)
        kvl_ring = (cfg.sliding_window and not cfg.local_global
                    and self._ring_for(cache))
        fam = cfg.family

        if fam in ("dense", "vlm", "audio"):
            if cfg.local_global:
                def pair_body(h, xs):
                    bp, cl, cg = xs
                    bpl = jax.tree_util.tree_map(lambda a: a[0], bp)
                    bpg = jax.tree_util.tree_map(lambda a: a[1], bp)
                    h, cl = decode_attn_block(bpl, h, cfg, pos, cl, sw_spec,
                                              dist)
                    h, cg = decode_attn_block(bpg, h, cfg, pos, cg, full_spec,
                                              dist)
                    return h, (cl, cg)
                x, (ncl, ncg) = jax.lax.scan(
                    pair_body, x, (p["blocks"], cache["local"],
                                   cache["global"]))
                new_cache = {"local": ncl, "global": ncg}
            else:
                spec = sw_spec if cfg.sliding_window else full_spec

                def body(h, xs):
                    bp, c = xs
                    h, c = decode_attn_block(bp, h, cfg, pos, c, spec, dist,
                                             ring=kvl_ring)
                    return h, c
                x, nkv = jax.lax.scan(body, x, (p["blocks"], cache["kv"]))
                new_cache = {"kv": nkv}

        elif fam == "moe":
            if cfg.moe_every == 2:
                def pair_body(h, xs):
                    bpd, bpm, c = xs
                    cd = jax.tree_util.tree_map(lambda a: a[0], c)
                    cm = jax.tree_util.tree_map(lambda a: a[1], c)
                    h, cd = decode_attn_block(bpd, h, cfg, pos, cd, full_spec,
                                              dist)
                    h, cm = decode_attn_block(bpm, h, cfg, pos, cm, full_spec,
                                              dist)
                    return h, jax.tree_util.tree_map(
                        lambda a, b: jnp.stack([a, b]), cd, cm)
                x, nkv = jax.lax.scan(pair_body, x,
                                      (p["pair_dense"], p["pair_moe"],
                                       cache["kv"]))
                new_cache = {"kv": nkv}
            else:
                new_cache = {}
                if "dense0" in p:
                    def d0(h, xs):
                        bp, c = xs
                        h, c = decode_attn_block(bp, h, cfg, pos, c,
                                                 full_spec, dist)
                        return h, c
                    x, nkv0 = jax.lax.scan(d0, x, (p["dense0"],
                                                   cache["kv0"]))
                    new_cache["kv0"] = nkv0

                def body(h, xs):
                    bp, c = xs
                    h, c = decode_attn_block(bp, h, cfg, pos, c, full_spec,
                                             dist)
                    return h, c
                x, nkv = jax.lax.scan(body, x, (p["blocks"], cache["kv"]))
                new_cache["kv"] = nkv

        elif fam == "ssm":
            def body(h, xs):
                bp, c = xs
                h, c = decode_mamba_block(bp, h, cfg, c, dist)
                return h, c
            x, nssm = jax.lax.scan(body, x, (p["blocks"], cache["ssm"]))
            new_cache = {"ssm": nssm}

        elif fam == "hybrid":
            sa = p["shared_attn"]
            # ring buffer when the attn cache was allocated window-sized
            ring = bool(cfg.sliding_window) and (
                cache["attn"]["k"].shape[-3] == cfg.sliding_window)

            def group_body(h, xs):
                bp, cs, ca = xs

                def inner(h2, xsi):
                    bpi, ci = xsi
                    h2, ci = decode_mamba_block(bpi, h2, cfg, ci, dist)
                    return h2, ci
                h, cs = jax.lax.scan(inner, h, (bp, cs))
                h, ca = decode_attn_block(sa, h, cfg, pos, ca, sw_spec, dist,
                                          ring=ring)
                return h, (cs, ca)
            x, (nssm, nattn) = jax.lax.scan(
                group_body, x, (p["groups"], cache["ssm"], cache["attn"]))
            new_cache = {"ssm": nssm, "attn": nattn}
            if "tail" in p:
                def tail_body(h, xs):
                    bp, c = xs
                    h, c = decode_mamba_block(bp, h, cfg, c, dist)
                    return h, c
                x, ntail = jax.lax.scan(tail_body, x,
                                        (p["tail"], cache["ssm_tail"]))
                new_cache["ssm_tail"] = ntail
        else:
            raise ValueError(fam)

        x = L.apply_norm(p["final_norm"], x, cfg.norm, cfg.norm_eps)
        return self.logits(p, x), new_cache

    def _ring_for(self, cache) -> bool:
        cfg = self.cfg
        if not cfg.sliding_window or cfg.local_global:
            return False
        kv = cache.get("kv") or cache.get("attn")
        if kv is None or "k" not in kv:
            return False
        # ring buffer when the allocated cache is window-sized
        return kv["k"].shape[-3] == cfg.sliding_window


def _constrain_logits(x, dist):
    return dist.constrain_logits(x) if dist is not None else x
