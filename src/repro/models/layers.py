"""Core neural-net building blocks (pure-functional JAX, no flax).

Conventions:
  * params are nested dicts of jnp arrays;
  * ``init_*`` take a PRNG key and return params;
  * norm/softmax run in fp32 regardless of activation dtype;
  * weights carry a leading ``stack`` dim when used inside lax.scan layer
    stacks (init with ``stack=(L,)``).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


def dense_init(key, shape, stack=(), dtype=jnp.float32, scale: float = 1.0):
    """Truncated-normal fan-in init."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, stack + shape,
                                        jnp.float32) * std).astype(dtype)


# ---------------------------------------------------------------------------
# Normalization
# ---------------------------------------------------------------------------

def init_norm(kind: str, d: int, stack=(), dtype=jnp.float32):
    p = {"scale": jnp.ones(stack + (d,), dtype)}
    if kind == "layer":
        p["bias"] = jnp.zeros(stack + (d,), dtype)
    return p


def apply_norm(p, x: Array, kind: str, eps: float) -> Array:
    xf = x.astype(jnp.float32)
    if kind == "rms":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    else:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., S, H, D); positions: (..., S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(ang)[..., None, :]                   # (..., S, 1, D/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU / plain GELU)
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, ff: int, kind: str, use_bias: bool, stack=(),
             dtype=jnp.float32):
    k1, k2 = jax.random.split(key)
    gated = kind in ("swiglu", "geglu")
    p = {}
    if gated:
        p["wi"] = dense_init(k1, (d, 2, ff), stack, dtype)       # gate, up
    else:
        p["wi"] = dense_init(k1, (d, ff), stack, dtype)
    p["wo"] = dense_init(k2, (ff, d), stack, dtype)
    if use_bias:
        p["bi"] = jnp.zeros(stack + ((2, ff) if gated else (ff,)), dtype)
        p["bo"] = jnp.zeros(stack + (d,), dtype)
    return p


def apply_mlp(p, x: Array, kind: str) -> Array:
    if kind in ("swiglu", "geglu"):
        h = jnp.einsum("...d,dgf->...gf", x, p["wi"])
        if "bi" in p:
            h = h + p["bi"]
        gate, up = h[..., 0, :], h[..., 1, :]
        act = jax.nn.silu(gate) if kind == "swiglu" else jax.nn.gelu(gate)
        h = act * up
    else:
        h = jnp.einsum("...d,df->...f", x, p["wi"])
        if "bi" in p:
            h = h + p["bi"]
        h = jax.nn.gelu(h)
    y = jnp.einsum("...f,fd->...d", h, p["wo"])
    if "bo" in p:
        y = y + p["bo"]
    return y


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------

def init_embed(key, vocab: int, d: int, dtype=jnp.float32):
    return {"table": dense_init(key, (vocab, d), (), dtype, scale=1.0)}


def embed_lookup(p, ids: Array, scale: bool, d: int) -> Array:
    out = jnp.take(p["table"], ids, axis=0)
    if scale:
        out = out * jnp.asarray(math.sqrt(d), out.dtype)
    return out


def lm_logits(table_or_head: Array, x: Array, softcap: float) -> Array:
    logits = jnp.einsum("...d,vd->...v", x, table_or_head)
    logits = logits.astype(jnp.float32)
    if softcap:
        logits = jnp.tanh(logits / softcap) * softcap
    return logits


def softcap(x: Array, cap: float) -> Array:
    return jnp.tanh(x / cap) * cap if cap else x


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def cross_entropy(logits: Array, labels: Array, vocab_size: int,
                  pad_id: int = -1) -> Tuple[Array, Array]:
    """Mean next-token NLL over non-pad labels. logits fp32 (..., V_padded);
    labels int32. Padded vocab positions are masked out."""
    v = logits.shape[-1]
    logits = jnp.where(
        jnp.arange(v) < vocab_size, logits, jnp.finfo(jnp.float32).min)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = lse - ll
    mask = (labels >= 0).astype(jnp.float32)
    total = jnp.maximum(mask.sum(), 1.0)
    return (nll * mask).sum() / total, total
