"""Mixture-of-Experts layer with expert parallelism.

Scheme (DESIGN.md §4, "replicated-token EP"): activations are sharded over
the data axes and *replicated* over the model axis; experts are sharded over
the model axis. Each model shard dispatches the tokens it already holds to
its local experts (capacity-bounded, sort-based — scatter/gather, **no
one-hot dispatch einsums**, which would poison HLO_FLOPs), computes the
grouped expert FFN, and the partial outputs are summed with a single
psum over the model axis — the same collective a Megatron row-parallel MLP
would issue, so EP adds no extra collective class.

Implemented with shard_map when a mesh is present; identical local math runs
un-mapped on a single device (smoke tests).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L

Array = jax.Array


def init_moe(key, cfg, stack=(), dtype=jnp.float32):
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    p = {
        "router": L.dense_init(ks[0], (d, E), stack, jnp.float32),
        "wi": L.dense_init(ks[1], (E, d, 2, ff), stack, dtype),
        "wo": L.dense_init(ks[2], (E, ff, d), stack, dtype),
    }
    if cfg.num_shared_experts:
        p["shared"] = L.init_mlp(ks[3], d, ff * cfg.num_shared_experts,
                                 cfg.mlp, cfg.use_bias, stack, dtype)
    return p


def _capacity(tokens_local: int, cfg) -> int:
    c = int(math.ceil(tokens_local * cfg.top_k / cfg.num_experts
                      * cfg.capacity_factor))
    return max(8, -(-c // 8) * 8)  # round up to 8 for TPU lane alignment


def _moe_local(p, x: Array, cfg, e_start: int, e_count: int, capacity: int
               ) -> Tuple[Array, Array]:
    """Dispatch + grouped expert FFN over the local expert slice.
    x: (T, d) local tokens; p['wi']: (e_count, d, 2, ff) (FSDP-gathered).
    Returns (y (T, d) partial output, aux load-balancing stats (2E,))."""
    T, d = x.shape
    E, k = cfg.num_experts, cfg.top_k
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                 # (T, E)
    gate_vals, idx = jax.lax.top_k(probs, k)                # (T, k)

    eid = idx.reshape(-1)                                   # (T*k,)
    tid = jnp.repeat(jnp.arange(T), k)
    gate = gate_vals.reshape(-1)

    order = jnp.argsort(eid, stable=True)
    eid_s, tid_s, gate_s = eid[order], tid[order], gate[order]
    counts = jnp.bincount(eid_s, length=E)                  # (E,)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(T * k) - starts[eid_s]                 # rank within expert
    keep = ((pos < capacity) & (eid_s >= e_start)
            & (eid_s < e_start + e_count))
    le = jnp.where(keep, eid_s - e_start, 0)
    sp = jnp.where(keep, pos, 0)

    buf = jnp.zeros((e_count, capacity, d), x.dtype)
    vals = jnp.where(keep[:, None], x[tid_s], 0)
    buf = buf.at[le, sp].add(vals)                          # scatter dispatch

    h = jnp.einsum("ecd,edgf->ecgf", buf, p["wi"])
    act = (jax.nn.silu if cfg.mlp == "swiglu" else jax.nn.gelu)
    h = act(h[:, :, 0, :]) * h[:, :, 1, :]
    out = jnp.einsum("ecf,efd->ecd", h, p["wo"])            # (e_count, C, d)

    tok_out = out[le, sp]                                   # gather combine
    w = jnp.where(keep, gate_s, 0.0).astype(x.dtype)
    y = jnp.zeros((T, d), x.dtype).at[tid_s].add(tok_out * w[:, None])

    # load-balance stats: tokens-per-expert + mean router prob (GShard aux)
    frac_tokens = counts.astype(jnp.float32)
    mean_prob = probs.sum(axis=0)
    return y, jnp.concatenate([frac_tokens, mean_prob])


def aux_loss_from_stats(stats: Array, cfg, total_tokens: float) -> Array:
    E = cfg.num_experts
    f = stats[:E] / jnp.maximum(total_tokens * cfg.top_k, 1.0)
    pbar = stats[E:] / jnp.maximum(total_tokens, 1.0)
    return E * jnp.sum(f * pbar) * cfg.aux_loss_weight


def apply_moe(p, x: Array, cfg, dist=None) -> Tuple[Array, Array]:
    """x: (B, S, d). Returns (y, aux stats (2E,) summed over the fleet)."""
    B, S, d = x.shape
    E = cfg.num_experts

    if dist is None or dist.mesh is None:
        y, stats = _moe_local(p, x.reshape(B * S, d), cfg, 0, E,
                              _capacity(B * S, cfg))
        routed = y.reshape(B, S, d)
    else:
        mesh = dist.mesh
        dp, tp = dist.dp_axes, dist.tp_axis
        ep = dist.tp_size
        assert E % ep == 0, (E, ep)
        e_loc = E // ep
        t_loc = (B // dist.dp_size) * S
        cap = _capacity(t_loc, cfg)

        # ZeRO-1 experts, and serving (fsdp off): weights resident, no
        # per-layer gathers
        zero1 = getattr(dist, "zero1_moe", False) or not dist.fsdp
        pspec = {"router": P(None, None),
                 "wi": P(tp, None, None, None) if zero1
                 else P(tp, dp, None, None),
                 "wo": P(tp, None, None) if zero1 else P(tp, None, dp)}
        routed_p = {k: p[k] for k in ("router", "wi", "wo")}

        def body(pl, xl):
            if zero1:
                # ZeRO-1: bf16 experts already resident — no gathers
                wi, wo = pl["wi"], pl["wo"]
            else:
                # FSDP-gather the local experts' weights over the data axes
                wi = jax.lax.all_gather(pl["wi"], dp, axis=1, tiled=True)
                wo = jax.lax.all_gather(pl["wo"], dp, axis=2, tiled=True)
            eg = {"router": pl["router"], "wi": wi, "wo": wo}
            e0 = jax.lax.axis_index(tp) * e_loc
            T = xl.shape[0] * xl.shape[1]
            y, stats = _moe_local(eg, xl.reshape(T, xl.shape[2]), cfg,
                                  e0, e_loc, cap)
            y = jax.lax.psum(y, tp)               # combine expert partials
            # every model shard computes identical router stats for its
            # data shard's tokens -> divide the tp duplication out
            stats = jax.lax.psum(stats, (tp,) + tuple(dp)) / ep
            return y.reshape(xl.shape), stats

        from repro.dist.compat import shard_map
        routed, stats = shard_map(
            body, mesh=mesh,
            in_specs=(pspec, P(dp, None, None)),
            out_specs=(P(dp, None, None), P()),
            check_vma=False,
        )(routed_p, x)

    if "shared" in p:
        routed = routed + L.apply_mlp(p["shared"], x, cfg.mlp)
    return routed, stats
