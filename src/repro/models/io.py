"""Model input construction: ShapeDtypeStruct specs for the dry-run (no
allocation) and synthetic concrete batches for tests/examples.

Modality frontends (vlm/audio) are STUBS per the assignment: ``input_specs``
supplies precomputed patch/frame embeddings.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.layers import _dtype


def batch_shapes(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, tuple]:
    """Logical (global) input shapes for a cell."""
    B, S = shape.global_batch, shape.seq_len
    out: Dict[str, tuple] = {}
    if shape.kind in ("train", "prefill"):
        if cfg.frontend == "audio":
            out["embeds"] = (B, S, cfg.d_model)
        elif cfg.frontend == "vision":
            F = cfg.frontend_tokens
            out["embeds"] = (B, F, cfg.d_model)
            out["tokens"] = (B, S - F)
        else:
            out["tokens"] = (B, S)
        if shape.kind == "train":
            out["labels"] = (B, S)
    else:  # decode: one new token against a cache of size S
        if cfg.frontend == "audio":
            out["embeds"] = (B, 1, cfg.d_model)
        else:
            out["tokens"] = (B, 1)
    return out


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (weak-type-correct,
    shardable, no device allocation)."""
    dt = _dtype(cfg.dtype)
    specs = {}
    for name, shp in batch_shapes(cfg, shape).items():
        kind = jnp.int32 if name in ("tokens", "labels") else dt
        specs[name] = jax.ShapeDtypeStruct(shp, kind)
    return specs


def synth_batch(cfg: ModelConfig, kind: str, batch: int, seq: int,
                seed: int = 0) -> Dict[str, jax.Array]:
    """Concrete synthetic batch for smoke tests / examples."""
    rng = np.random.default_rng(seed)
    dt = _dtype(cfg.dtype)
    out: Dict[str, jax.Array] = {}
    if kind in ("train", "prefill"):
        if cfg.frontend == "audio":
            out["embeds"] = jnp.asarray(
                rng.normal(size=(batch, seq, cfg.d_model)), dt)
        elif cfg.frontend == "vision":
            F = min(cfg.frontend_tokens, seq - 1)
            out["embeds"] = jnp.asarray(
                rng.normal(size=(batch, F, cfg.d_model)), dt)
            out["tokens"] = jnp.asarray(
                rng.integers(0, cfg.vocab_size, (batch, seq - F)), jnp.int32)
        else:
            out["tokens"] = jnp.asarray(
                rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32)
        if kind == "train":
            out["labels"] = jnp.asarray(
                rng.integers(0, cfg.vocab_size, (batch, seq)), jnp.int32)
    else:
        if cfg.frontend == "audio":
            out["embeds"] = jnp.asarray(
                rng.normal(size=(batch, 1, cfg.d_model)), dt)
        else:
            out["tokens"] = jnp.asarray(
                rng.integers(0, cfg.vocab_size, (batch, 1)), jnp.int32)
    return out
