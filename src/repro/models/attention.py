"""Attention: GQA/MQA/MHA with a double-blocked online-softmax implementation
(XLA "flash" — bounded activation memory, the lowering the dry-run measures),
sliding-window + logit-softcap variants (gemma2), MLA (deepseek) with absorbed
decode, and single-token decode paths against KV caches.

The Pallas TPU kernel (repro.kernels.flash_attention) implements the same
math with explicit VMEM tiling; this module is the distribution-friendly XLA
path and the numerical oracle.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers as L

Array = jax.Array
NEG_INF = -1.0e30


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def init_gqa(key, cfg, stack=(), dtype=jnp.float32):
    d, H, KV, D = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": L.dense_init(ks[0], (d, H, D), stack, dtype),
        "wk": L.dense_init(ks[1], (d, KV, D), stack, dtype),
        "wv": L.dense_init(ks[2], (d, KV, D), stack, dtype),
        "wo": L.dense_init(ks[3], (H, D, d), stack, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros(stack + (H, D), dtype)
        p["bk"] = jnp.zeros(stack + (KV, D), dtype)
        p["bv"] = jnp.zeros(stack + (KV, D), dtype)
    return p


def init_mla(key, cfg, stack=(), dtype=jnp.float32):
    d, H = cfg.d_model, cfg.num_heads
    r, nope, ro, vd = (cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim,
                       cfg.v_head_dim)
    ks = jax.random.split(key, 5)
    return {
        "wq": L.dense_init(ks[0], (d, H, nope + ro), stack, dtype),
        "wkv_down": L.dense_init(ks[1], (d, r + ro), stack, dtype),
        "latent_norm": jnp.ones(stack + (r,), dtype),
        "wk_up": L.dense_init(ks[2], (r, H, nope), stack, dtype),
        "wv_up": L.dense_init(ks[3], (r, H, vd), stack, dtype),
        "wo": L.dense_init(ks[4], (H, vd, d), stack, dtype),
    }


# ---------------------------------------------------------------------------
# Core blocked attention: flash-style custom-VJP implementation
# ---------------------------------------------------------------------------
from repro.models.attention_core import (  # noqa: E402
    AttnSpec, NEG_INF, _mask, blocked_attention)


def attention_ref(q, k, v, spec: AttnSpec, q_offset=0, kv_len=None):
    """Unblocked oracle for tests."""
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = spec.scale or 1.0 / math.sqrt(D)
    qg = q.reshape(B, Sq, KV, G, D)
    s = jnp.einsum("btkgd,bskd->btkgs", qg, k,
                   preferred_element_type=jnp.float32) * scale
    if spec.softcap:
        s = jnp.tanh(s / spec.softcap) * spec.softcap
    qpos = q_offset + jnp.arange(Sq)
    kpos = jnp.arange(k.shape[1])
    mask = _mask(qpos, kpos, spec, kv_len)
    s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("btkgs,bskd->btkgd", p.astype(v.dtype), v)
    return out.reshape(B, Sq, H, v.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA block apply
# ---------------------------------------------------------------------------

def apply_gqa(p, x: Array, cfg, positions: Array, spec: AttnSpec,
              impl=blocked_attention, dist=None, pad_heads=False) -> Array:
    q = jnp.einsum("bsd,dhx->bshx", x, p["wq"])
    k = jnp.einsum("bsd,dkx->bskx", x, p["wk"])
    v = jnp.einsum("bsd,dkx->bskx", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    H, KV = q.shape[2], k.shape[2]
    wo = p["wo"]
    tp = dist.tp_size if dist is not None else 1
    if pad_heads and dist is not None and tp > 1 and H % tp != 0:
        # PHANTOM-HEAD PADDING (EXPERIMENTS §Perf H2): expand GQA kv to
        # per-q-head layout and zero-pad q/k/v/wo to the next multiple of
        # tp so every attention tensor shards evenly — kills the padded
        # all-gather/reshard of the attention output. Phantom heads have
        # zero v and zero wo rows, so outputs and gradients are exact.
        G = H // KV
        Hp = -(-H // tp) * tp
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
        padw = ((0, 0), (0, 0), (0, Hp - H), (0, 0))
        q = jnp.pad(q, padw)
        k = jnp.pad(k, padw)
        v = jnp.pad(v, padw)
        wo = jnp.pad(wo, ((0, Hp - H), (0, 0), (0, 0)))
    if dist is not None:
        # steer the attention core to head sharding over TP
        q = dist.constrain_heads(q)
    out = impl(q, k, v, spec)
    if dist is not None:
        out = dist.constrain_heads(out)
    return jnp.einsum("bshx,hxd->bsd", out, wo), (k, v)


def gqa_decode(p, x: Array, cfg, pos: Array, k_cache: Array, v_cache: Array,
               spec: AttnSpec, ring: bool = False):
    """x: (B, 1, d); caches: (B, S_max, KV, D); pos: scalar current position.
    Returns (out, new_k_cache, new_v_cache)."""
    q = jnp.einsum("bsd,dhx->bshx", x, p["wq"])
    k = jnp.einsum("bsd,dkx->bskx", x, p["wk"])
    v = jnp.einsum("bsd,dkx->bskx", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = L.apply_rope(q, pos[None], cfg.rope_theta)
    k = L.apply_rope(k, pos[None], cfg.rope_theta)
    S_max = k_cache.shape[1]
    slot = pos % S_max if ring else jnp.minimum(pos, S_max - 1)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k, slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v, slot, axis=1)

    B, _, H, D = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    scale = spec.scale or 1.0 / math.sqrt(D)
    qg = q.reshape(B, KV, G, D)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    if spec.softcap:
        s = jnp.tanh(s / spec.softcap) * spec.softcap
    idx = jnp.arange(S_max)
    if ring:
        # ring buffer holds the last S_max tokens; until it wraps, only
        # slots <= pos are live.
        valid = jnp.where(pos >= S_max, jnp.ones((S_max,), bool), idx <= pos)
    else:
        valid = idx <= pos
        if spec.window:
            valid &= idx > pos - spec.window
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", pr.astype(v_cache.dtype), v_cache)
    out = out.reshape(B, 1, H, v_cache.shape[-1])
    y = jnp.einsum("bshx,hxd->bsd", out.astype(x.dtype), p["wo"])
    return y, k_cache, v_cache


# ---------------------------------------------------------------------------
# MLA (deepseek-v2)
# ---------------------------------------------------------------------------

def _mla_scale(cfg):
    return (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5


def apply_mla(p, x: Array, cfg, positions: Array, spec: AttnSpec,
              impl=blocked_attention, dist=None):
    """Training/prefill MLA: materialize per-head K/V from the latent (the
    cache-compression advantage matters only at decode)."""
    B, S, d = x.shape
    H, r = cfg.num_heads, cfg.kv_lora_rank
    nope, ro = cfg.qk_nope_dim, cfg.qk_rope_dim
    q = jnp.einsum("bsd,dhx->bshx", x, p["wq"])
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)

    down = jnp.einsum("bsd,dr->bsr", x, p["wkv_down"])
    latent, k_rope = down[..., :r], down[..., r:]
    latent = L.apply_norm({"scale": p["latent_norm"]}, latent, "rms",
                          cfg.norm_eps)
    k_rope = L.apply_rope(k_rope[:, :, None, :], positions,
                          cfg.rope_theta)          # (B,S,1,ro) shared head
    k_nope = jnp.einsum("bsr,rhx->bshx", latent, p["wk_up"])
    v = jnp.einsum("bsr,rhx->bshx", latent, p["wv_up"])

    qc = jnp.concatenate([q_nope, q_rope], axis=-1)
    kc = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, S, H, ro))], axis=-1)
    sp = spec._replace(scale=_mla_scale(cfg))
    if dist is not None:
        qc = dist.constrain_heads(qc)
        kc = dist.constrain_heads(kc)
        v = dist.constrain_heads(v)
    out = impl(qc, kc, v, sp)
    return jnp.einsum("bshx,hxd->bsd", out, p["wo"]), (latent, k_rope[:, :, 0])


def mla_decode(p, x: Array, cfg, pos: Array, latent_cache: Array,
               krope_cache: Array, spec: AttnSpec):
    """Absorbed MLA decode: cache only (latent r + rope ro) per token.
    latent_cache: (B, S_max, r); krope_cache: (B, S_max, ro)."""
    B = x.shape[0]
    H, r = cfg.num_heads, cfg.kv_lora_rank
    nope, ro = cfg.qk_nope_dim, cfg.qk_rope_dim
    q = jnp.einsum("bsd,dhx->bshx", x, p["wq"])[:, 0]       # (B,H,nope+ro)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = L.apply_rope(q_rope[:, None], pos[None], cfg.rope_theta)[:, 0]

    down = jnp.einsum("bsd,dr->bsr", x, p["wkv_down"])[:, 0]
    latent, k_rope = down[..., :r], down[..., r:]
    latent = L.apply_norm({"scale": p["latent_norm"]}, latent, "rms",
                          cfg.norm_eps)
    k_rope = L.apply_rope(k_rope[:, None, None, :], pos[None],
                          cfg.rope_theta)[:, 0, 0]

    latent_cache = jax.lax.dynamic_update_slice_in_dim(
        latent_cache, latent[:, None], pos, axis=1)
    krope_cache = jax.lax.dynamic_update_slice_in_dim(
        krope_cache, k_rope[:, None], pos, axis=1)

    # absorbed: q_nope through wk_up -> latent space
    q_abs = jnp.einsum("bhx,rhx->bhr", q_nope, p["wk_up"])   # (B,H,r)
    s = (jnp.einsum("bhr,bsr->bhs", q_abs, latent_cache,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bhx,bsx->bhs", q_rope, krope_cache,
                      preferred_element_type=jnp.float32))
    s = s * _mla_scale(cfg)
    valid = jnp.arange(latent_cache.shape[1]) <= pos
    s = jnp.where(valid[None, None, :], s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhs,bsr->bhr", pr.astype(latent_cache.dtype),
                       latent_cache)
    out = jnp.einsum("bhr,rhx->bhx", o_lat, p["wv_up"])      # (B,H,vd)
    y = jnp.einsum("bhx,hxd->bd", out.astype(x.dtype), p["wo"])
    return y[:, None], latent_cache, krope_cache
