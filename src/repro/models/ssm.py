"""Mamba2 (SSD — state-space duality) block in pure JAX.

Chunked SSD: intra-chunk quadratic-in-chunk matmul form + inter-chunk linear
state recurrence (lax.scan over chunks). This is the XLA path the dry-run
lowers; repro.kernels.ssd_scan is the Pallas TPU kernel for the intra-chunk
hot loop, and repro.kernels.ref holds the naive recurrent oracle.

Projections are kept SEPARATE (w_z / w_x / w_B / w_C / w_dt) rather than one
fused in_proj so tensor parallelism can shard the head/channel dims over the
model axis without resharding splits (DESIGN.md §4): heads are sharded
(80/16=5 for mamba2, 112/16=7 for zamba2), B/C group projections are small
and replicated.

Shapes: x (B, S, d_model); internal head layout (B, S, H, P) with
P = ssm_head_dim, state N = ssm_state, groups G (B/C shared per group).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L

Array = jax.Array


def init_mamba2(key, cfg, stack=(), dtype=jnp.float32):
    d = cfg.d_model
    di, N, G = cfg.d_inner, cfg.ssm_state, cfg.ssm_groups
    H, W = cfg.ssm_heads, cfg.conv_width
    ks = jax.random.split(key, 8)
    # dt bias: softplus^-1(dt) for dt ~ U[1e-3, 1e-1]
    u = jax.random.uniform(ks[0], stack + (H,), jnp.float32)
    dt = jnp.exp(u * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))
    a = jax.random.uniform(ks[1], stack + (H,), jnp.float32, 1.0, 16.0)

    def conv(key, ch):
        w = jax.random.normal(key, stack + (ch, W), jnp.float32)
        return (w / math.sqrt(W)).astype(dtype)

    return {
        "w_z": L.dense_init(ks[2], (d, di), stack, dtype),
        "w_x": L.dense_init(ks[3], (d, di), stack, dtype),
        "w_B": L.dense_init(ks[4], (d, G * N), stack, dtype),
        "w_C": L.dense_init(ks[5], (d, G * N), stack, dtype),
        "w_dt": L.dense_init(ks[6], (d, H), stack, dtype),
        "conv_x_w": conv(ks[7], di),
        "conv_x_b": jnp.zeros(stack + (di,), dtype),
        "conv_B_w": conv(ks[0], G * N),
        "conv_B_b": jnp.zeros(stack + (G * N,), dtype),
        "conv_C_w": conv(ks[1], G * N),
        "conv_C_b": jnp.zeros(stack + (G * N,), dtype),
        "A_log": jnp.log(a).astype(jnp.float32),
        "D": jnp.ones(stack + (H,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "gate_norm": jnp.ones(stack + (di,), dtype),
        "out_proj": L.dense_init(ks[6], (di, d), stack, dtype),
    }


def _causal_conv(x: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv. x: (B, S, C); w: (C, W)."""
    W = w.shape[-1]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp.astype(jnp.float32),
        w.astype(jnp.float32).T[:, None, :],      # (W, I=1, C)
        window_strides=(1,),
        padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1],
    )
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def ssd_chunked(x: Array, dt: Array, A: Array, Bm: Array, Cm: Array,
                chunk: int) -> Tuple[Array, Array]:
    """Chunked SSD scan.
    x: (B,S,H,P); dt: (B,S,H) post-softplus; A: (H) negative;
    Bm/Cm: (B,S,G,N). Returns (y (B,S,H,P), final_state (B,H,N,P))."""
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q
    rep = H // G

    xc = x.reshape(Bsz, nc, Q, H, P).transpose(1, 0, 2, 3, 4)
    dtc = dt.reshape(Bsz, nc, Q, H).transpose(1, 0, 2, 3)
    Bc = Bm.reshape(Bsz, nc, Q, G, N).transpose(1, 0, 2, 3, 4)
    Cc = Cm.reshape(Bsz, nc, Q, G, N).transpose(1, 0, 2, 3, 4)

    tril = jnp.tril(jnp.ones((Q, Q), bool))

    def body(state, inp):
        xq, dtq, Bq, Cq = inp                    # per-chunk blocks
        dA = (dtq * A).astype(jnp.float32)       # (B,Q,H), negative
        cum = jnp.cumsum(dA, axis=1)             # (B,Q,H)
        # ---- intra-chunk (quadratic in Q) --------------------------------
        CB = jnp.einsum("btgn,bsgn->bgts", Cq, Bq,
                        preferred_element_type=jnp.float32)   # (B,G,Q,Q)
        CB = jnp.repeat(CB, rep, axis=1)                      # (B,H,Q,Q)
        dec = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])  # (B,t,s,H)
        Lmat = jnp.where(tril[None, :, :, None], dec, 0.0)
        Lmat = Lmat * dtq[:, None, :, :]                      # weight dt_s
        scores = CB.transpose(0, 2, 3, 1) * Lmat              # (B,t,s,H)
        y_intra = jnp.einsum("btsh,bshp->bthp", scores.astype(xq.dtype), xq,
                             preferred_element_type=jnp.float32)
        # ---- inter-chunk (state from previous chunks) --------------------
        Ch = jnp.repeat(Cq, rep, axis=2)                      # (B,Q,H,N)
        y_inter = jnp.einsum("bthn,bhnp->bthp", Ch.astype(jnp.float32),
                             state) * jnp.exp(cum)[..., None]
        # ---- state update --------------------------------------------------
        decay_end = jnp.exp(cum[:, -1:, :] - cum) * dtq       # (B,Q,H)
        Bh = jnp.repeat(Bq, rep, axis=2)                      # (B,Q,H,N)
        ds = jnp.einsum("bqhn,bqhp,bqh->bhnp", Bh.astype(jnp.float32),
                        xq.astype(jnp.float32), decay_end)
        state = state * jnp.exp(cum[:, -1, :])[..., None, None] + ds
        return state, (y_intra + y_inter).astype(x.dtype)

    state0 = jnp.zeros((Bsz, H, N, P), jnp.float32)
    final, yc = jax.lax.scan(body, state0, (xc, dtc, Bc, Cc))
    y = yc.transpose(1, 0, 2, 3, 4).reshape(Bsz, S, H, P)
    return y, final


def apply_mamba2(p, x: Array, cfg, impl=ssd_chunked) -> Array:
    """Full Mamba2 block (train/prefill)."""
    di, N, G, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_groups, cfg.ssm_heads
    P = cfg.ssm_head_dim
    Bsz, S, _ = x.shape
    z = jnp.einsum("bsd,dk->bsk", x, p["w_z"])
    xs = jnp.einsum("bsd,dk->bsk", x, p["w_x"])
    Bm = jnp.einsum("bsd,dk->bsk", x, p["w_B"])
    Cm = jnp.einsum("bsd,dk->bsk", x, p["w_C"])
    dt_raw = jnp.einsum("bsd,dh->bsh", x, p["w_dt"])
    xs = jax.nn.silu(_causal_conv(xs, p["conv_x_w"], p["conv_x_b"]))
    Bm = jax.nn.silu(_causal_conv(Bm, p["conv_B_w"], p["conv_B_b"]))
    Cm = jax.nn.silu(_causal_conv(Cm, p["conv_C_w"], p["conv_C_b"]))
    xs = xs.reshape(Bsz, S, H, P)
    Bm = Bm.reshape(Bsz, S, G, N)
    Cm = Cm.reshape(Bsz, S, G, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, _ = impl(xs, dt, A, Bm, Cm, cfg.ssm_chunk)
    y = y + xs.astype(jnp.float32) * p["D"][:, None]
    y = y.reshape(Bsz, S, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = L.apply_norm({"scale": p["gate_norm"]}, y, "rms", cfg.norm_eps)
    return jnp.einsum("bsk,kd->bsd", y, p["out_proj"])


# ---------------------------------------------------------------------------
# Decode (single-token recurrent step)
# ---------------------------------------------------------------------------

def init_ssm_cache(cfg, batch, stack=(), dtype=jnp.float32):
    di, N, G = cfg.d_inner, cfg.ssm_state, cfg.ssm_groups
    W = cfg.conv_width
    return {
        "conv_x": jnp.zeros(stack + (batch, W - 1, di), dtype),
        "conv_B": jnp.zeros(stack + (batch, W - 1, G * N), dtype),
        "conv_C": jnp.zeros(stack + (batch, W - 1, G * N), dtype),
        "state": jnp.zeros(stack + (batch, cfg.ssm_heads, N,
                                    cfg.ssm_head_dim), jnp.float32),
    }


def _conv_step(window_prev, x_new, w, b):
    """window_prev: (B, W-1, C); x_new: (B, C). Returns (out (B,C), window)."""
    window = jnp.concatenate([window_prev, x_new[:, None, :]], axis=1)
    out = jnp.einsum("bwc,cw->bc", window.astype(jnp.float32),
                     w.astype(jnp.float32))
    return (out + b.astype(jnp.float32)).astype(x_new.dtype), window[:, 1:, :]


def mamba2_decode(p, x: Array, cfg, cache):
    """x: (B, 1, d). Returns (y (B,1,d), new_cache)."""
    di, N, G, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_groups, cfg.ssm_heads
    P = cfg.ssm_head_dim
    B = x.shape[0]
    x0 = x[:, 0]
    z = x0 @ p["w_z"]
    xs = x0 @ p["w_x"]
    Bm = x0 @ p["w_B"]
    Cm = x0 @ p["w_C"]
    dt_raw = x0 @ p["w_dt"]
    xs, conv_x = _conv_step(cache["conv_x"], xs, p["conv_x_w"], p["conv_x_b"])
    Bm, conv_B = _conv_step(cache["conv_B"], Bm, p["conv_B_w"], p["conv_B_b"])
    Cm, conv_C = _conv_step(cache["conv_C"], Cm, p["conv_C_w"], p["conv_C_b"])
    xs, Bm, Cm = jax.nn.silu(xs), jax.nn.silu(Bm), jax.nn.silu(Cm)
    xs = xs.reshape(B, H, P)
    Bm = Bm.reshape(B, G, N)
    Cm = Cm.reshape(B, G, N)
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=1)          # (B,H,N)
    Ch = jnp.repeat(Cm, rep, axis=1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A)                       # (B,H)
    state = (cache["state"] * a[..., None, None]
             + jnp.einsum("bhn,bhp,bh->bhnp", Bh.astype(jnp.float32),
                          xs.astype(jnp.float32), dt))
    y = jnp.einsum("bhn,bhnp->bhp", Ch.astype(jnp.float32), state)
    y = y + xs.astype(jnp.float32) * p["D"][:, None]
    y = y.reshape(B, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = L.apply_norm({"scale": p["gate_norm"]}, y, "rms", cfg.norm_eps)
    out = (y @ p["out_proj"])[:, None]
    new_cache = {"conv_x": conv_x, "conv_B": conv_B, "conv_C": conv_C,
                 "state": state}
    return out, new_cache
