from repro.models.transformer import Transformer  # noqa: F401
