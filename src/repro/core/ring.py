"""Ring-collective behavior model (paper §3, Figs. 3-5).

Chunked ring transfer: at every stage each worker forwards one chunk to its
neighbor, then waits for the slowest link before the next stage. With a link
degraded to a fraction rho of nominal bandwidth:

  * workers on rings that avoid the slow link: continuous ~max throughput
    (Fig. 5a);
  * workers on the affected ring but not driving the slow link: bursts at
    max for rho of each stage, idle otherwise -> mean ~rho, HIGH std
    (Fig. 5b);
  * the worker driving the slow link: continuous ~rho throughput, LOW std
    (Fig. 5c).

On TPU the same signature appears on ICI collective-permute schedules; the
(mu, sigma) differential is what the localizer consumes (DESIGN.md §2).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np


@dataclass
class RingConfig:
    n_workers: int = 32
    n_rings: int = 2            # NCCL builds multiple rings over the NICs
    stage_s: float = 0.004      # nominal chunk stage time
    noise: float = 0.02


def ring_utilization(cfg: RingConfig, duration_s: float, rate_hz: float,
                     slow_worker: Optional[int] = None, rho: float = 0.5,
                     slow_ring: int = 0, rng=None) -> np.ndarray:
    """Per-worker GPU->NIC utilization traces during a ring collective.
    Returns (n_workers, n_samples) in [0, 1].

    Ring r contains all workers (head-to-tail), but each ring uses a
    different NIC/bond; only ``slow_ring`` is affected by the degraded bond
    of ``slow_worker``. A worker's observed GPU-NIC throughput is the mean
    over its rings (they share the measured GPU-NIC path).
    """
    rng = rng or np.random.default_rng(0)
    n = int(duration_s * rate_hz)
    t = np.arange(n) / rate_hz
    out = np.zeros((cfg.n_workers, n), np.float64)

    for r in range(cfg.n_rings):
        affected = slow_worker is not None and r == slow_ring
        stage = cfg.stage_s / rho if affected else cfg.stage_s
        phase = (t % stage) / stage              # position within stage
        for w in range(cfg.n_workers):
            if not affected:
                u = np.ones(n)
            elif w == slow_worker:
                u = np.full(n, rho)              # continuous, low sigma
            else:
                u = (phase < rho).astype(np.float64)  # burst then wait
            out[w] += u
    out /= cfg.n_rings
    out += rng.normal(0, cfg.noise, out.shape)
    return np.clip(out, 0.0, 1.0)
