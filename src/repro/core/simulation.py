"""Fleet simulator: synthesizes per-worker event timelines + 10 kHz-class
resource sample streams for an LMT job, with fault injection (repro of the
paper's §3 / §6 cases; the paper itself uses simulated patterns for its
1M-GPU scaling result, Fig. 17c).

Two modes:
  * raw mode  — full WorkerProfile (events + sample streams) for small
    fleets; exercised end-to-end through critical-path + Algorithm 1;
  * pattern mode — direct (W, 3) pattern synthesis for 100k-1M-worker
    scaling benchmarks.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.events import FunctionEvent, Kind, SampleStream, WorkerProfile
from repro.core import faults as F
from repro.core.ring import RingConfig, ring_utilization

DATALOADER_STACK = ("train.py:train_loop/dataloader.py:__next__/"
                    "socket.py:recv_into")
FORWARD_STACK = "train.py:train_loop/model.py:forward"
GC_STACK = "train.py:train_loop/gradmode.py:__init__"
GEMM = "CUDA_GEMM_kernel"
ALLREDUCE = "AllReduce_RING"
ALLGATHER = "AllGather_RING"
H2D = "memcpy_h2d"
OPT_STACK = "train.py:train_loop/optimizer.py:step"

# serve-mode canonical names (DESIGN.md §13): one continuous-batched decode
# iteration = dequeue wait -> decode GEMMs -> KV block fetch -> token sync
SERVE_QUEUE_STACK = ("serve.py:serve_loop/scheduler.py:dequeue_wait")
DECODE_GEMM = "CUDA_DECODE_GEMM_kernel"
KV_FETCH = "kv_cache.py:read_block"
TOKEN_SYNC = "AllGather_TOKEN"


@dataclass
class SimConfig:
    n_workers: int = 32
    iteration_s: float = 1.0
    n_fwd_gemms: int = 6
    n_bwd_gemms: int = 6
    rate_hz: float = 2000.0
    window_s: float = 2.0
    dp_group_size: int = 16
    seed: int = 0
    family: str = "dense"
    #: cold-spare hosts (ids n_workers..n_workers+n_standby-1) that start
    #: inactive and join the fleet when ``replace_hosts`` re-meshes onto
    #: them (DESIGN.md §9)
    n_standby: int = 0
    #: 'train' (the historical behavior, byte-identical) or 'serve': a
    #: continuous-batched inference fleet whose anchors are request
    #: dequeue/complete pairs, whose profiles paint the serve iteration,
    #: and whose job-level sample stream is ``slo_window`` (DESIGN.md §13)
    workload: str = "train"


class FleetSimulator:
    def __init__(self, cfg: SimConfig, faults: Sequence[F.Fault] = ()):
        self.cfg = cfg
        self.faults = list(faults)
        self.rng = np.random.default_rng(cfg.seed)
        #: end of the last anchor_events span (continuous-timeline cursor)
        self.anchor_clock = 0.0
        #: workers currently in the training mesh; standbys start outside
        self.active = list(range(cfg.n_workers))
        self.standbys = list(range(cfg.n_workers,
                                   cfg.n_workers + cfg.n_standby))

    # -- fleet membership (elastic re-mesh, DESIGN.md §9) ------------------
    @property
    def total_workers(self) -> int:
        """Fleet row space: in-mesh workers + cold standbys."""
        return self.cfg.n_workers + self.cfg.n_standby

    @property
    def active_workers(self) -> List[int]:
        return list(self.active)

    def replace_hosts(self, workers: Sequence[int]
                      ) -> Dict[int, Optional[int]]:
        """Drop the given workers from the mesh and re-mesh elastically:
        each dropped worker is replaced by the next standby (None when the
        standby pool is exhausted — the fleet simply shrinks).  Returns
        {dropped worker -> replacement id or None}.  Dropped workers stop
        producing profiles; downstream, the present-mask machinery
        (DESIGN.md §8) carries diagnosis on the partial fleet."""
        mapping: Dict[int, Optional[int]] = {}
        for w in sorted({int(x) for x in workers}):
            if w not in self.active:
                continue
            self.active.remove(w)
            repl = self.standbys.pop(0) if self.standbys else None
            if repl is not None:
                self.active.append(repl)
            mapping[w] = repl
        self.active.sort()
        return mapping

    # -- helpers ----------------------------------------------------------
    def _fault(self, kind):
        return [f for f in self.faults if isinstance(f, kind)]

    def iteration_multiplier(self) -> float:
        """Job-level slowdown factor from active faults (all workers are
        gated by collectives, so the slowest worker sets the pace).  A
        fault pinned to workers that all left the mesh no longer gates
        anything."""
        m = 1.0
        in_mesh = set(self.active)
        for f in self.faults:
            pinned = F.affected_workers(f)
            if pinned is not None and not (pinned & in_mesh):
                continue
            if isinstance(f, F.GpuThrottle):
                m = max(m, 1 + 0.45 * (f.slowdown - 1))
            elif isinstance(f, F.NvlinkDown):
                m = max(m, 1 + 0.25 * (f.slowdown - 1))
            elif isinstance(f, F.RingSlowLink):
                m = max(m, 1 + 0.35 * (1 / f.rho - 1))
            elif isinstance(f, F.SlowDataloader):
                m = max(m, 1 + 0.005 * f.slowdown)
            elif isinstance(f, F.CpuBoundForward):
                m = max(m, 1 + 0.1 * f.slowdown)
            elif isinstance(f, F.AsyncGc):
                m = max(m, 1 + f.probability * f.pause_s
                        / self.cfg.iteration_s)
            elif isinstance(f, F.CgroupCpuThrottle):
                m = max(m, 1 + 0.012 * f.slowdown)
            elif isinstance(f, F.PageCacheThrash):
                m = max(m, 1 + 0.005 * f.slowdown)
            elif isinstance(f, F.DriverMismatch):
                m = max(m, 1 + 0.45 * (f.slowdown - 1))
            elif isinstance(f, F.DegradedNic):
                m = max(m, 1 + 0.35 * (1 / f.rho - 1))
            elif isinstance(f, F.ArrivalBurst):
                m = max(m, 1 + 0.005 * f.queue_mult)
            elif isinstance(f, F.KvCacheThrash):
                m = max(m, 1 + 0.08 * f.slowdown)
            # numerics faults (LossSpike / GradExplosion) are deliberately
            # absent: they never slow an iteration (DESIGN.md §12a)
        return m

    # -- anchor event stream (feeds the §4.1 detector) --------------------
    def anchor_events(self, n_iters: int, degrade_after: Optional[int] = None,
                      t0: float = 0.0) -> List[Tuple[str, float]]:
        """(name, t) stream of dataloader.next / optimizer.step anchors
        starting at ``t0``.  Faults kick in after iteration ``degrade_after``
        (None = from the first iteration).  The end of the generated span is
        left in ``self.anchor_clock`` so a scenario runner can chain calls
        into one continuous timeline (fault sets may change between calls)."""
        out = []
        t = t0
        mult = self.iteration_multiplier()
        # serve mode: the anchor pair is a request's dequeue->completion —
        # same cadence and draw count, so injecting a serve fault can never
        # shift any other stream.  (The iteration detector never locks on
        # these names; serve detection rides the SLO channel instead.)
        first, second = (("request.dequeue", "request.complete")
                         if self.cfg.workload == "serve"
                         else ("dataloader.next", "optimizer.step"))
        for i in range(n_iters):
            m = mult if degrade_after is None or i >= degrade_after else 1.0
            dur = self.cfg.iteration_s * m \
                * (1 + 0.01 * self.rng.standard_normal())
            out.append((first, t))
            out.append((second, t + dur * 0.97))
            t += dur
        self.anchor_clock = t
        return out

    # -- raw profiling window ---------------------------------------------
    def _ring_by_rate(self, rates: Optional[np.ndarray],
                      seed: Optional[int]) -> Dict[float, np.ndarray]:
        """Ring-collective traces per distinct sample rate.

        With a per-window ``seed`` the draw is seeded from it — NOT from
        the simulator's own rng — so the traces are a pure function of
        (seed, rates): every worker process of a multi-process run
        (DESIGN.md §8) reproduces the same ring, regardless of how many
        anchor draws its local simulator has made.  ``seed=None`` keeps
        the historical shared-rng behavior byte-identical."""
        cfg = self.cfg
        ring_fault = self._fault(F.RingSlowLink)
        if not ring_fault:
            return {}
        rf = ring_fault[0]
        rng = self.rng if seed is None \
            else np.random.default_rng((seed, 1 << 20))
        distinct = [cfg.rate_hz] if rates is None else \
            sorted({float(r) for r in rates})
        return {r: ring_utilization(
            RingConfig(n_workers=cfg.n_workers), cfg.window_s,
            r, slow_worker=rf.slow_worker, rho=rf.rho, rng=rng)
            for r in distinct}

    def profile_window(self, rates: Optional[Sequence[float]] = None,
                       seed: Optional[int] = None) -> List[WorkerProfile]:
        """One fleet of raw profiling windows — the ACTIVE fleet.

        Until ``replace_hosts`` runs, the active fleet is workers
        ``0..n_workers-1`` (byte-identical to the historical behavior);
        after a re-mesh, dropped workers stop profiling and activated
        standbys start.  ``rates`` (per-worker sample rates in Hz, length
        ``total_workers``) is the differential-escalation knob
        (DESIGN.md §7): workers may be sampled at different rates, and
        ``summarize_fleet``'s rate grouping batches them without
        re-padding.  ``seed`` varies the per-worker noise draw window to
        window (None keeps the config seed — byte-identical to the
        historical single-window behavior)."""
        return self.profile_window_slice(self.active_workers,
                                         rates=rates, seed=seed)

    def profile_window_slice(self, workers: Sequence[int],
                             rates: Optional[Sequence[float]] = None,
                             seed: Optional[int] = None
                             ) -> List[WorkerProfile]:
        """Raw profiling windows for a SLICE of the fleet.

        The per-worker noise is already seeded by (seed, worker), so a
        worker process materializing only its own workers produces
        bit-identical profiles to the full-fleet call — this is what each
        daemon process of ``ScenarioRunner.run_multiprocess`` runs over
        its share of the fleet.  ``rates`` stays FULL-fleet-shaped (the
        escalation decision is global); each worker reads its own entry."""
        cfg = self.cfg
        total = self.total_workers
        if rates is not None:
            rates = np.asarray(rates, np.float64)
            if rates.shape != (total,):
                raise ValueError(
                    f"rates must have shape ({total},), "
                    f"got {rates.shape}")
        ring_by_rate = self._ring_by_rate(rates, seed)
        profiles = []
        for w in workers:
            w = int(w)
            if not 0 <= w < total:
                raise ValueError(f"worker {w} outside fleet "
                                 f"[0, {total})")
            r = cfg.rate_hz if rates is None else float(rates[w])
            profiles.append(self._worker_profile(
                w, ring_by_rate.get(r), rate_hz=r, seed=seed))
        return profiles

    def _worker_profile(self, w: int, ring_traces,
                        rate_hz: Optional[float] = None,
                        seed: Optional[int] = None) -> WorkerProfile:
        cfg = self.cfg
        rate = cfg.rate_hz if rate_hz is None else float(rate_hz)
        rng = np.random.default_rng(
            (cfg.seed if seed is None else seed, w))
        if cfg.workload == "serve":
            return self._serve_worker_profile(w, rate, rng)
        n = int(cfg.window_s * rate)
        streams = {
            "gpu_sm": np.zeros(n),
            "cpu": np.zeros(n),
            "pcie_tx": np.zeros(n),
            "membw": np.zeros(n),
        }
        events: List[FunctionEvent] = []

        throttle = next((f for f in self._fault(F.GpuThrottle)
                         if w in f.workers), None)
        nvlink = self._fault(F.NvlinkDown)
        nv_self = any(w in f.workers for f in nvlink)
        nv_group = any((w // f.group_size) in {x // f.group_size
                                               for x in f.workers}
                       for f in nvlink)
        dl = self._fault(F.SlowDataloader)
        cpufwd = next((f for f in self._fault(F.CpuBoundForward)
                       if not f.workers or w in f.workers), None)
        gc = self._fault(F.AsyncGc)
        cgroup = next((f for f in self._fault(F.CgroupCpuThrottle)
                       if w in f.workers), None)
        thrash = next((f for f in self._fault(F.PageCacheThrash)
                       if not f.workers or w in f.workers), None)
        driver = next((f for f in self._fault(F.DriverMismatch)
                       if w in f.workers), None)
        # a degraded NIC manifests on the bad host itself (its recv stalls);
        # DP-group peers wait at the NEXT barrier, which is job-level
        # (iteration_multiplier) rather than a profile signature
        degnic = next((f for f in self._fault(F.DegradedNic)
                       if w in f.workers), None)

        def paint(stream: str, t0: float, t1: float, level: float,
                  jitter: float = 0.03):
            i0, i1 = int(t0 * rate), int(t1 * rate)
            i0, i1 = max(0, i0), min(n, i1)
            if i1 > i0:
                streams[stream][i0:i1] = np.clip(
                    level + rng.normal(0, jitter, i1 - i0), 0, 1)

        t = 0.0
        iter_s = cfg.iteration_s
        while t < cfg.window_s:
            # 1) dataloader
            dl_mult = (dl[0].slowdown if dl
                       else (thrash.slowdown if thrash else 1.0))
            d = 0.005 * iter_s * dl_mult
            events.append(FunctionEvent(DATALOADER_STACK, Kind.PYTHON,
                                        t, t + d, w, depth=3))
            if thrash:
                # page-cache thrash: long reads spent WAITING on disk —
                # low CPU, bursty (DESIGN.md §12b)
                paint("cpu", t, t + d, 0.15, jitter=0.18)
            else:
                paint("cpu", t, t + d, 0.35 if dl else 0.5)
            t += d
            # 2) forward: python wrapper + GEMMs (+ h2d)
            fwd_mult = (cpufwd.slowdown if cpufwd else 1.0)
            if cgroup:
                fwd_mult *= cgroup.slowdown
            fwd_py = 0.004 * iter_s * fwd_mult
            events.append(FunctionEvent(FORWARD_STACK, Kind.PYTHON,
                                        t, t + fwd_py, w, depth=2))
            if cgroup:
                # cgroup quota: utilization CLAMPED FLAT at the ceiling —
                # the scheduler enforces it exactly (near-zero jitter)
                paint("cpu", t, t + fwd_py, cgroup.quota, jitter=0.005)
            else:
                paint("cpu", t, t + fwd_py, 0.9 if cpufwd else 0.4)
            t += fwd_py
            gpu_slow = (throttle.slowdown if throttle
                        else (driver.slowdown if driver else 1.0))
            gpu_util = (throttle.util if throttle
                        else (driver.util if driver else 0.92))
            # driver/kernel mismatch: the mis-tuned stack picks varying
            # kernels, so SM utilization is ERRATIC (high sigma) at a
            # moderate mean — vs a throttled clock's stable low mean
            gpu_jit = 0.10 if (driver and not throttle) else 0.03
            g = 0.33 * iter_s / cfg.n_fwd_gemms
            for _ in range(cfg.n_fwd_gemms):
                gd = g * gpu_slow
                events.append(FunctionEvent(GEMM, Kind.GPU, t, t + gd, w))
                paint("gpu_sm", t, t + gd, gpu_util, jitter=gpu_jit)
                t += gd
            # 3) h2d memcpy
            md = 0.01 * iter_s
            events.append(FunctionEvent(H2D, Kind.MEM, t, t + md, w))
            paint("membw", t, t + md, 0.7)
            t += md
            # 4) backward GEMMs
            for _ in range(cfg.n_bwd_gemms):
                gd = g * gpu_slow
                events.append(FunctionEvent(GEMM, Kind.GPU, t, t + gd, w))
                paint("gpu_sm", t, t + gd, gpu_util, jitter=gpu_jit)
                t += gd
            # 5) collectives (AllGather + AllReduce)
            cd = 0.1 * iter_s
            if nv_group:
                cd *= nvlink[0].slowdown
            if degnic:
                cd *= 1.0 / degnic.rho
            if ring_traces is not None:
                cd *= 1.0 / self._fault(F.RingSlowLink)[0].rho * 0.8
            events.append(FunctionEvent(ALLGATHER, Kind.COMM, t, t + cd, w))
            if ring_traces is not None and w < ring_traces.shape[0]:
                i0, i1 = int(t * rate), min(n, int((t + cd) * rate))
                seg = ring_traces[w][i0:i1]
                streams["pcie_tx"][i0:i0 + len(seg)] = seg
            elif ring_traces is not None:
                # standby joined a ring that still has the slow bond: it
                # bursts like any non-driving member (§3 Fig. 5b)
                paint("pcie_tx", t, t + cd,
                      self._fault(F.RingSlowLink)[0].rho, jitter=0.15)
            elif degnic:
                # degraded NIC: collectives crawl at low, STABLE link
                # utilization while the fleet is healthy (DESIGN.md §12c)
                paint("pcie_tx", t, t + cd, 0.18, jitter=0.01)
            else:
                paint("pcie_tx", t, t + cd,
                      0.85 if nv_self else (0.35 if nv_group else 0.55))
            t += cd
            # 6) async GC pause (random python frame, low CPU)
            if gc and rng.random() < gc[0].probability:
                gd = gc[0].pause_s
                events.append(FunctionEvent(GC_STACK, Kind.PYTHON,
                                            t, t + gd, w, depth=2))
                paint("cpu", t, t + gd, 0.08)
                t += gd
            # 7) optimizer.step
            od = 0.004 * iter_s
            events.append(FunctionEvent(OPT_STACK, Kind.PYTHON, t, t + od,
                                        w, depth=2))
            paint("cpu", t, t + od, 0.6)
            t += od

        t0 = 0.0
        return WorkerProfile(
            worker=w, window=(t0, self.cfg.window_s),
            events=[e for e in events if e.start < self.cfg.window_s],
            streams={k: SampleStream(rate, 0.0, v)
                     for k, v in streams.items()})

    # -- serve-mode profile (DESIGN.md §13) --------------------------------
    def _serve_worker_profile(self, w: int, rate: float,
                              rng: np.random.Generator) -> WorkerProfile:
        """One serving worker's raw window: a continuous-batched decode
        iteration painted per the serve fault signatures.

          1. dequeue wait  (PYTHON, low idle CPU; an ``ArrivalBurst``
             stretches it fleet-wide — queue buildup);
          2. decode GEMMs  (GPU; a pinned ``GpuThrottle`` stretches them at
             low SM util — the hot-worker-slow-decode case);
          3. KV block fetch (MEM; ``KvCacheThrash`` stretches it fleet-wide
             at saturated memory bandwidth);
          4. token sync    (COMM; a pinned ``DegradedNic`` collapses it to
             rho at low, stable link utilization).

        Healthy betas sit inside the dense-family expectation boxes, so a
        healthy serving fleet localizes nothing — the same property the
        train iteration has."""
        cfg = self.cfg
        n = int(cfg.window_s * rate)
        streams = {
            "gpu_sm": np.zeros(n),
            "cpu": np.zeros(n),
            "pcie_tx": np.zeros(n),
            "membw": np.zeros(n),
        }
        events: List[FunctionEvent] = []

        burst = self._fault(F.ArrivalBurst)
        kv = self._fault(F.KvCacheThrash)
        throttle = next((f for f in self._fault(F.GpuThrottle)
                         if w in f.workers), None)
        degnic = next((f for f in self._fault(F.DegradedNic)
                       if w in f.workers), None)

        def paint(stream: str, t0: float, t1: float, level: float,
                  jitter: float = 0.03):
            i0, i1 = int(t0 * rate), int(t1 * rate)
            i0, i1 = max(0, i0), min(n, i1)
            if i1 > i0:
                streams[stream][i0:i1] = np.clip(
                    level + rng.normal(0, jitter, i1 - i0), 0, 1)

        t = 0.0
        iter_s = cfg.iteration_s
        n_gemms = cfg.n_fwd_gemms
        while t < cfg.window_s:
            # 1) dequeue wait: idle scheduler spin, low CPU either way —
            # a burst makes it LONG, not busy
            qd = 0.005 * iter_s * (burst[0].queue_mult if burst else 1.0)
            events.append(FunctionEvent(SERVE_QUEUE_STACK, Kind.PYTHON,
                                        t, t + qd, w, depth=3))
            paint("cpu", t, t + qd, 0.12)
            t += qd
            # 2) decode GEMMs (continuous-batched step)
            gpu_slow = throttle.slowdown if throttle else 1.0
            gpu_util = throttle.util if throttle else 0.92
            g = 0.45 * iter_s / n_gemms
            for _ in range(n_gemms):
                gd = g * gpu_slow
                events.append(FunctionEvent(DECODE_GEMM, Kind.GPU,
                                            t, t + gd, w))
                paint("gpu_sm", t, t + gd, gpu_util)
                t += gd
            # 3) KV block fetch
            md = 0.08 * iter_s * (kv[0].slowdown if kv else 1.0)
            events.append(FunctionEvent(KV_FETCH, Kind.MEM, t, t + md, w))
            if kv:
                # working set blew past device memory: fetch path saturated
                # and BURSTY
                paint("membw", t, t + md, 0.95, jitter=0.1)
            else:
                paint("membw", t, t + md, 0.7)
            t += md
            # 4) token sync collective
            cd = 0.1 * iter_s
            if degnic:
                cd *= 1.0 / degnic.rho
            events.append(FunctionEvent(TOKEN_SYNC, Kind.COMM,
                                        t, t + cd, w))
            if degnic:
                # degraded NIC: low, STABLE link utilization (§12c)
                paint("pcie_tx", t, t + cd, 0.18, jitter=0.01)
            else:
                paint("pcie_tx", t, t + cd, 0.55)
            t += cd

        return WorkerProfile(
            worker=w, window=(0.0, cfg.window_s),
            events=[e for e in events if e.start < cfg.window_s],
            streams={k: SampleStream(rate, 0.0, v)
                     for k, v in streams.items()})

    # -- numerics channel (DESIGN.md §12a) ---------------------------------
    def numerics_window(self, n_iters: int, seed: int, t0: float,
                        t1: float) -> List[Tuple[float, float, float]]:
        """One window of job-level (t, loss, grad_norm) samples.

        Seeded from ``(seed, 1 << 21)`` (the ring traces own ``1 << 20``)
        with exactly two draws per iteration REGARDLESS of active faults,
        so the stream is a pure function of (seed, n_iters): every worker
        process reproduces it, ``self.rng`` is never touched, and injecting
        or curing a numerics fault cannot shift any other stream — the six
        original faults stay byte-identical.
        """
        rng = np.random.default_rng((seed, 1 << 21))
        spike = self._fault(F.LossSpike)
        grad = self._fault(F.GradExplosion)
        samples: List[Tuple[float, float, float]] = []
        for i in range(n_iters):
            t = t0 + (i + 1) * (t1 - t0) / max(1, n_iters)
            loss = 2.5 * (1 + 0.01 * rng.standard_normal())
            g = 1.0 * (1 + 0.02 * rng.standard_normal())
            if spike:
                loss *= spike[0].magnitude
            if grad:
                g = float("nan") if grad[0].nan else g * grad[0].magnitude
            samples.append((float(t), float(loss), float(g)))
        return samples

    # -- serving latency-SLO channel (DESIGN.md §13) -----------------------
    def slo_window(self, n_iters: int, seed: int, t0: float,
                   t1: float) -> List[Tuple[float, float, float]]:
        """One window of job-level (t, p99_ttft, p99_tbt) samples.

        Seeded from ``(seed, 1 << 22)`` (ring traces own ``1 << 20``, the
        numerics lane ``1 << 21``) with exactly two draws per sample
        REGARDLESS of active faults, so the stream is a pure function of
        (seed, n_iters) and injecting or curing a serve fault cannot shift
        any other stream.

        Fault effects mirror how serving latency actually degrades: a
        queue backlog explodes TTFT (requests wait to be admitted), while
        hot decode / KV thrash / a degraded token-sync link stretch TBT.
        A fault pinned to workers that all left the mesh (drained and
        replaced) stops gating latency, like ``iteration_multiplier``."""
        rng = np.random.default_rng((seed, 1 << 22))
        in_mesh = set(self.active)

        def gates(f) -> bool:
            pinned = F.affected_workers(f)
            return pinned is None or bool(pinned & in_mesh)

        ttft_mult = 1.0
        tbt_mult = 1.0
        for f in self.faults:
            if not gates(f):
                continue
            if isinstance(f, F.ArrivalBurst):
                ttft_mult = max(ttft_mult, f.queue_mult)
            elif isinstance(f, F.KvCacheThrash):
                tbt_mult = max(tbt_mult, 1 + 0.1 * f.slowdown)
            elif isinstance(f, F.GpuThrottle):
                tbt_mult = max(tbt_mult, 1 + 0.75 * (f.slowdown - 1))
            elif isinstance(f, F.DegradedNic):
                tbt_mult = max(tbt_mult, 1 + 0.5 * (1 / f.rho - 1))
        samples: List[Tuple[float, float, float]] = []
        for i in range(n_iters):
            t = t0 + (i + 1) * (t1 - t0) / max(1, n_iters)
            ttft = 0.08 * (1 + 0.03 * rng.standard_normal())
            tbt = 0.020 * (1 + 0.02 * rng.standard_normal())
            samples.append((float(t), float(ttft * ttft_mult),
                            float(tbt * tbt_mult)))
        return samples

    # -- pattern mode (scaling benchmarks) ---------------------------------
    def synth_patterns(self, n_functions: int = 20
                       ) -> Tuple[Dict[str, np.ndarray], Dict[str, Kind]]:
        """Direct (W, 3) pattern synthesis for very large fleets.

        Uses the same canonical function names as raw mode and injects
        every fault model's §3/§6 pattern signature, so the scaling
        benchmarks and the scenario-matrix tests can exercise localization
        on all six production cases without materializing raw windows."""
        W = self.cfg.n_workers
        rng = self.rng
        patterns: Dict[str, np.ndarray] = {}
        kinds: Dict[str, Kind] = {}

        def add(name, kind, beta0, mu0, sig0):
            # BOUNDED (uniform) jitter: worst-case pairwise Manhattan after
            # Eq. 8 max-normalization is 2*(.05+.05+.08)*(1+j) < 0.4, so a
            # healthy fleet can never cross the delta threshold at any W
            patterns[name] = np.stack([
                np.clip(beta0 * (1 + 0.05 * rng.uniform(-1, 1, W)), 0, 1),
                np.clip(mu0 * (1 + 0.05 * rng.uniform(-1, 1, W)), 0, 1),
                np.clip(sig0 * (1 + 0.08 * rng.uniform(-1, 1, W)), 0, 1),
            ], axis=1).astype(np.float32)
            kinds[name] = kind
            return patterns[name]

        gemm = add(GEMM, Kind.GPU, 0.55, 0.92, 0.03)
        allg = add(ALLGATHER, Kind.COMM, 0.15, 0.55, 0.05)
        add(H2D, Kind.MEM, 0.01, 0.7, 0.03)
        dl = add(DATALOADER_STACK, Kind.PYTHON, 0.005, 0.5, 0.05)
        fwd = add(FORWARD_STACK, Kind.PYTHON, 0.004, 0.4, 0.05)
        gc = add(GC_STACK, Kind.PYTHON, 0.0005, 0.1, 0.03)
        for i in range(len(patterns), n_functions):
            kind = [Kind.GPU, Kind.COMM, Kind.PYTHON, Kind.MEM][i % 4]
            beta0 = {Kind.GPU: 0.5, Kind.COMM: 0.15, Kind.PYTHON: 0.005,
                     Kind.MEM: 0.05}[kind] / max(1, n_functions // 8)
            add(f"{kind.name.lower()}_func_{i}", kind, beta0, 0.8, 0.05)

        # -- fault signatures (one per production case) --------------------
        for f in self._fault(F.GpuThrottle):
            # C1P1: longer GEMMs (beta up) at LOW SM utilization (mu down)
            idx = np.asarray(list(f.workers), np.int64)
            gemm[idx, 0] = np.clip(gemm[idx, 0] * f.slowdown, 0, 1)
            gemm[idx, 1] = f.util
        for f in self._fault(F.NvlinkDown):
            # C1P2: fallback traffic at HIGH PCIe mu on the fault workers;
            # everyone in their DP groups stalls (beta above the COMM box)
            idx = np.asarray(list(f.workers), np.int64)
            groups = {w // f.group_size for w in f.workers}
            member = np.isin(np.arange(W) // f.group_size, list(groups))
            allg[member, 0] = np.clip(allg[member, 0] * f.slowdown, 0, 1)
            allg[member, 1] = 0.35
            allg[idx, 1] = 0.9
        for f in self._fault(F.RingSlowLink):
            # §3 Fig. 5b/5c: every worker's mean drops to ~rho; the slow
            # worker is STABLE while the rest of the ring fluctuates
            allg[:, 1] = np.clip(
                f.rho * (1 + 0.03 * rng.standard_normal(W)), 0, 1)
            allg[:, 2] = np.clip(
                0.2 * (1 + 0.2 * rng.standard_normal(W)), 0.05, 1)
            allg[f.slow_worker, 2] = 0.01
        for f in self._fault(F.SlowDataloader):
            # C2P1: socket recv dominates on ALL workers
            dl[:, 0] = np.clip(dl[:, 0] * f.slowdown, 0, 1)
            dl[:, 1] = 0.35
        for f in self._fault(F.CpuBoundForward):
            # C2P2: CPU-bound forward() on the affected workers
            idx = np.asarray(list(f.workers) if f.workers
                             else list(range(W)))
            fwd[idx, 0] = np.clip(fwd[idx, 0] * f.slowdown, 0, 1)
            fwd[idx, 1] = 0.9
        for f in self._fault(F.AsyncGc):
            # C2P3: random workers pause in non-CPU-intensive frames
            hit = np.flatnonzero(rng.random(W) < f.probability)
            if hit.size == 0:
                hit = np.array([int(rng.integers(0, W))])
            gc[hit, 0] = np.clip(
                f.probability * f.pause_s / self.cfg.iteration_s, 0, 1)
            gc[hit, 1] = 0.08
        return patterns, kinds
