"""Event & sample model for PerfTracker.

A "function" is any procedure in LMT (paper §3): Python functions (full call
stack = identity), GPU compute kernels, memory ops, collective communication.
Events are intervals on one worker's timeline; resource samples are fixed-rate
utilization streams (10 kHz in the paper).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from enum import IntEnum
from typing import Dict, List, Optional, Tuple

import numpy as np


class Kind(IntEnum):
    """Critical-path priority classes (paper §4.2, Fig. 9): lower value =
    higher priority."""
    GPU = 0        # GPU computation kernels
    MEM = 1        # memory operations (malloc/memcpy/H2D/D2H)
    COMM = 2       # collective communication kernels
    PYTHON = 3     # Python functions (training thread, leaf frames)
    NUMERICS = 4   # job-level numerics signals (loss / grad-norm channel,
    #                DESIGN.md §12a) — never appears in worker profiles;
    #                exists so numerics abnormalities ride the same
    #                report/mitigation path as perf kinds


#: resource stream that determines performance per kind (paper §4.2)
RESOURCE_FOR_KIND = {
    Kind.GPU: "gpu_sm",
    Kind.MEM: "membw",
    Kind.COMM: "pcie_tx",     # GPU->NIC for inter-host collectives
    Kind.PYTHON: "cpu",
    Kind.NUMERICS: "cpu",     # defensive: numerics events are synthetic
}


@dataclass(frozen=True)
class FunctionEvent:
    name: str                 # identity; Python functions: full call stack
    kind: Kind
    start: float              # seconds
    end: float
    worker: int = 0
    thread: str = "train"     # Python events: only 'train' thread counts
    depth: int = 0            # call-stack depth (leaf selection)
    resource: str = ""        # override of RESOURCE_FOR_KIND

    @property
    def duration(self) -> float:
        return self.end - self.start

    def resource_stream(self) -> str:
        return self.resource or RESOURCE_FOR_KIND[self.kind]


@dataclass
class SampleStream:
    """Fixed-rate utilization samples in [0, 1]."""
    rate_hz: float
    t0: float
    values: np.ndarray

    def window(self, start: float, end: float) -> np.ndarray:
        i0 = max(0, int((start - self.t0) * self.rate_hz))
        i1 = min(len(self.values), int(np.ceil((end - self.t0)
                                               * self.rate_hz)))
        return self.values[i0:max(i0, i1)]


@dataclass
class WorkerProfile:
    """One worker's raw profiling window (paper: ~3 GB; here: whatever the
    simulator / tracer produced)."""
    worker: int
    window: Tuple[float, float]
    events: List[FunctionEvent] = field(default_factory=list)
    streams: Dict[str, SampleStream] = field(default_factory=dict)
    #: optional pre-built (E, n) batch for the summarize backends
    #: (repro.summarize.packing.PackedEvents); tracers that know their
    #: events fill this so the daemon skips the packing pass
    packed: Optional[object] = None

    def raw_size_bytes(self) -> int:
        ev = sum(64 + len(e.name) for e in self.events)
        st = sum(v.values.nbytes for v in self.streams.values())
        return ev + st
