"""PerfTracker core — the paper's contribution (see DESIGN.md §1).

Pipeline: detector (§4.1) -> profiling window -> behavior patterns (§4.2,
Algorithm 1) -> differential localization (§4.3) -> report + mitigation.
"""
from repro.core.detector import DetectorConfig, IterationDetector, Trigger  # noqa: F401
from repro.core.events import FunctionEvent, Kind, SampleStream, WorkerProfile  # noqa: F401
from repro.core.localizer import Localizer  # noqa: F401
from repro.core.patterns import Pattern, critical_duration, summarize_worker  # noqa: F401
from repro.core.service import PerfTrackerService  # noqa: F401
