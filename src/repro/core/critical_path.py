"""Critical-path extraction (paper §4.2, Fig. 9).

Priorities: GPU compute > memory ops > collective comm > Python. A function
execution (or a subinterval of it) is on the critical path iff no
higher-priority function is executing then. Python events must additionally
be on the training thread and be LEAF frames (no child executing).

Winners for *all* segments are computed in one event x segment numpy pass
(min-kind per segment, then the max-depth leaf rule on Python segments) —
no Python loop over segments.  ``fleet_critical_times`` stacks many workers
into one padded ``(W, E, S)`` batch and amortizes that pass across the
whole fleet; zero-width padding segments and padded dummy events are
float-exact no-ops, so the batched result is bit-identical to the
per-worker one.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.events import FunctionEvent, Kind

_EPS = 1e-12
_BIG_KIND = np.int8(127)           # > any Kind value: "no eligible event"


def _event_arrays(events: Sequence[FunctionEvent],
                  window: Tuple[float, float]
                  ) -> Tuple[np.ndarray, ...]:
    """Clipped (starts, ends, kinds, depth, eligible) arrays for one worker."""
    t0, t1 = window
    starts = np.array([max(t0, min(t1, e.start)) for e in events])
    ends = np.array([max(t0, min(t1, e.end)) for e in events])
    kinds = np.array([int(e.kind) for e in events], np.int8)
    depth = np.array([e.depth for e in events], np.int16)
    # eligible python events: training thread only
    eligible = np.array([e.kind != Kind.PYTHON or e.thread == "train"
                         for e in events], bool)
    return starts, ends, kinds, depth, eligible


def _bounds(starts: np.ndarray, ends: np.ndarray, t0: float, t1: float,
            pad_to: int = 0) -> np.ndarray:
    """Sorted segment bounds for one worker: window edges + every clipped
    event boundary.  Duplicates stay (zero-width segments contribute exactly
    0.0 everywhere); optional right-padding with t1 for fleet batching."""
    E = len(starts)
    m = max(2 * E + 2, pad_to)
    pts = np.full(m, t1)
    pts[0] = t0
    pts[2:2 + E] = starts
    pts[2 + E:2 + 2 * E] = ends
    return np.sort(pts)


def _compact_bounds(bounds: np.ndarray, t1w: np.ndarray) -> np.ndarray:
    """Compact duplicate segment bounds (adjacent events share boundaries):
    push duplicates to +inf, re-sort, trim, clamp the inf tail back to t1.
    Zero-width segments survive only as a right-aligned tail, so any two
    compactions of the same worker differ purely by trailing zero-width
    padding — a float-exact no-op for every downstream reduction."""
    dup = np.zeros_like(bounds, bool)
    dup[:, 1:] = bounds[:, 1:] <= bounds[:, :-1]
    b = np.where(dup, np.inf, bounds)
    b.sort(axis=1)
    S_u = max(1, int((~dup).sum(axis=1).max()) - 1)
    b = b[:, :S_u + 1]
    return np.where(np.isinf(b), t1w[:, None], b)


def _winner_mask(starts: np.ndarray, ends: np.ndarray, kinds: np.ndarray,
                 depth: np.ndarray, eligible: np.ndarray,
                 seg_lo: np.ndarray, seg_hi: np.ndarray) -> np.ndarray:
    """Critical-path winners, batched: all inputs (W, E) / (W, S), output
    (W, E, S) bool.  An event wins a segment iff it covers it, is eligible,
    has the minimal (= highest-priority) kind there, and — on Python-won
    segments — is a deepest (leaf) frame among the winners."""
    active = (starts[:, :, None] <= seg_lo[:, None, :] + _EPS) \
        & (ends[:, :, None] >= seg_hi[:, None, :] - _EPS) \
        & eligible[:, :, None]
    kmat = np.where(active, kinds[:, :, None], _BIG_KIND)
    best = kmat.min(axis=1)                                # (W, S)
    winner = active & (kinds[:, :, None] == best[:, None, :])
    py_seg = best == int(Kind.PYTHON)
    if py_seg.any():
        dmat = np.where(winner, depth[:, :, None], -1)
        dmax = dmat.max(axis=1)                            # (W, S)
        winner &= ~py_seg[:, None, :] \
            | (depth[:, :, None] == dmax[:, None, :])
    return winner


def _event_times(winner: np.ndarray, widths: np.ndarray) -> np.ndarray:
    """Per-event critical seconds: (W, E, S) winners x (W, S) widths ->
    (W, E).  ``add.reduceat`` accumulates each event's segments
    sequentially left-to-right, so padded zero-width segments never
    perturb the float result (and no (W*E*S) id array is materialized)."""
    W, E, S = winner.shape
    weights = (winner * widths[:, None, :]).ravel()
    return np.add.reduceat(weights,
                           np.arange(W * E) * S).reshape(W, E)


def critical_intervals(events: List[FunctionEvent],
                       window: Tuple[float, float]
                       ) -> Dict[int, List[Tuple[float, float]]]:
    """Returns, per event index, the sub-intervals on the critical path."""
    t0, t1 = window
    if not events or t1 - t0 <= 0:
        return {}
    starts, ends, kinds, depth, eligible = _event_arrays(events, window)
    bounds = _compact_bounds(_bounds(starts, ends, t0, t1)[None],
                             np.array([t1]))[0]
    seg_lo, seg_hi = bounds[:-1], bounds[1:]
    winner = _winner_mask(starts[None], ends[None], kinds[None],
                          depth[None], eligible[None],
                          seg_lo[None], seg_hi[None])[0]
    winner &= (seg_hi - seg_lo)[None, :] > 0

    # runs of winner segments per event -> (lo, hi) intervals
    E, S = winner.shape
    edged = np.zeros((E, S + 2), np.int8)
    edged[:, 1:-1] = winner
    trans = np.diff(edged, axis=1)
    ei, si = np.nonzero(trans == 1)                  # run starts (row-major)
    si_end = np.nonzero(trans == -1)[1]              # paired run ends
    merged: Dict[int, List[Tuple[float, float]]] = {}
    for k in range(len(ei)):
        i = int(ei[k])
        lo, hi = float(bounds[si[k]]), float(bounds[si_end[k]])
        ivs = merged.setdefault(i, [])
        # runs arrive left-to-right; zero-width segments may split a run
        if ivs and lo <= ivs[-1][1] + _EPS:
            ivs[-1] = (ivs[-1][0], max(ivs[-1][1], hi))
        else:
            ivs.append((lo, hi))
    return merged


def critical_time_by_function(events: List[FunctionEvent],
                              window: Tuple[float, float]) -> Dict[str, float]:
    """Per-function critical-path seconds (the beta numerator of Eq. 2-3)."""
    t0, t1 = window
    if not events or t1 - t0 <= 0:
        return {}
    starts, ends, kinds, depth, eligible = _event_arrays(events, window)
    bounds = _compact_bounds(_bounds(starts, ends, t0, t1)[None],
                             np.array([t1]))
    winner = _winner_mask(starts[None], ends[None], kinds[None],
                          depth[None], eligible[None],
                          bounds[:, :-1], bounds[:, 1:])
    times = _event_times(winner, bounds[:, 1:] - bounds[:, :-1])[0]
    return _fold_by_function(events, times)


def _fold_by_function(events: Sequence[FunctionEvent],
                      times: np.ndarray) -> Dict[str, float]:
    """Sum per-event seconds into {function -> seconds}, first-seen order,
    dropping functions that never touch the critical path."""
    names: List[str] = []
    index: Dict[str, int] = {}
    for e in events:
        if e.name not in index:
            index[e.name] = len(names)
            names.append(e.name)
    fid = np.array([index[e.name] for e in events], np.int64)
    per_fn = np.bincount(fid, weights=times[:len(events)],
                         minlength=len(names))
    return {nm: float(per_fn[j]) for j, nm in enumerate(names)
            if per_fn[j] > 0.0}


def batched_event_times(starts: np.ndarray, ends: np.ndarray,
                        kinds: np.ndarray, depth: np.ndarray,
                        eligible: np.ndarray, worker: np.ndarray,
                        counts: np.ndarray, windows: np.ndarray,
                        max_cells: int = 4_000_000) -> np.ndarray:
    """Critical-path seconds per execution for a whole fleet of workers.

    All inputs are flat worker-major event columns (``worker[i]`` is event
    ``i``'s profile index, ``counts`` its per-worker totals, ``windows`` the
    (W, 2) profiling windows).  Workers are padded to a common (E_max, S)
    and swept chunk-by-chunk (bounded by ``max_cells`` event x segment
    cells) through one ``_winner_mask`` pass per chunk.  Padded events are
    ineligible and padded/duplicate segments have zero width, so each
    worker's result is bit-identical to its own per-worker sweep.
    """
    W = len(counts)
    total = int(worker.shape[0])
    out = np.zeros(total)
    if total == 0:
        return out
    E_max = int(counts.max())
    if E_max == 0:
        return out
    S_max = 2 * E_max + 1
    t0w = windows[:, 0]
    t1w = windows[:, 1]

    # flat -> (worker, position) padded coordinates
    first = np.concatenate([[0], np.cumsum(counts)[:-1]])
    pos = np.arange(total) - first[worker]
    starts_c = np.clip(starts, t0w[worker], t1w[worker])
    ends_c = np.clip(ends, t0w[worker], t1w[worker])
    eligible = eligible & (t1w[worker] > t0w[worker])   # degenerate windows

    chunk = max(1, max_cells // (E_max * S_max))
    for c0 in range(0, W, chunk):
        c1 = min(W, c0 + chunk)
        Wc = c1 - c0
        in_c = (worker >= c0) & (worker < c1)
        wl = worker[in_c] - c0
        pl = pos[in_c]
        st = np.broadcast_to(t1w[c0:c1, None], (Wc, E_max)).copy()
        en = np.full((Wc, E_max), -np.inf)       # padded: never active
        kn = np.full((Wc, E_max), _BIG_KIND)
        dp = np.zeros((Wc, E_max), np.int16)
        el = np.zeros((Wc, E_max), bool)
        st[wl, pl] = starts_c[in_c]
        en[wl, pl] = ends_c[in_c]
        kn[wl, pl] = kinds[in_c]
        dp[wl, pl] = depth[in_c]
        el[wl, pl] = eligible[in_c]

        pts = np.empty((Wc, S_max + 1))
        pts[:, 0] = t0w[c0:c1]
        pts[:, 1] = t1w[c0:c1]
        pts[:, 2:2 + E_max] = st
        pts[:, 2 + E_max:] = np.where(np.isneginf(en),
                                      t1w[c0:c1, None], en)
        bounds = _compact_bounds(np.sort(pts, axis=1), t1w[c0:c1])
        winner = _winner_mask(st, en, kn, dp, el,
                              bounds[:, :-1], bounds[:, 1:])
        times = _event_times(winner, bounds[:, 1:] - bounds[:, :-1])
        out[in_c] = times[wl, pl]
    return out


def fleet_critical_times(profiles: Sequence,
                         max_cells: int = 4_000_000
                         ) -> List[Dict[str, float]]:
    """``critical_time_by_function`` for every worker in one batched pass."""
    # late import: the fleet module builds on this one
    from repro.summarize.fleet import extract_events
    if len(profiles) == 0:
        return []
    ev = extract_events(profiles)
    eligible = (ev.kinds != int(Kind.PYTHON)) | ev.train
    times = batched_event_times(ev.starts, ev.ends, ev.kinds, ev.depth,
                                eligible, ev.worker, ev.counts, ev.windows,
                                max_cells)
    out: List[Dict[str, float]] = []
    off = 0
    for p in profiles:
        E = len(p.events)
        out.append(_fold_by_function(p.events, times[off:off + E])
                   if E else {})
        off += E
    return out
