"""Critical-path extraction (paper §4.2, Fig. 9).

Priorities: GPU compute > memory ops > collective comm > Python. A function
execution (or a subinterval of it) is on the critical path iff no
higher-priority function is executing then. Python events must additionally
be on the training thread and be LEAF frames (no child executing).

Sweep-line over event boundaries; O((n log n)) in the number of events.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, List, Tuple

import numpy as np

from repro.core.events import FunctionEvent, Kind


def critical_intervals(events: List[FunctionEvent],
                       window: Tuple[float, float]
                       ) -> Dict[int, List[Tuple[float, float]]]:
    """Returns, per event index, the sub-intervals on the critical path."""
    t0, t1 = window
    if not events:   # empty window: np.array([]) is float64 and the bool
        return {}    # masks below would die on ~float
    # boundaries
    pts = {t0, t1}
    for e in events:
        pts.add(max(t0, min(t1, e.start)))
        pts.add(max(t0, min(t1, e.end)))
    bounds = sorted(pts)
    n_seg = len(bounds) - 1
    if n_seg <= 0:
        return {}

    starts = np.array([max(t0, e.start) for e in events])
    ends = np.array([min(t1, e.end) for e in events])
    seg_lo = np.array(bounds[:-1])
    seg_hi = np.array(bounds[1:])

    # active[i, s] for event i, segment s (events << segments typical;
    # vectorized interval containment)
    active = (starts[:, None] <= seg_lo[None, :] + 1e-12) & \
             (ends[:, None] >= seg_hi[None, :] - 1e-12)

    kinds = np.array([int(e.kind) for e in events])
    is_py = kinds == int(Kind.PYTHON)
    train_thread = np.array([e.thread == "train" for e in events])
    depth = np.array([e.depth for e in events])

    # eligible python events: training thread only
    eligible = np.ones(len(events), bool)
    eligible[is_py & ~train_thread] = False

    out: Dict[int, List[Tuple[float, float]]] = defaultdict(list)
    for s in range(n_seg):
        if seg_hi[s] - seg_lo[s] <= 0:
            continue
        act = np.where(active[:, s] & eligible)[0]
        if act.size == 0:
            continue
        best_kind = kinds[act].min()
        winners = act[kinds[act] == best_kind]
        if best_kind == int(Kind.PYTHON):
            # leaf frame: deepest call wins
            dmax = depth[winners].max()
            winners = winners[depth[winners] == dmax]
        for i in winners:
            out[int(i)].append((float(seg_lo[s]), float(seg_hi[s])))
    # merge adjacent intervals per event
    merged: Dict[int, List[Tuple[float, float]]] = {}
    for i, ivs in out.items():
        ivs.sort()
        acc = [list(ivs[0])]
        for lo, hi in ivs[1:]:
            if lo <= acc[-1][1] + 1e-12:
                acc[-1][1] = max(acc[-1][1], hi)
            else:
                acc.append([lo, hi])
        merged[i] = [(a, b) for a, b in acc]
    return merged


def critical_time_by_function(events: List[FunctionEvent],
                              window: Tuple[float, float]) -> Dict[str, float]:
    ivs = critical_intervals(events, window)
    out: Dict[str, float] = defaultdict(float)
    for i, spans in ivs.items():
        out[events[i].name] += sum(hi - lo for lo, hi in spans)
    return dict(out)
