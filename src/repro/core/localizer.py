"""Root-cause localization (paper §4.3).

Given aggregated behavior patterns {function -> (W, 3) array}, computes per
(f, w):
  D_{f,w}     — Manhattan distance to the expected box R_f (Eq. 6-7);
  Delta_{f,w} — differential distance: fraction of N (=100) sampled peers
                whose max-normalized pattern differs by >= delta (=0.4)
                Manhattan (Eq. 8-10);
and flags (f, w) abnormal iff
  beta > 0.01  AND  ( D > 0  OR  Delta > median(Delta) + k*MAD(Delta) ),
with k=5 (Eq. 11). Fully vectorized in numpy — scales to 1,000,000 workers
on one CPU core (benchmarks/localization_scaling.py reproduces Fig. 17c).
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from repro.core import channels
from repro.core.events import Kind
from repro.core.expectations import expected_box

BETA_MIN = 0.01
DELTA_THRESHOLD = 0.4
K_MAD = 5.0
N_PEERS = 100


@dataclass
class Abnormality:
    function: str
    workers: np.ndarray           # abnormal worker ids
    kind: Kind
    d_expect: np.ndarray          # D_{f,w} for those workers
    delta: np.ndarray             # Delta_{f,w}
    patterns: np.ndarray          # (n_abnormal, 3)
    typical: np.ndarray           # median pattern across fleet (3,)
    reason: str = ""              # 'expectation' | 'differential' | both
    channel: str = channels.PERF  # detector channel (a registered
    #                               repro.core.channels name) — numerics
    #                               abnormalities are synthesized from the
    #                               numerics detector stream, not from
    #                               profile patterns (DESIGN.md §12a); serve
    #                               profiles are retagged 'slo' by the
    #                               pipeline (§13)

    def __post_init__(self):
        channels.validate_channel(self.channel)


class Localizer:
    def __init__(self, family: str = "dense", n_peers: int = N_PEERS,
                 delta_threshold: float = DELTA_THRESHOLD, k_mad: float = K_MAD,
                 seed: int = 0):
        self.family = family
        self.n_peers = n_peers
        self.delta_threshold = delta_threshold
        self.k_mad = k_mad
        self.seed = seed
        self.rng = np.random.default_rng(seed)   # kept for API compat

    def _fn_rng(self, function: str) -> np.random.Generator:
        """Peer sampling is seeded per function (base seed + name hash) so
        Delta_{f,w} never depends on dict iteration order or on how many
        functions were localized before this one."""
        return np.random.default_rng(
            (self.seed, zlib.crc32(function.encode("utf-8"))))

    def delta_distance(self, pats: np.ndarray, function: str = ""
                       ) -> np.ndarray:
        """Delta_{f,w} for one function. pats: (W, 3).

        Workers drawn into their own peer sample are masked out of the
        (W, n) distance matrix: a self-pair contributes a guaranteed-zero
        distance, deflating Delta_{f,w} by up to 1/n — Eq. 9-10 count
        disagreement with *other* workers."""
        W = pats.shape[0]
        mx = pats.max(axis=0)
        mx[mx <= 0] = 1.0
        norm = pats / mx                               # Eq. 8
        n = min(self.n_peers, W)
        peers = self._fn_rng(function).choice(W, size=n, replace=False)
        # (W, n) Manhattan distances
        d = np.abs(norm[:, None, :] - norm[peers][None, :, :]).sum(axis=2)
        not_self = peers[None, :] != np.arange(W)[:, None]
        hits = ((d >= self.delta_threshold) & not_self).sum(axis=1)
        return hits / np.maximum(not_self.sum(axis=1), 1)  # Eq. 9-10

    def localize(self, patterns: Dict[str, np.ndarray],
                 kinds: Dict[str, Kind],
                 present: Optional[np.ndarray] = None) -> List[Abnormality]:
        """Localize abnormal (function, worker) pairs.

        ``present`` (bool mask over the fleet's worker rows) restricts the
        statistics to workers whose patterns actually arrived — the wire
        transport's partial-window semantics (DESIGN.md §8).  Absent
        workers contribute no peers, no median, and can never be flagged;
        with fewer peers Delta_{f,w} quantizes coarser, so localization
        confidence degrades gracefully instead of the missing rows' zeros
        poisoning the fleet median.  Reported worker ids stay GLOBAL."""
        if present is not None:
            present = np.asarray(present, bool)
            idx_global = np.flatnonzero(present)
            if idx_global.size == present.size:
                present = None        # full fleet: identical to the default
        if present is None:
            return self._localize_full(patterns, kinds)
        sub = {name: np.asarray(p)[idx_global] for name, p in
               patterns.items()}
        out = self._localize_full(sub, kinds)
        for a in out:
            a.workers = idx_global[a.workers]
        return out

    def _localize_full(self, patterns: Dict[str, np.ndarray],
                       kinds: Dict[str, Kind]) -> List[Abnormality]:
        out: List[Abnormality] = []
        for name, pats in patterns.items():
            kind = kinds.get(name, Kind.PYTHON)
            W = pats.shape[0]
            beta = pats[:, 0]
            if beta.max() <= BETA_MIN:
                continue                                # Eq. 11 gate
            box = expected_box(kind, name, self.family)
            lo = np.array([b[0] for b in box])
            hi = np.array([b[1] for b in box])
            d_exp = (np.maximum(lo - pats, 0)
                     + np.maximum(pats - hi, 0)).sum(axis=1)
            delta = self.delta_distance(pats, function=name)
            med = np.median(delta)
            mad = np.median(np.abs(delta - med))
            thr = med + self.k_mad * mad
            differential = delta > thr
            if mad == 0:
                differential = delta > max(med, 0.5)
            abnormal = (beta > BETA_MIN) & ((d_exp > 0) | differential)
            if not abnormal.any():
                continue
            idx = np.where(abnormal)[0]
            reasons = []
            if (d_exp[idx] > 0).any():
                reasons.append("expectation")
            if differential[idx].any():
                reasons.append("differential")
            out.append(Abnormality(
                function=name, workers=idx, kind=kind,
                d_expect=d_exp[idx], delta=delta[idx],
                patterns=pats[idx],
                typical=np.median(pats, axis=0),
                reason="+".join(reasons)))
        out.sort(key=lambda a: -float(a.patterns[:, 0].max()))
        return out
