"""Per-worker PerfTracker daemon (paper §4, Fig. 6): receives the raw
profiling window from its worker, summarizes runtime behavior patterns in a
separate process/core (the training thread is never blocked), and uploads
only the ~KB pattern dict.

Summarization runs through the pluggable batched backend in
``repro.summarize`` (DESIGN.md §3); pick one per call, or fleet-wide via the
``REPRO_SUMMARIZE_BACKEND`` env var.  ``PerfTrackerDaemon`` is the deployed
shape: summarize locally, ship the payload over the real wire transport
(``repro.transport``, DESIGN.md §8) through a bounded drop-oldest send
queue, never stalling on a slow collector.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import msgpack
import numpy as np

from repro.core.events import Kind, WorkerProfile


@dataclass
class PatternUpload:
    worker: int
    payload: bytes            # msgpack {name: (beta, mu, sigma, kind)}
    summarize_s: float
    raw_bytes: int

    def unpack(self) -> Tuple[Dict[str, np.ndarray], Dict[str, Kind]]:
        d = msgpack.unpackb(self.payload, strict_map_key=False)
        pats = {k: np.array(v[:3], np.float32) for k, v in d.items()}
        kinds = {k: Kind(v[3]) for k, v in d.items()}
        return pats, kinds


def summarize_and_upload(profile: WorkerProfile,
                         kind_of: Optional[Dict[str, Kind]] = None,
                         backend=None) -> PatternUpload:
    """Summarize one worker and build its upload. ``kind_of`` overrides flow
    through the single kind-resolution path in ``repro.summarize.packing``
    (stream routing AND the uploaded kind byte come from the same map)."""
    # late import: repro.core <-> repro.summarize would otherwise cycle
    from repro.summarize.engine import summarize_profile
    t0 = time.perf_counter()
    pats, kinds = summarize_profile(profile, kind_of=kind_of, backend=backend)
    payload = msgpack.packb({
        name: (p.beta, p.mu, p.sigma, int(kinds.get(name, Kind.PYTHON)))
        for name, p in pats.items()})
    return PatternUpload(worker=profile.worker, payload=payload,
                         summarize_s=time.perf_counter() - t0,
                         raw_bytes=profile.raw_size_bytes())


class PerfTrackerDaemon:
    """One worker's resident daemon: summarize each profiling window and
    ship the ~KB upload over the wire (DESIGN.md §8).

    The wire client's bounded queue is the backpressure valve: a slow or
    unreachable collector costs dropped (oldest-first) uploads, never a
    blocked training step.  ``end_window`` closes the window on the wire so
    the collector can assemble it without waiting on holes.
    """

    def __init__(self, worker: int, address, backend=None,
                 max_queue: int = 64, frame_filter=None,
                 auth_token=None, max_frame=None):
        # late import: repro.transport pulls framing/msgpack only when a
        # daemon actually goes on the wire
        from repro.transport.client import WireClient
        self.worker = int(worker)
        self.backend = backend
        self.client = WireClient(address, worker, max_queue=max_queue,
                                 frame_filter=frame_filter,
                                 auth_token=auth_token, max_frame=max_frame)

    def process_window(self, window: int, profile: WorkerProfile,
                       kind_of: Optional[Dict[str, Kind]] = None
                       ) -> PatternUpload:
        """Summarize one raw window, enqueue its upload, close the window."""
        upload = summarize_and_upload(profile, kind_of, backend=self.backend)
        self.client.send_upload(window, upload)
        self.client.end_window(window)
        return upload

    def send_anchors(self, window: int, durations,
                     numerics=None, slo=None) -> None:
        """Ship a REAL workload's measured iteration durations for the
        window (control grade — the job-level detector stream is merged
        from these, so the frame is never dropped).  ``numerics``
        optionally carries the worker's per-iteration (loss, grad_norm)
        pairs for the numerics channel (DESIGN.md §12a) and ``slo`` the
        per-iteration (p99_ttft, p99_tbt) pairs for the serving SLO
        channel (§13); omitted, the frame is byte-identical to the
        historical format."""
        from repro.transport import framing
        self.client.send_msg(framing.anchors_msg(window, self.worker,
                                                 durations,
                                                 numerics=numerics,
                                                 slo=slo),
                             droppable=False)

    def recv_control(self, timeout: Optional[float] = None):
        return self.client.recv_control(timeout=timeout)

    @property
    def dropped(self) -> int:
        return self.client.dropped

    def close(self) -> None:
        self.client.close()
