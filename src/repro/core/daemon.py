"""Per-worker PerfTracker daemon (paper §4, Fig. 6): receives the raw
profiling window from its worker, summarizes runtime behavior patterns in a
separate process/core (here: same process, separate function — the training
thread is never blocked), and uploads only the ~KB pattern dict."""
from __future__ import annotations

import struct
import time
from dataclasses import dataclass
from typing import Dict, Tuple

import msgpack
import numpy as np

from repro.core.events import Kind, WorkerProfile
from repro.core.patterns import Pattern, summarize_worker


@dataclass
class PatternUpload:
    worker: int
    payload: bytes            # msgpack {name: (beta, mu, sigma, kind)}
    summarize_s: float
    raw_bytes: int

    def unpack(self) -> Tuple[Dict[str, np.ndarray], Dict[str, Kind]]:
        d = msgpack.unpackb(self.payload, strict_map_key=False)
        pats = {k: np.array(v[:3], np.float32) for k, v in d.items()}
        kinds = {k: Kind(v[3]) for k, v in d.items()}
        return pats, kinds


def summarize_and_upload(profile: WorkerProfile,
                         kind_of: Dict[str, Kind] = None) -> PatternUpload:
    t0 = time.perf_counter()
    pats = summarize_worker(profile)
    kinds: Dict[str, Kind] = dict(kind_of or {})
    for e in profile.events:   # function kind comes from its events
        kinds.setdefault(e.name, e.kind)
    payload = msgpack.packb({
        name: (p.beta, p.mu, p.sigma, int(kinds.get(name, Kind.PYTHON)))
        for name, p in pats.items()})
    return PatternUpload(worker=profile.worker, payload=payload,
                         summarize_s=time.perf_counter() - t0,
                         raw_bytes=profile.raw_size_bytes())
