"""Per-worker PerfTracker daemon (paper §4, Fig. 6): receives the raw
profiling window from its worker, summarizes runtime behavior patterns in a
separate process/core (here: same process, separate function — the training
thread is never blocked), and uploads only the ~KB pattern dict.

Summarization runs through the pluggable batched backend in
``repro.summarize`` (DESIGN.md §3); pick one per call, or fleet-wide via the
``REPRO_SUMMARIZE_BACKEND`` env var.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, Tuple

import msgpack
import numpy as np

from repro.core.events import Kind, WorkerProfile


@dataclass
class PatternUpload:
    worker: int
    payload: bytes            # msgpack {name: (beta, mu, sigma, kind)}
    summarize_s: float
    raw_bytes: int

    def unpack(self) -> Tuple[Dict[str, np.ndarray], Dict[str, Kind]]:
        d = msgpack.unpackb(self.payload, strict_map_key=False)
        pats = {k: np.array(v[:3], np.float32) for k, v in d.items()}
        kinds = {k: Kind(v[3]) for k, v in d.items()}
        return pats, kinds


def summarize_and_upload(profile: WorkerProfile,
                         kind_of: Dict[str, Kind] = None,
                         backend=None) -> PatternUpload:
    """Summarize one worker and build its upload. ``kind_of`` overrides flow
    through the single kind-resolution path in ``repro.summarize.packing``
    (stream routing AND the uploaded kind byte come from the same map)."""
    # late import: repro.core <-> repro.summarize would otherwise cycle
    from repro.summarize.engine import summarize_profile
    t0 = time.perf_counter()
    pats, kinds = summarize_profile(profile, kind_of=kind_of, backend=backend)
    payload = msgpack.packb({
        name: (p.beta, p.mu, p.sigma, int(kinds.get(name, Kind.PYTHON)))
        for name, p in pats.items()})
    return PatternUpload(worker=profile.worker, payload=payload,
                         summarize_s=time.perf_counter() - t0,
                         raw_bytes=profile.raw_size_bytes())
