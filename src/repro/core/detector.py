"""Performance-degradation detection (paper §4.1, Fig. 8).

PerfTracker wraps exactly two anchors — ``dataloader.next()`` and
``optimizer.step()`` — and, with no access to user code:

 1. *Iteration detection*: after M (=10) identical event sequences that start
    with dataloader.next and end with optimizer.step, that sequence becomes
    the training iteration sequence.
 2. *Degradation detection*: each matched iteration records a duration;
    degradation fires when the mean of the last N (=50) durations exceeds the
    recent minimum by >5%, or when the in-flight sequence stalls for at least
    5x the average iteration duration (blockage).
 3. *Robustness*: K (=200) consecutive unmatched events re-enter iteration
    detection.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Deque, Dict, List, Optional, Tuple

from repro.core import channels

DATALOADER_NEXT = "dataloader.next"
OPTIMIZER_STEP = "optimizer.step"


@dataclass(frozen=True)
class Trigger:
    reason: str               # 'slowdown' | 'blockage' | stream reasons
    time: float
    mean_duration: float      # sample channels: the offending sample value
    baseline: float
    detail: str = ""
    channel: str = channels.PERF   # which detector stream fired; incidents
    #                                keep the channels apart

    def __post_init__(self):
        channels.validate_channel(self.channel)


@dataclass(frozen=True)
class Recovery:
    """Emitted when a degradation the detector triggered on clears: the
    slowdown re-arm fires (recent mean back under threshold) or a blockage
    stall ends (anchor events flow again).  This is the signal the online
    incident pipeline resolves incidents on (DESIGN.md §7)."""
    reason: str               # 'slowdown' | 'blockage' | stream reasons
    time: float
    channel: str = channels.PERF

    def __post_init__(self):
        channels.validate_channel(self.channel)


@dataclass
class DetectorConfig:
    m_identical: int = 10     # M
    n_recent: int = 50        # N
    slowdown_ratio: float = 1.05
    blockage_factor: float = 5.0
    k_resync: int = 200       # K
    history_iters: int = 512  # window for the 'recent shortest' baseline
    #: slowdown re-arm: after a trigger, no new slowdown trigger until the
    #: recent mean recovers below threshold, or this many further matched
    #: iterations elapse while still degraded (0 = fire once per recovery)
    rearm_cooldown: int = 50


class IterationDetector:
    """Online automaton over (event_name, timestamp) pairs."""

    def __init__(self, cfg: Optional[DetectorConfig] = None):
        # None -> fresh config: a dataclass default would be one shared
        # module-level instance aliased across every detector
        self.cfg = cfg if cfg is not None else DetectorConfig()
        cfg = self.cfg
        self.phase = "detect"                 # detect -> monitor
        self.sequence: Optional[Tuple[str, ...]] = None
        self._events: Deque[Tuple[str, float]] = deque(maxlen=4096)
        self._match_pos = 0
        self._match_start = 0.0
        self._mismatches = 0
        self._last_event_t: Optional[float] = None
        self.durations: Deque[float] = deque(
            maxlen=cfg.history_iters)
        self.triggers: List[Trigger] = []
        self.recoveries: List[Recovery] = []
        # re-arm state: a degradation fires ONE trigger, then stays silent
        # until the metric recovers (or, for slowdown, a cooldown elapses)
        self._slowdown_armed = True
        self._iters_since_trigger = 0
        self._blockage_armed = True

    # -- phase 1: iteration detection -----------------------------------
    def _candidate_iterations(self) -> List[Tuple[Tuple[str, ...], float,
                                                  float]]:
        """Split history into candidate iterations: D...O maximal chunks
        (an iteration starts at a dataloader.next that follows an
        optimizer.step)."""
        evs = list(self._events)
        iters = []
        cur: List[Tuple[str, float]] = []
        for i, (name, t) in enumerate(evs):
            if name == DATALOADER_NEXT and cur \
                    and cur[-1][0] == OPTIMIZER_STEP:
                iters.append(cur)
                cur = []
            cur.append((name, t))
        if cur and cur[-1][0] == OPTIMIZER_STEP:
            iters.append(cur)
        out = []
        for chunk in iters:
            names = tuple(n for n, _ in chunk)
            if names and names[0] == DATALOADER_NEXT \
                    and names[-1] == OPTIMIZER_STEP:
                out.append((names, chunk[0][1], chunk[-1][1]))
        return out

    def _try_lock_sequence(self):
        cands = self._candidate_iterations()
        m = self.cfg.m_identical
        if len(cands) < m:
            return
        last = cands[-m:]
        names0 = last[0][0]
        if all(c[0] == names0 for c in last):
            self.sequence = names0
            self.phase = "monitor"
            self._match_pos = 0
            self._mismatches = 0
            # seed durations from the locked candidates
            for names, t0, t1 in last:
                self.durations.append(t1 - t0)

    # -- phase 2: monitoring --------------------------------------------
    def _record_iteration(self, t0: float, t1: float) -> Optional[Trigger]:
        self.durations.append(t1 - t0)
        cfg = self.cfg
        if len(self.durations) < cfg.n_recent:
            return None
        recent = list(self.durations)[-cfg.n_recent:]
        mean = sum(recent) / len(recent)
        baseline = min(self.durations)
        if mean <= baseline * cfg.slowdown_ratio:
            # recovered: the next degradation is a new incident
            if not self._slowdown_armed:
                self.recoveries.append(Recovery("slowdown", t1))
            self._slowdown_armed = True
            self._iters_since_trigger = 0
            return None
        if not self._slowdown_armed:
            # still degraded since the last trigger: stay silent until the
            # cooldown elapses (then remind once and restart the clock)
            self._iters_since_trigger += 1
            if cfg.rearm_cooldown <= 0 \
                    or self._iters_since_trigger < cfg.rearm_cooldown:
                return None
        trig = Trigger("slowdown", t1, mean, baseline,
                       f"mean {mean:.3f}s > {cfg.slowdown_ratio:.2f}x "
                       f"min {baseline:.3f}s over last {cfg.n_recent}")
        self.triggers.append(trig)
        self._slowdown_armed = False
        self._iters_since_trigger = 0
        return trig

    # -- public API ------------------------------------------------------
    def feed(self, name: str, t: float) -> Optional[Trigger]:
        """Feed one anchor event; returns a Trigger if degradation fired."""
        self._last_event_t = t
        if not self._blockage_armed:       # events flowing again: stall over
            self.recoveries.append(Recovery("blockage", t))
        self._blockage_armed = True
        self._events.append((name, t))
        if self.phase == "detect":
            self._try_lock_sequence()
            return None

        seq = self.sequence
        assert seq is not None
        if name == seq[self._match_pos]:
            if self._match_pos == 0:
                self._match_start = t
            self._match_pos += 1
            self._mismatches = 0
            if self._match_pos == len(seq):
                self._match_pos = 0
                return self._record_iteration(self._match_start, t)
            return None
        # mismatch
        self._mismatches += 1
        if name == seq[0]:
            self._match_pos = 1
            self._match_start = t
        else:
            self._match_pos = 0
        if self._mismatches >= self.cfg.k_resync:
            self.phase = "detect"
            self.sequence = None
            self._mismatches = 0
        return None

    def check_blockage(self, now: float) -> Optional[Trigger]:
        """Type-(2) detection: mid-sequence stall >= 5x avg iteration.

        Fires once per stall: after a blockage trigger, repeated polls stay
        silent until an anchor event arrives (``feed`` re-arms)."""
        if self.phase != "monitor" or not self.durations \
                or self._last_event_t is None or not self._blockage_armed:
            return None
        avg = sum(self.durations) / len(self.durations)
        if now - self._last_event_t >= self.cfg.blockage_factor * avg:
            trig = Trigger("blockage", now,
                           now - self._last_event_t, avg,
                           f"no events for {now - self._last_event_t:.3f}s "
                           f">= {self.cfg.blockage_factor}x avg {avg:.3f}s")
            self.triggers.append(trig)
            self._blockage_armed = False
            return trig
        return None

    @property
    def locked(self) -> bool:
        return self.phase == "monitor"

    @property
    def healthy(self) -> bool:
        """True when no triggered degradation is outstanding: every fired
        trigger's re-arm condition has recovered (or nothing ever fired)."""
        return self._slowdown_armed and self._blockage_armed


# -- sample-stream channels (DESIGN.md §12a, §13) -----------------------------

class _StreamDetector:
    """Shared per-signal state machine for sample-stream detector
    channels: values judged against a rolling healthy-median baseline,
    one state machine per signal.

    Subclasses declare ``signals`` (feed order), ``reasons`` (per-signal
    trigger reason), ``channel`` (the registered detector channel stamped
    on every Trigger/Recovery) and implement ``_ratio``.

    Mirrors ``IterationDetector``'s contract — feeding returns Triggers,
    ``recoveries`` accumulates, ``healthy`` says nothing is outstanding —
    so the incident pipeline treats every channel identically.

    Robustness rules (shared by all stream channels):
      * abnormal samples (and non-finite ones) NEVER fold into the
        baseline — a spike must not poison the median it is judged by;
      * a single abnormal sample recovers silently (``confirm=2``): loss
        routinely jumps for one step on a hard batch, and p99 latency
        jumps for one chunk under a benign burst;
      * a NON-FINITE sample skips confirmation and fires immediately —
        there is no benign single-sample NaN.
    """

    signals: Tuple[str, ...] = ()
    reasons: Dict[str, str] = {}
    channel: str = channels.PERF

    def __init__(self, cfg):
        self.cfg = cfg
        self._hist = {s: deque(maxlen=self.cfg.history)
                      for s in self.signals}
        self._bad_streak = {s: 0 for s in self.signals}
        self._ok_streak = {s: 0 for s in self.signals}
        self._outstanding = {s: False for s in self.signals}
        self.triggers: List[Trigger] = []
        self.recoveries: List[Recovery] = []

    def _ratio(self, signal: str) -> float:
        raise NotImplementedError

    def _feed_signal(self, signal: str, t: float, value: float
                     ) -> Optional[Trigger]:
        cfg = self.cfg
        hist = self._hist[signal]
        reason = self.reasons[signal]
        finite = value == value and abs(value) != float("inf")
        baseline = (sorted(hist)[len(hist) // 2]) if hist else 0.0
        if not finite:
            abnormal = True
        elif len(hist) < cfg.warmup:
            hist.append(value)
            return None
        else:
            abnormal = value > baseline * self._ratio(signal)

        if not abnormal:
            hist.append(value)
            self._bad_streak[signal] = 0
            if self._outstanding[signal]:
                self._ok_streak[signal] += 1
                if self._ok_streak[signal] >= cfg.recover:
                    self._outstanding[signal] = False
                    self._ok_streak[signal] = 0
                    self.recoveries.append(
                        Recovery(reason, t, channel=self.channel))
            return None

        self._ok_streak[signal] = 0
        self._bad_streak[signal] += 1
        if self._outstanding[signal]:
            return None               # already fired; silent until recovery
        if finite and self._bad_streak[signal] < cfg.confirm:
            return None               # single spike: wait for confirmation
        self._outstanding[signal] = True
        trig = Trigger(
            reason, t, value, baseline,
            (f"{signal}={value!r} vs healthy median {baseline:.4g} "
             f"(x{self._ratio(signal):.1f} bound"
             + (", non-finite)" if not finite else ")")),
            channel=self.channel)
        self.triggers.append(trig)
        return trig

    def _feed_samples(self, t: float, *values: float) -> List[Trigger]:
        out = []
        for signal, value in zip(self.signals, values):
            trig = self._feed_signal(signal, t, float(value))
            if trig is not None:
                out.append(trig)
        return out

    def outstanding(self) -> List[str]:
        """Signals with a fired, not-yet-recovered trigger."""
        return [s for s in self.signals if self._outstanding[s]]

    @property
    def healthy(self) -> bool:
        return not any(self._outstanding.values())


# -- numerics channel (DESIGN.md §12a) ----------------------------------------

@dataclass
class NumericsConfig:
    warmup: int = 8           # healthy samples before a baseline exists
    history: int = 256        # rolling healthy-sample window per signal
    spike_ratio: float = 2.0  # loss > ratio x median(healthy) = abnormal
    grad_ratio: float = 3.0   # grad_norm ratio (norms jitter more)
    confirm: int = 2          # consecutive abnormal samples to trigger
    recover: int = 2          # consecutive healthy samples to recover


#: numerics signals in feed order; also the function-name suffixes the
#: pipeline uses when it synthesizes numerics abnormalities
NUMERICS_SIGNALS = ("loss", "grad_norm")

_NUMERICS_REASON = {"loss": "loss_spike", "grad_norm": "grad_explosion"}


class NumericsDetector(_StreamDetector):
    """FLARE-style divergence channel: job-level (loss, grad_norm) samples
    against a rolling healthy-median baseline (see ``_StreamDetector`` for
    the shared state machine); Triggers and Recoveries carry
    ``channel='numerics'``."""

    signals = NUMERICS_SIGNALS
    reasons = _NUMERICS_REASON
    channel = channels.NUMERICS

    def __init__(self, cfg: Optional[NumericsConfig] = None):
        super().__init__(cfg if cfg is not None else NumericsConfig())

    def _ratio(self, signal: str) -> float:
        return (self.cfg.spike_ratio if signal == "loss"
                else self.cfg.grad_ratio)

    def feed(self, t: float, loss: float, grad_norm: float
             ) -> List[Trigger]:
        """Feed one training step's (loss, grad_norm); returns any
        triggers that fired (one per signal at most)."""
        return self._feed_samples(t, loss, grad_norm)


# -- serving latency-SLO channel (DESIGN.md §13) ------------------------------

@dataclass
class SloConfig:
    warmup: int = 8           # healthy samples before a baseline exists
    history: int = 256        # rolling healthy-sample window per signal
    ttft_ratio: float = 2.5   # p99 TTFT > ratio x median = violation
    #                           (queueing amplifies tails; leave headroom)
    tbt_ratio: float = 1.5    # p99 time-between-tokens ratio (decode is
    #                           steady; a hot worker shows up fast)
    confirm: int = 2          # consecutive violating samples to trigger
    recover: int = 2          # consecutive healthy samples to recover


#: SLO signals in feed order: p99 time-to-first-token, p99
#: time-between-tokens — the two user-facing serving latencies
SLO_SIGNALS = ("ttft", "tbt")

_SLO_REASON = {"ttft": "ttft_violation", "tbt": "tbt_violation"}


class SloDetector(_StreamDetector):
    """Serving latency-SLO channel: per-chunk p99 (TTFT, TBT) samples
    against a rolling healthy-median baseline calibrated from the run
    itself (see ``_StreamDetector`` for the shared state machine);
    Triggers and Recoveries carry ``channel='slo'``.

    ``confirm=2`` is the burst tolerance: one bad p99 chunk from a benign
    arrival burst recovers silently, a sustained violation fires.
    """

    signals = SLO_SIGNALS
    reasons = _SLO_REASON
    channel = channels.SLO

    def __init__(self, cfg: Optional[SloConfig] = None):
        super().__init__(cfg if cfg is not None else SloConfig())

    def _ratio(self, signal: str) -> float:
        return (self.cfg.ttft_ratio if signal == "ttft"
                else self.cfg.tbt_ratio)

    def feed(self, t: float, ttft: float, tbt: float) -> List[Trigger]:
        """Feed one chunk's (p99 TTFT, p99 TBT); returns any triggers
        that fired (one per signal at most)."""
        return self._feed_samples(t, ttft, tbt)
