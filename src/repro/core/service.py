"""PerfTracker service: the end-to-end pipeline of Fig. 6.

  anchor events -> IterationDetector -> trigger -> 20s profiling window on
  every worker -> pattern summarization -> centralized localization (single
  core) -> Fig.-7 report (+ mitigation hooks).

Summarization runs in one of two modes (DESIGN.md §5, §8):

  * ``fleet`` (default) — the in-process fast path: all W workers'
    executions are packed into one ragged batch per stream rate, the
    selected backend's ``batch_stats`` runs once per group for the entire
    fleet, and patterns scatter-reduce straight into the aggregator's
    columnar ``(W, F, 3)`` buffer.  msgpack never runs.
  * ``wire`` — the distributed-daemon shape: one ``summarize_and_upload``
    per worker, each ~KB msgpack payload shipped through the REAL
    transport (``repro.transport``: length-prefixed frames over a Unix
    socket, per-worker connections, bounded send queues), reassembled by
    the ``WindowCollector``, and folded into the ``PatternAggregator``.
    Dropped uploads degrade the diagnosis (absent workers are excluded
    from localization statistics) instead of crashing it.

With no loss, both modes produce byte-identical diagnoses (a tested
invariant).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.detector import DetectorConfig, IterationDetector, Trigger
from repro.core.daemon import PatternUpload, summarize_and_upload
from repro.core.events import Kind, WorkerProfile
from repro.core.localizer import Localizer
from repro.core.mitigation import (MitigationPlan, format_plans,
                                   plan_mitigations)
from repro.core.report import (Diagnosis, build_report, format_report,
                               format_transport)
from repro.summarize.aggregate import PatternAggregator
from repro.summarize.fleet import summarize_fleet


@dataclass
class DiagnosisResult:
    trigger: Optional[Trigger]
    diagnoses: List[Diagnosis]
    fleet_size: int
    timing: Dict[str, float]
    pattern_bytes: int
    raw_bytes: int
    #: wire-transport counters for this diagnosis (None off the wire):
    #: present/missing workers, dedup and client-side drop counts
    transport: Optional[Dict[str, object]] = field(default=None)

    def report(self, mitigation: bool = False) -> str:
        """Fig.-7 report; ``mitigation=True`` appends the suggested plans
        (first rung of each diagnosis's ladder, DESIGN.md §9)."""
        out = format_report(self.diagnoses, self.fleet_size)
        if self.transport is not None:
            out += "\n" + format_transport(self.transport)
        if mitigation and self.diagnoses:
            out += "\n" + format_plans(self.suggested_plans())
        return out

    def suggested_plans(self) -> List[MitigationPlan]:
        """Flat batch mitigation view of this diagnosis
        (``plan_mitigations``: merged REPLACE_HOSTS + per-diagnosis first
        rungs)."""
        return plan_mitigations(self.diagnoses, self.fleet_size)

    def functions(self) -> List[str]:
        return [d.abnormality.function for d in self.diagnoses]


class PerfTrackerService:
    """Global side of PerfTracker. ``family`` tunes expected-range boxes."""

    def __init__(self, family: str = "dense",
                 detector_cfg: Optional[DetectorConfig] = None,
                 summarize_backend=None,
                 wire_frame_filter=None):
        self.family = family
        #: framing-layer fault hook threaded into ``mode="wire"`` loopback
        #: clients (tests inject upload loss/duplication here)
        self.wire_frame_filter = wire_frame_filter
        # None -> a fresh DetectorConfig per service; an eagerly-evaluated
        # default would be ONE module-level instance aliased across every
        # PerfTrackerService (mutating one service's thresholds would
        # silently retune all others)
        self.detector = IterationDetector(
            detector_cfg if detector_cfg is not None else DetectorConfig())
        self.localizer = Localizer(family=family)
        # name/instance/None — threaded into every per-worker summarization
        self.summarize_backend = summarize_backend

    # -- detection ---------------------------------------------------------
    def feed_anchors(self, events: Sequence[Tuple[str, float]]
                     ) -> Optional[Trigger]:
        for name, t in events:
            trig = self.detector.feed(name, t)
            if trig is not None:
                return trig
        return None

    # -- diagnosis ---------------------------------------------------------
    def aggregate(self, uploads: Sequence[PatternUpload]
                  ) -> Tuple[Dict[str, np.ndarray], Dict[str, Kind]]:
        """Fold per-worker uploads into {function -> (W, 3)} views of one
        columnar buffer (streaming — each upload's dict is transient).
        Functions missing on a worker get that worker's zeros (never on its
        critical path)."""
        agg = PatternAggregator(expected_workers=len(uploads))
        return agg.extend(uploads).finalize()

    def aggregate_batch(self, uploads: Sequence[PatternUpload],
                        fleet_size: int,
                        row_of: Optional[Dict[int, int]] = None
                        ) -> Tuple[PatternAggregator, np.ndarray]:
        """Scatter a (possibly partial) set of uploads into a full-width
        ``(fleet_size, F, 3)`` aggregator.  ``row_of`` maps worker id ->
        fleet row (identity when None).  Returns the aggregator and the
        present-row mask; absent rows stay zero."""
        agg = PatternAggregator(expected_workers=max(1, fleet_size))
        agg.reserve_workers(fleet_size)
        present = np.zeros(fleet_size, bool)
        # ascending-row order keeps function interning (and therefore
        # first-seen kinds + column order) identical to the streaming path
        def row(u):
            return row_of[u.worker] if row_of else u.worker
        for u in sorted(uploads, key=row):
            agg.add_upload_at(u, row(u))
            present[row(u)] = True
        return agg, present

    def diagnose_batch(self, batch, fleet_size: Optional[int] = None,
                       row_of: Optional[Dict[int, int]] = None,
                       trigger: Optional[Trigger] = None,
                       timing: Optional[Dict[str, float]] = None
                       ) -> DiagnosisResult:
        """Diagnose one assembled wire window (``transport.WindowBatch``).

        Missing workers' rows stay zero and are masked out of localization
        (fewer peers -> coarser Delta, degraded confidence — DESIGN.md §8)
        instead of crashing or polluting the fleet median."""
        if fleet_size is None:
            fleet_size = len(batch.expected)
        t1 = time.perf_counter()
        if hasattr(batch, "aggregate"):
            # collector-tree window (transport.TreeWindowBatch): shard
            # blocks scatter straight into the aggregator — the per-worker
            # msgpack was already unpacked at the leaves (DESIGN.md §10)
            agg, present = batch.aggregate(fleet_size)
            summarize_s = batch.summarize_s
            pattern_bytes = batch.pattern_bytes
            raw_bytes = batch.raw_bytes
        else:
            uploads = batch.sorted_uploads()
            agg, present = self.aggregate_batch(uploads, fleet_size, row_of)
            summarize_s = sum(u.summarize_s for u in uploads)
            pattern_bytes = sum(len(u.payload) for u in uploads)
            raw_bytes = sum(u.raw_bytes for u in uploads)
        pats, kinds = agg.finalize()
        abn = self.localizer.localize(pats, kinds, present=present)
        timing = dict(timing or {})
        timing["localize_s"] = time.perf_counter() - t1
        timing["upload_summarize_s"] = summarize_s
        return DiagnosisResult(
            trigger=trigger,
            diagnoses=build_report(abn, fleet_size),
            fleet_size=fleet_size,
            timing=timing,
            pattern_bytes=pattern_bytes,
            raw_bytes=raw_bytes,
            transport=batch.stats())

    def diagnose_profiles(self, profiles: Sequence[WorkerProfile],
                          kind_of: Optional[Dict[str, Kind]] = None,
                          trigger: Optional[Trigger] = None,
                          mode: str = "fleet") -> DiagnosisResult:
        """Diagnose one fleet of raw profiling windows.

        ``mode="fleet"`` (default) batches the whole fleet through one
        summarization pass in-process; ``mode="wire"`` runs the
        distributed-daemon shape over the REAL transport: per-worker
        summarize + upload through Unix-socket connections into the
        ``WindowCollector`` (DESIGN.md §8).  With no loss, diagnoses are
        byte-identical between the two.
        """
        timing = {}
        t0 = time.perf_counter()
        if mode == "fleet":
            fs = summarize_fleet(profiles, kind_of,
                                 backend=self.summarize_backend)
            timing["summarize_s"] = time.perf_counter() - t0
            t1 = time.perf_counter()
            agg, kinds = fs.agg.finalize()
            pattern_bytes = fs.pattern_bytes
        elif mode == "wire":
            from repro.transport import LoopbackWire
            uploads = [summarize_and_upload(p, kind_of,
                                            backend=self.summarize_backend)
                       for p in profiles]
            timing["summarize_s"] = time.perf_counter() - t0
            t2 = time.perf_counter()
            with LoopbackWire([p.worker for p in profiles],
                              frame_filter=self.wire_frame_filter) as wire:
                batch = wire.send_round(uploads, window=0)
            timing["transport_s"] = time.perf_counter() - t2
            row_of = {p.worker: i for i, p in enumerate(profiles)}
            res = self.diagnose_batch(batch, fleet_size=len(profiles),
                                      row_of=row_of, trigger=trigger,
                                      timing=timing)
            # raw bytes are the profiles actually materialized, delivered
            # or not — the transport only ever sees the ~KB patterns
            res.raw_bytes = sum(p.raw_size_bytes() for p in profiles)
            return res
        else:
            raise ValueError(f"unknown diagnosis mode {mode!r}; "
                             "expected 'fleet' or 'wire'")
        abn = self.localizer.localize(agg, kinds)
        timing["localize_s"] = time.perf_counter() - t1
        return DiagnosisResult(
            trigger=trigger,
            diagnoses=build_report(abn, len(profiles)),
            fleet_size=len(profiles),
            timing=timing,
            pattern_bytes=pattern_bytes,
            raw_bytes=sum(p.raw_size_bytes() for p in profiles))

    def diagnose_patterns(self, patterns: Dict[str, np.ndarray],
                          kinds: Dict[str, Kind]) -> DiagnosisResult:
        """Pattern-mode entry (scaling benchmarks / pre-aggregated fleets)."""
        W = next(iter(patterns.values())).shape[0] if patterns else 0
        t0 = time.perf_counter()
        abn = self.localizer.localize(patterns, kinds)
        dt = time.perf_counter() - t0
        return DiagnosisResult(
            trigger=None, diagnoses=build_report(abn, W), fleet_size=W,
            timing={"localize_s": dt}, pattern_bytes=0, raw_bytes=0)
