"""PerfTracker service: the end-to-end pipeline of Fig. 6.

  anchor events -> IterationDetector -> trigger -> 20s profiling window on
  every worker -> pattern summarization -> centralized localization (single
  core) -> Fig.-7 report (+ mitigation hooks).

Summarization runs in one of two modes (DESIGN.md §5):

  * ``fleet`` (default) — the in-process fast path: all W workers'
    executions are packed into one ragged batch per stream rate, the
    selected backend's ``batch_stats`` runs once per group for the entire
    fleet, and patterns scatter-reduce straight into the aggregator's
    columnar ``(W, F, 3)`` buffer.  msgpack never runs.
  * ``wire`` — the distributed-daemon shape: one ``summarize_and_upload``
    per worker, each producing the ~KB msgpack payload that would cross the
    network, folded in by the streaming ``PatternAggregator``.

Both modes produce byte-identical diagnoses (a tested invariant).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.detector import DetectorConfig, IterationDetector, Trigger
from repro.core.daemon import PatternUpload, summarize_and_upload
from repro.core.events import Kind, WorkerProfile
from repro.core.localizer import Localizer
from repro.core.report import Diagnosis, build_report, format_report
from repro.summarize.aggregate import PatternAggregator
from repro.summarize.fleet import summarize_fleet


@dataclass
class DiagnosisResult:
    trigger: Optional[Trigger]
    diagnoses: List[Diagnosis]
    fleet_size: int
    timing: Dict[str, float]
    pattern_bytes: int
    raw_bytes: int

    def report(self) -> str:
        return format_report(self.diagnoses, self.fleet_size)

    def functions(self) -> List[str]:
        return [d.abnormality.function for d in self.diagnoses]


class PerfTrackerService:
    """Global side of PerfTracker. ``family`` tunes expected-range boxes."""

    def __init__(self, family: str = "dense",
                 detector_cfg: Optional[DetectorConfig] = None,
                 summarize_backend=None):
        self.family = family
        # None -> a fresh DetectorConfig per service; an eagerly-evaluated
        # default would be ONE module-level instance aliased across every
        # PerfTrackerService (mutating one service's thresholds would
        # silently retune all others)
        self.detector = IterationDetector(
            detector_cfg if detector_cfg is not None else DetectorConfig())
        self.localizer = Localizer(family=family)
        # name/instance/None — threaded into every per-worker summarization
        self.summarize_backend = summarize_backend

    # -- detection ---------------------------------------------------------
    def feed_anchors(self, events: Sequence[Tuple[str, float]]
                     ) -> Optional[Trigger]:
        for name, t in events:
            trig = self.detector.feed(name, t)
            if trig is not None:
                return trig
        return None

    # -- diagnosis ---------------------------------------------------------
    def aggregate(self, uploads: Sequence[PatternUpload]
                  ) -> Tuple[Dict[str, np.ndarray], Dict[str, Kind]]:
        """Fold per-worker uploads into {function -> (W, 3)} views of one
        columnar buffer (streaming — each upload's dict is transient).
        Functions missing on a worker get that worker's zeros (never on its
        critical path)."""
        agg = PatternAggregator(expected_workers=len(uploads))
        return agg.extend(uploads).finalize()

    def diagnose_profiles(self, profiles: Sequence[WorkerProfile],
                          kind_of: Dict[str, Kind] = None,
                          trigger: Optional[Trigger] = None,
                          mode: str = "fleet") -> DiagnosisResult:
        """Diagnose one fleet of raw profiling windows.

        ``mode="fleet"`` (default) batches the whole fleet through one
        summarization pass in-process; ``mode="wire"`` exercises the
        per-worker daemon/upload shape used in distributed deployments.
        Diagnoses are byte-identical between the two.
        """
        timing = {}
        t0 = time.perf_counter()
        if mode == "fleet":
            fs = summarize_fleet(profiles, kind_of,
                                 backend=self.summarize_backend)
            timing["summarize_s"] = time.perf_counter() - t0
            t1 = time.perf_counter()
            agg, kinds = fs.agg.finalize()
            pattern_bytes = fs.pattern_bytes
        elif mode == "wire":
            uploads = [summarize_and_upload(p, kind_of,
                                            backend=self.summarize_backend)
                       for p in profiles]
            timing["summarize_s"] = time.perf_counter() - t0
            t1 = time.perf_counter()
            agg, kinds = self.aggregate(uploads)
            pattern_bytes = sum(len(u.payload) for u in uploads)
        else:
            raise ValueError(f"unknown diagnosis mode {mode!r}; "
                             "expected 'fleet' or 'wire'")
        abn = self.localizer.localize(agg, kinds)
        timing["localize_s"] = time.perf_counter() - t1
        return DiagnosisResult(
            trigger=trigger,
            diagnoses=build_report(abn, len(profiles)),
            fleet_size=len(profiles),
            timing=timing,
            pattern_bytes=pattern_bytes,
            raw_bytes=sum(p.raw_size_bytes() for p in profiles))

    def diagnose_patterns(self, patterns: Dict[str, np.ndarray],
                          kinds: Dict[str, Kind]) -> DiagnosisResult:
        """Pattern-mode entry (scaling benchmarks / pre-aggregated fleets)."""
        W = next(iter(patterns.values())).shape[0] if patterns else 0
        t0 = time.perf_counter()
        abn = self.localizer.localize(patterns, kinds)
        dt = time.perf_counter() - t0
        return DiagnosisResult(
            trigger=None, diagnoses=build_report(abn, W), fleet_size=W,
            timing={"localize_s": dt}, pattern_bytes=0, raw_bytes=0)
