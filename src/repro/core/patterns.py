"""Runtime behavior patterns P_{f,w} = (beta, mu, sigma) — paper §4.2.

beta: fraction of the profiling window the function spends on the critical
      path (Eq. 2-3).
mu:   duration-weighted mean resource utilization over the *critical
      execution duration* L(e) of each execution (Eq. 4), where L(e) is found
      by Algorithm 1 — the subinterval holding >=80% of the utilization mass
      with the smallest allowed run of consecutive zero samples (binary
      search over the gap bound g).
sigma: same weighting for the utilization std-dev (Eq. 5).

``critical_duration`` here is the scalar oracle for Algorithm 1; the batched
execution lives in ``repro.summarize`` behind a pluggable backend protocol
(python oracle loop / vectorized numpy / TPU Pallas kernel — DESIGN.md §3).
``summarize_worker`` delegates there and keeps its historical signature.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.events import Kind, WorkerProfile

MASS_FRACTION = 0.8


def critical_duration(u: np.ndarray, mass: float = MASS_FRACTION
                      ) -> Tuple[int, int]:
    """Algorithm 1: smallest max-zero-gap subinterval with >= mass of the
    total utilization. Returns [l, r) sample indices (r exclusive).

    For a gap bound g, the feasible subintervals that avoid any zero-run
    longer than g are exactly the maximal regions obtained by splitting at
    zero-runs of length > g; feasibility <=> some region holds >= mass*S.
    Binary search over g in [0, n]."""
    n = len(u)
    if n == 0:
        return (0, 0)
    # f64 accumulation: exact for f32 inputs, so the mass target (and hence
    # the selected region) is independent of trailing zero-padding width
    total = float(u.sum(dtype=np.float64))
    if total <= 0.0:
        return (0, n)
    target = mass * total

    zero = u <= 0.0
    # zero-run ids and lengths
    csum = np.concatenate([[0.0], np.cumsum(u)])

    def best_region(g: int) -> Optional[Tuple[int, int]]:
        # split points: zero-runs strictly longer than g
        regions = []
        start = 0
        run = 0
        for i in range(n):
            if zero[i]:
                run += 1
            else:
                if run > g and i - run >= start:
                    regions.append((start, i - run))
                    start = i
                run = 0
        regions.append((start, n))
        best = None
        best_mass = -1.0
        for lo, hi in regions:
            # trim leading/trailing zeros
            while lo < hi and zero[lo]:
                lo += 1
            while hi > lo and zero[hi - 1]:
                hi -= 1
            if hi <= lo:
                continue
            s = csum[hi] - csum[lo]
            # among feasible regions keep the max-mass one (leftmost tie) —
            # matches the vectorized TPU kernel's selection rule
            if s >= target - 1e-9 and s > best_mass + 1e-12:
                best = (lo, hi)
                best_mass = s
        return best

    lo_g, hi_g = 0, n
    result = (0, n)
    while lo_g <= hi_g:
        g = (lo_g + hi_g) // 2
        reg = best_region(g)
        if reg is not None:
            result = reg
            hi_g = g - 1
        else:
            lo_g = g + 1
    return result


@dataclass
class Pattern:
    beta: float
    mu: float
    sigma: float

    def as_array(self) -> np.ndarray:
        return np.array([self.beta, self.mu, self.sigma], np.float32)


def summarize_worker(profile: WorkerProfile,
                     kinds: Optional[Dict[str, Kind]] = None,
                     backend=None) -> Dict[str, Pattern]:
    """Per-function behavior patterns for one worker (paper §4.2).

    ``kinds`` overrides the per-event function kinds (stream routing +
    uploaded kind map); ``backend`` picks the batched Algorithm-1 executor
    (name, instance, or None for env/auto — see repro.summarize).
    """
    from repro.summarize.engine import summarize_profile
    pats, _ = summarize_profile(profile, kind_of=kinds, backend=backend)
    return pats


def pattern_size_bytes(patterns: Dict[str, Pattern]) -> int:
    """Serialized size: full function identity (call stack) + 3 floats."""
    return sum(len(name.encode()) + 12 for name in patterns)
