"""First-class detector-channel registry (DESIGN.md §13).

PR 8 threaded a second detector channel (``numerics``) through the
incident pipeline as bare strings with scattered
``getattr(x, "channel", "perf")`` defaults — silently coercing typos and
unknown channels to ``perf``.  This module makes channels explicit: the
three known channels are constants, every carrier (``Trigger``,
``Recovery``, ``Abnormality``, ``Incident``, ``ExpectedIncident``)
validates its channel at construction, and consumers resolve an object's
channel through :func:`channel_of`, which RAISES on anything unknown
instead of guessing.

Channels:
  * ``perf``     — anchor-duration degradation (slowdown / blockage);
  * ``numerics`` — loss-spike / grad-explosion / NaN divergence;
  * ``slo``      — serving latency-SLO violations (p99 TTFT / TBT).
"""
from __future__ import annotations

PERF = "perf"
NUMERICS = "numerics"
SLO = "slo"

#: every channel the incident pipeline knows how to route
CHANNELS = (PERF, NUMERICS, SLO)


class UnknownChannelError(ValueError):
    """Raised when a trigger/abnormality/incident names a channel the
    registry does not know — a typo'd channel must fail loudly, not
    silently coerce to ``perf``."""


def validate_channel(name: str) -> str:
    """Return ``name`` if it is a registered channel; raise otherwise."""
    if name not in CHANNELS:
        raise UnknownChannelError(
            f"unknown detector channel {name!r}; registered channels: "
            f"{', '.join(CHANNELS)}")
    return name


def channel_of(obj) -> str:
    """The validated channel of a Trigger/Recovery/Abnormality/Incident.

    Carriers declare ``channel`` as a first-class attribute (no getattr
    default): an object without one is a bug, and an object with an
    unregistered one raises :class:`UnknownChannelError`.
    """
    return validate_channel(obj.channel)
