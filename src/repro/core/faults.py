"""Fault models injected into the fleet simulator — one per production case
the paper diagnoses (§3, §6.1, §6.2)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence


@dataclass(frozen=True)
class Fault:
    pass


@dataclass(frozen=True)
class GpuThrottle(Fault):
    """§6.1 P1: intermittent GPU clock throttling on some hosts — GEMMs take
    longer (larger beta) at lower SM/frequency utilization (smaller mu)."""
    workers: Sequence[int]
    slowdown: float = 2.0
    util: float = 0.33


@dataclass(frozen=True)
class NvlinkDown(Fault):
    """§6.1 P2: NVLink NS error — traffic falls back to PCIe. The affected
    workers' collectives show high PCIe mu; every worker in their DP groups
    shows larger beta."""
    workers: Sequence[int]
    group_size: int = 16
    slowdown: float = 3.0


@dataclass(frozen=True)
class RingSlowLink(Fault):
    """§3: one NIC bond degraded to ``rho`` of nominal."""
    slow_worker: int
    rho: float = 0.5
    ring_workers: Optional[Sequence[int]] = None  # None = all


@dataclass(frozen=True)
class SlowDataloader(Fault):
    """§6.2 P1: slow storage — socket recv_into dominates on ALL workers."""
    slowdown: float = 20.0


@dataclass(frozen=True)
class CpuBoundForward(Fault):
    """§6.2 P2: inefficient Python forward() — CPU-bound on some workers."""
    workers: Sequence[int] = ()
    slowdown: float = 6.0


@dataclass(frozen=True)
class AsyncGc(Fault):
    """§6.2 P3: unsynchronized Python GC — random workers pause on random
    iterations in non-CPU-intensive Python frames; peers wait."""
    probability: float = 0.15
    pause_s: float = 0.25
