"""Fault models injected into the fleet simulator — one per production case
the paper diagnoses (§3, §6.1, §6.2).

``affected_workers`` / ``remap_workers`` are the hooks the mitigation
engine (DESIGN.md §9) uses to reason about host replacement: which workers
a fault is pinned to, and where a rank-pinned fault lands after an elastic
re-mesh moves its ranks onto standby hosts.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, Optional, Sequence


@dataclass(frozen=True)
class Fault:
    pass


@dataclass(frozen=True)
class GpuThrottle(Fault):
    """§6.1 P1: intermittent GPU clock throttling on some hosts — GEMMs take
    longer (larger beta) at lower SM/frequency utilization (smaller mu)."""
    workers: Sequence[int]
    slowdown: float = 2.0
    util: float = 0.33


@dataclass(frozen=True)
class NvlinkDown(Fault):
    """§6.1 P2: NVLink NS error — traffic falls back to PCIe. The affected
    workers' collectives show high PCIe mu; every worker in their DP groups
    shows larger beta."""
    workers: Sequence[int]
    group_size: int = 16
    slowdown: float = 3.0


@dataclass(frozen=True)
class RingSlowLink(Fault):
    """§3: one NIC bond degraded to ``rho`` of nominal."""
    slow_worker: int
    rho: float = 0.5
    ring_workers: Optional[Sequence[int]] = None  # None = all


@dataclass(frozen=True)
class SlowDataloader(Fault):
    """§6.2 P1: slow storage — socket recv_into dominates on ALL workers."""
    slowdown: float = 20.0


@dataclass(frozen=True)
class CpuBoundForward(Fault):
    """§6.2 P2: inefficient Python forward() — CPU-bound on some workers."""
    workers: Sequence[int] = ()
    slowdown: float = 6.0


@dataclass(frozen=True)
class AsyncGc(Fault):
    """§6.2 P3: unsynchronized Python GC — random workers pause on random
    iterations in non-CPU-intensive Python frames; peers wait."""
    probability: float = 0.15
    pause_s: float = 0.25


def affected_workers(f: Fault) -> Optional[frozenset]:
    """The worker set a fault is pinned to, or None for fleet-wide faults
    (slow storage, unsynchronized GC, fleet-wide CPU-bound forward): those
    cannot be cured or dodged by replacing hosts."""
    if isinstance(f, (GpuThrottle, NvlinkDown)):
        return frozenset(int(w) for w in f.workers)
    if isinstance(f, CpuBoundForward):
        if not f.workers:
            return None
        return frozenset(int(w) for w in f.workers)
    if isinstance(f, RingSlowLink):
        return frozenset({int(f.slow_worker)})
    return None


def remap_workers(f: Fault, mapping: Dict[int, Optional[int]]
                  ) -> Optional[Fault]:
    """Re-pin a worker-pinned fault through a replace-hosts mapping
    (dropped worker -> standby id, or None when no standby was left).

    Returns the same object when nothing changes, a new Fault on the
    remapped workers, or None when every pinned worker dropped out of the
    fleet without replacement (the fault has nowhere left to manifest).
    Fleet-wide faults and ``RingSlowLink`` (the degraded NIC bond stays
    where it is) are returned unchanged.
    """
    if isinstance(f, (GpuThrottle, NvlinkDown, CpuBoundForward)):
        if not f.workers:
            return f
        new = []
        changed = False
        for w in f.workers:
            w = int(w)
            if w in mapping:
                changed = True
                if mapping[w] is not None:
                    new.append(int(mapping[w]))
            else:
                new.append(w)
        if not changed:
            return f
        if not new:
            return None
        return replace(f, workers=tuple(new))
    return f
