"""Fault models injected into the fleet simulator — the paper's production
cases (§3, §6.1, §6.2) plus the beyond-performance classes the ROADMAP's
scenario-diversity item names (DESIGN.md §12): cross-layer HOST faults
(cgroup CPU throttling, page-cache thrash), ENVIRONMENT faults that live
on specific hosts (driver/kernel mismatch, degraded NIC — including cold
standbys, so a ``replace_hosts`` re-mesh can land on a bad spare), and
NUMERICS faults (loss spikes, gradient-norm explosions) that never slow an
iteration and are only visible to the numerics detector channel.

``affected_workers`` / ``remap_workers`` are the hooks the mitigation
engine (DESIGN.md §9) uses to reason about host replacement: which workers
a fault is pinned to, and where a rank-pinned fault lands after an elastic
re-mesh moves its ranks onto standby hosts.  ``default_cures()`` is the
per-fault-model playbook ground truth for ``ScheduledFault.cures`` — part
of the fault DATA, not of the diagnosis path, which never mentions a fault
class by name.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, Optional, Sequence, Tuple


@dataclass(frozen=True)
class Fault:
    pass


@dataclass(frozen=True)
class GpuThrottle(Fault):
    """§6.1 P1: intermittent GPU clock throttling on some hosts — GEMMs take
    longer (larger beta) at lower SM/frequency utilization (smaller mu)."""
    workers: Sequence[int]
    slowdown: float = 2.0
    util: float = 0.33


@dataclass(frozen=True)
class NvlinkDown(Fault):
    """§6.1 P2: NVLink NS error — traffic falls back to PCIe. The affected
    workers' collectives show high PCIe mu; every worker in their DP groups
    shows larger beta."""
    workers: Sequence[int]
    group_size: int = 16
    slowdown: float = 3.0


@dataclass(frozen=True)
class RingSlowLink(Fault):
    """§3: one NIC bond degraded to ``rho`` of nominal."""
    slow_worker: int
    rho: float = 0.5
    ring_workers: Optional[Sequence[int]] = None  # None = all


@dataclass(frozen=True)
class SlowDataloader(Fault):
    """§6.2 P1: slow storage — socket recv_into dominates on ALL workers."""
    slowdown: float = 20.0


@dataclass(frozen=True)
class CpuBoundForward(Fault):
    """§6.2 P2: inefficient Python forward() — CPU-bound on some workers."""
    workers: Sequence[int] = ()
    slowdown: float = 6.0


@dataclass(frozen=True)
class AsyncGc(Fault):
    """§6.2 P3: unsynchronized Python GC — random workers pause on random
    iterations in non-CPU-intensive Python frames; peers wait."""
    probability: float = 0.15
    pause_s: float = 0.25


# -- cross-layer host faults (DESIGN.md §12b) ---------------------------------

@dataclass(frozen=True)
class CgroupCpuThrottle(Fault):
    """OS-level CPU quota throttling on some hosts: the Python forward
    wrapper stretches while the cpu stream sits CLAMPED FLAT at the cgroup
    quota (tiny sigma — the scheduler enforces the ceiling exactly)."""
    workers: Sequence[int]
    quota: float = 0.35          # cpu utilization ceiling the cgroup allows
    slowdown: float = 8.0


@dataclass(frozen=True)
class PageCacheThrash(Fault):
    """Page-cache thrash / IO contention: dataloader reads that should hit
    cache go to disk — long, BURSTY, non-CPU-intensive dataloader frames
    (low mu, large sigma).  ``workers=()`` = fleet-wide (a shared
    filesystem melting down, cured by migrating the data, not by replacing
    hosts)."""
    workers: Sequence[int] = ()
    slowdown: float = 14.0


# -- environment faults (DESIGN.md §12c) --------------------------------------

@dataclass(frozen=True)
class DriverMismatch(Fault):
    """Driver/kernel version mismatch on specific hosts (the llm-self-
    hosting post-mortem): GEMMs run at MODERATE SM utilization — not the
    near-zero of a throttled clock, just a mis-tuned stack — and take
    longer.  Pinned to hosts; pin it to a cold standby to model a
    ``replace_hosts`` rung landing on a bad spare."""
    workers: Sequence[int]
    slowdown: float = 2.0
    util: float = 0.55


@dataclass(frozen=True)
class DegradedNic(Fault):
    """A degraded NIC on specific hosts: the host's collectives collapse to
    ``rho`` of nominal at low, STABLE link utilization while the rest of
    the fleet stays healthy (unlike ``RingSlowLink``, which drags the whole
    ring down with it)."""
    workers: Sequence[int]
    rho: float = 0.25
    group_size: int = 8          # DP-group peers wait on the slow host


# -- serving faults (DESIGN.md §13) -------------------------------------------

@dataclass(frozen=True)
class ArrivalBurst(Fault):
    """Sustained request-arrival burst beyond the fleet's serving capacity:
    every worker's admission queue backs up, TTFT explodes while decode
    stays healthy.  Fleet-wide — no host replacement helps; the cure is
    shedding load (reject/route the excess)."""
    queue_mult: float = 20.0     # dequeue-wait stretch factor


@dataclass(frozen=True)
class KvCacheThrash(Fault):
    """KV-cache working set exceeds device memory: block reads that should
    hit cache go to fetch path, stretching every decode step (TBT) across
    the fleet.  Fleet-wide — cured by shedding load until the working set
    fits again."""
    slowdown: float = 20.0       # kv block-read stretch factor


# -- numerics faults (DESIGN.md §12a) -----------------------------------------

@dataclass(frozen=True)
class LossSpike(Fault):
    """Training-loss spike: the loss jumps to ``magnitude``x its healthy
    level.  Job-level — iterations run at full speed, profiles stay
    healthy; only the numerics detector channel sees it."""
    magnitude: float = 8.0


@dataclass(frozen=True)
class GradExplosion(Fault):
    """Gradient-norm explosion (``nan=True`` = the norm goes non-finite).
    Job-level, perf-invisible, numerics-channel only."""
    magnitude: float = 50.0
    nan: bool = False


def affected_workers(f: Fault) -> Optional[frozenset]:
    """The worker set a fault is pinned to, or None for fleet-wide faults
    (slow storage, unsynchronized GC, fleet-wide CPU-bound forward,
    numerics anomalies): those cannot be cured or dodged by replacing
    hosts."""
    if isinstance(f, (GpuThrottle, NvlinkDown, CgroupCpuThrottle,
                      DriverMismatch, DegradedNic)):
        return frozenset(int(w) for w in f.workers)
    if isinstance(f, (CpuBoundForward, PageCacheThrash)):
        if not f.workers:
            return None
        return frozenset(int(w) for w in f.workers)
    if isinstance(f, RingSlowLink):
        return frozenset({int(f.slow_worker)})
    return None


def remap_workers(f: Fault, mapping: Dict[int, Optional[int]]
                  ) -> Optional[Fault]:
    """Re-pin a worker-pinned fault through a replace-hosts mapping
    (dropped worker -> standby id, or None when no standby was left).

    Returns the same object when nothing changes, a new Fault on the
    remapped workers, or None when every pinned worker dropped out of the
    fleet without replacement (the fault has nowhere left to manifest).
    Fleet-wide faults and ``RingSlowLink`` (the degraded NIC bond stays
    where it is) are returned unchanged.
    """
    if isinstance(f, (GpuThrottle, NvlinkDown, CpuBoundForward,
                      CgroupCpuThrottle, PageCacheThrash, DriverMismatch,
                      DegradedNic)):
        if not f.workers:
            return f
        new = []
        changed = False
        for w in f.workers:
            w = int(w)
            if w in mapping:
                changed = True
                if mapping[w] is not None:
                    new.append(int(mapping[w]))
            else:
                new.append(w)
        if not changed:
            return f
        if not new:
            return None
        return replace(f, workers=tuple(new))
    return f


def default_cures() -> Dict[type, Tuple]:
    """Which ``Action`` actually cures each fault model, per the paper's §6
    case studies plus the DESIGN.md §12 classes — the scenario-level default
    for ``ScheduledFault.cures``.  Ground truth about the WORLD (fault data),
    never consulted by the diagnosis path.

    A function (not a module constant) so importing this module never pulls
    in the mitigation layer; the mapping is memoized on first call.
    """
    global _DEFAULT_CURES
    if _DEFAULT_CURES is None:
        from repro.core.mitigation import Action
        _DEFAULT_CURES = {
            GpuThrottle: (Action.REPLACE_HOSTS,),
            NvlinkDown: (Action.REPLACE_HOSTS,),
            RingSlowLink: (Action.REPLACE_HOSTS,),
            SlowDataloader: (Action.MIGRATE_DATALOADER,),
            CpuBoundForward: (Action.FLAG_CODE,),
            AsyncGc: (Action.SYNCHRONIZE_GC,),
            # host faults: pinned ones leave with their hosts; fleet-wide
            # page-cache thrash needs the data moved, not hosts replaced
            CgroupCpuThrottle: (Action.REPLACE_HOSTS,),
            PageCacheThrash: (Action.REPLACE_HOSTS,
                              Action.MIGRATE_DATALOADER),
            # environment faults live on specific hosts
            DriverMismatch: (Action.REPLACE_HOSTS,),
            DegradedNic: (Action.REPLACE_HOSTS,),
            # numerics faults: only restoring a good checkpoint helps
            LossSpike: (Action.ROLLBACK_TO_CHECKPOINT,),
            GradExplosion: (Action.ROLLBACK_TO_CHECKPOINT,),
            # serving faults: fleet-wide overload sheds load; host-pinned
            # serve faults are declared per scenario (DRAIN_AND_REPLACE)
            ArrivalBurst: (Action.SHED_LOAD,),
            KvCacheThrash: (Action.SHED_LOAD,),
        }
    return _DEFAULT_CURES


_DEFAULT_CURES: Optional[Dict[type, Tuple]] = None
