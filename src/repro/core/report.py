"""Diagnosis report (paper Fig. 7): which functions on which workers behave
abnormally, how they differ from expectation/peers, plus root-cause hints
(the diagnosis rules the paper walks through in §3/§6)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

import numpy as np

from repro.core.events import Kind
from repro.core.localizer import Abnormality


@dataclass
class Diagnosis:
    abnormality: Abnormality
    hint: str


def _fmt_workers(ws: np.ndarray, limit: int = 8) -> str:
    lst = ws.tolist()
    if len(lst) <= limit:
        return "{" + ",".join(map(str, lst)) + "}"
    return ("{" + ",".join(map(str, lst[:limit]))
            + f",...}} ({len(lst)} workers)")


def root_cause_hint(a: Abnormality, fleet_size: int) -> str:
    """Paper's diagnosis playbook, encoded."""
    frac = len(a.workers) / max(1, fleet_size)
    beta = float(np.median(a.patterns[:, 0]))
    mu = float(np.median(a.patterns[:, 1]))
    sigma = float(np.median(a.patterns[:, 2]))
    t_beta, t_mu, t_sigma = (float(x) for x in a.typical)

    if a.kind == Kind.NUMERICS:
        if "grad" in a.function:
            return ("gradient-norm explosion on the numerics channel -> "
                    "model state is suspect; roll back to the last good "
                    "checkpoint and skip the offending batch")
        return ("training-loss spike on the numerics channel -> model "
                "state is suspect; roll back to the last good checkpoint "
                "and skip the offending batch")
    if a.kind == Kind.GPU:
        if beta > t_beta and mu < t_mu * 0.45:
            return ("slow GPU computation at low SM/frequency utilization "
                    "-> suspect GPU throttling / degraded GPUs (case C1P1)")
        if beta > t_beta and mu < t_mu * 0.75:
            return ("slow GPU computation at MODERATE SM utilization -> "
                    "suspect driver/kernel version mismatch on these hosts "
                    "(mis-tuned stack, not a throttled clock)")
        return "GPU kernels slower than peers"
    if a.kind == Kind.COMM:
        mu_max = float(np.max(a.patterns[:, 1]))
        if mu > t_mu * 1.5 or (mu_max > t_mu * 1.5 and mu_max > 0.7):
            return ("collective traffic at unusually HIGH PCIe utilization "
                    "-> NVLink down, traffic falling back to PCIe (C1P2)")
        if mu < t_mu * 0.5 and frac < 0.2 and sigma < t_sigma:
            return ("collectives collapsed to low, stable link utilization "
                    "on these hosts while the fleet is healthy -> degraded "
                    "NIC; replace the hosts")
        if sigma < t_sigma * 0.5 and frac < 0.2:
            return ("stable throughput while peers fluctuate -> this worker "
                    "drives the degraded link (ring slow-link, §3 Fig. 5c)")
        if mu < t_mu and sigma <= t_sigma * 1.2 and frac < 0.2:
            return ("low, stable link throughput -> this worker drives the "
                    "degraded link (ring slow-link, §3 Fig. 5c)")
        if mu < t_mu and sigma > t_sigma:
            return ("low, fluctuating throughput -> ring limited by a slow "
                    "link elsewhere in the ring (§3 Fig. 5b)")
        return "collective communication slower than peers"
    if a.kind == Kind.PYTHON:
        if "queue" in a.function or "dequeue" in a.function:
            if frac > 0.5:
                return ("request dequeue wait dominates fleet-wide -> "
                        "arrival rate exceeds serving capacity (queue "
                        "buildup); shed load until the backlog drains")
            return ("long dequeue waits on a subset of serving hosts -> "
                    "local scheduler backlog; drain and investigate")
        if "socket" in a.function or "dataloader" in a.function:
            if mu < 0.3 and sigma > t_sigma * 1.5 and 0.0 < frac < 0.5:
                return ("long, bursty, non-CPU-intensive dataloader frames "
                        "on a few hosts -> page-cache thrash / local IO "
                        "contention; replace the hosts")
            if frac > 0.5:
                return ("dataloader socket recv dominates on most workers "
                        "-> slow storage / data loading (C2P1)")
            return "slow data loading on a subset of workers"
        if "forward" in a.function and mu > 0.7:
            return ("CPU-bound Python forward -> inefficient host-side "
                    "implementation (C2P2)")
        if mu < 0.3 and 0.0 < frac < 0.95:
            return ("long non-CPU-intensive Python frames scattered over "
                    "random workers -> asynchronous garbage collection; "
                    "synchronize gc across workers (C2P3)")
        if sigma < max(0.01, t_sigma * 0.5) and 0.25 <= mu <= 0.6 \
                and 0.0 < frac < 0.5:
            return ("Python frames stretched with CPU utilization CLAMPED "
                    "FLAT at a ceiling on these hosts -> cgroup CPU quota "
                    "throttling; replace or re-image the hosts")
        return "Python function exceeds the 1% critical-path budget"
    if a.kind == Kind.MEM:
        if "kv" in a.function:
            return ("KV block reads dominate the decode step -> KV-cache "
                    "working set exceeds device memory (cache thrash); "
                    "shed load until the working set fits")
        return "memory operations dominate -> host/device copy bottleneck"
    return "abnormal behavior"


def build_report(abnormalities: List[Abnormality], fleet_size: int
                 ) -> List[Diagnosis]:
    return [Diagnosis(a, root_cause_hint(a, fleet_size))
            for a in abnormalities]


def format_report(diagnoses: List[Diagnosis], fleet_size: int) -> str:
    if not diagnoses:
        return "PerfTracker: no abnormal function executions found."
    lines = [
        "PerfTracker diagnosis "
        f"({len(diagnoses)} abnormal function(s), fleet={fleet_size}):",
        f"{'function':40s} {'workers':28s} {'beta':>6s} {'mu':>6s} "
        f"{'sigma':>6s} {'typ.beta':>8s} {'typ.mu':>7s}",
    ]
    for d in diagnoses:
        a = d.abnormality
        med = np.median(a.patterns, axis=0)
        lines.append(
            f"{a.function[:40]:40s} {_fmt_workers(a.workers):28s} "
            f"{med[0]:6.3f} {med[1]:6.3f} {med[2]:6.3f} "
            f"{a.typical[0]:8.3f} {a.typical[1]:7.3f}")
        lines.append(f"    [{a.reason}] -> {d.hint}")
    return "\n".join(lines)


def format_transport(tr) -> str:
    """One-line wire-transport summary for reports (DESIGN.md §8): the
    counters a ``transport.WindowBatch.stats()`` dict carries."""
    out = (f"transport: {tr['present']}/{tr['expected']} workers "
           f"reported; dropped={tr['client_dropped']} "
           f"duplicates={tr['duplicates']}")
    if tr.get("reconnects"):
        out += f" reconnects={tr['reconnects']}"
    if tr["missing"]:
        out += f" missing={list(tr['missing'])}"
    if "shards" in tr:
        out += (f"\ntransport: collector tree "
                f"{tr['shards']}/{tr['expected_shards']} shards reported")
        if tr.get("missing_shards"):
            out += f" missing_shards={list(tr['missing_shards'])}"
        if tr.get("duplicate_shards"):
            out += f" duplicate_shards={tr['duplicate_shards']}"
    return out
