"""Mitigation hooks: PerfTracker's localization output drives the
fault-tolerance machinery (DESIGN.md §4, §9) — the paper's observability
becomes the cluster's straggler/failure sensor.

Actions map 1:1 to what the paper's operators did (§6): replace flagged
hosts (checkpoint-now + elastic re-mesh without them), move data loading,
synchronize GC, flag code for optimization.

Two entry points:

  * ``plan_ladder(diagnosis)``     — a RANKED ladder of plans for one
    diagnosis: rung 0 is the playbook's best first move, later rungs are
    what an operator tries when verification shows the signature survived
    the previous rung (e.g. flag-code first, replace the hosts when the
    "software" problem follows the hardware).  The online mitigation
    engine (``repro.online.mitigation``) executes ladders rung by rung and
    escalates on failed verification.
  * ``plan_mitigations(diagnoses)`` — the flat batch view: the first rung
    of every diagnosis's ladder, with REPLACE_HOSTS plans merged into one
    fleet operation (one checkpoint + one re-mesh, not one per diagnosis).

Ladders live in a declarative registry keyed by ``(channel, Kind)``
(DESIGN.md §13): workload playbooks (e.g. ``repro.serve.playbook``)
register channel-specific rules without editing this dispatch; a channel
with no specific rule falls back to the channel-agnostic ``(None, Kind)``
rule, and an unregistered Kind falls back to checkpoint-and-hand-off.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from enum import Enum
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core import channels
from repro.core.events import Kind
from repro.core.report import Diagnosis


class Action(Enum):
    REPLACE_HOSTS = "replace_hosts"          # checkpoint-now + re-mesh
    CHECKPOINT_NOW = "checkpoint_now"
    ROLLBACK_TO_CHECKPOINT = "rollback_to_checkpoint"   # numerics: restore
    MIGRATE_DATALOADER = "migrate_dataloader"
    SYNCHRONIZE_GC = "synchronize_gc"
    FLAG_CODE = "flag_code_for_optimization"
    SHED_LOAD = "shed_load"                  # serving: reject/route excess
    DRAIN_AND_REPLACE = "drain_and_replace"  # serving: drain in-flight
    #                                          requests, then re-mesh
    NONE = "none"


@dataclass
class MitigationPlan:
    action: Action
    workers: List[int] = field(default_factory=list)
    detail: str = ""


LadderRule = Callable[[Diagnosis, int], List[MitigationPlan]]

#: (channel | None, Kind) -> rule; None = channel-agnostic fallback
_LADDERS: Dict[Tuple[Optional[str], Kind], LadderRule] = {}


def register_ladder(channel: Optional[str], *kinds: Kind
                    ) -> Callable[[LadderRule], LadderRule]:
    """Register a ladder rule for ``(channel, kind)`` pairs.

    ``channel=None`` registers the channel-agnostic fallback used when no
    channel-specific rule exists; a non-None channel must be registered
    in :mod:`repro.core.channels`.
    """
    if channel is not None:
        channels.validate_channel(channel)

    def deco(fn: LadderRule) -> LadderRule:
        for kind in kinds:
            _LADDERS[(channel, kind)] = fn
        return fn
    return deco


def plan_ladder(d: Diagnosis, fleet_size: int) -> List[MitigationPlan]:
    """Ranked mitigation ladder for ONE diagnosis.

    Rung 0 is the paper-§6 playbook's first move for the diagnosed
    pattern; each later rung is the escalation an operator reaches for
    when the signature survives verification of the rung before it.

    Dispatch: the ``(channel, kind)`` rule if one is registered, else the
    channel-agnostic ``(None, kind)`` rule, else checkpoint-and-hand-off.
    """
    a = d.abnormality
    rule = _LADDERS.get((channels.channel_of(a), a.kind),
                        _LADDERS.get((None, a.kind)))
    if rule is not None:
        return rule(d, fleet_size)
    return [MitigationPlan(
        Action.CHECKPOINT_NOW, [],
        f"unclassified abnormality kind {a.kind!r} in {a.function}: "
        "checkpoint and hand to an operator")]


def _frac_ws(d: Diagnosis, fleet_size: int):
    a = d.abnormality
    return (len(a.workers) / max(1, fleet_size),
            sorted(int(w) for w in a.workers))


@register_ladder(None, Kind.NUMERICS)
def _numerics_ladder(d: Diagnosis, fleet_size: int) -> List[MitigationPlan]:
    # loss spike / gradient-norm explosion: the model state is suspect,
    # not the hardware — restore the last good checkpoint (skipping the
    # poisoned batch), and when divergence recurs flag the code
    # (lr schedule / data) for a human
    a = d.abnormality
    return [
        MitigationPlan(
            Action.ROLLBACK_TO_CHECKPOINT, [],
            f"numerics anomaly in {a.function}: restore last good "
            "checkpoint and skip the offending data shard"),
        MitigationPlan(
            Action.FLAG_CODE, [],
            "divergence survived rollback -> flag lr schedule / data "
            "pipeline for investigation"),
    ]


@register_ladder(None, Kind.GPU, Kind.COMM)
def _hardware_ladder(d: Diagnosis, fleet_size: int) -> List[MitigationPlan]:
    a = d.abnormality
    frac, ws = _frac_ws(d, fleet_size)
    if frac >= 0.5:
        # widespread hardware abnormality: replacing half the fleet is
        # not a plan — checkpoint immediately and flag the fabric /
        # topology for investigation (regression: this used to fall
        # through to Action.NONE)
        return [MitigationPlan(
            Action.CHECKPOINT_NOW, [],
            f"{a.kind.name} abnormality on {frac:.0%} of the fleet: "
            "checkpoint now, flag fabric/topology for investigation")]
    ladder = [MitigationPlan(
        Action.REPLACE_HOSTS, ws,
        "checkpoint-now, drop flagged hosts, elastic re-mesh on "
        "standbys (see repro.ckpt + launch.train --elastic)")]
    if a.kind == Kind.GPU:
        ladder.append(MitigationPlan(
            Action.FLAG_CODE, ws,
            f"persists across host replacement -> suspect software; "
            f"optimize {a.function}"))
    else:
        ladder.append(MitigationPlan(
            Action.CHECKPOINT_NOW, [],
            "persists across host replacement -> checkpoint and page "
            "network/topology on-call"))
    return ladder


@register_ladder(None, Kind.PYTHON)
def _python_ladder(d: Diagnosis, fleet_size: int) -> List[MitigationPlan]:
    a = d.abnormality
    frac, ws = _frac_ws(d, fleet_size)
    if "socket" in a.function or "dataloader" in a.function:
        if ("thrash" in d.hint or "page-cache" in d.hint) \
                and ws and frac < 0.5:
            # IO contention localized to a few hosts: their page cache
            # (or local disk) is sick, not the shared storage — replace
            # them before reaching for a storage migration
            return [
                MitigationPlan(
                    Action.REPLACE_HOSTS, ws,
                    "page-cache thrash pinned to these hosts: replace "
                    "them (local IO path is sick)"),
                MitigationPlan(
                    Action.MIGRATE_DATALOADER, [],
                    "thrash survived host replacement -> move input "
                    "data to the parallel file system"),
            ]
        return [
            MitigationPlan(
                Action.MIGRATE_DATALOADER, [],
                "move input data to the parallel file system"),
            MitigationPlan(
                Action.FLAG_CODE, ws,
                "storage migration did not clear it -> optimize the "
                "input pipeline itself"),
        ]
    if "cgroup" in d.hint and ws and frac < 0.5:
        # OS-level CPU quota on specific hosts: no code change fixes a
        # misconfigured cgroup — replace (or re-image) the hosts
        return [
            MitigationPlan(
                Action.REPLACE_HOSTS, ws,
                "cgroup CPU quota throttling these hosts: replace "
                "them and flag the node config"),
            MitigationPlan(
                Action.FLAG_CODE, ws,
                "persists on fresh hosts -> suspect the training "
                f"code; optimize {a.function}"),
        ]
    if "gc" in d.hint or "garbage" in d.hint:
        return [
            MitigationPlan(
                Action.SYNCHRONIZE_GC, [],
                "manually collect garbage every K iterations on all "
                "workers"),
            MitigationPlan(
                Action.FLAG_CODE, ws,
                f"synchronized GC did not clear it -> optimize "
                f"{a.function}"),
        ]
    # generic slow Python frame: flag the code first; when the
    # "software" problem follows the flagged hosts, replace them
    ladder = [MitigationPlan(Action.FLAG_CODE, ws,
                             f"optimize {a.function}")]
    if ws and frac < 0.5:
        ladder.append(MitigationPlan(
            Action.REPLACE_HOSTS, ws,
            "optimization did not clear it and only these hosts are "
            "implicated -> replace them"))
    else:
        ladder.append(MitigationPlan(
            Action.CHECKPOINT_NOW, [],
            "fleet-wide slow Python frame persists -> checkpoint and "
            "hand to an operator"))
    return ladder


@register_ladder(None, Kind.MEM)
def _mem_ladder(d: Diagnosis, fleet_size: int) -> List[MitigationPlan]:
    # explicit non-GPU/COMM/PYTHON handling (used to fall through)
    a = d.abnormality
    _, ws = _frac_ws(d, fleet_size)
    return [MitigationPlan(
        Action.FLAG_CODE, ws,
        f"host/device copy bottleneck in {a.function}: batch or "
        "overlap transfers")]


def plan_mitigations(diagnoses: Sequence[Diagnosis], fleet_size: int
                     ) -> List[MitigationPlan]:
    """First rung of every diagnosis's ladder, REPLACE_HOSTS merged.

    Host replacement is one fleet operation (a single checkpoint + elastic
    re-mesh drops every flagged host at once), so REPLACE_HOSTS rungs from
    different diagnoses merge into one leading plan; other plans keep
    diagnosis order, with exact duplicates (same action + workers)
    dropped.
    """
    plans: List[MitigationPlan] = []
    seen = set()
    bad_hosts: set = set()
    for d in diagnoses:
        head = plan_ladder(d, fleet_size)[0]
        if head.action is Action.REPLACE_HOSTS:
            bad_hosts.update(head.workers)
            continue
        key = (head.action, tuple(head.workers))
        if key in seen:
            continue
        seen.add(key)
        plans.append(head)
    if bad_hosts:
        plans.insert(0, MitigationPlan(
            Action.REPLACE_HOSTS, sorted(bad_hosts),
            "checkpoint-now, drop flagged hosts, elastic re-mesh on "
            "standbys (see repro.ckpt + launch.train --elastic)"))
    if not plans:
        plans.append(MitigationPlan(Action.NONE))
    return plans


def format_plans(plans: Sequence[MitigationPlan]) -> str:
    """One line per plan, for reports and demos."""
    if not plans:
        return "mitigation: none"
    lines = []
    for p in plans:
        ws = f" workers={p.workers}" if p.workers else ""
        detail = f" — {p.detail}" if p.detail else ""
        lines.append(f"mitigation: {p.action.value}{ws}{detail}")
    return "\n".join(lines)
