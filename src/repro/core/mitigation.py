"""Mitigation hooks: PerfTracker's localization output drives the
fault-tolerance machinery (DESIGN.md §4) — the paper's observability becomes
the cluster's straggler/failure sensor.

Actions map 1:1 to what the paper's operators did (§6): replace flagged
hosts (checkpoint-now + elastic re-mesh without them), move data loading,
synchronize GC, flag code for optimization.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from enum import Enum
from typing import List, Sequence

import numpy as np

from repro.core.events import Kind
from repro.core.report import Diagnosis


class Action(Enum):
    REPLACE_HOSTS = "replace_hosts"          # checkpoint-now + re-mesh
    CHECKPOINT_NOW = "checkpoint_now"
    MIGRATE_DATALOADER = "migrate_dataloader"
    SYNCHRONIZE_GC = "synchronize_gc"
    FLAG_CODE = "flag_code_for_optimization"
    NONE = "none"


@dataclass
class MitigationPlan:
    action: Action
    workers: List[int] = field(default_factory=list)
    detail: str = ""


def plan_mitigations(diagnoses: Sequence[Diagnosis], fleet_size: int
                     ) -> List[MitigationPlan]:
    plans: List[MitigationPlan] = []
    bad_hosts: set = set()
    for d in diagnoses:
        a = d.abnormality
        frac = len(a.workers) / max(1, fleet_size)
        if a.kind in (Kind.GPU, Kind.COMM) and frac < 0.5:
            bad_hosts.update(a.workers.tolist())
        elif a.kind == Kind.PYTHON:
            if "socket" in a.function or "dataloader" in a.function:
                plans.append(MitigationPlan(
                    Action.MIGRATE_DATALOADER, [],
                    "move input data to the parallel file system"))
            elif "gc" in d.hint or "garbage" in d.hint:
                plans.append(MitigationPlan(
                    Action.SYNCHRONIZE_GC, [],
                    "manually collect garbage every K iterations on all "
                    "workers"))
            else:
                plans.append(MitigationPlan(
                    Action.FLAG_CODE, a.workers.tolist(),
                    f"optimize {a.function}"))
    if bad_hosts:
        plans.insert(0, MitigationPlan(
            Action.REPLACE_HOSTS, sorted(bad_hosts),
            "checkpoint-now, drop flagged hosts, elastic re-mesh on "
            "standbys (see repro.ckpt + launch.train --elastic)"))
    if not plans:
        plans.append(MitigationPlan(Action.NONE))
    return plans
