"""Expected ranges R_f (paper §4.3, Eq. 6) assigned by function class.

Paper values: Python functions R = [0, 0.01] x [0,1] x [0,1] (an LMT should
not be bottlenecked >1% by any Python function); collective communication
R = [0, 0.3] x [0,1] x [0,1]; GPU compute kernels are never 'unexpected'
(R = full box). Per-family adjustments (DESIGN.md §6): MoE archs allow a
wider collective box for all_to_all/dispatch phases.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.core.events import Kind

Box = Tuple[Tuple[float, float], Tuple[float, float], Tuple[float, float]]

FULL: Box = ((0.0, 1.0), (0.0, 1.0), (0.0, 1.0))
PYTHON_BOX: Box = ((0.0, 0.01), (0.0, 1.0), (0.0, 1.0))
COMM_BOX: Box = ((0.0, 0.3), (0.0, 1.0), (0.0, 1.0))
MEM_BOX: Box = ((0.0, 0.4), (0.0, 1.0), (0.0, 1.0))
MOE_COMM_BOX: Box = ((0.0, 0.45), (0.0, 1.0), (0.0, 1.0))


def expected_box(kind: Kind, name: str = "", family: str = "dense") -> Box:
    if kind == Kind.GPU:
        return FULL
    if kind == Kind.COMM:
        if family == "moe" and ("all_to_all" in name or "dispatch" in name
                                or "combine" in name):
            return MOE_COMM_BOX
        return COMM_BOX
    if kind == Kind.MEM:
        return MEM_BOX
    return PYTHON_BOX


def distance_from_expectation(p: np.ndarray, box: Box) -> float:
    """Minimal Manhattan distance from pattern p=(beta,mu,sigma) to the box
    (Eq. 7)."""
    d = 0.0
    for x, (lo, hi) in zip(p, box):
        if x < lo:
            d += lo - x
        elif x > hi:
            d += x - hi
    return float(d)
