"""Expected ranges R_f (paper §4.3, Eq. 6) assigned by function class.

Paper values: Python functions R = [0, 0.01] x [0,1] x [0,1] (an LMT should
not be bottlenecked >1% by any Python function); collective communication
R = [0, 0.3] x [0,1] x [0,1]; GPU compute kernels are never 'unexpected'
(R = full box). Per-family adjustments (DESIGN.md §6): MoE archs allow a
wider collective box for all_to_all/dispatch phases.

The ``host`` family (DESIGN.md §11) calibrates the Python box for ALL-HOST
workloads — real trainers jit'd to CPU, where data loading and bookkeeping
legitimately hold ~10% of busy samples because there is no accelerator for
the step to hide behind.  The paper's 1% bound encodes "Python work should
vanish next to GPU kernels"; on a host-only fleet the equivalent healthy
ceiling is ~20%, and faults (dataloader burns, GC pauses) blow far past it.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.core.events import Kind

Box = Tuple[Tuple[float, float], Tuple[float, float], Tuple[float, float]]

FULL: Box = ((0.0, 1.0), (0.0, 1.0), (0.0, 1.0))
PYTHON_BOX: Box = ((0.0, 0.01), (0.0, 1.0), (0.0, 1.0))
COMM_BOX: Box = ((0.0, 0.3), (0.0, 1.0), (0.0, 1.0))
MEM_BOX: Box = ((0.0, 0.4), (0.0, 1.0), (0.0, 1.0))
MOE_COMM_BOX: Box = ((0.0, 0.45), (0.0, 1.0), (0.0, 1.0))
HOST_PYTHON_BOX: Box = ((0.0, 0.2), (0.0, 1.0), (0.0, 1.0))


def expected_box(kind: Kind, name: str = "", family: str = "dense") -> Box:
    if kind in (Kind.GPU, Kind.NUMERICS):
        # GPU kernels are never 'unexpected'; NUMERICS abnormalities are
        # synthetic (no busy-fraction semantics), the trigger itself is the
        # evidence
        return FULL
    if kind == Kind.COMM:
        if family == "moe" and ("all_to_all" in name or "dispatch" in name
                                or "combine" in name):
            return MOE_COMM_BOX
        return COMM_BOX
    if kind == Kind.MEM:
        return MEM_BOX
    if family == "host":
        return HOST_PYTHON_BOX
    return PYTHON_BOX


def distance_from_expectation(p: np.ndarray, box: Box) -> float:
    """Minimal Manhattan distance from pattern p=(beta,mu,sigma) to the box
    (Eq. 7)."""
    d = 0.0
    for x, (lo, hi) in zip(p, box):
        if x < lo:
            d += lo - x
        elif x > hi:
            d += x - hi
    return float(d)
