"""The steady-state loop of PerfTracker (DESIGN.md §7) — EROICA's *online*
claim, made concrete:

  anchors stream into the ``IterationDetector`` continuously; a ``Trigger``
  opens an Incident; every profiling-window tick runs the fleet-batched
  summarize path, folds the window's ``(W, F, 3)`` pattern block into the
  cross-window EMA (``repro.online.ema``), localizes on the *smoothed*
  patterns, advances incident lifecycles, and retunes per-worker sample
  rates via differential escalation (``repro.online.escalation``).

The one-shot ``PerfTrackerService.diagnose_profiles`` remains the batch
entry point; ``OnlinePipeline`` wraps the same detector/localizer/backend
components into the continuous loop the paper ran for 1.5 years.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import channels
from repro.core.detector import (DetectorConfig, NumericsConfig,
                                 NumericsDetector, SloConfig, SloDetector,
                                 Trigger)
from repro.core.events import Kind
from repro.core.localizer import Abnormality
from repro.core.report import (Diagnosis, build_report, format_report,
                               format_transport)
from repro.core.service import PerfTrackerService
from repro.online.ema import EmaPatternAggregator
from repro.online.escalation import EscalationPolicy
from repro.online.incident import Incident, IncidentManager
from repro.summarize.fleet import summarize_fleet


@dataclass
class WindowReport:
    """Everything one profiling-window tick produced."""
    index: int
    t: float                       # scenario/deployment clock at tick
    diagnoses: List[Diagnosis]
    changed: List[Incident]        # incidents that transitioned this window
    escalated: List[int]           # workers escalated for the NEXT window
    rates: Optional[np.ndarray]    # per-worker rates USED for this window
    raw_bytes: int
    pattern_bytes: int
    summarize_s: float
    localize_s: float
    #: workers whose evidence arrived this window (None = full fleet)
    present: Optional[np.ndarray] = None
    #: wire-transport counters for this window (None off the wire)
    transport: Optional[Dict[str, object]] = None
    #: mitigation plans the engine executed this tick (DESIGN.md §9)
    mitigations: List = field(default_factory=list)

    def functions(self) -> List[str]:
        return [d.abnormality.function for d in self.diagnoses]

    def report(self, fleet_size: int) -> str:
        out = format_report(self.diagnoses, fleet_size)
        if self.transport is not None:
            out += "\n" + format_transport(self.transport)
        return out


class OnlinePipeline:
    """Continuous detection -> profiling -> localization -> incident loop."""

    def __init__(self, n_workers: int, family: str = "dense",
                 detector_cfg: Optional[DetectorConfig] = None,
                 summarize_backend=None, alpha: float = 0.6,
                 escalation: Optional[EscalationPolicy] = None,
                 clear_windows: int = 2, verify_windows: int = 2,
                 max_escalations: int = 2, settle_windows: int = 1,
                 numerics_cfg: Optional[NumericsConfig] = None,
                 slo_cfg: Optional[SloConfig] = None,
                 profile_channel: str = channels.PERF,
                 history=None):
        self.n_workers = int(n_workers)
        self.service = PerfTrackerService(
            family=family, detector_cfg=detector_cfg,
            summarize_backend=summarize_backend)
        self.detector = self.service.detector
        #: job-level numerics channel (DESIGN.md §12a): loss / grad-norm
        #: samples stream in via ``feed_numerics`` beside the anchor stream
        self.numerics = NumericsDetector(numerics_cfg)
        #: serving latency-SLO channel (DESIGN.md §13): p99 (TTFT, TBT)
        #: samples stream in via ``feed_slo``
        self.slo = SloDetector(slo_cfg)
        #: the channel localized PROFILE abnormalities belong to — ``perf``
        #: for training workloads, ``slo`` for serving ones, where a slow
        #: function manifests to users as a latency violation, not an
        #: iteration slowdown (the anchor detector has no train sequence
        #: to lock onto there)
        self.profile_channel = channels.validate_channel(profile_channel)
        self.ema = EmaPatternAggregator(self.n_workers, alpha=alpha)
        self.incidents = IncidentManager(self.n_workers,
                                         clear_windows=clear_windows,
                                         verify_windows=verify_windows,
                                         max_escalations=max_escalations,
                                         settle_windows=settle_windows,
                                         history=history)
        self.escalation = escalation
        #: MitigationEngine executing incident ladders each tick (None =
        #: plans are attached but never acted on, the pre-§9 behavior)
        self.mitigator = None
        #: mesh-membership mask (None = every row is in the mesh); see
        #: ``set_membership``
        self._members: Optional[np.ndarray] = None
        self.windows: List[WindowReport] = []
        self._recoveries_seen = 0
        self._num_recoveries_seen = 0
        self._slo_recoveries_seen = 0

    def attach_mitigator(self, engine) -> None:
        """Install a ``repro.online.mitigation.MitigationEngine``: every
        tick, incidents' pending ladder rungs are executed against the
        engine's simulator and verification clocks start."""
        self.mitigator = engine

    def set_membership(self, workers: Sequence[int]) -> None:
        """Declare the CURRENT training-mesh membership (global ids).

        Distinct from per-window *presence* (§8 upload loss): rows outside
        the mesh — cold standbys, replaced hosts — are structurally
        excluded from localization, and plan sizing (the widespread-fault
        fraction in ``plan_ladder``) is computed over the ACTIVE mesh, not
        the row space.  With a mitigator attached this tracks its
        simulator automatically; scenario runners call it per tick."""
        mem = np.zeros(self.n_workers, bool)
        mem[np.asarray(list(workers), np.int64)] = True
        self._members = None if mem.all() else mem
        self.incidents.fleet_size = int(mem.sum())

    # -- detection side (runs between profiling windows) -------------------
    def feed_anchors(self, events: Sequence[Tuple[str, float]]
                     ) -> List[Trigger]:
        """Stream anchor events; every trigger is folded into the incident
        set (at most one new incident — reminders attach to the active
        one), every detector recovery resolves what it can."""
        triggers = []
        for name, t in events:
            trig = self.detector.feed(name, t)
            if trig is not None:
                triggers.append(trig)
                self.incidents.on_trigger(trig)
            self._drain_recoveries()
        return triggers

    def feed_numerics(self, samples: Sequence[Tuple[float, float, float]]
                      ) -> List[Trigger]:
        """Stream job-level (t, loss, grad_norm) samples into the numerics
        channel.  Triggers and recoveries fold into the SAME incident set
        as the perf channel — on their own ``channel='numerics'`` lane, so
        a loss spike during an open perf incident is a distinct incident.

        Unlike a perf recovery, a numerics recovery does NOT reset the EMA:
        numerics evidence never enters the pattern aggregator, and perf
        incidents must keep their smoothed evidence."""
        triggers = []
        for t, loss, grad_norm in samples:
            for trig in self.numerics.feed(t, loss, grad_norm):
                triggers.append(trig)
                self.incidents.on_trigger(trig)
        recs = self.numerics.recoveries
        for rec in recs[self._num_recoveries_seen:]:
            self.incidents.on_recovery(rec)
        self._num_recoveries_seen = len(recs)
        return triggers

    def feed_slo(self, samples: Sequence[Tuple[float, float, float]]
                 ) -> List[Trigger]:
        """Stream job-level (t, p99_ttft, p99_tbt) samples into the SLO
        channel (DESIGN.md §13).  Triggers and recoveries fold into the
        same incident set on the ``channel='slo'`` lane.

        When the workload's profile abnormalities live on the SLO channel
        (``profile_channel='slo'``, a serving fleet), an SLO recovery
        plays the role a perf recovery plays for training: the user-facing
        metric is healthy again, so the EMA drains and stale fault
        evidence stops implicating already-mitigated workers."""
        triggers = []
        for t, ttft, tbt in samples:
            for trig in self.slo.feed(t, ttft, tbt):
                triggers.append(trig)
                self.incidents.on_trigger(trig)
        recs = self.slo.recoveries
        fresh = recs[self._slo_recoveries_seen:]
        for rec in fresh:
            self.incidents.on_recovery(rec)
        self._slo_recoveries_seen = len(recs)
        if fresh and self.profile_channel == channels.SLO:
            self.ema = EmaPatternAggregator(self.n_workers,
                                            alpha=self.ema.alpha)
        return triggers

    def feed_metrics(self, metrics: Dict[str, Sequence[Tuple[float, ...]]]
                     ) -> List[Trigger]:
        """Dispatch a ``WindowData.metrics`` dict to the matching
        sample-stream detectors.  Stream names are validated against the
        channel registry; a stream with no sample-feed (``perf`` rides the
        anchor stream, not a metrics stream) raises."""
        triggers: List[Trigger] = []
        for name, samples in metrics.items():
            channels.validate_channel(name)
            if name == channels.NUMERICS:
                triggers.extend(self.feed_numerics(samples))
            elif name == channels.SLO:
                triggers.extend(self.feed_slo(samples))
            else:
                raise ValueError(
                    f"channel {name!r} has no metrics-stream detector; "
                    "perf consumes the anchor stream via feed_anchors")
        return triggers

    def poll_blockage(self, now: float) -> Optional[Trigger]:
        trig = self.detector.check_blockage(now)
        if trig is not None:
            self.incidents.on_trigger(trig)
        return trig

    def _drain_recoveries(self) -> None:
        recs = self.detector.recoveries
        if len(recs) > self._recoveries_seen:
            for rec in recs[self._recoveries_seen:]:
                self.incidents.on_recovery(rec)
            self._recoveries_seen = len(recs)
            # the job-level metric is healthy again: drain the EMA so stale
            # fault evidence stops implicating already-mitigated workers
            # (a recovery only fires when EVERY fault has cleared, so no
            # concurrent incident loses live evidence)
            self.ema = EmaPatternAggregator(self.n_workers,
                                            alpha=self.ema.alpha)

    # -- profiling side -----------------------------------------------------
    def rates(self) -> Optional[np.ndarray]:
        """Per-worker sample rates for the next window (None = no
        escalation policy installed; profile at whatever the deployment's
        fixed rate is)."""
        return self.escalation.rates() if self.escalation else None

    def window_tick(self, profiles, t: Optional[float] = None,
                    rates: Optional[np.ndarray] = None,
                    present_workers: Optional[Sequence[int]] = None
                    ) -> WindowReport:
        """Fold one fleet of raw profiling windows into the online state.

        ``present_workers`` maps a PARTIAL profile list to global fleet
        rows (``present_workers[i]`` is ``profiles[i]``'s worker id):
        absent workers' EMA rows freeze instead of decaying on a window
        they never reported (DESIGN.md §8)."""
        t0 = time.perf_counter()
        present = None
        if present_workers is not None:
            ids = np.asarray(list(present_workers), np.int64)
            fs = summarize_fleet(profiles,
                                 backend=self.service.summarize_backend,
                                 workers=ids, fleet_size=self.n_workers)
            present = np.zeros(self.n_workers, bool)
            present[ids] = True
        else:
            fs = summarize_fleet(profiles,
                                 backend=self.service.summarize_backend)
        self.ema.fold(fs.agg, present=present)
        summarize_s = time.perf_counter() - t0
        return self._finish_tick(
            t=t, rates=rates, present=present,
            raw_bytes=sum(p.raw_size_bytes() for p in profiles),
            pattern_bytes=fs.pattern_bytes, summarize_s=summarize_s)

    def window_tick_batch(self, batch, t: Optional[float] = None,
                          rates: Optional[np.ndarray] = None
                          ) -> WindowReport:
        """Fold one assembled wire window (``transport.WindowBatch``) into
        the online state — the cross-process twin of ``window_tick``.

        Uploads address EMA rows by worker id; workers whose upload was
        dropped (backpressure, loss) keep their previous smoothed pattern,
        and the batch's transport counters surface in the report."""
        t0 = time.perf_counter()
        if hasattr(batch, "aggregate"):
            # collector-tree window (transport.TreeWindowBatch): shard
            # blocks were compacted at the leaves; scatter them straight
            # into the fleet aggregator (DESIGN.md §10)
            agg, present = batch.aggregate(self.n_workers)
            raw_bytes = batch.raw_bytes
            pattern_bytes = batch.pattern_bytes
        else:
            uploads = batch.sorted_uploads()
            agg, present = self.service.aggregate_batch(uploads,
                                                        self.n_workers)
            raw_bytes = sum(u.raw_bytes for u in uploads)
            pattern_bytes = sum(len(u.payload) for u in uploads)
        self.ema.fold(agg, present=present)
        summarize_s = time.perf_counter() - t0
        return self._finish_tick(
            t=t, rates=rates, present=present,
            raw_bytes=raw_bytes, pattern_bytes=pattern_bytes,
            summarize_s=summarize_s, transport=batch.stats())

    def _finish_tick(self, t: Optional[float], rates, present,
                     raw_bytes: int, pattern_bytes: int, summarize_s: float,
                     transport: Optional[Dict[str, object]] = None
                     ) -> WindowReport:
        """Shared tail of every tick flavor: localize on the smoothed
        patterns, advance incidents, retune escalation."""
        if t is None:
            t = float(len(self.windows))
        pats, kinds = self.ema.finalize()
        t1 = time.perf_counter()
        # mesh membership vs transient presence: a worker whose UPLOAD was
        # lost keeps implicating via its frozen EMA row (DESIGN.md §8), but
        # a worker REPLACED out of the mesh (and a standby not yet in it)
        # is structurally excluded from localization (DESIGN.md §9)
        if self.mitigator is not None and self.mitigator.sim is not None:
            self.set_membership(self.mitigator.sim.active_workers)
        abn: List[Abnormality] = self.service.localizer.localize(
            pats, kinds, present=self._members)
        if self.profile_channel != channels.PERF:
            # serving fleet: a localized profile abnormality IS the SLO
            # violation's root cause — retag it onto the workload's channel
            # so it pairs with the SLO trigger's incident lane (§13)
            for a in abn:
                a.channel = self.profile_channel
        # outstanding numerics signals ride the same diagnosis path as a
        # synthesized job-level abnormality: no worker set (the channel is
        # job-level), kind NUMERICS, full-box expectation — everything
        # downstream (report/incident/ladder) treats it like any other
        abn.extend(Abnormality(
            function=f"numerics.{signal}",
            workers=np.zeros(0, np.int64), kind=Kind.NUMERICS,
            d_expect=np.array([1.0]), delta=np.array([0.0]),
            patterns=np.array([[1.0, 0.0, 0.0]]),
            typical=np.zeros(3), reason="numerics", channel="numerics")
            for signal in self.numerics.outstanding())
        # hint fractions size over the ACTIVE mesh, like plan sizing —
        # standbys/replaced rows must not dilute them
        diagnoses = build_report(abn, self.incidents.fleet_size)
        localize_s = time.perf_counter() - t1
        changed = self.incidents.on_window(
            t, diagnoses,
            detector_healthy=(self.detector.healthy
                              and self.numerics.healthy
                              and self.slo.healthy))
        mitigations = []
        if self.mitigator is not None:
            mitigations = self.mitigator.step(self.incidents, t=t,
                                              window=len(self.windows))
        escalated = (self.escalation.observe(abn)
                     if self.escalation else [])
        report = WindowReport(
            index=len(self.windows), t=t, diagnoses=diagnoses,
            changed=changed, escalated=escalated, rates=rates,
            raw_bytes=raw_bytes, pattern_bytes=pattern_bytes,
            summarize_s=summarize_s, localize_s=localize_s,
            present=present, transport=transport,
            mitigations=mitigations)
        self.windows.append(report)
        return report

    # -- reporting ----------------------------------------------------------
    def timeline(self) -> str:
        return self.incidents.timeline()
