"""MitigationEngine: executes mitigation ladders against the running
``FleetSimulator`` and closes the act -> verify -> escalate loop
(DESIGN.md §9; ROADMAP "mitigation validation loop").

The incident manager attaches a RANKED ladder of ``MitigationPlan``s when
an abnormality persists (``plan_ladder``); this engine is what actually
*acts* on the current rung:

  * ``REPLACE_HOSTS``       — ``FleetSimulator.replace_hosts``: flagged
    workers leave the mesh, standbys join (elastic re-mesh; the fleet
    simply shrinks when the standby pool is dry).  A host-pinned fault
    whose hosts were all dropped is cured by construction; a RANK-pinned
    software fault follows its ranks onto the replacement hosts —
    replacing hardware does not fix code, and verification will catch the
    signature reappearing on the new workers;
  * ``MIGRATE_DATALOADER`` / ``SYNCHRONIZE_GC`` / ``FLAG_CODE`` /
    ``CHECKPOINT_NOW`` — clear every live scheduled fault that declares
    the action curative (``ScheduledFault.cures``, defaulting to the
    per-fault-model playbook below).  A misdiagnosed/no-op plan cures
    nothing and leaves the fault live.

Whether an action cures a fault is the SCENARIO's ground truth, not the
diagnosis's: a schedule can declare that a GPU-looking fault is really a
software problem (``cures=(Action.FLAG_CODE,)``), in which case replacing
the hosts moves the fault to the standbys, verification fails, and the
incident escalates to the next rung — the wrong-plan-first family of
tests.  ``on_cure`` optionally replaces a cured fault with a weaker
residual one (the partial-fix family).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import faults as F
from repro.core.mitigation import Action, MitigationPlan
from repro.core.simulation import FleetSimulator

#: which Action actually cures each injected fault model — the playbook
#: lives with the fault data (``repro.core.faults.default_cures``); this
#: module-level view keeps the engine's historical import path working
DEFAULT_CURES: Dict[type, Tuple[Action, ...]] = F.default_cures()

#: actions that drop hosts from the mesh and re-mesh onto standbys:
#: training's checkpoint-now replace, and serving's drain-in-flight-then-
#: replace (DESIGN.md §13) — identical world effect, different protocol
#: around it, so the engine executes both through ``replace_hosts``
_REPLACE_LIKE = (Action.REPLACE_HOSTS, Action.DRAIN_AND_REPLACE)


@dataclass
class AppliedMitigation:
    """One executed plan and what it did to the simulated world."""
    incident_id: int
    window: int
    rung: int
    plan: MitigationPlan
    cured: List[str] = field(default_factory=list)      # fault class names
    remapped: List[str] = field(default_factory=list)   # followed ranks
    dropped: List[int] = field(default_factory=list)
    replacements: List[int] = field(default_factory=list)

    def __str__(self) -> str:
        out = (f"incident #{self.incident_id} rung {self.rung}: "
               f"{self.plan.action.value}")
        if self.dropped:
            out += f" dropped={self.dropped} standbys={self.replacements}"
        if self.cured:
            out += f" cured={self.cured}"
        if self.remapped:
            out += f" followed_ranks={self.remapped}"
        return out


def plan_to_wire(m: AppliedMitigation) -> Dict:
    """Serialize one executed plan for the wire control plane (DESIGN.md
    §10): the (action, workers, window) triple is everything a worker
    process needs to replay the plan deterministically on its OWN engine
    — ``FleetSimulator.replace_hosts`` and every cure decision are pure
    functions of that triple plus shared scenario state."""
    return {"window": int(m.window), "action": m.plan.action.value,
            "workers": [int(w) for w in m.plan.workers]}


def plan_from_wire(d: Dict) -> Tuple[MitigationPlan, int]:
    """Inverse of ``plan_to_wire``: (plan, window it was applied at)."""
    return (MitigationPlan(action=Action(d["action"]),
                           workers=[int(w) for w in d["workers"]]),
            int(d["window"]))


class MitigationEngine:
    """Applies incident ladders to a ``FleetSimulator`` + fault schedule.

    Owns the schedule's LIVE view: ``faults_at(window)`` is what the
    scenario runner injects each window — scheduled activity minus cures,
    plus any re-pinning replace-hosts caused.
    """

    def __init__(self, sim: FleetSimulator, schedule: Sequence):
        self.sim = sim
        self.schedule = list(schedule)
        #: current Fault object per schedule entry (replace_hosts re-pins
        #: rank-pinned software faults onto their replacement workers)
        self._live: List[F.Fault] = [sf.fault for sf in self.schedule]
        #: window each entry was cured at (None = still live)
        self._cured_at: List[Optional[int]] = [None] * len(self.schedule)
        self.log: List[AppliedMitigation] = []

    def cures(self, sf) -> Tuple[Action, ...]:
        declared = getattr(sf, "cures", None)
        if declared is not None:
            return tuple(declared)
        return DEFAULT_CURES.get(type(sf.fault), ())

    def cured_window(self, index: int) -> Optional[int]:
        """Window schedule entry ``index`` was cured at (None = live)."""
        return self._cured_at[index]

    def faults_at(self, window: int) -> List[F.Fault]:
        """The schedule's live fault view for one window."""
        out = []
        for j, sf in enumerate(self.schedule):
            if not sf.active(window):
                continue
            if self._cured_at[j] is not None:
                residual = getattr(sf, "on_cure", None)
                if residual is not None:
                    out.append(residual)     # partial fix
                continue
            out.append(self._live[j])
        return out

    # -- plan execution ----------------------------------------------------
    def step(self, manager, t: float, window: int
             ) -> List[AppliedMitigation]:
        """Execute every incident's pending ladder rung for this window
        (called by the pipeline right after incident transitions)."""
        applied = []
        for inc in manager.active:
            plan = inc.pending_plan
            if plan is None:
                continue
            rec = self.apply(plan, window, incident_id=inc.id,
                             rung=inc.rung)
            inc.mark_applied(plan, t)
            applied.append(rec)
        return applied

    def apply(self, plan: MitigationPlan, window: int,
              incident_id: int = -1, rung: int = 0) -> AppliedMitigation:
        """Execute one plan against the simulator + schedule."""
        rec = AppliedMitigation(incident_id=incident_id, window=window,
                                rung=rung, plan=plan)
        mapping: Dict[int, Optional[int]] = {}
        if plan.action in _REPLACE_LIKE and plan.workers:
            mapping = self.sim.replace_hosts(plan.workers)
            rec.dropped = sorted(mapping)
            rec.replacements = sorted(
                r for r in mapping.values() if r is not None)
        for j, sf in enumerate(self.schedule):
            if self._cured_at[j] is not None or not sf.active(window):
                continue
            fault = self._live[j]
            name = type(fault).__name__
            cures = self.cures(sf)
            if plan.action in _REPLACE_LIKE:
                if not mapping:
                    continue
                pinned = F.affected_workers(fault)
                if pinned is None or not (pinned & set(mapping)):
                    continue          # replacement can't touch this fault
                if set(cures) & set(_REPLACE_LIKE):
                    # host-pinned fault: replacements are healthy, the
                    # fault shrinks off the dropped hosts (to nothing =
                    # cured, e.g. the degraded NIC bond leaving the ring)
                    if pinned <= set(mapping):
                        self._cured_at[j] = window
                        rec.cured.append(name)
                        continue
                    kept = F.remap_workers(fault,
                                           {w: None for w in mapping})
                    if kept is None:
                        self._cured_at[j] = window
                        rec.cured.append(name)
                    else:
                        self._live[j] = kept
                else:
                    # rank-pinned software fault: it follows its ranks
                    # onto the replacement hosts
                    moved = F.remap_workers(fault, mapping)
                    if moved is None:
                        # ranks left the fleet entirely (standby pool
                        # dry): the signature has nowhere to manifest
                        self._cured_at[j] = window
                        rec.cured.append(name)
                    elif moved is not fault:
                        self._live[j] = moved
                        rec.remapped.append(name)
            elif plan.action in cures:
                self._cured_at[j] = window
                rec.cured.append(name)
        self.log.append(rec)
        return rec
