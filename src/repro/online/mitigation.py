"""MitigationEngine: executes mitigation ladders against the running
``FleetSimulator`` and closes the act -> verify -> escalate loop
(DESIGN.md §9; ROADMAP "mitigation validation loop").

The incident manager attaches a RANKED ladder of ``MitigationPlan``s when
an abnormality persists (``plan_ladder``); this engine is what actually
*acts* on the current rung:

  * ``REPLACE_HOSTS``       — ``FleetSimulator.replace_hosts``: flagged
    workers leave the mesh, standbys join (elastic re-mesh; the fleet
    simply shrinks when the standby pool is dry).  A host-pinned fault
    whose hosts were all dropped is cured by construction; a RANK-pinned
    software fault follows its ranks onto the replacement hosts —
    replacing hardware does not fix code, and verification will catch the
    signature reappearing on the new workers;
  * ``MIGRATE_DATALOADER`` / ``SYNCHRONIZE_GC`` / ``FLAG_CODE`` /
    ``CHECKPOINT_NOW`` — clear every live scheduled fault that declares
    the action curative (``ScheduledFault.cures``, defaulting to the
    per-fault-model playbook below).  A misdiagnosed/no-op plan cures
    nothing and leaves the fault live.

With a ``RecoveryManager`` attached (DESIGN.md §14) the checkpoint verbs
act on REAL on-disk state: ``CHECKPOINT_NOW`` drives an actual async save,
``ROLLBACK_TO_CHECKPOINT`` restores the latest valid step into the live
workload (parameter-equality verified), and a replace-like rung first
checkpoints, re-meshes, then elastically restores onto the new mesh.  A
rollback that finds no usable checkpoint is an HONEST failure: the engine
cures nothing, the record carries ``rollback_failed``, verification sees
the signature survive, and the incident escalates — never a faked cure.
Without a recovery manager (worker-process replay engines, legacy
callers) the checkpoint verbs keep their historical label-only cure
semantics; replayed plans carry the parent's rollback outcome so cure
decisions stay bit-identical across process boundaries.

Whether an action cures a fault is the SCENARIO's ground truth, not the
diagnosis's: a schedule can declare that a GPU-looking fault is really a
software problem (``cures=(Action.FLAG_CODE,)``), in which case replacing
the hosts moves the fault to the standbys, verification fails, and the
incident escalates to the next rung — the wrong-plan-first family of
tests.  ``on_cure`` optionally replaces a cured fault with a weaker
residual one (the partial-fix family).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import faults as F
from repro.core.mitigation import Action, MitigationPlan
from repro.core.simulation import FleetSimulator

#: which Action actually cures each injected fault model — the playbook
#: lives with the fault data (``repro.core.faults.default_cures``); this
#: module-level view keeps the engine's historical import path working
DEFAULT_CURES: Dict[type, Tuple[Action, ...]] = F.default_cures()

#: actions that drop hosts from the mesh and re-mesh onto standbys:
#: training's checkpoint-now replace, and serving's drain-in-flight-then-
#: replace (DESIGN.md §13) — identical world effect, different protocol
#: around it, so the engine executes both through ``replace_hosts``
_REPLACE_LIKE = (Action.REPLACE_HOSTS, Action.DRAIN_AND_REPLACE)


@dataclass
class AppliedMitigation:
    """One executed plan and what it did to the simulated world."""
    incident_id: int
    window: int
    rung: int
    plan: MitigationPlan
    cured: List[str] = field(default_factory=list)      # fault class names
    remapped: List[str] = field(default_factory=list)   # followed ranks
    dropped: List[int] = field(default_factory=list)
    replacements: List[int] = field(default_factory=list)
    #: real-state effects (RecoveryManager attached, DESIGN.md §14):
    #: step saved by CHECKPOINT_NOW / a replace-like rung's pre-drop save
    checkpoint_step: Optional[int] = None
    #: step a rollback (or post-replace elastic restore) installed
    restored_step: Optional[int] = None
    #: training steps the rollback discarded
    lost_steps: int = 0
    #: wall-clock restore cost, seconds (goodput accounting)
    restore_s: float = 0.0
    #: installed state compared equal to the on-disk arrays
    rollback_verified: bool = False
    #: the rollback found no usable checkpoint (honest degradation: the
    #: engine cured nothing and verification will fail)
    rollback_failed: bool = False

    def __str__(self) -> str:
        out = (f"incident #{self.incident_id} rung {self.rung}: "
               f"{self.plan.action.value}")
        if self.dropped:
            out += f" dropped={self.dropped} standbys={self.replacements}"
        if self.cured:
            out += f" cured={self.cured}"
        if self.remapped:
            out += f" followed_ranks={self.remapped}"
        if self.restored_step is not None:
            out += (f" restored_step={self.restored_step}"
                    f" lost_steps={self.lost_steps}")
        if self.rollback_failed:
            out += " ROLLBACK-FAILED"
        return out


def plan_to_wire(m: AppliedMitigation) -> Dict:
    """Serialize one executed plan for the wire control plane (DESIGN.md
    §10): the (action, workers, window) triple is everything a worker
    process needs to replay the plan deterministically on its OWN engine
    — ``FleetSimulator.replace_hosts`` and every cure decision are pure
    functions of that triple plus shared scenario state.  The one
    exception is a rollback's outcome, which depends on the parent's
    on-disk checkpoint state: it rides as ``rollback_failed`` (present
    only when true, keeping legacy frames byte-identical) so replay
    engines skip the same cures the parent skipped."""
    out = {"window": int(m.window), "action": m.plan.action.value,
           "workers": [int(w) for w in m.plan.workers]}
    if m.rollback_failed:
        out["rollback_failed"] = True
    return out


def plan_from_wire(d: Dict) -> Tuple[MitigationPlan, int]:
    """Inverse of ``plan_to_wire``: (plan, window it was applied at)."""
    return (MitigationPlan(action=Action(d["action"]),
                           workers=[int(w) for w in d["workers"]]),
            int(d["window"]))


class MitigationEngine:
    """Applies incident ladders to a ``FleetSimulator`` + fault schedule.

    Owns the schedule's LIVE view: ``faults_at(window)`` is what the
    scenario runner injects each window — scheduled activity minus cures,
    plus any re-pinning replace-hosts caused.
    """

    def __init__(self, sim: Optional[FleetSimulator], schedule: Sequence,
                 recovery=None):
        #: None for real (trainer) workloads — there is no simulated mesh
        #: to re-mesh; checkpoint verbs still act through ``recovery``
        self.sim = sim
        self.schedule = list(schedule)
        #: current Fault object per schedule entry (replace_hosts re-pins
        #: rank-pinned software faults onto their replacement workers)
        self._live: List[F.Fault] = [sf.fault for sf in self.schedule]
        #: window each entry was cured at (None = still live)
        self._cured_at: List[Optional[int]] = [None] * len(self.schedule)
        #: ``repro.ckpt.recovery.RecoveryManager`` binding checkpoint
        #: verbs to real on-disk state (None = label-only semantics)
        self.recovery = recovery
        self.log: List[AppliedMitigation] = []

    def begin_window(self, window: int) -> None:
        """Cadence hook, called by the scenario runner at the top of every
        window: periodic baseline checkpoints + the sim side-car's
        training step (no-op without a recovery manager)."""
        if self.recovery is not None:
            self.recovery.on_window(window)

    def cures(self, sf) -> Tuple[Action, ...]:
        declared = getattr(sf, "cures", None)
        if declared is not None:
            return tuple(declared)
        return DEFAULT_CURES.get(type(sf.fault), ())

    def cured_window(self, index: int) -> Optional[int]:
        """Window schedule entry ``index`` was cured at (None = live)."""
        return self._cured_at[index]

    def faults_at(self, window: int) -> List[F.Fault]:
        """The schedule's live fault view for one window."""
        out = []
        for j, sf in enumerate(self.schedule):
            if not sf.active(window):
                continue
            if self._cured_at[j] is not None:
                residual = getattr(sf, "on_cure", None)
                if residual is not None:
                    out.append(residual)     # partial fix
                continue
            out.append(self._live[j])
        return out

    # -- plan execution ----------------------------------------------------
    def step(self, manager, t: float, window: int
             ) -> List[AppliedMitigation]:
        """Execute every incident's pending ladder rung for this window
        (called by the pipeline right after incident transitions)."""
        applied = []
        for inc in manager.active:
            plan = inc.pending_plan
            if plan is None:
                continue
            rec = self.apply(plan, window, incident_id=inc.id,
                             rung=inc.rung)
            inc.mark_applied(plan, t)
            applied.append(rec)
        return applied

    def apply(self, plan: MitigationPlan, window: int,
              incident_id: int = -1, rung: int = 0,
              rollback_failed: Optional[bool] = None) -> AppliedMitigation:
        """Execute one plan against the simulator + schedule (and, with a
        recovery manager, against real on-disk state).

        ``rollback_failed`` replays a remote engine's rollback outcome
        (wire control plane): None = decide locally."""
        rec = AppliedMitigation(incident_id=incident_id, window=window,
                                rung=rung, plan=plan)
        mapping: Dict[int, Optional[int]] = {}
        if plan.action in _REPLACE_LIKE and plan.workers \
                and self.sim is not None:
            if self.recovery is not None:
                # checkpoint-then-replace: protect state before hosts drop
                rec.checkpoint_step = self.recovery.checkpoint()
            mapping = self.sim.replace_hosts(plan.workers)
            rec.dropped = sorted(mapping)
            rec.replacements = sorted(
                r for r in mapping.values() if r is not None)
            if self.recovery is not None and mapping:
                # elastic restore of the pre-drop save onto the re-meshed
                # fleet (DESIGN.md §4: shardings follow the CURRENT mesh)
                out = self.recovery.rollback()
                if out.ok:
                    rec.restored_step = out.step
                    rec.restore_s = out.restore_s
                    rec.rollback_verified = out.verified
        if plan.action is Action.CHECKPOINT_NOW \
                and self.recovery is not None:
            rec.checkpoint_step = self.recovery.checkpoint()
        if plan.action is Action.ROLLBACK_TO_CHECKPOINT:
            failed = rollback_failed
            if failed is None and self.recovery is not None:
                out = self.recovery.rollback()
                rec.restored_step = out.step if out.ok else None
                rec.restore_s = out.restore_s
                rec.lost_steps = out.lost_steps
                rec.rollback_verified = out.verified
                failed = not (out.ok and out.verified)
            rec.rollback_failed = bool(failed)
        for j, sf in enumerate(self.schedule):
            if self._cured_at[j] is not None or not sf.active(window):
                continue
            fault = self._live[j]
            name = type(fault).__name__
            cures = self.cures(sf)
            if plan.action in _REPLACE_LIKE:
                if not mapping:
                    continue
                pinned = F.affected_workers(fault)
                if pinned is None or not (pinned & set(mapping)):
                    continue          # replacement can't touch this fault
                if set(cures) & set(_REPLACE_LIKE):
                    # host-pinned fault: replacements are healthy, the
                    # fault shrinks off the dropped hosts (to nothing =
                    # cured, e.g. the degraded NIC bond leaving the ring)
                    if pinned <= set(mapping):
                        self._cured_at[j] = window
                        rec.cured.append(name)
                        continue
                    kept = F.remap_workers(fault,
                                           {w: None for w in mapping})
                    if kept is None:
                        self._cured_at[j] = window
                        rec.cured.append(name)
                    else:
                        self._live[j] = kept
                else:
                    # rank-pinned software fault: it follows its ranks
                    # onto the replacement hosts
                    moved = F.remap_workers(fault, mapping)
                    if moved is None:
                        # ranks left the fleet entirely (standby pool
                        # dry): the signature has nowhere to manifest
                        self._cured_at[j] = window
                        rec.cured.append(name)
                    elif moved is not fault:
                        self._live[j] = moved
                        rec.remapped.append(name)
            elif plan.action in cures:
                if plan.action is Action.ROLLBACK_TO_CHECKPOINT \
                        and rec.rollback_failed:
                    # nothing was restored: claiming a cure here would be
                    # a lie — the signature stays live and verification
                    # fails honestly
                    continue
                self._cured_at[j] = window
                rec.cured.append(name)
        self.log.append(rec)
        return rec
