"""Incident lifecycle for the online pipeline (DESIGN.md §7).

An *incident* is one performance problem with a lifecycle:

    open ──▶ confirmed ──▶ mitigating ──▶ resolved

  * ``open``       — the detector fired a Trigger (anchor-level degradation)
    but localization has not yet named a culprit function;
  * ``confirmed``  — a profiling window's localization produced an
    ``Abnormality`` matching this incident (the incident's identity is its
    abnormal *function*, which is what keeps overlapping faults distinct);
  * ``mitigating`` — the abnormality persisted into a further window and a
    mitigation plan (``repro.core.mitigation``) is attached;
  * ``resolved``   — the detector's recovery re-arm fired
    (``IterationDetector.recoveries``) while the signature is clear, or the
    signature stayed clear for ``clear_windows`` consecutive windows (the
    fallback for overlapping incidents, where the job-level iteration time
    only recovers when the LAST fault clears).

One detector trigger never spawns more than one incident — reminder
triggers (``rearm_cooldown``) and additional abnormal functions fold into
the open incident set instead.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.detector import Recovery, Trigger
from repro.core.localizer import Abnormality
from repro.core.mitigation import MitigationPlan, plan_mitigations
from repro.core.report import Diagnosis

OPEN = "open"
CONFIRMED = "confirmed"
MITIGATING = "mitigating"
RESOLVED = "resolved"

#: lifecycle order, for monotonicity checks in tests
STATES = (OPEN, CONFIRMED, MITIGATING, RESOLVED)


@dataclass
class Incident:
    id: int
    opened_at: float
    trigger: Optional[Trigger]
    state: str = OPEN
    function: str = ""                  # set at confirmation
    kind: Optional[object] = None
    workers: Tuple[int, ...] = ()       # last implicated worker set
    confirmed_at: Optional[float] = None
    resolved_at: Optional[float] = None
    plans: List[MitigationPlan] = field(default_factory=list)
    #: consecutive windows whose localization did NOT reproduce the
    #: signature (reset on every hit)
    windows_clear: int = 0
    #: (time, state) transition log
    history: List[Tuple[float, str]] = field(default_factory=list)

    def _transition(self, state: str, t: float) -> None:
        self.state = state
        self.history.append((t, state))

    @property
    def active(self) -> bool:
        return self.state != RESOLVED


class IncidentManager:
    """Folds detector triggers/recoveries and per-window localizations into
    a set of distinct incidents."""

    def __init__(self, fleet_size: int, clear_windows: int = 2,
                 confirm_windows: int = 2):
        self.fleet_size = fleet_size
        self.clear_windows = clear_windows
        #: consecutive abnormal windows a TRIGGER-LESS abnormality needs
        #: before it becomes its own incident.  An abnormality matching a
        #: pending trigger confirms immediately (the job-level detector
        #: corroborates it); without that corroboration one window could be
        #: EMA residue draining after a mitigation, not a new fault.
        self.confirm_windows = confirm_windows
        self.incidents: List[Incident] = []
        self._candidates: Dict[str, int] = {}
        self._next_id = 0

    # -- views -------------------------------------------------------------
    @property
    def active(self) -> List[Incident]:
        return [i for i in self.incidents if i.active]

    def by_function(self, function: str) -> Optional[Incident]:
        for inc in self.incidents:
            if inc.active and inc.function == function:
                return inc
        return None

    def _pending(self) -> Optional[Incident]:
        """The unconfirmed OPEN incident holding the latest trigger."""
        for inc in self.incidents:
            if inc.active and inc.state == OPEN:
                return inc
        return None

    # -- detector events ----------------------------------------------------
    def on_trigger(self, trig: Trigger) -> Optional[Incident]:
        """A detector trigger opens at most one incident: while ANY incident
        is active the trigger is a reminder of the ongoing degradation, not
        a new problem (the detector is job-level and cannot tell two
        concurrent faults apart — localization can, and does, below)."""
        if self.active:
            return None
        inc = Incident(id=self._next_id, opened_at=trig.time, trigger=trig)
        inc.history.append((trig.time, OPEN))
        self._next_id += 1
        self.incidents.append(inc)
        return inc

    def on_recovery(self, rec: Recovery) -> List[Incident]:
        """Detector recovery re-arm: the job-level metric is healthy again.
        Every active incident whose signature is currently clear resolves;
        an unconfirmed OPEN incident (trigger never localized) resolves as
        transient."""
        resolved = []
        for inc in self.active:
            if inc.state == OPEN or inc.windows_clear >= 1:
                inc.resolved_at = rec.time
                inc._transition(RESOLVED, rec.time)
                resolved.append(inc)
        return resolved

    # -- per-window localization -------------------------------------------
    def on_window(self, t: float, diagnoses: Sequence[Diagnosis],
                  detector_healthy: bool = False) -> List[Incident]:
        """Fold one profiling window's diagnoses in; returns incidents that
        changed state this window.

        ``detector_healthy`` relaxes resolution to a single clear window:
        when the job-level metric has already recovered, a clean
        localization is confirmation, not coincidence."""
        changed: List[Incident] = []
        hit: Dict[int, bool] = {}
        seen_fns = set()
        for d in diagnoses:
            a: Abnormality = d.abnormality
            seen_fns.add(a.function)
            inc = self.by_function(a.function)
            if inc is None:
                pending = self._pending()
                if pending is not None:
                    inc = pending          # the trigger's culprit, found
                else:
                    # a second fault surfacing while another incident holds
                    # the trigger: distinct function -> distinct incident,
                    # but only after it persists (hysteresis against EMA
                    # residue flapping one window after a mitigation)
                    streak = self._candidates.get(a.function, 0) + 1
                    self._candidates[a.function] = streak
                    if streak < self.confirm_windows:
                        continue
                    inc = Incident(id=self._next_id, opened_at=t,
                                   trigger=None)
                    inc.history.append((t, OPEN))
                    self._next_id += 1
                    self.incidents.append(inc)
                self._candidates.pop(a.function, None)
                inc.function = a.function
                inc.kind = a.kind
            inc.workers = tuple(int(w) for w in a.workers)
            inc.windows_clear = 0
            hit[inc.id] = True
            if inc.state == OPEN:
                inc.confirmed_at = t
                inc._transition(CONFIRMED, t)
                changed.append(inc)
            elif inc.state == CONFIRMED:
                inc.plans = plan_mitigations([d], self.fleet_size)
                inc._transition(MITIGATING, t)
                changed.append(inc)
        # candidate streaks break the first window their function is clean
        self._candidates = {f: c for f, c in self._candidates.items()
                            if f in seen_fns}
        need_clear = 1 if detector_healthy else self.clear_windows
        for inc in self.active:
            if hit.get(inc.id) or inc.state == OPEN:
                continue
            inc.windows_clear += 1
            if inc.windows_clear >= need_clear:
                inc.resolved_at = t
                inc._transition(RESOLVED, t)
                changed.append(inc)
        return changed

    # -- reporting ----------------------------------------------------------
    def timeline(self) -> str:
        lines = []
        for inc in self.incidents:
            head = (f"incident #{inc.id} [{inc.state}] "
                    f"{inc.function or '<unlocalized>'} "
                    f"workers={list(inc.workers)}")
            lines.append(head)
            for t, st in inc.history:
                lines.append(f"    t={t:9.2f}s  -> {st}")
        return "\n".join(lines) if lines else "no incidents"
