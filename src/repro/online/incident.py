"""Incident lifecycle for the online pipeline (DESIGN.md §7, §9).

An *incident* is one performance problem with a lifecycle:

    open ──▶ confirmed ──▶ mitigating ──▶ verifying ──▶ resolved
                                              │
                                              └──▶ escalated

  * ``open``       — the detector fired a Trigger (anchor-level degradation)
    but localization has not yet named a culprit function;
  * ``confirmed``  — a profiling window's localization produced an
    ``Abnormality`` matching this incident (the incident's identity is its
    abnormal *function*, which is what keeps overlapping faults distinct);
  * ``mitigating`` — the abnormality persisted into a further window and a
    RANKED mitigation ladder (``repro.core.mitigation.plan_ladder``) is
    attached;
  * ``verifying``  — a ``MitigationEngine`` applied the current rung's plan
    and the next ``verify_windows`` profiling windows must show the
    signature clear.  A hit after ``settle_windows`` of EMA grace means the
    plan did not work: the manager escalates to the next rung (the engine
    applies it; the state STAYS ``verifying`` so the lifecycle only ever
    moves forward), bounded by ``max_escalations``;
  * ``resolved``   — the signature stayed clear for ``verify_windows``
    consecutive windows (one window suffices when the job-level detector
    has already recovered), or — for incidents nobody executes plans for —
    the legacy ``clear_windows`` / detector-recovery paths;
  * ``escalated``  — the ladder ran dry or ``max_escalations`` was spent
    with the signature still live: terminal, a human owns it now.  An
    escalated incident is NEVER silently resolved, and its function is
    suppressed from opening fresh incidents until the signature has
    actually been clear for ``clear_windows`` (so a later reappearance is
    a genuine recurrence, not the same live fault).

Recurrence linking: when a new incident confirms with the signature
(function + worker set) of a prior terminal incident, it carries
``recurrence_of`` = that incident's id instead of being treated as novel.

One detector trigger never spawns more than one incident — reminder
triggers (``rearm_cooldown``) and additional abnormal functions fold into
the open incident set instead.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import channels
from repro.core.detector import Recovery, Trigger
from repro.core.localizer import Abnormality
from repro.core.mitigation import MitigationPlan, plan_ladder
from repro.core.report import Diagnosis

OPEN = "open"
CONFIRMED = "confirmed"
MITIGATING = "mitigating"
VERIFYING = "verifying"
RESOLVED = "resolved"
ESCALATED = "escalated"

#: lifecycle order, for monotonicity checks in tests (resolved/escalated
#: are alternative terminals; an incident reaches at most one of them)
STATES = (OPEN, CONFIRMED, MITIGATING, VERIFYING, RESOLVED, ESCALATED)

#: terminal states
TERMINAL = (RESOLVED, ESCALATED)


@dataclass
class Incident:
    id: int
    opened_at: float
    trigger: Optional[Trigger]
    state: str = OPEN
    #: detector channel this incident lives on (a registered
    #: ``repro.core.channels`` name) — part of the incident's identity
    #: alongside ``function``: a numerics incident and a perf incident are
    #: distinct problems even when their function names collide, and are
    #: never recurrence-linked
    channel: str = channels.PERF
    function: str = ""                  # set at confirmation
    kind: Optional[object] = None
    workers: Tuple[int, ...] = ()       # last implicated worker set
    #: union of every worker set this incident implicated over its life —
    #: the persistence signature survives a re-mesh moving the fault
    workers_seen: Tuple[int, ...] = ()
    #: the attached ladder was re-ranked from persisted outcomes: rung 0
    #: is the action that cured this signature in a previous run
    chronic: bool = False
    confirmed_at: Optional[float] = None
    resolved_at: Optional[float] = None
    escalated_at: Optional[float] = None
    #: ranked mitigation ladder (rung 0 first); ``rung`` is the current one
    plans: List[MitigationPlan] = field(default_factory=list)
    rung: int = 0
    #: (time, plan) log of every plan actually executed
    applied: List[Tuple[float, MitigationPlan]] = field(default_factory=list)
    #: rung switches after failed verification
    escalations: int = 0
    #: windows observed since the current rung was applied (None = the
    #: current rung has not been applied yet)
    windows_since_apply: Optional[int] = None
    #: id of the prior terminal incident this one is a recurrence of
    recurrence_of: Optional[int] = None
    #: consecutive windows whose localization did NOT reproduce the
    #: signature (reset on every hit)
    windows_clear: int = 0
    #: (time, state) transition log
    history: List[Tuple[float, str]] = field(default_factory=list)

    def __post_init__(self):
        channels.validate_channel(self.channel)

    def _transition(self, state: str, t: float) -> None:
        self.state = state
        self.history.append((t, state))

    @property
    def active(self) -> bool:
        return self.state not in TERMINAL

    @property
    def pending_plan(self) -> Optional[MitigationPlan]:
        """The ladder rung awaiting execution by a MitigationEngine, or
        None (nothing attached / current rung already applied and under
        verification / ladder exhausted)."""
        if self.state not in (MITIGATING, VERIFYING):
            return None
        if self.windows_since_apply is not None:
            return None
        if self.rung >= len(self.plans):
            return None
        return self.plans[self.rung]

    def mark_applied(self, plan: MitigationPlan, t: float) -> None:
        """Record that an engine executed ``plan``; verification of the
        next windows starts now."""
        self.applied.append((t, plan))
        self.windows_since_apply = 0
        if self.state == MITIGATING:
            self._transition(VERIFYING, t)


class IncidentManager:
    """Folds detector triggers/recoveries and per-window localizations into
    a set of distinct incidents."""

    def __init__(self, fleet_size: int, clear_windows: int = 2,
                 confirm_windows: int = 2, verify_windows: int = 2,
                 max_escalations: int = 2, settle_windows: int = 1,
                 history=None):
        self.fleet_size = fleet_size
        #: optional ``repro.online.history.IncidentHistory``: terminal
        #: incidents are recorded, and freshly-attached ladders re-rank
        #: from persisted outcomes (chronic-fault memory)
        self.history = history
        self.clear_windows = clear_windows
        #: consecutive abnormal windows a TRIGGER-LESS abnormality needs
        #: before it becomes its own incident.  An abnormality matching a
        #: pending trigger confirms immediately (the job-level detector
        #: corroborates it); without that corroboration one window could be
        #: EMA residue draining after a mitigation, not a new fault.
        self.confirm_windows = confirm_windows
        #: clear windows an applied plan needs before its incident resolves
        self.verify_windows = verify_windows
        #: rung switches allowed before the incident escalates to a human
        self.max_escalations = max_escalations
        #: post-application grace windows where a hit is EMA residue, not
        #: proof the plan failed
        self.settle_windows = settle_windows
        self.incidents: List[Incident] = []
        #: (channel, function) -> consecutive abnormal-window streak
        self._candidates: Dict[Tuple[str, str], int] = {}
        #: (channel, function) of live ESCALATED incidents -> consecutive
        #: clear windows since escalation; a fresh incident for the
        #: signature is suppressed until it has genuinely cleared once
        self._suppressed: Dict[Tuple[str, str], int] = {}
        self._next_id = 0

    # -- views -------------------------------------------------------------
    @property
    def active(self) -> List[Incident]:
        return [i for i in self.incidents if i.active]

    def by_function(self, function: str, channel: str = channels.PERF
                    ) -> Optional[Incident]:
        for inc in self.incidents:
            if inc.active and inc.function == function \
                    and inc.channel == channel:
                return inc
        return None

    def _pending(self, channel: str = channels.PERF
                 ) -> Optional[Incident]:
        """The unconfirmed OPEN incident holding the latest trigger on
        this channel."""
        for inc in self.incidents:
            if inc.active and inc.state == OPEN \
                    and inc.channel == channel:
                return inc
        return None

    # -- detector events ----------------------------------------------------
    def on_trigger(self, trig: Trigger) -> Optional[Incident]:
        """A detector trigger opens at most one incident PER CHANNEL: while
        an incident is active on the trigger's channel the trigger is a
        reminder of the ongoing degradation, not a new problem (each
        detector is job-level and cannot tell two concurrent faults apart —
        localization can, and does, below).  A numerics trigger during an
        open perf incident IS a new problem: the channels are independent
        sensors."""
        channel = channels.channel_of(trig)
        if any(i.channel == channel for i in self.active):
            return None
        inc = Incident(id=self._next_id, opened_at=trig.time, trigger=trig,
                       channel=channel)
        inc.history.append((trig.time, OPEN))
        self._next_id += 1
        self.incidents.append(inc)
        return inc

    def on_recovery(self, rec: Recovery) -> List[Incident]:
        """Detector recovery re-arm: the job-level metric on the recovery's
        channel is healthy again.  Every active incident ON THAT CHANNEL
        whose signature is currently clear resolves; an unconfirmed OPEN
        incident (trigger never localized) resolves as transient."""
        channel = channels.channel_of(rec)
        resolved = []
        for inc in self.active:
            if inc.channel != channel:
                continue
            if inc.state == OPEN or inc.windows_clear >= 1:
                inc.resolved_at = rec.time
                inc._transition(RESOLVED, rec.time)
                self._record_history(inc)
                resolved.append(inc)
        return resolved

    # -- per-window localization -------------------------------------------
    def on_window(self, t: float, diagnoses: Sequence[Diagnosis],
                  detector_healthy: bool = False) -> List[Incident]:
        """Fold one profiling window's diagnoses in; returns incidents that
        changed state this window.

        ``detector_healthy`` relaxes resolution to a single clear window:
        when the job-level metric has already recovered, a clean
        localization is confirmation, not coincidence."""
        changed: List[Incident] = []
        hit: Dict[int, bool] = {}
        seen_fns = set()
        # verification clocks tick first: "windows since apply" counts the
        # windows OBSERVED after the application tick
        for inc in self.active:
            if inc.windows_since_apply is not None:
                inc.windows_since_apply += 1
        for d in diagnoses:
            a: Abnormality = d.abnormality
            ch = channels.channel_of(a)
            sig = (ch, a.function)
            seen_fns.add(sig)
            if sig in self._suppressed:
                # the escalated incident's fault is still live: a human
                # owns it, no fresh incident flaps underneath them
                self._suppressed[sig] = 0
                continue
            inc = self.by_function(a.function, ch)
            if inc is None:
                pending = self._pending(ch)
                if pending is not None:
                    inc = pending          # the trigger's culprit, found
                else:
                    # a second fault surfacing while another incident holds
                    # the trigger: distinct function -> distinct incident,
                    # but only after it persists (hysteresis against EMA
                    # residue flapping one window after a mitigation)
                    streak = self._candidates.get(sig, 0) + 1
                    self._candidates[sig] = streak
                    if streak < self.confirm_windows:
                        continue
                    inc = Incident(id=self._next_id, opened_at=t,
                                   trigger=None, channel=ch)
                    inc.history.append((t, OPEN))
                    self._next_id += 1
                    self.incidents.append(inc)
                self._candidates.pop(sig, None)
                inc.function = a.function
                inc.kind = a.kind
                self._link_recurrence(inc, a)
            inc.workers = tuple(int(w) for w in a.workers)
            inc.workers_seen = tuple(sorted(
                set(inc.workers_seen) | set(inc.workers)))
            inc.windows_clear = 0
            hit[inc.id] = True
            if inc.state == OPEN:
                inc.confirmed_at = t
                inc._transition(CONFIRMED, t)
                changed.append(inc)
            elif inc.state == CONFIRMED:
                inc.plans = plan_ladder(d, self.fleet_size)
                if self.history is not None:
                    inc.plans, inc.chronic = self.history.rerank(
                        inc.plans, inc.channel, inc.function,
                        inc.workers_seen)
                inc._transition(MITIGATING, t)
                changed.append(inc)
            elif inc.state == VERIFYING \
                    and inc.windows_since_apply is not None \
                    and inc.windows_since_apply > self.settle_windows:
                # the signature survived the applied plan past the EMA
                # grace: verification failed
                self._escalate(inc, t)
                changed.append(inc)
        # candidate streaks break the first window their signature is clean
        self._candidates = {s: c for s, c in self._candidates.items()
                            if s in seen_fns}
        # escalated-signature suppression lifts once it has been genuinely
        # clear (its NEXT appearance is a recurrence)
        for s in list(self._suppressed):
            if s not in seen_fns:
                self._suppressed[s] += 1
                if self._suppressed[s] >= self.clear_windows:
                    del self._suppressed[s]
        need_clear = 1 if detector_healthy else self.clear_windows
        for inc in self.active:
            if hit.get(inc.id) or inc.state == OPEN:
                continue
            inc.windows_clear += 1
            if inc.state == VERIFYING:
                need = 1 if detector_healthy else self.verify_windows
                if inc.windows_since_apply is None \
                        or inc.windows_clear < need:
                    continue
            elif inc.windows_clear < need_clear:
                continue
            inc.resolved_at = t
            inc._transition(RESOLVED, t)
            self._record_history(inc)
            changed.append(inc)
        return changed

    def _escalate(self, inc: Incident, t: float) -> None:
        """Verification of the current rung failed: move to the next rung,
        or hand the incident to a human when the ladder/budget is spent."""
        inc.escalations += 1
        inc.windows_since_apply = None
        inc.windows_clear = 0
        if inc.rung + 1 >= len(inc.plans) \
                or inc.escalations > self.max_escalations:
            inc.escalated_at = t
            inc._transition(ESCALATED, t)
            self._suppressed[(inc.channel, inc.function)] = 0
            self._record_history(inc)
        else:
            inc.rung += 1

    def _record_history(self, inc: Incident) -> None:
        """Persist a terminal incident's signature + ladder outcome to the
        chronic-fault store (no-op without one, or for incidents that
        never localized a function)."""
        if self.history is None or not inc.function:
            return
        n = len(inc.applied)
        attempts = [{"action": plan.action.value, "rung": k,
                     "ok": inc.state == RESOLVED and k == n - 1}
                    for k, (_, plan) in enumerate(inc.applied)]
        self.history.record(inc.channel, inc.function,
                            inc.workers_seen, inc.state, attempts)

    def _link_recurrence(self, inc: Incident, a: Abnormality) -> None:
        """Link a freshly-confirmed incident to the most recent terminal
        incident sharing its signature (channel + function + overlapping
        worker set).  The channel check is what keeps a numerics incident
        from linking to a prior PERF incident on the same function."""
        sig = {int(w) for w in a.workers}
        for prior in reversed(self.incidents):
            if prior is inc or prior.active \
                    or prior.function != inc.function \
                    or prior.channel != inc.channel:
                continue
            pw = set(prior.workers)
            if pw == sig or (pw & sig):
                inc.recurrence_of = prior.id
                return

    # -- reporting ----------------------------------------------------------
    def timeline(self) -> str:
        lines = []
        for inc in self.incidents:
            head = (f"incident #{inc.id} [{inc.state}] "
                    f"{inc.function or '<unlocalized>'} "
                    f"workers={list(inc.workers)}")
            if inc.recurrence_of is not None:
                head += f" recurrence_of=#{inc.recurrence_of}"
            if inc.escalations:
                head += f" escalations={inc.escalations}"
            lines.append(head)
            entries = [(t, 0, f"-> {st}") for t, st in inc.history]
            entries += [(t, 1, f"applied {p.action.value}"
                         + (f" workers={p.workers}" if p.workers else ""))
                        for t, p in inc.applied]
            for t, _, msg in sorted(entries, key=lambda e: (e[0], e[1])):
                lines.append(f"    t={t:9.2f}s  {msg}")
        return "\n".join(lines) if lines else "no incidents"
