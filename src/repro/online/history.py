"""Chronic-fault memory: incident signatures persisted to disk
(DESIGN.md §14).

Large jobs restart; faults do not.  Every terminal incident writes one
JSONL record — its signature (detector channel + abnormal function + the
union of worker sets it implicated over its life) plus the ladder outcome
(which actions were applied, at which rung, and which one actually
cured) — to an append-only store.  A restarted job loads the store and,
when a fresh incident confirms with a known signature, ``rerank`` reorders
its plan ladder so the rung that worked last time runs FIRST and rungs
that are known failures sink: the job skips re-learning the same lesson
at the price of another failed verification cycle.

The store is deliberately dumb: newline-delimited JSON, tolerant of a
torn final line (a crashed writer), no locking (one writer per incident
manager).  Matching is signature overlap — same channel, same function,
and an overlapping worker set (or either side job-level/empty), the same
rule recurrence linking uses — so a fault that followed its ranks onto
replacement hosts still matches its pre-replacement signature.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple


class IncidentHistory:
    """Append-only JSONL store of terminal-incident outcomes."""

    def __init__(self, path):
        self.path = Path(path)
        self.records: List[dict] = []
        if self.path.exists():
            for line in self.path.read_text().splitlines():
                line = line.strip()
                if not line:
                    continue
                try:
                    self.records.append(json.loads(line))
                except ValueError:
                    continue          # torn final line from a crashed writer

    # -- writing -------------------------------------------------------------
    def record(self, channel: str, function: str, workers: Sequence[int],
               outcome: str, attempts: Sequence[Dict]) -> dict:
        """Persist one terminal incident.  ``attempts`` is the applied
        ladder in order: ``{"action": str, "rung": int, "ok": bool}`` —
        ``ok`` marks the action that actually cured (the last applied one
        of a resolved incident)."""
        rec = {"channel": str(channel), "function": str(function),
               "workers": sorted(int(w) for w in set(workers)),
               "outcome": str(outcome),
               "attempts": [{"action": str(a["action"]),
                             "rung": int(a["rung"]),
                             "ok": bool(a["ok"])} for a in attempts]}
        self.records.append(rec)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        with self.path.open("a") as f:
            f.write(json.dumps(rec) + "\n")
        return rec

    # -- matching ------------------------------------------------------------
    def _matching(self, channel: str, function: str,
                  workers: Sequence[int]) -> List[dict]:
        ws = {int(w) for w in workers}
        out = []
        for r in self.records:
            if r.get("channel") != channel or r.get("function") != function:
                continue
            rw = set(r.get("workers", []))
            if not ws or not rw or (ws & rw):
                out.append(r)
        return out

    def successful_action(self, channel: str, function: str,
                          workers: Sequence[int]) -> Optional[str]:
        """The action that most recently cured this signature, or None."""
        for r in reversed(self._matching(channel, function, workers)):
            if r.get("outcome") != "resolved":
                continue
            for a in reversed(r.get("attempts", [])):
                if a.get("ok"):
                    return a["action"]
        return None

    def action_stats(self, channel: str, function: str,
                     workers: Sequence[int]) -> Dict[str, Tuple[int, int]]:
        """action -> (successes, failures) over matching records."""
        stats: Dict[str, List[int]] = {}
        for r in self._matching(channel, function, workers):
            for a in r.get("attempts", []):
                s = stats.setdefault(a["action"], [0, 0])
                s[0 if a.get("ok") else 1] += 1
        return {k: (v[0], v[1]) for k, v in stats.items()}

    def rerank(self, plans: List, channel: str, function: str,
               workers: Sequence[int]) -> Tuple[List, bool]:
        """Reorder a plan ladder from recorded outcomes: actions with
        recorded successes float to the front (the restarted job starts at
        the rung that worked last time), known-failed actions sink, and
        unknowns keep their planner order.  Returns ``(plans, chronic)``
        where ``chronic`` flags a recognized signature with a previously
        successful action now at rung 0."""
        stats = self.action_stats(channel, function, workers)
        if not stats:
            return plans, False
        winner = self.successful_action(channel, function, workers)

        def key(ip):
            i, p = ip
            succ, fail = stats.get(p.action.value, (0, 0))
            return (-succ, fail if not succ else 0, i)

        ranked = [p for _, p in sorted(enumerate(plans), key=key)]
        chronic = (winner is not None and bool(ranked)
                   and ranked[0].action.value == winner)
        return ranked, chronic
