"""The gated fault-scenario catalog (DESIGN.md §12): every troubleshooting
ability the repo claims, declared as DATA.

A catalog entry is a fault schedule plus the incidents the closed
act -> verify -> escalate loop is expected to produce — nothing else.  The
diagnosis path (detector -> localizer -> report -> plan ladder -> engine)
contains no knowledge of any scenario: adding a fault class means adding a
fault model + its pattern signature + a playbook rule, then DECLARING the
scenario here.  ``tests/test_catalog.py`` enforces the invariant by
grepping the diagnosis-path modules for scenario names.

Five fault classes (the class is metadata for reporting, not dispatch):

  * ``perf``        — the six original paper cases (C1P1, C1P2, §3 ring,
    C2P1, C2P2, C2P3);
  * ``numerics``    — loss spikes / gradient-norm explosions on the
    numerics channel, cured by ``ROLLBACK_TO_CHECKPOINT``;
  * ``host``        — cross-layer OS faults fused with GPU profiles
    (cgroup CPU quota, page-cache thrash);
  * ``environment`` — bad-host environments (driver/kernel mismatch,
    degraded NIC), including the BAD-STANDBY family: ``replace_hosts``
    lands on a poisoned standby, verification fails honestly, and the
    incident must ESCALATE — a green "resolved" there would be a lie;
  * ``serve``       — latency-SLO violations under the simulator's serve
    workload shape (DESIGN.md §13): the ``slo`` detector channel opens
    the incident, localization runs over the serve profiles, and the
    serving playbook (``repro.serve.playbook``) plans ``SHED_LOAD`` /
    ``DRAIN_AND_REPLACE`` ladders.

Every scenario runs under one standard deployment shape (``run_scenario``)
with mitigation closed-loop; ``evaluate`` scores the outcome against the
declared expectations.  The matrix is deterministic (seeded simulator,
fixed schedules), so CI gates per-class windows-to-resolution ceilings and
the escalate-honestly flags (benchmarks/ability_matrix.py +
benchmarks/baselines.json).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import repro.serve.playbook  # noqa: F401  (registers the slo ladder rules)
from repro.core import faults as F
from repro.core.mitigation import Action
from repro.core.simulation import (ALLGATHER, DATALOADER_STACK, DECODE_GEMM,
                                   FORWARD_STACK, GC_STACK, GEMM, KV_FETCH,
                                   SERVE_QUEUE_STACK, SimConfig, TOKEN_SYNC)
from repro.online.escalation import EscalationPolicy
from repro.online.scenario import (ScenarioResult, ScenarioRunner,
                                   ScheduledFault)

#: the standard catalog deployment shape (mirrors benchmarks/mitigation_loop)
W = 24
N_STANDBY = 4
WINDOW_S = 1.0
BASE_HZ, FULL_HZ = 250.0, 2000.0
SEED = 5
INJECT = 2                    # faults switch on at window 2
N_WINDOWS = 12

#: the numerics channel's synthesized function names
#: (``OnlinePipeline._finish_tick``)
LOSS_FN = "numerics.loss"
GRAD_FN = "numerics.grad_norm"

FAULT_CLASSES = ("perf", "numerics", "host", "environment", "serve")


@dataclass(frozen=True)
class ExpectedIncident:
    """One incident the closed loop must produce for a scenario."""
    function: str
    channel: str = "perf"
    #: first plan the engine must execute for it (None = don't care)
    first_action: Optional[Action] = None
    #: terminal state the incident must reach: "resolved" incidents must
    #: get there with ZERO escalations; "escalated" incidents must NOT be
    #: reported resolved (the honest-failure family)
    outcome: str = "resolved"


@dataclass(frozen=True)
class Scenario:
    """One catalog entry: a schedule plus its expected incidents."""
    name: str
    fault_class: str              # one of FAULT_CLASSES
    schedule: Tuple[ScheduledFault, ...]
    expect: Tuple[ExpectedIncident, ...]
    n_windows: int = N_WINDOWS
    #: which simulator workload shape the scenario runs under ("train"
    #: iterations or "serve" continuous-batched decode, DESIGN.md §13)
    workload: str = "train"


def _never_removed(fault: F.Fault, n_windows: int = N_WINDOWS,
                   start: int = INJECT) -> ScheduledFault:
    """A fault only a mitigation can clear (active through the last window)."""
    return ScheduledFault(fault, start, n_windows)


SCENARIOS: Tuple[Scenario, ...] = (
    # -- perf: the six original paper cases --------------------------------
    Scenario(
        "C1P1_gpu_throttle", "perf",
        (_never_removed(F.GpuThrottle(workers=(3, W // 2 + 1))),),
        (ExpectedIncident(GEMM, first_action=Action.REPLACE_HOSTS),)),
    Scenario(
        "C1P2_nvlink_down", "perf",
        (_never_removed(F.NvlinkDown(workers=(5,), group_size=8)),),
        (ExpectedIncident(ALLGATHER, first_action=Action.REPLACE_HOSTS),)),
    Scenario(
        "S3_ring_slow_link", "perf",
        (_never_removed(F.RingSlowLink(slow_worker=9, rho=0.4)),),
        (ExpectedIncident(ALLGATHER, first_action=Action.REPLACE_HOSTS),)),
    Scenario(
        "C2P1_slow_dataloader", "perf",
        (_never_removed(F.SlowDataloader()),),
        (ExpectedIncident(DATALOADER_STACK,
                          first_action=Action.MIGRATE_DATALOADER),)),
    Scenario(
        "C2P2_cpu_forward", "perf",
        (_never_removed(F.CpuBoundForward(workers=tuple(range(6)))),),
        (ExpectedIncident(FORWARD_STACK, first_action=Action.FLAG_CODE),)),
    Scenario(
        "C2P3_async_gc", "perf",
        (_never_removed(F.AsyncGc(probability=0.5, pause_s=0.25)),),
        (ExpectedIncident(GC_STACK, first_action=Action.SYNCHRONIZE_GC),)),

    # -- numerics: divergence signatures, rollback-shaped plans ------------
    Scenario(
        "N1_loss_spike", "numerics",
        (_never_removed(F.LossSpike()),),
        (ExpectedIncident(LOSS_FN, channel="numerics",
                          first_action=Action.ROLLBACK_TO_CHECKPOINT),)),
    Scenario(
        "N2_grad_explosion", "numerics",
        (_never_removed(F.GradExplosion()),),
        (ExpectedIncident(GRAD_FN, channel="numerics",
                          first_action=Action.ROLLBACK_TO_CHECKPOINT),)),
    Scenario(
        "N3_grad_norm_nan", "numerics",
        (_never_removed(F.GradExplosion(nan=True)),),
        (ExpectedIncident(GRAD_FN, channel="numerics",
                          first_action=Action.ROLLBACK_TO_CHECKPOINT),)),
    Scenario(
        # a loss spike UNDER an open perf incident: the channels are
        # independent sensors, both incidents must run to resolution
        "N4_loss_spike_under_perf", "numerics",
        (_never_removed(F.GpuThrottle(workers=(3, W // 2 + 1)),
                        n_windows=14),
         _never_removed(F.LossSpike(), n_windows=14)),
        (ExpectedIncident(GEMM, first_action=Action.REPLACE_HOSTS),
         ExpectedIncident(LOSS_FN, channel="numerics",
                          first_action=Action.ROLLBACK_TO_CHECKPOINT)),
        n_windows=14),

    # -- host: cross-layer OS faults fused with GPU profiles ---------------
    Scenario(
        "H1_cgroup_cpu_throttle", "host",
        (_never_removed(F.CgroupCpuThrottle(workers=(7, 19))),),
        (ExpectedIncident(FORWARD_STACK,
                          first_action=Action.REPLACE_HOSTS),)),
    Scenario(
        "H2_page_cache_thrash", "host",
        (_never_removed(F.PageCacheThrash(workers=(2, 9))),),
        (ExpectedIncident(DATALOADER_STACK,
                          first_action=Action.REPLACE_HOSTS),)),
    Scenario(
        # fleet-wide thrash reads as slow shared storage, not sick hosts:
        # the playbook must migrate the dataloader, not replace 24 hosts
        "H3_page_cache_fleetwide", "host",
        (_never_removed(F.PageCacheThrash(workers=())),),
        (ExpectedIncident(DATALOADER_STACK,
                          first_action=Action.MIGRATE_DATALOADER),)),

    # -- environment: bad-host environments + the bad-standby family -------
    Scenario(
        "E1_driver_mismatch", "environment",
        (_never_removed(F.DriverMismatch(workers=(3, 11))),),
        (ExpectedIncident(GEMM, first_action=Action.REPLACE_HOSTS),)),
    Scenario(
        "E2_degraded_nic", "environment",
        (_never_removed(F.DegradedNic(workers=(9,))),),
        (ExpectedIncident(ALLGATHER, first_action=Action.REPLACE_HOSTS),)),
    Scenario(
        # replace_hosts lands on standby W (first in the pool), whose
        # driver stack is bad: verification must FAIL and the incident
        # must escalate to a human — never report a poisoned fleet healthy
        "E3_bad_standby_driver", "environment",
        (_never_removed(F.GpuThrottle(workers=(3, W // 2 + 1)),
                        n_windows=14),
         ScheduledFault(F.DriverMismatch(workers=(W,)), 0, 14)),
        (ExpectedIncident(GEMM, first_action=Action.REPLACE_HOSTS,
                          outcome="escalated"),),
        n_windows=14),
    Scenario(
        "E4_bad_standby_nic", "environment",
        (_never_removed(F.NvlinkDown(workers=(5,), group_size=8),
                        n_windows=14),
         ScheduledFault(F.DegradedNic(workers=(W,)), 0, 14)),
        (ExpectedIncident(ALLGATHER, first_action=Action.REPLACE_HOSTS,
                          outcome="escalated"),),
        n_windows=14),

    # -- serve: latency-SLO incidents under the serve workload shape -------
    Scenario(
        # one serving host's decode GPU throttled: p99 TBT blows the SLO,
        # localization pins the decode GEMMs to that host, the serving
        # playbook drains + replaces it
        "SV1_hot_worker_decode", "serve",
        (ScheduledFault(F.GpuThrottle(workers=(4,), slowdown=3.0),
                        INJECT, N_WINDOWS,
                        cures=(Action.DRAIN_AND_REPLACE,)),),
        (ExpectedIncident(DECODE_GEMM, channel="slo",
                          first_action=Action.DRAIN_AND_REPLACE),),
        workload="serve"),
    Scenario(
        # sustained arrival burst: TTFT explodes fleet-wide while decode
        # stays healthy; queue buildup is cured by shedding load, never by
        # replacing hosts
        "SV2_arrival_burst", "serve",
        (_never_removed(F.ArrivalBurst()),),
        (ExpectedIncident(SERVE_QUEUE_STACK, channel="slo",
                          first_action=Action.SHED_LOAD),),
        workload="serve"),
    Scenario(
        # KV working set exceeds device memory: every decode step's block
        # reads go to the fetch path, TBT blows the SLO fleet-wide
        "SV3_kv_cache_thrash", "serve",
        (_never_removed(F.KvCacheThrash()),),
        (ExpectedIncident(KV_FETCH, channel="slo",
                          first_action=Action.SHED_LOAD),),
        workload="serve"),
    Scenario(
        # degraded NIC on one serving host: its token-path collectives
        # collapse, stretching time-between-tokens; drain + replace
        "SV4_degraded_nic_serve", "serve",
        (ScheduledFault(F.DegradedNic(workers=(9,)), INJECT, N_WINDOWS,
                        cures=(Action.DRAIN_AND_REPLACE,)),),
        (ExpectedIncident(TOKEN_SYNC, channel="slo",
                          first_action=Action.DRAIN_AND_REPLACE),),
        workload="serve"),
    Scenario(
        # an arrival burst lands while one host's decode GPU is already
        # hot: two independent slo incidents, two different cures, both
        # must resolve
        "SV5_burst_under_hot_worker", "serve",
        (ScheduledFault(F.GpuThrottle(workers=(4,), slowdown=3.0),
                        INJECT, 14, cures=(Action.DRAIN_AND_REPLACE,)),
         _never_removed(F.ArrivalBurst(), n_windows=14)),
        (ExpectedIncident(DECODE_GEMM, channel="slo",
                          first_action=Action.DRAIN_AND_REPLACE),
         ExpectedIncident(SERVE_QUEUE_STACK, channel="slo",
                          first_action=Action.SHED_LOAD)),
        n_windows=14, workload="serve"),
)


def by_name(name: str) -> Scenario:
    for sc in SCENARIOS:
        if sc.name == name:
            return sc
    raise KeyError(f"unknown scenario {name!r} "
                   f"(known: {', '.join(s.name for s in SCENARIOS)})")


def run_scenario(sc: Scenario, verbose: bool = False, history=None
                 ) -> Tuple[ScenarioRunner, ScenarioResult]:
    """Run one catalog scenario under the standard deployment shape with
    the mitigation loop closed; returns (runner, result).  ``history``
    optionally threads a chronic-fault store through the run (a restarted
    job re-ranking its ladders from persisted outcomes)."""
    esc = EscalationPolicy(n_workers=W + N_STANDBY, base_rate_hz=BASE_HZ,
                           full_rate_hz=FULL_HZ,
                           max_escalated=max(4, W // 16))
    runner = ScenarioRunner(
        SimConfig(n_workers=W, window_s=WINDOW_S, rate_hz=FULL_HZ,
                  seed=SEED, n_standby=N_STANDBY, workload=sc.workload),
        list(sc.schedule), n_windows=sc.n_windows,
        escalation=esc, mitigation=True, history=history)
    return runner, runner.run(verbose=verbose)


def evaluate(sc: Scenario, runner: ScenarioRunner,
             result: ScenarioResult) -> List[Dict]:
    """Score a scenario run against its declared expectations.

    One row per ``ExpectedIncident``: ``ok`` is the gate, ``wtr`` the
    windows from first plan application to resolution (None when the
    expectation is an escalation, or when the run missed it)."""
    rows: List[Dict] = []
    for exp in sc.expect:
        inc = next((i for i in result.incidents
                    if i.function == exp.function
                    and i.channel == exp.channel), None)
        mine = ([m for m in runner.engine.log if m.incident_id == inc.id]
                if inc is not None and runner.engine is not None else [])
        first = mine[0].plan.action if mine else None
        resolved = inc is not None and inc.state == "resolved"
        escalated = inc is not None and inc.state == "escalated"
        wtr: Optional[int] = None
        if exp.outcome == "resolved":
            ok = (resolved and inc.escalations == 0
                  and (exp.first_action is None
                       or first is exp.first_action))
            if ok:
                wtr = result.window_of(inc.resolved_at) - mine[0].window
        else:
            ok = (escalated and not resolved
                  and (exp.first_action is None
                       or first is exp.first_action))
        rows.append({
            "scenario": sc.name, "fault_class": sc.fault_class,
            "function": exp.function, "channel": exp.channel,
            "resolved": resolved, "escalated": escalated,
            "first_action": first.value if first else None,
            "escalations": inc.escalations if inc else -1,
            "wtr": wtr, "ok": ok,
        })
    return rows
