"""Decaying cross-window pattern aggregation (DESIGN.md §7).

One profiling window's ``PatternAggregator`` holds a columnar ``(W, F, 3)``
block of behavior patterns.  A single window is noisy — especially under
differential escalation, where most of the fleet samples at the cheap base
rate — so the online pipeline folds consecutive windows into an exponential
moving average over the same columnar layout:

    ema[:, f] = alpha * new[:, f] + (1 - alpha) * ema[:, f]

Semantics per column (function):

  * first appearance       — the column initializes at the new block's value
    (no zero-bias: a function discovered mid-run starts at its observed
    pattern instead of ramping up from 0);
  * present this window    — standard EMA fold;
  * absent this window     — the column decays toward zero (``new = 0``:
    the function left every worker's critical path, and its beta share
    should fade at the same rate fresh evidence accrues).

Diagnoses therefore *sharpen* across consecutive windows of one incident
instead of restarting from scratch, and fault signatures drain away within
a few windows of mitigation — which is what lets the incident manager
resolve on signature-clear (``repro.online.incident``).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.events import Kind
from repro.summarize.aggregate import PatternAggregator


class EmaPatternAggregator:
    """Cross-window EMA over ``PatternAggregator`` columnar blocks.

    The worker axis is fixed (one row per fleet worker); the function axis
    grows as new functions are interned, exactly like the per-window
    aggregator it decays over.
    """

    def __init__(self, n_workers: int, alpha: float = 0.6,
                 expected_functions: int = 32):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.n_workers = int(n_workers)
        self.alpha = float(alpha)
        self._names: List[str] = []
        self._col: Dict[str, int] = {}
        self._kinds: Dict[str, Kind] = {}
        self._buf = np.zeros((self.n_workers, max(1, expected_functions), 3),
                             np.float32)
        #: per (worker, column): has this ROW ever folded present evidence
        #: for the column?  Per-row (not per-column) so a worker whose
        #: upload was dropped when a column first appeared still gets the
        #: first-seen-full-value treatment on its own first evidence,
        #: instead of an alpha-scaled ramp from the zero it never reported
        self._seen = np.zeros((self.n_workers, max(1, expected_functions)),
                              bool)
        self.n_windows = 0

    # -- growth (function axis only) ---------------------------------------
    def _intern(self, name: str, kind: Kind) -> int:
        j = self._col.get(name)
        if j is None:
            j = len(self._names)
            F_cap = self._buf.shape[1]
            if j >= F_cap:
                grown = np.zeros((self.n_workers, 2 * F_cap, 3), np.float32)
                grown[:, :F_cap] = self._buf
                self._buf = grown
                seen = np.zeros((self.n_workers, 2 * F_cap), bool)
                seen[:, :F_cap] = self._seen
                self._seen = seen
            self._col[name] = j
            self._names.append(name)
        if name not in self._kinds and kind is not None:
            self._kinds[name] = kind
        return j

    # -- folding -----------------------------------------------------------
    def fold(self, agg: PatternAggregator,
             present: Optional[np.ndarray] = None) -> "EmaPatternAggregator":
        """Fold one finished window's aggregator into the EMA state.

        ``present`` (bool mask, length W) marks the workers whose evidence
        actually arrived this window — the wire transport's partial-window
        semantics (DESIGN.md §8).  Absent workers' rows are FROZEN: no
        decay, no update.  A dropped upload is the absence of evidence,
        not evidence of absence, so the worker's last smoothed pattern
        keeps implicating (or clearing) it until fresh data lands."""
        mat, names = agg.matrix()
        if mat.shape[0] != self.n_workers:
            raise ValueError(
                f"window has {mat.shape[0]} workers, EMA tracks "
                f"{self.n_workers}")
        return self.fold_block(mat, names, agg.kinds(), present=present)

    def fold_block(self, mat: np.ndarray, names: List[str],
                   kinds: Dict[str, Kind],
                   present: Optional[np.ndarray] = None
                   ) -> "EmaPatternAggregator":
        """Fold a raw ``(W, F_new, 3)`` block with its column names."""
        if present is not None:
            present = np.asarray(present, bool)
            if present.shape != (self.n_workers,):
                raise ValueError(
                    f"present mask {present.shape} != ({self.n_workers},)")
            if present.all():
                present = None        # identical to the full-fleet fold
        cols = np.array([self._intern(nm, kinds.get(nm)) for nm in names],
                        np.int64)
        F = len(self._names)
        a = self.alpha
        buf = self._buf[:, :F]
        if present is None:
            # decay-toward-zero for every existing column ...
            buf *= (1.0 - a)
            if cols.size:
                # ... then add the fresh evidence where this window reported
                mat = mat.astype(np.float32, copy=False)
                buf[:, cols] += a * mat
                # a row's FIRST evidence for a column: full value, not an
                # alpha-scaled ramp-up from a zero it never reported
                fresh = ~self._seen[:, cols]            # (W, n_cols)
                if fresh.any():
                    sub = buf[:, cols]
                    sub[fresh] = mat[fresh]
                    buf[:, cols] = sub
                    self._seen[:, cols] = True
        else:
            rows = np.flatnonzero(present)
            buf[rows] *= (1.0 - a)
            if cols.size and rows.size:
                m = mat.astype(np.float32, copy=False)[rows]
                ix = np.ix_(rows, cols)
                sub = buf[ix]
                sub += a * m
                # per-row first-seen: a worker absent when the column first
                # appeared initializes at full value on ITS first evidence
                # (absent rows stay zero + unseen: beta 0 = "never on that
                # worker's critical path", like any missing function)
                fresh = ~self._seen[ix]
                if fresh.any():
                    sub[fresh] = m[fresh]
                self._seen[ix] = True
                buf[ix] = sub
        self.n_windows += 1
        return self

    # -- results -----------------------------------------------------------
    @property
    def n_functions(self) -> int:
        return len(self._names)

    def matrix(self) -> Tuple[np.ndarray, List[str]]:
        return self._buf[:, :len(self._names)], list(self._names)

    def finalize(self, sort_names: bool = True
                 ) -> Tuple[Dict[str, np.ndarray], Dict[str, Kind]]:
        """Localizer-shaped view: {name: (W, 3)}, kinds.  Views alias the
        EMA buffer and are valid until the next ``fold``."""
        mat, names = self.matrix()
        order = sorted(names) if sort_names else names
        return ({n: mat[:, self._col[n], :] for n in order},
                dict(self._kinds))
