"""Online incident pipeline (DESIGN.md §7, §9): continuous detection,
cross-window EMA aggregation, incident lifecycles with a closed
act->verify->escalate mitigation loop, and differential escalation over
the fleet-batched diagnosis path."""
from repro.online.catalog import (FAULT_CLASSES, SCENARIOS, ExpectedIncident,
                                  Scenario, evaluate, run_scenario)
from repro.online.ema import EmaPatternAggregator
from repro.online.escalation import EscalationPolicy
from repro.online.incident import (CONFIRMED, ESCALATED, MITIGATING, OPEN,
                                   RESOLVED, STATES, VERIFYING, Incident,
                                   IncidentManager)
from repro.online.mitigation import (DEFAULT_CURES, AppliedMitigation,
                                     MitigationEngine)
from repro.online.pipeline import OnlinePipeline, WindowReport
from repro.online.scenario import (ScenarioResult, ScenarioRunner,
                                   ScheduledFault, default_detector_cfg)
from repro.online.workload import (SimWorkload, WindowData, WorkloadSource,
                                   merge_anchor_durations,
                                   synth_anchor_events)

__all__ = [
    "FAULT_CLASSES", "SCENARIOS", "ExpectedIncident", "Scenario",
    "evaluate", "run_scenario",
    "EmaPatternAggregator", "EscalationPolicy",
    "OPEN", "CONFIRMED", "MITIGATING", "VERIFYING", "RESOLVED",
    "ESCALATED", "STATES",
    "Incident", "IncidentManager",
    "DEFAULT_CURES", "AppliedMitigation", "MitigationEngine",
    "OnlinePipeline", "WindowReport",
    "ScenarioResult", "ScenarioRunner", "ScheduledFault",
    "default_detector_cfg",
    "WorkloadSource", "SimWorkload", "WindowData",
    "merge_anchor_durations", "synth_anchor_events",
]
