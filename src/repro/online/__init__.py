"""Online incident pipeline (DESIGN.md §7): continuous detection,
cross-window EMA aggregation, incident lifecycles, and differential
escalation over the fleet-batched diagnosis path."""
from repro.online.ema import EmaPatternAggregator
from repro.online.escalation import EscalationPolicy
from repro.online.incident import (CONFIRMED, MITIGATING, OPEN, RESOLVED,
                                   Incident, IncidentManager)
from repro.online.pipeline import OnlinePipeline, WindowReport
from repro.online.scenario import (ScenarioResult, ScenarioRunner,
                                   ScheduledFault, default_detector_cfg)

__all__ = [
    "EmaPatternAggregator", "EscalationPolicy",
    "OPEN", "CONFIRMED", "MITIGATING", "RESOLVED",
    "Incident", "IncidentManager",
    "OnlinePipeline", "WindowReport",
    "ScenarioResult", "ScenarioRunner", "ScheduledFault",
    "default_detector_cfg",
]
