"""WorkloadSource: where profiles and anchors come from (DESIGN.md §11).

Everything downstream of profile production — detector, summarize, EMA,
localizer, incidents, escalation, mitigation — is workload-agnostic: it
consumes ``(anchors, profiles, membership, clock)`` per window.  This module
names that contract.  Two implementations exist:

  * ``SimWorkload`` wraps the historical ``FleetSimulator`` path
    byte-for-byte (``ScenarioRunner`` without an explicit workload builds
    one, so every existing scenario/benchmark is unchanged);
  * ``TrainerWorkload`` (``repro.train.workload``) drives REAL ``Trainer``
    instances with the ``Tracer`` wired into every phase of an actual jit'd
    train step — anchors are measured iteration durations, profiles are
    real host-sampled ``WorkerProfile``s.

Multi-worker anchor merging: the job-level iteration detector consumes ONE
(D, O) stream, but a fleet produces per-worker iteration durations.  A
synchronous data-parallel step is gated by its slowest worker, so the merge
takes the per-iteration MAX across workers and resynthesizes the anchor
pair stream on a continuous job clock (``merge_anchor_durations`` +
``synth_anchor_events``) — the same shape ``FleetSimulator.anchor_events``
emits.
"""
from __future__ import annotations

from abc import ABC, abstractmethod
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import channels
from repro.core.events import WorkerProfile

#: fraction of the iteration at which the optimizer.step anchor lands
#: (matches FleetSimulator.anchor_events; the detector only consumes the
#: D..O sequence and the D->D durations, not the interior offset)
_OPT_ANCHOR_FRAC = 0.97


@dataclass
class WindowData:
    """One profiling window's worth of workload output."""
    anchors: List[Tuple[str, float]]     # (name, t) on the workload clock
    profiles: List[WorkerProfile]        # active workers, ascending id
    workers: np.ndarray                  # active (mesh-member) worker ids
    clock: float                         # workload clock at window end
    t0: float                            # workload clock at window start
    #: named job-level sample streams, stream -> [(t, *values), ...]:
    #: ``"numerics"`` carries (t, loss, grad_norm) for the numerics channel
    #: (DESIGN.md §12a), ``"slo"`` carries (t, p99_ttft, p99_tbt) for the
    #: serving latency channel (§13); empty dict when the workload has no
    #: sample streams
    metrics: Dict[str, List[Tuple[float, ...]]] = field(default_factory=dict)

    @property
    def numerics(self) -> List[Tuple[float, float, float]]:
        """Deprecation shim for the pre-§13 ``numerics`` field: the
        numerics stream of ``metrics`` (empty list when absent)."""
        return self.metrics.get(channels.NUMERICS, [])


class WorkloadSource(ABC):
    """Produces anchors + per-worker profiles, one window at a time."""

    @property
    @abstractmethod
    def total_workers(self) -> int:
        """Fleet width of the pipeline's worker axis (standbys included)."""

    @property
    @abstractmethod
    def active_workers(self) -> np.ndarray:
        """Current mesh membership (global worker ids, ascending)."""

    @property
    def family(self) -> str:
        return "dense"

    @property
    def channel(self) -> str:
        """The detector channel this workload's profile abnormalities
        belong to: ``perf`` for training workloads (iteration slowdown),
        ``slo`` for serving ones (latency violations).  The pipeline uses
        it to retag localized profile abnormalities (DESIGN.md §13)."""
        return channels.PERF

    @abstractmethod
    def run_window(self, window: int, faults: Sequence, iters: int,
                   rates: Optional[np.ndarray]) -> WindowData:
        """Advance the workload by one profiling window of ``iters``
        iterations under the given active ``faults``, profiling at the
        per-worker sample ``rates`` (None = deployment default)."""

    def close(self) -> None:
        """Release workload resources (loaders, threads); idempotent."""


def merge_anchor_durations(per_worker: Sequence[Sequence[float]]
                           ) -> List[float]:
    """Job-level iteration durations from per-worker ones: max per
    iteration index (a synchronous step waits for its slowest worker).
    Ragged inputs (a worker lost mid-window) merge over the indices it
    reported."""
    n = max((len(d) for d in per_worker), default=0)
    out = []
    for i in range(n):
        vals = [d[i] for d in per_worker if i < len(d)]
        out.append(float(max(vals)))
    return out


def merge_numerics(per_worker: Sequence[Sequence[Tuple[float, float]]],
                   durations: Sequence[float], t0: float
                   ) -> List[Tuple[float, float, float]]:
    """Job-level (t, loss, grad_norm) samples from per-worker per-iteration
    (loss, grad_norm) pairs: worst (max) value per iteration index, with
    non-finite values winning outright — one worker's NaN IS the job's NaN.
    Timestamps come from the measured iteration ``durations`` chained on
    the job clock starting at ``t0`` (same clock as the anchor stream)."""
    def worst(vals: List[float]) -> float:
        for v in vals:
            if v != v or abs(v) == float("inf"):
                return v
        return max(vals)

    n = max((len(d) for d in per_worker), default=0)
    out: List[Tuple[float, float, float]] = []
    t = float(t0)
    for i in range(n):
        t += float(durations[i]) if i < len(durations) else 0.0
        pairs = [d[i] for d in per_worker if i < len(d)]
        out.append((t, worst([float(p[0]) for p in pairs]),
                    worst([float(p[1]) for p in pairs])))
    return out


def merge_slo(per_worker: Sequence[Sequence[Tuple[float, float]]],
              durations: Sequence[float], t0: float
              ) -> List[Tuple[float, float, float]]:
    """Job-level (t, p99_ttft, p99_tbt) samples from per-worker
    per-iteration (ttft, tbt) pairs shipped on ``anchors`` wire frames:
    the fleet's p99 is dominated by its worst worker, so the merge rule is
    the same worst-per-index fold the numerics channel uses (one stalled
    worker IS the job's SLO violation)."""
    return merge_numerics(per_worker, durations, t0)


def synth_anchor_events(durations: Sequence[float], t0: float
                        ) -> Tuple[List[Tuple[str, float]], float]:
    """(D, O) anchor pairs for measured iteration durations, chained on a
    continuous clock starting at ``t0``.  Returns (events, end_clock)."""
    out: List[Tuple[str, float]] = []
    t = float(t0)
    for dur in durations:
        out.append(("dataloader.next", t))
        out.append(("optimizer.step", t + dur * _OPT_ANCHOR_FRAC))
        t += dur
    return out, t


class SimWorkload(WorkloadSource):
    """The historical profile source: ``FleetSimulator`` synthesis.

    Byte-identical to the pre-refactor ``ScenarioRunner.run`` loop: the
    anchor stream draws from ``sim.rng`` before the (window-seeded)
    profile materialization, faults are installed by assignment, and the
    escalation rates the caller passes are a pure read taken before any
    of it (the policy only updates at the previous window's tick)."""

    def __init__(self, sim, seed: int, seed_stride: int):
        self.sim = sim
        self._seed = int(seed)
        self._stride = int(seed_stride)

    @property
    def total_workers(self) -> int:
        return self.sim.total_workers

    @property
    def active_workers(self) -> np.ndarray:
        return self.sim.active_workers

    @property
    def family(self) -> str:
        return self.sim.cfg.family

    @property
    def channel(self) -> str:
        return (channels.SLO if self.sim.cfg.workload == "serve"
                else channels.PERF)

    def seed_of(self, window: int) -> int:
        return self._seed + self._stride * (window + 1)

    def run_window(self, window: int, faults: Sequence, iters: int,
                   rates: Optional[np.ndarray]) -> WindowData:
        self.sim.faults = list(faults)
        t0 = self.sim.anchor_clock
        anchors = self.sim.anchor_events(iters, t0=t0)
        profiles = self.sim.profile_window(rates=rates,
                                           seed=self.seed_of(window))
        if self.sim.cfg.workload == "serve":
            metrics = {channels.SLO: self.sim.slo_window(
                iters, self.seed_of(window), t0, self.sim.anchor_clock)}
        else:
            metrics = {channels.NUMERICS: self.sim.numerics_window(
                iters, self.seed_of(window), t0, self.sim.anchor_clock)}
        return WindowData(anchors=anchors, profiles=profiles,
                          workers=self.sim.active_workers,
                          clock=self.sim.anchor_clock, t0=t0,
                          metrics=metrics)
