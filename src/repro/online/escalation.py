"""Differential escalation: the minimal-production-impact profiling knob
(paper §5 "minimal impact"; DESIGN.md §7).

The fleet profiles continuously at a cheap *base* sample rate.  Only
workers implicated by the previous window's ``Abnormality`` set — plus any
still inside a cooldown after their last implication — are escalated to
the *full* rate for the next window.  Healthy steady state therefore costs
``base/full`` of always-on full-rate profiling, while suspected workers
get full-fidelity evidence exactly when localization needs it.

``rates()`` is what a deployment feeds each worker's tracer
(``Tracer.set_rate``) and what the scenario runner feeds
``FleetSimulator.profile_window(rates=...)``; ``summarize_fleet`` already
groups execution rows by stream rate, so a mixed-rate fleet batches
without any re-padding.
"""
from __future__ import annotations

from typing import Iterable, List, Optional, Sequence

import numpy as np

from repro.core.localizer import Abnormality


class EscalationPolicy:
    """Per-worker sample-rate controller."""

    def __init__(self, n_workers: int, base_rate_hz: float,
                 full_rate_hz: float, cooldown_windows: int = 2,
                 max_escalated: Optional[int] = None):
        if base_rate_hz > full_rate_hz:
            raise ValueError("base rate must not exceed full rate")
        self.n_workers = int(n_workers)
        self.base_rate_hz = float(base_rate_hz)
        self.full_rate_hz = float(full_rate_hz)
        self.cooldown_windows = int(cooldown_windows)
        #: hard budget on concurrently-escalated workers (None = unbounded).
        #: This bounds the profiling overhead even for FLEET-WIDE faults:
        #: a pattern every worker exhibits is already confirmed at the base
        #: rate, so full-rate evidence from a bounded sample suffices —
        #: localization ranks abnormalities by beta, and the budget keeps
        #: the highest-ranked workers.
        self.max_escalated = max_escalated
        #: remaining escalated windows per worker (0 = base rate)
        self._ttl = np.zeros(self.n_workers, np.int64)

    @property
    def escalated(self) -> List[int]:
        return np.flatnonzero(self._ttl > 0).tolist()

    def rates(self) -> np.ndarray:
        """(W,) per-worker sample rates for the NEXT profiling window."""
        return np.where(self._ttl > 0, self.full_rate_hz,
                        self.base_rate_hz)

    def observe(self, abnormalities: Iterable[Abnormality]) -> List[int]:
        """Fold one window's localization result: implicated workers are
        (re-)escalated for ``cooldown_windows`` windows, everyone else's
        cooldown burns down one window.  Returns the new escalated set.

        With a ``max_escalated`` budget, implication order breaks the tie:
        abnormalities arrive beta-ranked from the localizer, so the budget
        keeps the workers of the most dominant abnormal functions."""
        self._ttl = np.maximum(self._ttl - 1, 0)
        fresh: List[int] = []
        seen = set()
        for a in abnormalities:
            for w in np.asarray(a.workers, np.int64).tolist():
                if 0 <= w < self.n_workers and w not in seen:
                    seen.add(w)
                    fresh.append(w)
        if self.max_escalated is not None:
            fresh = fresh[:max(0, self.max_escalated)]
        for w in fresh:
            self._ttl[w] = self.cooldown_windows
        if self.max_escalated is not None:
            idx = np.flatnonzero(self._ttl > 0)
            if idx.size > self.max_escalated:
                # the budget is hard: everything beyond the (already
                # truncated) fresh set competes for the remaining room —
                # higher TTL wins, worker id breaks exact ties
                kept = set(fresh)
                extras = [w for w in idx.tolist() if w not in kept]
                extras.sort(key=lambda w: (-int(self._ttl[w]), w))
                room = max(0, self.max_escalated - len(kept))
                for w in extras[room:]:
                    self._ttl[w] = 0
        return self.escalated

    def escalate(self, workers: Sequence[int]) -> None:
        """Manual escalation hook (e.g. operator-pinned suspects)."""
        idx = np.asarray(list(workers), np.int64)
        self._ttl[idx] = np.maximum(self._ttl[idx], self.cooldown_windows)

    def window_bytes(self, window_s: float, streams: int = 4,
                     itemsize: int = 8) -> float:
        """Raw sample bytes the NEXT window will collect fleet-wide —
        the benchmarked cost of the current escalation decision."""
        return float(self.rates().sum() * window_s * streams * itemsize)
