"""Multi-window scenario runner: drives the ``OnlinePipeline`` over a
simulated training run with faults injected and removed mid-run
(DESIGN.md §7).

A scenario is a fault *schedule* over profiling windows: each
``ScheduledFault`` is active for windows ``[start_window, end_window)``.
Every window the runner

  1. sets the simulator's active fault set from the schedule (the anchor
     stream's iteration durations and the profiling window's resource
     signatures both follow);
  2. streams ``iters_per_window`` anchors into the pipeline's detector
     (continuous timeline across windows via ``FleetSimulator.anchor_clock``);
  3. asks the escalation policy for per-worker rates and materializes the
     fleet's raw profiling windows at those rates;
  4. ticks the pipeline (fleet-batched summarize -> EMA fold -> localize ->
     incident transitions -> next escalation decision).

Overlapping schedules exercise the distinct-incident path: the detector
only fires once at job level, but each fault's abnormal *function* gets its
own incident.

``run_multiprocess`` is the same loop across REAL process boundaries
(DESIGN.md §8): ``n_procs`` spawned worker processes each run a
``PerfTrackerDaemon`` + simulator over their slice of the fleet and upload
~KB patterns over the wire transport; the parent runs detection, window
assembly (loss-tolerant), localization, and incident lifecycles.

Profile production is pluggable (DESIGN.md §11): the runner drives any
``WorkloadSource``.  With no explicit workload it builds the historical
``FleetSimulator`` path (``SimWorkload`` — byte-identical to the
pre-refactor loop); pass a ``repro.train.workload.TrainerWorkload`` to run
the identical detect -> summarize -> localize -> incident machinery over
REAL jit'd training processes, whose measured iteration durations arrive
as ``anchors`` wire frames and are merged (max per index) into the
job-level detector stream.
"""
from __future__ import annotations

import multiprocessing as mp
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.ckpt.recovery import RecoveryManager
from repro.core import faults as F
from repro.core.detector import DetectorConfig
from repro.core.mitigation import Action
from repro.core.simulation import FleetSimulator, SimConfig
from repro.online.escalation import EscalationPolicy
from repro.online.mitigation import MitigationEngine, plan_to_wire
from repro.online.pipeline import OnlinePipeline, WindowReport
from repro.online.workload import (SimWorkload, WorkloadSource,
                                   merge_anchor_durations, merge_numerics,
                                   merge_slo, synth_anchor_events)

#: per-window profile seed offset (must match _mp_worker_main)
_WINDOW_SEED_STRIDE = 7919


@dataclass(frozen=True)
class ScheduledFault:
    fault: F.Fault
    start_window: int
    end_window: int                 # exclusive
    #: which mitigation Actions actually cure this fault — the scenario's
    #: ground truth for the act->verify->escalate loop (DESIGN.md §9).
    #: None = the fault model's playbook default
    #: (``repro.online.mitigation.DEFAULT_CURES``); an empty tuple = nothing
    #: cures it (the incident must end up ``escalated``)
    cures: Optional[Tuple[Action, ...]] = None
    #: partial fix: the weaker residual fault left behind after a cure
    on_cure: Optional[F.Fault] = None

    def active(self, window: int) -> bool:
        return self.start_window <= window < self.end_window


@dataclass
class ScenarioResult:
    pipeline: OnlinePipeline
    reports: List[WindowReport]
    spans: List[Tuple[float, float]]   # (t_start, t_end) per window

    def wire_summary(self) -> Optional[dict]:
        """Aggregate transport counters over the run (None for in-process
        runs): delivered/dropped/duplicate uploads and per-window holes."""
        stats = [r.transport for r in self.reports if r.transport]
        if not stats:
            return None
        return {
            "windows": len(stats),
            "delivered": sum(s["present"] for s in stats),
            "expected": sum(s["expected"] for s in stats),
            "duplicates": sum(s["duplicates"] for s in stats),
            "client_dropped": max(s["client_dropped"] for s in stats),
            "partial_windows": sum(1 for s in stats if s["missing"]),
        }

    def window_of(self, t: float) -> int:
        """Map a timeline instant (e.g. an incident transition time) to the
        profiling window it fell in.  Window ticks run at exactly the span
        end, so the upper boundary is inclusive."""
        for i, (t0, t1) in enumerate(self.spans):
            if t <= t1:
                return i
        return len(self.spans) - 1

    @property
    def incidents(self):
        return self.pipeline.incidents.incidents

    def timeline(self) -> str:
        return self.pipeline.timeline()


def default_detector_cfg(iters_per_window: int) -> DetectorConfig:
    """Windows-scale detector thresholds: lock fast, judge the slowdown
    over roughly half a window of iterations so both the trigger and the
    recovery re-arm land within a window or two of the fault edge.

    ``history_iters`` bounds the 'recent shortest' baseline: once a fault
    outlives the whole history, the pre-fault minimum ages out, the
    baseline drifts up to the degraded level, and the detector emits a
    spurious Recovery mid-fault (draining the pipeline's EMA).  50 windows
    of headroom keeps that horizon far beyond any scheduled scenario while
    still letting a production baseline drift eventually."""
    n_recent = max(5, min(20, iters_per_window // 2))
    return DetectorConfig(m_identical=5, n_recent=n_recent,
                          history_iters=50 * iters_per_window,
                          rearm_cooldown=0)


class ScenarioRunner:
    def __init__(self, sim_cfg: Optional[SimConfig],
                 schedule: Sequence[ScheduledFault],
                 n_windows: int = 8, iters_per_window: int = 24,
                 escalation: Optional[EscalationPolicy] = None,
                 detector_cfg: Optional[DetectorConfig] = None,
                 summarize_backend="numpy", alpha: float = 0.6,
                 clear_windows: int = 2, mitigation: bool = False,
                 verify_windows: int = 2, max_escalations: int = 2,
                 settle_windows: int = 1,
                 workload: Optional[WorkloadSource] = None,
                 recovery="auto", history=None):
        self.sim_cfg = sim_cfg
        self.schedule = list(schedule)
        self.n_windows = n_windows
        self.iters_per_window = iters_per_window
        if workload is None:
            if sim_cfg is None:
                raise ValueError("pass a SimConfig or a WorkloadSource")
            self.sim = FleetSimulator(sim_cfg, [])
            self.workload: WorkloadSource = SimWorkload(
                self.sim, sim_cfg.seed, _WINDOW_SEED_STRIDE)
        else:
            self.sim = getattr(workload, "sim", None)
            self.workload = workload
        # the pipeline's worker axis spans standbys too: their rows stay
        # absent (present-masked) until a re-mesh activates them
        self.pipeline = OnlinePipeline(
            n_workers=self.workload.total_workers,
            family=self.workload.family,
            detector_cfg=(detector_cfg if detector_cfg is not None
                          else default_detector_cfg(iters_per_window)),
            summarize_backend=summarize_backend, alpha=alpha,
            escalation=escalation, clear_windows=clear_windows,
            verify_windows=verify_windows,
            max_escalations=max_escalations,
            settle_windows=settle_windows,
            profile_channel=self.workload.channel,
            history=history)
        #: ``mitigation=True`` closes the loop (DESIGN.md §9): incidents'
        #: ladder rungs execute against the simulator each tick, and the
        #: schedule's live fault view follows cures/re-meshes.  A
        #: ``RecoveryManager`` (DESIGN.md §14) binds the checkpoint verbs
        #: to real on-disk state: ``recovery="auto"`` provisions one per
        #: run — the sim side-car state for simulator workloads, the live
        #: ``snapshot_state``/``install_state`` hooks for real workloads
        #: that expose them — pass None (or an explicit manager) to
        #: override
        self.engine: Optional[MitigationEngine] = None
        if mitigation:
            rec = recovery
            if isinstance(rec, str) and rec == "auto":
                if self.sim is not None and isinstance(self.workload,
                                                       SimWorkload):
                    rec = RecoveryManager.for_sim(seed=self.sim.cfg.seed)
                elif hasattr(self.workload, "snapshot_state"):
                    rec = RecoveryManager.for_workload(self.workload)
                else:
                    rec = None
            self.engine = MitigationEngine(self.sim, self.schedule,
                                           recovery=rec)
            self.pipeline.attach_mitigator(self.engine)

    def faults_at(self, window: int) -> List[F.Fault]:
        if self.engine is not None:
            return self.engine.faults_at(window)
        return [sf.fault for sf in self.schedule if sf.active(window)]

    def run(self, verbose: bool = False) -> ScenarioResult:
        reports: List[WindowReport] = []
        spans: List[Tuple[float, float]] = []
        for i in range(self.n_windows):
            if self.engine is not None:
                self.engine.begin_window(i)
            faults = self.faults_at(i)
            # the escalation rates are a pure read (the policy only updates
            # at the previous window's tick), so sampling them before the
            # workload runs is byte-identical to the historical loop order
            rates = self.pipeline.rates()
            wd = self.workload.run_window(i, faults,
                                          self.iters_per_window, rates)
            self.pipeline.feed_anchors(wd.anchors)
            self.pipeline.feed_metrics(wd.metrics)
            self.pipeline.poll_blockage(wd.clock)
            # profiles come from the ACTIVE fleet only; with standbys
            # and/or after a re-mesh the absent rows are present-masked
            # and kept out of the mesh membership (the full-fleet path
            # stays byte-identical to the historical behavior when every
            # row is active)
            active = wd.workers
            self.pipeline.set_membership(active)
            report = self.pipeline.window_tick(
                wd.profiles, t=wd.clock, rates=rates,
                present_workers=(None if len(active)
                                 == self.pipeline.n_workers else active))
            spans.append((wd.t0, wd.clock))
            reports.append(report)
            if verbose:
                print(f"-- window {i} (t={report.t:.1f}s, "
                      f"faults={[type(f).__name__ for f in faults]},"
                      f" escalated={report.escalated})")
                for m in report.mitigations:
                    print(f"   mitigation: {m}")
                print(report.report(len(active)))
        return ScenarioResult(pipeline=self.pipeline, reports=reports,
                              spans=spans)

    def run_multiprocess(self, n_procs: int = 4, loss: float = 0.0,
                         loss_seed: Optional[int] = None,
                         window_timeout: float = 60.0,
                         log_path: Optional[str] = None,
                         max_queue: int = 64,
                         n_shards: Optional[int] = None,
                         auth_token: Optional[str] = None,
                         verbose: bool = False) -> ScenarioResult:
        """The same scenario across REAL process boundaries (DESIGN.md §8,
        §10).

        Spawns ``n_procs`` worker processes (``multiprocessing`` spawn
        context — a cold interpreter each, like a real per-host daemon).
        Each runs one ``PerfTrackerDaemon`` per fleet worker in its slice:
        per-window it materializes its workers' raw profiles, summarizes
        locally, and uploads ~KB patterns over its own socket.  The parent
        runs the anchor stream/detector, broadcasts ``window_start``
        control frames (carrying the escalation rates — and, with
        mitigation or standbys, the mesh membership plus the mitigation
        plans applied since the previous window), assembles each window
        loss-tolerantly, and ticks the online pipeline on the batches.

        ``mitigation=True`` works across the wire: the parent's engine
        executes incident ladders as usual, and each executed plan is
        serialized (``plan_to_wire``) into the next ``window_start``;
        every child replays it on its OWN ``MitigationEngine`` +
        ``FleetSimulator`` — both deterministic — so cures, residual
        faults, and ``replace_hosts`` re-meshes stay bit-identical across
        process boundaries, and collectors' expected sets follow the mesh.

        ``n_shards >= 1`` routes uploads through a two-tier collector
        tree (``transport.CollectorTree``): each worker daemon dials its
        rack's LEAF, leaves assemble + compact their slices, and the root
        ingests O(n_shards) frames per window instead of O(W).

        ``loss`` injects that fraction of upload-frame drops at the
        framing layer in every child (deterministic per (worker, window)
        via ``loss_seed``) — the collector's partial-window semantics and
        the EMA's frozen-row policy carry diagnosis through the holes.
        """
        from repro.transport import (CollectorTree, DaemonServer,
                                     WindowCollector, framing,
                                     max_frame_bytes)
        if getattr(self.workload, "is_trainer", False):
            if n_shards is not None:
                raise ValueError("collector-tree sharding is not supported "
                                 "for trainer workloads (leaves compact "
                                 "uploads; anchors frames need the flat "
                                 "collector)")
            if loss > 0.0:
                raise ValueError("frame-loss injection is simulator-only; "
                                 "trainer workloads lose frames the honest "
                                 "way (kill the socket)")
            return self._run_trainer_mp(n_procs=n_procs,
                                        window_timeout=window_timeout,
                                        log_path=log_path,
                                        max_queue=max_queue,
                                        auth_token=auth_token,
                                        verbose=verbose)
        if self.sim is None:
            raise ValueError("run_multiprocess needs the sim or trainer "
                             "workload (custom WorkloadSources run "
                             "in-process via run())")
        backend = self.pipeline.service.summarize_backend
        if backend is not None and not isinstance(backend, str):
            raise ValueError("run_multiprocess needs a picklable backend "
                             "name (str or None), got an instance")
        # the wire spans the TOTAL worker axis: standby daemons connect
        # and idle outside the mesh until a re-mesh activates them
        W_total = self.sim.total_workers
        active = [int(w) for w in self.sim.active_workers]
        #: the control plane carries membership/plan deltas only when the
        #: mesh can actually change mid-run — the static-mesh wire format
        #: (and its byte-for-byte behavior) is untouched otherwise
        need_membership = self.engine is not None \
            or bool(self.sim_cfg.n_standby)
        max_frame = max_frame_bytes(W_total)
        n_procs = max(1, min(int(n_procs), W_total))
        slices = np.array_split(np.arange(W_total), n_procs)
        tree: Optional[CollectorTree] = None
        if n_shards is not None:
            tree = CollectorTree(range(W_total), n_shards,
                                 auth_token=auth_token, max_frame=max_frame,
                                 window_timeout=window_timeout,
                                 log_path=log_path).start()
            hub, server = tree, tree.root
            addr_of = {w: tree.address_of(w) for w in range(W_total)}
        else:
            collector = WindowCollector(active)
            server = DaemonServer(collector, log_path=log_path,
                                  auth_token=auth_token,
                                  max_frame=max_frame).start()
            hub = collector
            addr_of = {w: server.address for w in range(W_total)}
        ctx = mp.get_context("spawn")
        procs = [
            ctx.Process(
                target=_mp_worker_main,
                args=([addr_of[int(w)] for w in sl],
                      [int(w) for w in sl], self.sim_cfg,
                      self.schedule, _WINDOW_SEED_STRIDE, float(loss),
                      (self.sim_cfg.seed if loss_seed is None
                       else int(loss_seed)),
                      backend, int(max_queue),
                      self.engine is not None, auth_token, max_frame),
                daemon=True)
            for sl in slices if len(sl)]
        reports: List[WindowReport] = []
        spans: List[Tuple[float, float]] = []
        pending_plans: List[dict] = []
        try:
            for p in procs:
                p.start()
            connected = (tree.wait_connections(W_total,
                                               timeout=window_timeout)
                         if tree is not None else
                         server.wait_connections(W_total,
                                                 timeout=window_timeout))
            if not connected:
                raise RuntimeError(
                    f"fewer than {W_total} daemons connected within "
                    f"{window_timeout}s (see {log_path or 'log'})")
            for i in range(self.n_windows):
                if self.engine is not None:
                    self.engine.begin_window(i)
                self.sim.faults = self.faults_at(i)
                t0 = self.sim.anchor_clock
                anchors = self.sim.anchor_events(self.iters_per_window,
                                                 t0=t0)
                self.pipeline.feed_anchors(anchors)
                # the sample streams (numerics / slo) are job-level and
                # deterministic per (seed, window) — the parent generates
                # them itself, same as the anchor stream (children never
                # ship them for sims)
                wseed = self.sim_cfg.seed + _WINDOW_SEED_STRIDE * (i + 1)
                if self.sim_cfg.workload == "serve":
                    self.pipeline.feed_slo(self.sim.slo_window(
                        self.iters_per_window, wseed, t0,
                        self.sim.anchor_clock))
                else:
                    self.pipeline.feed_numerics(self.sim.numerics_window(
                        self.iters_per_window, wseed, t0,
                        self.sim.anchor_clock))
                self.pipeline.poll_blockage(self.sim.anchor_clock)
                rates = self.pipeline.rates()
                active = [int(w) for w in self.sim.active_workers]
                if need_membership:
                    # expected sets follow the mesh BEFORE the window
                    # opens (the tree root re-keys inside broadcast();
                    # leaves re-key from the frame's membership field)
                    if tree is None:
                        hub.set_expected(active)
                    msg = framing.window_start_msg(
                        i, rates, membership=active, plans=pending_plans)
                else:
                    msg = framing.window_start_msg(i, rates)
                pending_plans = []
                (tree if tree is not None else server).broadcast(msg)
                batch = hub.wait_window(i, timeout=window_timeout)
                server.log(f"window {i} assembled: {len(batch.present)}/"
                           f"{len(batch.expected)} uploads, "
                           f"missing={batch.missing}, "
                           f"dups={batch.duplicates}")
                report = self.pipeline.window_tick_batch(
                    batch, t=self.sim.anchor_clock, rates=rates)
                # plans the engine just executed reach the children on the
                # NEXT window_start — same cadence as the in-process loop,
                # where window i's mitigations first shape window i+1
                pending_plans = [plan_to_wire(m)
                                 for m in report.mitigations]
                spans.append((t0, self.sim.anchor_clock))
                reports.append(report)
                if verbose:
                    print(f"-- window {i} (t={report.t:.1f}s, "
                          f"present={len(batch.present)}/"
                          f"{len(batch.expected)}, "
                          f"escalated={report.escalated})")
                    for m in report.mitigations:
                        print(f"   mitigation: {m}")
                    print(report.report(len(active)))
        finally:
            (tree if tree is not None else server).broadcast(
                framing.stop_msg())
            started = [p for p in procs if p.pid is not None]
            for p in started:
                p.join(timeout=30)
            for p in started:
                if p.is_alive():          # wedged child: don't hang the CI
                    p.terminate()
                    p.join(timeout=5)
            if tree is not None:
                tree.stop()
            else:
                server.stop()
        return ScenarioResult(pipeline=self.pipeline, reports=reports,
                              spans=spans)

    def _run_trainer_mp(self, n_procs: int, window_timeout: float,
                        log_path: Optional[str], max_queue: int,
                        auth_token: Optional[str],
                        verbose: bool) -> ScenarioResult:
        """REAL training processes over the wire (DESIGN.md §11): each
        spawned child runs actual ``Trainer`` instances for its fleet slice
        (cold interpreter, own XLA compile), profiles them with the
        ``Tracer``, and ships BOTH the pattern upload and the measured
        iteration durations (``anchors`` frames).  The parent has no
        simulator and builds no model — it merges the fleet's anchors into
        the job-level detector stream and ticks the pipeline on assembled
        batches, exactly as it does for simulated uploads."""
        from repro.train.workload import trainer_worker_main
        from repro.transport import (DaemonServer, WindowCollector, framing,
                                     max_frame_bytes)
        backend = self.pipeline.service.summarize_backend
        if backend is not None and not isinstance(backend, str):
            raise ValueError("run_multiprocess needs a picklable backend "
                             "name (str or None), got an instance")
        wl = self.workload
        W = wl.total_workers
        max_frame = max_frame_bytes(W)
        collector = WindowCollector(range(W))
        server = DaemonServer(collector, log_path=log_path,
                              auth_token=auth_token,
                              max_frame=max_frame).start()
        n_procs = max(1, min(int(n_procs), W))
        slices = np.array_split(np.arange(W), n_procs)
        ctx = mp.get_context("spawn")
        procs = [
            ctx.Process(
                target=trainer_worker_main,
                args=([server.address] * len(sl), [int(w) for w in sl], W,
                      wl.cfgs, self.schedule, backend, int(max_queue),
                      auth_token, max_frame, int(self.iters_per_window),
                      wl.rate_hz),
                daemon=True)
            for sl in slices if len(sl)]
        reports: List[WindowReport] = []
        spans: List[Tuple[float, float]] = []
        clock = 0.0
        try:
            for p in procs:
                p.start()
            # the children compile + warm up BEFORE dialing, so the
            # connection wait doubles as the compile barrier — give it
            # headroom beyond the steady-state window timeout
            if not server.wait_connections(
                    W, timeout=max(window_timeout, 120.0)):
                raise RuntimeError(
                    f"fewer than {W} trainer daemons connected "
                    f"(see {log_path or 'log'})")
            for i in range(self.n_windows):
                rates = self.pipeline.rates()
                server.broadcast(framing.window_start_msg(i, rates))
                batch = collector.wait_window(i, timeout=window_timeout)
                server.log(f"window {i} assembled: {len(batch.present)}/"
                           f"{len(batch.expected)} uploads, "
                           f"anchors from {sorted(batch.anchors)}, "
                           f"missing={batch.missing}")
                t0 = clock
                merged = merge_anchor_durations(
                    [batch.anchors[w] for w in sorted(batch.anchors)])
                anchors, clock = synth_anchor_events(merged, t0)
                self.pipeline.feed_anchors(anchors)
                num = getattr(batch, "numerics", None) or {}
                if num:
                    self.pipeline.feed_numerics(merge_numerics(
                        [num[w] for w in sorted(num)], merged, t0))
                slo = getattr(batch, "slo", None) or {}
                if slo:
                    self.pipeline.feed_slo(merge_slo(
                        [slo[w] for w in sorted(slo)], merged, t0))
                self.pipeline.poll_blockage(clock)
                report = self.pipeline.window_tick_batch(batch, t=clock,
                                                         rates=rates)
                spans.append((t0, clock))
                reports.append(report)
                if verbose:
                    print(f"-- window {i} (t={report.t:.2f}s, "
                          f"present={len(batch.present)}/"
                          f"{len(batch.expected)}, "
                          f"escalated={report.escalated})")
                    print(report.report(W))
        finally:
            server.broadcast(framing.stop_msg())
            started = [p for p in procs if p.pid is not None]
            for p in started:
                p.join(timeout=30)
            for p in started:
                if p.is_alive():
                    p.terminate()
                    p.join(timeout=5)
            server.stop()
        return ScenarioResult(pipeline=self.pipeline, reports=reports,
                              spans=spans)


def _mp_worker_main(addresses, worker_ids, sim_cfg, schedule,
                    seed_stride, loss, loss_seed, backend,
                    max_queue, mitigation=False, auth_token=None,
                    max_frame=None) -> None:
    """Entry point of one spawned worker process: daemons for a fleet
    slice, driven by the parent's ``window_start`` broadcasts.

    ``addresses[i]`` is the collector endpoint worker ``worker_ids[i]``
    dials — the flat server, or that worker's rack LEAF in tree mode.

    With ``mitigation`` the child owns its own ``MitigationEngine`` over
    its own ``FleetSimulator`` and REPLAYS the plan deltas each
    ``window_start`` carries (``plan_from_wire`` -> ``engine.apply``):
    plan execution is deterministic, so the child's live-fault view and
    mesh match the parent's exactly, one window behind the decision —
    the same cadence the in-process loop has."""
    from repro.core.daemon import PerfTrackerDaemon
    from repro.online.mitigation import MitigationEngine as _Engine
    from repro.online.mitigation import plan_from_wire
    frame_filter = None
    if loss > 0.0:
        def frame_filter(msg, frame):
            if msg.get("t") != "upload":
                return None
            r = np.random.default_rng(
                (loss_seed, int(msg["worker"]), int(msg["window"])))
            return [] if r.random() < loss else None
    sim = FleetSimulator(sim_cfg, [])
    engine = _Engine(sim, schedule) if mitigation else None
    daemons = [PerfTrackerDaemon(int(w), addr, backend=backend,
                                 max_queue=max_queue,
                                 frame_filter=frame_filter,
                                 auth_token=auth_token,
                                 max_frame=max_frame)
               for w, addr in zip(worker_ids, addresses)]
    daemon_of = {int(w): d for w, d in zip(worker_ids, daemons)}
    control = daemons[0]
    try:
        while True:
            msg = control.recv_control(timeout=120.0)
            if msg is None or msg.get("t") == "stop":
                return
            if msg.get("t") != "window_start":
                continue
            i = int(msg["window"])
            rates = msg.get("rates")
            rates = None if rates is None else np.asarray(rates, np.float64)
            if engine is not None:
                for d in msg.get("plans", []):
                    plan, applied_at = plan_from_wire(d)
                    # cures must match the parent bit-for-bit: a rollback's
                    # outcome depends on the parent's on-disk checkpoints,
                    # so it rides the wire instead of being re-decided here
                    engine.apply(plan, applied_at,
                                 rollback_failed=d.get("rollback_failed",
                                                       False))
                sim.faults = engine.faults_at(i)
            else:
                sim.faults = [sf.fault for sf in schedule if sf.active(i)]
            members = msg.get("membership")
            mine = (list(worker_ids) if members is None
                    else [w for w in worker_ids if w in set(members)])
            seed = sim_cfg.seed + seed_stride * (i + 1)
            profiles = sim.profile_window_slice(mine, rates=rates,
                                                seed=seed)
            for w, p in zip(mine, profiles):
                daemon_of[int(w)].process_window(i, p)
    finally:
        for d in daemons:
            d.close()
