"""Multi-window scenario runner: drives the ``OnlinePipeline`` over a
simulated training run with faults injected and removed mid-run
(DESIGN.md §7).

A scenario is a fault *schedule* over profiling windows: each
``ScheduledFault`` is active for windows ``[start_window, end_window)``.
Every window the runner

  1. sets the simulator's active fault set from the schedule (the anchor
     stream's iteration durations and the profiling window's resource
     signatures both follow);
  2. streams ``iters_per_window`` anchors into the pipeline's detector
     (continuous timeline across windows via ``FleetSimulator.anchor_clock``);
  3. asks the escalation policy for per-worker rates and materializes the
     fleet's raw profiling windows at those rates;
  4. ticks the pipeline (fleet-batched summarize -> EMA fold -> localize ->
     incident transitions -> next escalation decision).

Overlapping schedules exercise the distinct-incident path: the detector
only fires once at job level, but each fault's abnormal *function* gets its
own incident.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

from repro.core import faults as F
from repro.core.detector import DetectorConfig
from repro.core.simulation import FleetSimulator, SimConfig
from repro.online.escalation import EscalationPolicy
from repro.online.pipeline import OnlinePipeline, WindowReport


@dataclass(frozen=True)
class ScheduledFault:
    fault: F.Fault
    start_window: int
    end_window: int                 # exclusive

    def active(self, window: int) -> bool:
        return self.start_window <= window < self.end_window


@dataclass
class ScenarioResult:
    pipeline: OnlinePipeline
    reports: List[WindowReport]
    spans: List[Tuple[float, float]]   # (t_start, t_end) per window

    def window_of(self, t: float) -> int:
        """Map a timeline instant (e.g. an incident transition time) to the
        profiling window it fell in.  Window ticks run at exactly the span
        end, so the upper boundary is inclusive."""
        for i, (t0, t1) in enumerate(self.spans):
            if t <= t1:
                return i
        return len(self.spans) - 1

    @property
    def incidents(self):
        return self.pipeline.incidents.incidents

    def timeline(self) -> str:
        return self.pipeline.timeline()


def default_detector_cfg(iters_per_window: int) -> DetectorConfig:
    """Windows-scale detector thresholds: lock fast, judge the slowdown
    over roughly half a window of iterations so both the trigger and the
    recovery re-arm land within a window or two of the fault edge.

    ``history_iters`` bounds the 'recent shortest' baseline: once a fault
    outlives the whole history, the pre-fault minimum ages out, the
    baseline drifts up to the degraded level, and the detector emits a
    spurious Recovery mid-fault (draining the pipeline's EMA).  50 windows
    of headroom keeps that horizon far beyond any scheduled scenario while
    still letting a production baseline drift eventually."""
    n_recent = max(5, min(20, iters_per_window // 2))
    return DetectorConfig(m_identical=5, n_recent=n_recent,
                          history_iters=50 * iters_per_window,
                          rearm_cooldown=0)


class ScenarioRunner:
    def __init__(self, sim_cfg: SimConfig,
                 schedule: Sequence[ScheduledFault],
                 n_windows: int = 8, iters_per_window: int = 24,
                 escalation: Optional[EscalationPolicy] = None,
                 detector_cfg: Optional[DetectorConfig] = None,
                 summarize_backend="numpy", alpha: float = 0.6,
                 clear_windows: int = 2):
        self.sim_cfg = sim_cfg
        self.schedule = list(schedule)
        self.n_windows = n_windows
        self.iters_per_window = iters_per_window
        self.sim = FleetSimulator(sim_cfg, [])
        self.pipeline = OnlinePipeline(
            n_workers=sim_cfg.n_workers, family=sim_cfg.family,
            detector_cfg=(detector_cfg if detector_cfg is not None
                          else default_detector_cfg(iters_per_window)),
            summarize_backend=summarize_backend, alpha=alpha,
            escalation=escalation, clear_windows=clear_windows)

    def faults_at(self, window: int) -> List[F.Fault]:
        return [sf.fault for sf in self.schedule if sf.active(window)]

    def run(self, verbose: bool = False) -> ScenarioResult:
        reports: List[WindowReport] = []
        spans: List[Tuple[float, float]] = []
        for i in range(self.n_windows):
            self.sim.faults = self.faults_at(i)
            t0 = self.sim.anchor_clock
            anchors = self.sim.anchor_events(self.iters_per_window, t0=t0)
            self.pipeline.feed_anchors(anchors)
            self.pipeline.poll_blockage(self.sim.anchor_clock)
            rates = self.pipeline.rates()
            profiles = self.sim.profile_window(
                rates=rates, seed=self.sim_cfg.seed + 7919 * (i + 1))
            report = self.pipeline.window_tick(
                profiles, t=self.sim.anchor_clock, rates=rates)
            spans.append((t0, self.sim.anchor_clock))
            reports.append(report)
            if verbose:
                print(f"-- window {i} (t={report.t:.1f}s, "
                      f"faults={[type(f).__name__ for f in self.sim.faults]},"
                      f" escalated={report.escalated})")
                print(report.report(self.sim_cfg.n_workers))
        return ScenarioResult(pipeline=self.pipeline, reports=reports,
                              spans=spans)
