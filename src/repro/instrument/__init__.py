from repro.instrument.hooks import PerfTracker, PerfTrackerConfig  # noqa: F401
from repro.instrument.tracer import HostSampler, Tracer  # noqa: F401
