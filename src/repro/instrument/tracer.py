"""Host-side tracer + hardware sampler for the REAL training loop (the JAX
analogue of the paper's Torch-profiler/nsys collectors — DESIGN.md §2).

Phases (data.next / train.step / fwd / bwd / optimizer.step / ckpt.save /
collectives) are recorded as FunctionEvents with ``block_until_ready``
fencing at phase ends; inside one jit we attribute on-device time via the
compiled HLO cost model instead of per-op hooks (XLA fuses ops).

The HostSampler thread samples real /proc/stat CPU utilization at up to
~1 kHz into a SampleStream.  The stream set is EXPLICIT per resource:
only resources with a real sampler appear in the profile (this container
has no GPU/ICI counters, so the default tracer exposes only ``cpu`` —
absent streams are omitted, never faked by aliasing; the pack layer drops
events whose resource stream is missing and the summarize engine still
emits beta-only patterns for them).
"""
from __future__ import annotations

import threading
import time
from contextlib import contextmanager
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.events import FunctionEvent, Kind, SampleStream, WorkerProfile


def _read_proc_stat() -> Tuple[float, float]:
    with open("/proc/stat") as f:
        parts = f.readline().split()
    vals = [float(x) for x in parts[1:8]]
    idle = vals[3] + vals[4]
    return sum(vals), idle


class HostSampler:
    """Background CPU-utilization sampler."""

    def __init__(self, rate_hz: float = 500.0):
        self.rate_hz = rate_hz
        self._stop = threading.Event()
        self._vals: List[float] = []
        self._t0 = 0.0
        self._thread: Optional[threading.Thread] = None

    def start(self):
        self._stop.clear()
        self._vals = []
        self._t0 = time.perf_counter()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        prev_total, prev_idle = _read_proc_stat()
        period = 1.0 / self.rate_hz
        while not self._stop.is_set():
            time.sleep(period)
            total, idle = _read_proc_stat()
            dt, di = total - prev_total, idle - prev_idle
            prev_total, prev_idle = total, idle
            util = 1.0 - (di / dt) if dt > 0 else 0.0
            self._vals.append(max(0.0, min(1.0, util)))

    def stop(self) -> SampleStream:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=1.0)
        vals = np.asarray(self._vals, np.float64)
        n = len(vals)
        eff_rate = n / max(1e-9, time.perf_counter() - self._t0)
        return SampleStream(rate_hz=max(eff_rate, 1.0), t0=self._t0,
                            values=vals)


class ProcessSampler(HostSampler):
    """Per-PROCESS CPU sampler (CLOCK_PROCESS_CPUTIME_ID via
    ``time.process_time``).

    The machine-wide ``/proc/stat`` sampler floors utilization at whatever
    the host's background load is — a real trainer sleeping on a stalled
    device still reads ~0.4 busy on a shared box.  Process CPU time reads 0
    the moment THIS process goes idle, and at nanosecond resolution (no
    10 ms jiffy quantization), which is what makes the localizer's mu-based
    playbook rules (GC pauses, throttling) reliable for real trainer
    workloads (DESIGN.md §11).  Multi-threaded compute (XLA intra-op pools)
    saturates to 1.0."""

    def _run(self):
        prev_c = time.process_time()
        prev_w = time.perf_counter()
        period = 1.0 / self.rate_hz
        while not self._stop.is_set():
            time.sleep(period)
            c, w = time.process_time(), time.perf_counter()
            dc, dw = c - prev_c, w - prev_w
            prev_c, prev_w = c, w
            util = dc / dw if dw > 0 else 0.0
            self._vals.append(max(0.0, min(1.0, util)))


class Tracer:
    """Records phase events; active only during a profiling window.

    The tracer is the producer side of the batched summarize pipeline:
    ``stop_window`` pre-packs the recorded events into the ``(E, n)`` matrix
    the summarize backends consume (DESIGN.md §3), so the daemon's
    summarization starts from packed rows instead of re-slicing streams
    event by event.  Which backend consumes the pack is the service/daemon's
    choice (``PerfTrackerService(summarize_backend=...)`` or the
    ``REPRO_SUMMARIZE_BACKEND`` env var).

    ``samplers`` maps resource name -> sampler; the default is one real
    ``cpu`` HostSampler.  A platform with hardware counters registers more
    (``gpu_sm``/``pcie_tx``/``membw``) — resources without a sampler are
    simply absent from the profile's stream set, not faked.
    """

    def __init__(self, worker: int = 0, pack: bool = True,
                 rate_hz: float = 500.0,
                 samplers: Optional[Dict[str, HostSampler]] = None):
        self.worker = worker
        self.pack = pack
        self.events: List[FunctionEvent] = []
        self.active = False
        self._window_start = 0.0
        self.samplers: Dict[str, HostSampler] = (
            dict(samplers) if samplers is not None
            else {"cpu": HostSampler(rate_hz=rate_hz)})

    @property
    def sampler(self) -> HostSampler:
        """The cpu sampler (back-compat alias for the single-sampler API)."""
        return self.samplers["cpu"]

    @property
    def rate_hz(self) -> float:
        return self.samplers["cpu"].rate_hz

    def set_rate(self, rate_hz: float) -> None:
        """Differential escalation (DESIGN.md §7): the service retunes each
        worker's sampling rate between profiling windows — implicated
        workers run at the full rate, the rest at the cheap base rate.
        Takes effect at the next ``start_window`` (the sampler thread reads
        its rate once at start)."""
        if self.active:
            raise RuntimeError("cannot retune rate_hz mid-window")
        for s in self.samplers.values():
            s.rate_hz = float(rate_hz)

    def start_window(self):
        self.events = []
        self.active = True
        self._window_start = time.perf_counter()
        for s in self.samplers.values():
            s.start()

    def stop_window(self) -> WorkerProfile:
        self.active = False
        t0 = self._window_start
        streams: Dict[str, SampleStream] = {}
        for res, sampler in self.samplers.items():
            s = sampler.stop()
            streams[res] = SampleStream(s.rate_hz, 0.0, s.values)
        end = time.perf_counter()
        events = [
            FunctionEvent(e.name, e.kind, e.start - t0, e.end - t0,
                          self.worker, e.thread, e.depth, e.resource)
            for e in self.events]
        profile = WorkerProfile(
            worker=self.worker, window=(0.0, end - t0), events=events,
            streams=streams)
        if self.pack:
            from repro.summarize.packing import pack_profile
            profile.packed = pack_profile(profile)
        return profile

    @contextmanager
    def phase(self, name: str, kind: Kind = Kind.PYTHON, depth: int = 1,
              fence=None, resource: str = ""):
        if not self.active:
            yield
            return
        t0 = time.perf_counter()
        try:
            yield
        finally:
            if fence is not None:
                import jax
                jax.block_until_ready(fence() if callable(fence) else fence)
            self.events.append(FunctionEvent(
                name, kind, t0, time.perf_counter(), self.worker,
                depth=depth, resource=resource))

    def add_event(self, name: str, kind: Kind, start: float, end: float,
                  depth: int = 2, resource: str = "") -> None:
        """Record a sub-event with explicit absolute perf_counter times —
        used for HLO-cost attribution inside a fused jit step, where the
        host never observes per-op boundaries and we split the fenced span
        by the compiled cost model instead."""
        if not self.active:
            return
        self.events.append(FunctionEvent(
            name, kind, start, end, self.worker, depth=depth,
            resource=resource))
