"""Host-side tracer + hardware sampler for the REAL training loop (the JAX
analogue of the paper's Torch-profiler/nsys collectors — DESIGN.md §2).

Phases (data.next / train.step / fwd / bwd / optimizer.step / ckpt.save /
collectives) are recorded as FunctionEvents with ``block_until_ready``
fencing at phase ends; inside one jit we attribute on-device time via the
compiled HLO cost model instead of per-op hooks (XLA fuses ops).

The HostSampler thread samples real /proc/stat CPU utilization at up to
~1 kHz into a SampleStream (the container has no GPU/ICI counters; the fleet
simulator supplies those — same methodology as the paper's own >3k-GPU
scaling evaluation).
"""
from __future__ import annotations

import threading
import time
from contextlib import contextmanager
from typing import List, Optional, Tuple

import numpy as np

from repro.core.events import FunctionEvent, Kind, SampleStream, WorkerProfile


def _read_proc_stat() -> Tuple[float, float]:
    with open("/proc/stat") as f:
        parts = f.readline().split()
    vals = [float(x) for x in parts[1:8]]
    idle = vals[3] + vals[4]
    return sum(vals), idle


class HostSampler:
    """Background CPU-utilization sampler."""

    def __init__(self, rate_hz: float = 500.0):
        self.rate_hz = rate_hz
        self._stop = threading.Event()
        self._vals: List[float] = []
        self._t0 = 0.0
        self._thread: Optional[threading.Thread] = None

    def start(self):
        self._stop.clear()
        self._vals = []
        self._t0 = time.perf_counter()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        prev_total, prev_idle = _read_proc_stat()
        period = 1.0 / self.rate_hz
        while not self._stop.is_set():
            time.sleep(period)
            total, idle = _read_proc_stat()
            dt, di = total - prev_total, idle - prev_idle
            prev_total, prev_idle = total, idle
            util = 1.0 - (di / dt) if dt > 0 else 0.0
            self._vals.append(max(0.0, min(1.0, util)))

    def stop(self) -> SampleStream:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=1.0)
        vals = np.asarray(self._vals, np.float64)
        n = len(vals)
        eff_rate = n / max(1e-9, time.perf_counter() - self._t0)
        return SampleStream(rate_hz=max(eff_rate, 1.0), t0=self._t0,
                            values=vals)


class Tracer:
    """Records phase events; active only during a profiling window.

    The tracer is the producer side of the batched summarize pipeline:
    ``stop_window`` pre-packs the recorded events into the ``(E, n)`` matrix
    the summarize backends consume (DESIGN.md §3), so the daemon's
    summarization starts from packed rows instead of re-slicing streams
    event by event.  Which backend consumes the pack is the service/daemon's
    choice (``PerfTrackerService(summarize_backend=...)`` or the
    ``REPRO_SUMMARIZE_BACKEND`` env var).
    """

    def __init__(self, worker: int = 0, pack: bool = True,
                 rate_hz: float = 500.0):
        self.worker = worker
        self.pack = pack
        self.events: List[FunctionEvent] = []
        self.active = False
        self._window_start = 0.0
        self.sampler = HostSampler(rate_hz=rate_hz)

    @property
    def rate_hz(self) -> float:
        return self.sampler.rate_hz

    def set_rate(self, rate_hz: float) -> None:
        """Differential escalation (DESIGN.md §7): the service retunes each
        worker's sampling rate between profiling windows — implicated
        workers run at the full rate, the rest at the cheap base rate.
        Takes effect at the next ``start_window`` (the sampler thread reads
        its rate once at start)."""
        if self.active:
            raise RuntimeError("cannot retune rate_hz mid-window")
        self.sampler.rate_hz = float(rate_hz)

    def start_window(self):
        self.events = []
        self.active = True
        self._window_start = time.perf_counter()
        self.sampler.start()

    def stop_window(self) -> WorkerProfile:
        self.active = False
        stream = self.sampler.stop()
        t0 = self._window_start
        end = time.perf_counter()
        events = [
            FunctionEvent(e.name, e.kind, e.start - t0, e.end - t0,
                          self.worker, e.thread, e.depth, e.resource)
            for e in self.events]
        stream = SampleStream(stream.rate_hz, 0.0, stream.values)
        profile = WorkerProfile(
            worker=self.worker, window=(0.0, end - t0), events=events,
            streams={"cpu": stream, "gpu_sm": stream, "pcie_tx": stream,
                     "membw": stream})
        if self.pack:
            from repro.summarize.packing import pack_profile
            profile.packed = pack_profile(profile)
        return profile

    @contextmanager
    def phase(self, name: str, kind: Kind = Kind.PYTHON, depth: int = 1,
              fence=None):
        if not self.active:
            yield
            return
        t0 = time.perf_counter()
        try:
            yield
        finally:
            if fence is not None:
                import jax
                jax.block_until_ready(fence() if callable(fence) else fence)
            self.events.append(FunctionEvent(
                name, kind, t0, time.perf_counter(), self.worker,
                depth=depth))
