"""``import PerfTracker``-style attachment (paper §4, Usage).

The provider never sees user code: ``PerfTracker.wrap(loader, opt_step)``
replaces the two anchor callables with timed versions (the paper
monkey-patches ``dataloader.next`` / ``optimizer.step`` the same way);
everything else (iteration detection, trigger, profiling window, pattern
upload, localization) happens behind the wrappers.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional

from repro.core.detector import DetectorConfig, Trigger
from repro.core.events import Kind
from repro.core.service import DiagnosisResult, PerfTrackerService
from repro.instrument.tracer import Tracer


@dataclass
class PerfTrackerConfig:
    window_s: float = 2.0            # paper default 20 s; scaled for tests
    detector: DetectorConfig = field(default_factory=DetectorConfig)
    family: str = "dense"
    auto_profile: bool = True
    #: summarize backend name for this worker's daemon (None = env/auto)
    summarize_backend: Optional[str] = None


class PerfTracker:
    """Single-worker online attachment. In a fleet, one instance runs per
    worker and uploads patterns to the global service (see core.service)."""

    def __init__(self, cfg: PerfTrackerConfig = PerfTrackerConfig(),
                 worker: int = 0):
        self.cfg = cfg
        self.service = PerfTrackerService(
            family=cfg.family, detector_cfg=cfg.detector,
            summarize_backend=cfg.summarize_backend)
        self.tracer = Tracer(worker)
        self._window_deadline: Optional[float] = None
        self.last_trigger: Optional[Trigger] = None
        self.results: List[DiagnosisResult] = []

    # -- anchors -----------------------------------------------------------
    def _on_anchor(self, name: str):
        now = time.perf_counter()
        trig = self.service.detector.feed(name, now)
        if trig is not None and self.cfg.auto_profile \
                and self._window_deadline is None:
            self.last_trigger = trig
            self.tracer.start_window()
            self._window_deadline = now + self.cfg.window_s
        elif self._window_deadline is not None \
                and now >= self._window_deadline:
            self._finish_window()

    def _finish_window(self):
        self._window_deadline = None
        profile = self.tracer.stop_window()
        # wire mode: the true single-worker daemon shape — and it reuses
        # the (E, n) batch the tracer pre-packed onto profile.packed, which
        # the fleet-wide gather path would rebuild from raw streams
        res = self.service.diagnose_profiles([profile],
                                             trigger=self.last_trigger,
                                             mode="wire")
        self.results.append(res)

    def flush(self) -> Optional[DiagnosisResult]:
        if self._window_deadline is not None:
            self._finish_window()
        return self.results[-1] if self.results else None

    # -- wrapping ----------------------------------------------------------
    def wrap(self, dataloader_next: Callable, optimizer_step: Callable):
        def wrapped_next(*a, **kw):
            self._on_anchor("dataloader.next")
            with self.tracer.phase("dataloader.py:__next__", Kind.PYTHON,
                                   depth=2):
                return dataloader_next(*a, **kw)

        def wrapped_step(*a, **kw):
            with self.tracer.phase("optimizer.py:step", Kind.PYTHON,
                                   depth=2):
                out = optimizer_step(*a, **kw)
            self._on_anchor("optimizer.step")
            return out

        return wrapped_next, wrapped_step
