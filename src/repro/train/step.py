"""Train / prefill / serve step builders (the programs the dry-run lowers and
the train loop executes)."""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.transformer import Transformer
from repro.optim.adamw import AdamW


def make_train_step(model: Transformer, opt: AdamW, accum_steps: int = 1):
    """accum_steps > 1: gradient accumulation over micro-batches via
    lax.scan — per-device activation memory scales with the micro-batch
    (HBM-fit lever for the big archs; EXPERIMENTS §Perf H5). The global
    batch is split on the leading axis; grads are averaged."""
    grad_fn = jax.value_and_grad(model.loss, has_aux=True)

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def micro(b):
                return jax.tree_util.tree_map(
                    lambda x: x.reshape((accum_steps,
                                         x.shape[0] // accum_steps)
                                        + x.shape[1:]), b)

            def body(acc, mb):
                (l, m), g = grad_fn(params, mb)
                acc = jax.tree_util.tree_map(jnp.add, acc, g)
                return acc, (l, m)

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            gsum, (losses, ms) = jax.lax.scan(body, zeros, micro(batch))
            grads = jax.tree_util.tree_map(
                lambda g: g / accum_steps, gsum)
            loss = losses.mean()
            metrics = jax.tree_util.tree_map(lambda x: x.mean(), ms)
        new_params, new_state, opt_metrics = opt.update(
            grads, opt_state, params)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return new_params, new_state, metrics
    return train_step


def make_split_train_step(model: Transformer, opt: AdamW):
    """Two separately-jittable halves of the fused step — (grad, opt) — for
    instrumented loops that want a real host-visible fence between the
    fwd+bwd dispatch and the optimizer update (``train.step`` vs
    ``optimizer.step`` phases).  Numerically identical to
    ``make_train_step(accum_steps=1)``; slightly slower (two dispatches,
    grads round-trip through HBM)."""
    grad_fn = jax.value_and_grad(model.loss, has_aux=True)

    def grad_step(params, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        metrics = dict(metrics)
        metrics["loss"] = loss
        return grads, metrics

    def opt_step(grads, opt_state, params):
        new_params, new_state, opt_metrics = opt.update(
            grads, opt_state, params)
        return new_params, new_state, opt_metrics

    return grad_step, opt_step


def make_prefill_step(model: Transformer):
    def prefill_step(params, batch):
        hidden, _, cache = model.forward(params, batch, collect_cache=True)
        last_logits = model.logits(params, hidden[:, -1:, :])
        return last_logits, cache
    return prefill_step


def make_serve_step(model: Transformer):
    def serve_step(params, cache, batch, pos):
        return model.decode_step(params, cache, batch, pos)
    return serve_step
