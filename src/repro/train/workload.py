"""TrainerWorkload: the EROICA loop over REAL jit'd JAX training jobs
(DESIGN.md §11).

Profiles stop being simulated: each fleet worker is a real ``Trainer``
running ``train_iteration`` — genuine XLA dispatch, fenced with
``block_until_ready`` — with the ``Tracer`` recording every phase
(``dataloader.next`` / ``train.step`` + HLO-cost sub-events /
``optimizer.step`` / ``ckpt.save``) and the /proc/stat ``HostSampler``
supplying the cpu stream.  Anchors are the measured per-iteration wall
times, merged across workers (max per index: a synchronous step is gated
by its slowest worker) into the job-level detector stream.

In-process mode runs the workers' windows SEQUENTIALLY: /proc/stat is
machine-global, so concurrent in-process workers would pollute each
other's cpu streams; one-at-a-time keeps every sample attributable to the
worker being profiled.  ``trainer_worker_main`` is the multi-process
variant (one process per fleet slice, uploads + anchors over the socket
transport — concurrency across processes is the honest deployment shape).

Live faults perturb the REAL loop (no synthesis anywhere):

  * ``DataloaderBurn``  — CPU spin inside ``dataloader.next`` (slow
    storage / preprocessing, paper C2P1);
  * ``StepThrottle``    — stall inside the fenced ``train.step`` span
    (degraded device, paper C1P1);
  * ``GcPause``         — ``gc.collect()`` + stall on a worker subset
    (unsynchronized garbage collection, paper C2P3).

Fault magnitudes default to multiples of the worker's measured warmup
iteration time, so scenarios stay detectable (>= the detector's slowdown
ratio) on any machine speed.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, replace
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.detector import DetectorConfig
from repro.online.workload import (WindowData, WorkloadSource,
                                   merge_anchor_durations, merge_numerics,
                                   synth_anchor_events)


def _env_int(name: str, default: int) -> int:
    return int(os.environ.get(name, default))


def tiny_train_setup(steps: Optional[int] = None):
    """Smoke-scale real-training configs (a shrunk ``gemma2-2b``), sized by
    env knobs so CI runners can shrink further:

      REPRO_TRAIN_ARCH / REPRO_TRAIN_LAYERS / REPRO_TRAIN_D_MODEL /
      REPRO_TRAIN_VOCAB / REPRO_TRAIN_BATCH / REPRO_TRAIN_SEQ_LEN /
      REPRO_TRAIN_STEPS

    Returns ``(model_cfg, data_cfg, opt_cfg, train_cfg)``."""
    from repro.configs.registry import ARCHS, reduced
    from repro.data.pipeline import DataConfig
    from repro.optim.adamw import OptConfig
    from repro.train.loop import TrainConfig
    arch = os.environ.get("REPRO_TRAIN_ARCH", "gemma2-2b")
    cfg = reduced(ARCHS[arch],
                  layers=_env_int("REPRO_TRAIN_LAYERS", 2),
                  d_model=_env_int("REPRO_TRAIN_D_MODEL", 64),
                  vocab=_env_int("REPRO_TRAIN_VOCAB", 512))
    data = DataConfig(batch=_env_int("REPRO_TRAIN_BATCH", 4),
                      seq_len=_env_int("REPRO_TRAIN_SEQ_LEN", 32))
    opt = OptConfig(lr_peak=5e-3, warmup_steps=2, total_steps=10_000)
    tc = TrainConfig(steps=(steps if steps is not None
                            else _env_int("REPRO_TRAIN_STEPS", 24)),
                     log_every=10_000, perftracker=False)
    return cfg, data, opt, tc


def default_trainer_detector_cfg(iters_per_window: int) -> DetectorConfig:
    """Detector thresholds for REAL (noisy) iteration times.

    The slowdown rule compares mean(last ``n_recent``) against the single
    SHORTEST iteration in history, so CPU-jit jitter alone can push the
    ratio to ~1.3-1.5x; a 2.0x threshold plus >=3x injected faults keeps a
    wide margin on both sides.  Locks fast (m=3) because a real warmed-up
    loop emits an identical (D, O) pair every iteration."""
    n_recent = max(3, min(8, iters_per_window // 2))
    return DetectorConfig(m_identical=3, n_recent=n_recent,
                          slowdown_ratio=2.0,
                          history_iters=50 * max(1, iters_per_window),
                          rearm_cooldown=0)


# -- live faults --------------------------------------------------------------

@dataclass(frozen=True)
class LiveFault:
    """A perturbation of the real loop on a worker subset."""
    workers: Tuple[int, ...]

    def apply(self, worker: "_TrainWorker") -> None:
        raise NotImplementedError


@dataclass(frozen=True)
class DataloaderBurn(LiveFault):
    """CPU burn inside ``dataloader.next`` (slow storage/preprocess, C2P1)."""
    factor: float = 3.0          # burn = factor x measured base iteration
    burn_s: float = 0.0          # absolute override

    def apply(self, worker: "_TrainWorker") -> None:
        worker.trainer.data_burn_s = \
            self.burn_s or self.factor * worker.base_iter_s


@dataclass(frozen=True)
class StepThrottle(LiveFault):
    """Stall inside the fenced ``train.step`` span (degraded device, C1P1)."""
    factor: float = 3.0          # iteration grows to ~factor x baseline
    pad_s: float = 0.0

    def apply(self, worker: "_TrainWorker") -> None:
        worker.trainer.step_pad_s = \
            self.pad_s or max(0.0, self.factor - 1.0) * worker.base_iter_s


@dataclass(frozen=True)
class GcPause(LiveFault):
    """``gc.collect()`` + stall on a worker subset (async GC, C2P3).

    The default pause is LONG (8x an iteration): ``gc.collect()`` itself
    burns real CPU walking a JAX-sized heap, and the paper's C2P3
    signature is a long NON-CPU-intensive frame — the idle wait has to
    dominate the collection work for mu to read < 0.3."""
    factor: float = 8.0
    pause_s: float = 0.0
    every: int = 1               # fire every N-th iteration

    def apply(self, worker: "_TrainWorker") -> None:
        worker.trainer.gc_pause_s = \
            self.pause_s or self.factor * worker.base_iter_s
        worker.trainer.gc_every = max(1, int(self.every))


@dataclass(frozen=True)
class ParamCorruption(LiveFault):
    """Corrupt the LIVE model state (a bad batch / optimizer blow-up,
    FLARE-style): every parameter is scaled so the REAL loss and gradient
    norm explode on the numerics channel.  Unlike the timing faults above
    this is state damage, not a hook — ``clear_faults`` cannot undo it;
    only restoring a checkpoint can, which is exactly what the
    ``ROLLBACK_TO_CHECKPOINT`` rung must prove it does.  While the fault
    stays scheduled it re-corrupts each window, so a rollback alone (with
    the underlying cause uncured) does not fake a recovery."""
    scale: float = 1e3
    nan: bool = False            # plant a NaN too (the immediate trigger)

    def apply(self, worker: "_TrainWorker") -> None:
        worker.corrupt_params(self.scale, self.nan)


def _install_faults(workers: Sequence["_TrainWorker"],
                    faults: Sequence[LiveFault]) -> None:
    for tw in workers:
        tw.clear_faults()
    for f in faults or []:
        for tw in workers:
            if tw.worker in f.workers:
                f.apply(tw)


# -- one real worker ----------------------------------------------------------

class _TrainWorker:
    """One fleet worker: a real ``Trainer`` + its ``Tracer``."""

    def __init__(self, worker: int, model_cfg, data_cfg, opt_cfg, train_cfg,
                 n_shards: int, rate_hz: float = 100.0, bundle=None):
        from repro.instrument.tracer import ProcessSampler, Tracer
        from repro.train.loop import Trainer
        self.worker = int(worker)
        data = replace(data_cfg, shard=self.worker % max(1, n_shards),
                       num_shards=max(1, n_shards))
        self.trainer = Trainer(model_cfg, data, opt_cfg,
                               replace(train_cfg, perftracker=False))
        if bundle is not None:
            self.trainer.bundle = bundle
        # per-process CPU: an idle wait in THIS trainer reads mu~0 even on
        # a busy shared host, which the playbook's mu rules depend on
        self.tracer = Tracer(worker=self.worker, samplers={
            "cpu": ProcessSampler(rate_hz=rate_hz)})
        self.params, self.opt_state, _ = self.trainer.init_state()
        self.base_iter_s = 0.0
        self.last_metrics: dict = {}

    def step(self) -> float:
        """One instrumented iteration; returns its wall duration."""
        t0 = time.perf_counter()
        self.params, self.opt_state, self.last_metrics = \
            self.trainer.train_iteration(self.params, self.opt_state,
                                         tracer=self.tracer)
        return time.perf_counter() - t0

    def warmup(self, iters: int = 3):
        """Compile (first step) + measure the healthy iteration baseline
        (tracer inactive, faults off).  Returns the compiled bundle so
        same-shape siblings can share it."""
        durs = [self.step() for _ in range(max(2, iters))]
        self.base_iter_s = float(np.median(durs[1:]))   # drop compile step
        return self.trainer.bundle

    def clear_faults(self) -> None:
        t = self.trainer
        t.data_burn_s = t.step_pad_s = t.gc_pause_s = 0.0
        t.gc_every = 1

    def corrupt_params(self, scale: float, nan: bool = False) -> None:
        """State-damage fault hook: blow up the live parameters (and with
        ``nan``, plant a non-finite value) so the next real train steps
        diverge for real."""
        import jax
        import jax.numpy as jnp
        self.params = jax.tree_util.tree_map(
            lambda x: x * jnp.asarray(scale, x.dtype), self.params)
        if nan:
            leaves, treedef = jax.tree_util.tree_flatten(self.params)
            first = leaves[0]
            leaves[0] = first.at[(0,) * first.ndim].set(
                jnp.asarray(float("nan"), first.dtype)) \
                if first.ndim else jnp.asarray(float("nan"), first.dtype)
            self.params = jax.tree_util.tree_unflatten(treedef, leaves)

    def run_window(self, iters: int, rate: Optional[float] = None):
        """One profiling window: returns (durations, WorkerProfile).

        Side effect: ``self.window_numerics`` holds the window's REAL
        per-iteration (loss, grad_norm) pairs from the train step's
        metrics — the numerics channel's raw material (DESIGN.md §12a)."""
        if rate is not None:
            self.tracer.set_rate(float(rate))
        self.tracer.start_window()
        durs: List[float] = []
        self.window_numerics: List[Tuple[float, float]] = []
        for _ in range(iters):
            durs.append(self.step())
            m = self.last_metrics or {}
            self.window_numerics.append(
                (float(m.get("loss", 0.0)),
                 float(m.get("grad_norm", 0.0))))
        return durs, self.tracer.stop_window()

    def close(self) -> None:
        self.trainer.loader.close()
        if self.trainer.ckpt is not None:
            self.trainer.ckpt.wait()


# -- the in-process workload --------------------------------------------------

class TrainerWorkload(WorkloadSource):
    """Real-trainer profile source for ``ScenarioRunner``.

    Workers build lazily on the first window (compiling eagerly would
    penalize the multi-process path, whose parent never steps a model).
    All workers share ONE compiled ``StepBundle``: identical configs lower
    to identical programs, so the fleet compiles exactly once."""

    is_trainer = True

    @property
    def family(self) -> str:
        """All-host workload: the localizer's Python expectation box uses
        the calibrated ``host`` ceiling (``repro.core.expectations``)."""
        return "host"

    def __init__(self, n_workers: int = 2, setup=None,
                 rate_hz: float = 100.0, warmup_iters: int = 3):
        self.n = int(n_workers)
        self.cfgs = setup if setup is not None else tiny_train_setup()
        self.rate_hz = float(rate_hz)
        self.warmup_iters = int(warmup_iters)
        self.workers: List[_TrainWorker] = []
        self._clock = 0.0

    @property
    def total_workers(self) -> int:
        return self.n

    @property
    def active_workers(self) -> np.ndarray:
        return np.arange(self.n)

    def _ensure_workers(self) -> None:
        if self.workers:
            return
        mc, dc, oc, tc = self.cfgs
        bundle = None
        for w in range(self.n):
            tw = _TrainWorker(w, mc, dc, oc, tc, n_shards=self.n,
                              rate_hz=self.rate_hz, bundle=bundle)
            bundle = tw.warmup(self.warmup_iters)
            self.workers.append(tw)

    @property
    def base_iter_s(self) -> float:
        self._ensure_workers()
        return float(np.median([tw.base_iter_s for tw in self.workers]))

    # -- recovery hooks (DESIGN.md §14) ------------------------------------
    def snapshot_state(self):
        """Gather the fleet's LIVE training state for a checkpoint:
        ``(step, tree)`` with one ``{params, opt}`` subtree per worker.
        The step is the trainers' iteration counter (identical across
        workers — they run the same windows)."""
        self._ensure_workers()
        step = int(self.workers[0].trainer._iter)
        tree = {str(tw.worker): {"params": tw.params, "opt": tw.opt_state}
                for tw in self.workers}
        return step, tree

    def install_state(self, step: int, tree) -> None:
        """Push a restored checkpoint back into the running trainers
        (the ROLLBACK_TO_CHECKPOINT landing): live params/opt_state and
        the iteration counters rewind to the saved step."""
        self._ensure_workers()
        for tw in self.workers:
            st = tree[str(tw.worker)]
            tw.params, tw.opt_state = st["params"], st["opt"]
            tw.trainer._iter = int(step)

    def run_window(self, window: int, faults: Sequence, iters: int,
                   rates: Optional[np.ndarray]) -> WindowData:
        self._ensure_workers()
        _install_faults(self.workers, faults)
        t0 = self._clock
        per_durs, per_num, profiles = [], [], []
        for tw in self.workers:       # sequential: per-worker cpu streams
            r = None if rates is None else float(rates[tw.worker])
            durs, prof = tw.run_window(iters, rate=r)
            per_durs.append(durs)
            per_num.append(tw.window_numerics)
            profiles.append(prof)
        merged = merge_anchor_durations(per_durs)
        anchors, self._clock = synth_anchor_events(merged, t0)
        return WindowData(anchors=anchors, profiles=profiles,
                          workers=np.arange(self.n), clock=self._clock,
                          t0=t0, metrics={"numerics": merge_numerics(
                              per_num, merged, t0)})

    def close(self) -> None:
        for tw in self.workers:
            tw.close()
        self.workers = []


# -- the multi-process worker entry point -------------------------------------

def trainer_worker_main(addresses, worker_ids, n_total, cfgs, schedule,
                        backend, max_queue, auth_token, max_frame,
                        iters_per_window, rate_hz=100.0) -> None:
    """One spawned process: real trainers for a fleet slice, driven by the
    parent's ``window_start`` broadcasts over the socket transport.

    Compiles + warms up BEFORE dialing the collector, so the parent's
    connection-wait doubles as the compile barrier and window 0's anchors
    are already steady-state.  Per window: install the schedule's live
    faults, run each worker's iterations, ship the measured durations
    (``anchors`` frame, undroppable) and the summarized pattern upload."""
    from repro.core.daemon import PerfTrackerDaemon
    mc, dc, oc, tc = cfgs
    workers: List[_TrainWorker] = []
    bundle = None
    for w in worker_ids:
        tw = _TrainWorker(int(w), mc, dc, oc, tc, n_shards=int(n_total),
                          rate_hz=rate_hz, bundle=bundle)
        bundle = tw.warmup()
        workers.append(tw)
    daemons = {tw.worker: PerfTrackerDaemon(tw.worker, addr, backend=backend,
                                            max_queue=max_queue,
                                            auth_token=auth_token,
                                            max_frame=max_frame)
               for tw, addr in zip(workers, addresses)}
    control = daemons[workers[0].worker]
    try:
        while True:
            msg = control.recv_control(timeout=120.0)
            if msg is None or msg.get("t") == "stop":
                return
            if msg.get("t") != "window_start":
                continue
            i = int(msg["window"])
            rates = msg.get("rates")
            _install_faults(workers,
                            [sf.fault for sf in schedule if sf.active(i)])
            for tw in workers:
                r = None if rates is None else float(rates[tw.worker])
                durs, prof = tw.run_window(int(iters_per_window), rate=r)
                d = daemons[tw.worker]
                d.send_anchors(i, durs, numerics=tw.window_numerics)
                d.process_window(i, prof)
    finally:
        for d in daemons.values():
            d.close()
        for tw in workers:
            tw.close()
