"""Production training loop: jit'd train step with sharded state, PerfTracker
attached (import-only anchors), async checkpointing, elastic restart, and a
mitigation hook (``_maybe_mitigate``: consumes PerfTracker diagnoses as they
land, records the planned actions, and fronts REPLACE_HOSTS/CHECKPOINT_NOW
plans with an immediate checkpoint save — it does not re-mesh by itself).

``train_iteration`` is the fully-instrumented single step the
``TrainerWorkload`` (``repro.train.workload``) drives: every phase of a real
jit'd step — ``dataloader.next`` / ``train.step`` (fwd+bwd, fenced with
``block_until_ready``) / ``optimizer.step`` / ``ckpt.save`` — is recorded
as a Tracer event, and the fused fwd+bwd span is additionally split into
``xla.gemm`` / ``xla.other`` sub-events by the compiled module's HLO cost
model (XLA fuses ops, so the host never sees per-op boundaries; the
roofline split is the cost-model attribution DESIGN.md §11 describes).
"""
from __future__ import annotations

import gc
import time
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.ckpt.checkpoint import Checkpointer, CheckpointError
from repro.core.events import Kind
from repro.core.mitigation import Action, plan_mitigations
from repro.data.pipeline import DataConfig, DataLoader, SyntheticLM
from repro.dist.sharding import DistCtx
from repro.instrument.hooks import PerfTracker, PerfTrackerConfig
from repro.models.transformer import Transformer
from repro.optim.adamw import AdamW, OptConfig
from repro.train.step import make_split_train_step, make_train_step

#: CPU-ish roofline used to split the fused step's fenced span between the
#: "xla.gemm" and "xla.other" cost-model sub-events (absolute values only
#: set the split ratio; it is identical across same-program workers, so
#: differential localization is insensitive to the constants)
_ROOFLINE_FLOPS_S = 5e10
_ROOFLINE_BYTES_S = 2e10


@contextmanager
def _noop_phase(name, kind=None, depth=1, fence=None, resource=""):
    yield


@dataclass
class StepBundle:
    """Compiled split-step executables shared across same-shape trainers.

    ``grad_step`` is the AOT-compiled fwd+bwd (compiled once via
    ``jit.lower(...).compile()`` so the same compile also yields the HLO
    text for cost attribution); ``opt_step`` is the jitted optimizer
    update with donated inputs.  An in-process fleet of identical tiny
    trainers assigns one bundle to every ``Trainer.bundle`` and compiles
    exactly once."""
    grad_step: Callable
    opt_step: Callable
    gemm_frac: Optional[float]      # None = HLO cost attribution unavailable


@dataclass
class TrainConfig:
    steps: int = 50
    log_every: int = 10
    ckpt_every: int = 0              # 0 = off
    ckpt_dir: str = ""
    remat: str = "none"
    folded: bool = False
    perftracker: bool = True
    pt_window_s: float = 1.0
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ModelConfig, data: DataConfig,
                 opt_cfg: OptConfig, tc: TrainConfig,
                 dist: Optional[DistCtx] = None):
        self.cfg, self.data_cfg, self.tc = cfg, data, tc
        self.dist = dist
        self.model = Transformer(cfg, dist=dist, remat=tc.remat,
                                 folded=tc.folded)
        self.opt = AdamW(opt_cfg)
        self.source = SyntheticLM(cfg, data)
        self.loader = DataLoader(self.source)
        step_fn = make_train_step(self.model, self.opt)
        self._jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
        self.pt: Optional[PerfTracker] = None
        if tc.perftracker:
            self.pt = PerfTracker(PerfTrackerConfig(
                window_s=tc.pt_window_s,
                family="moe" if cfg.is_moe else "dense"))
            self._next, self._opt_anchor = self.pt.wrap(
                self.loader.next, lambda: None)
        else:
            self._next, self._opt_anchor = self.loader.next, lambda: None
        self.ckpt = Checkpointer(tc.ckpt_dir) if tc.ckpt_dir else None
        self.history: list = []
        self.mitigations: list = []
        self.last_diagnosis = None       # most recent consumed PT result
        # split-step bundle for the instrumented train_iteration path
        # (built lazily on first use; assignable so an in-process fleet of
        # identical trainers shares one compile)
        self.bundle: Optional[StepBundle] = None
        self._step_resource = "cpu" if jax.default_backend() == "cpu" else ""
        self._iter = 0
        # live fault-injection hooks (repro.train.workload perturbs the
        # REAL loop for end-to-end diagnosis scenarios); all off by default
        self.data_burn_s = 0.0           # CPU spin inside dataloader.next
        self.step_pad_s = 0.0            # stall inside train.step
        self.gc_pause_s = 0.0            # gc.collect + stall, every
        self.gc_every = 1                # gc_every iterations

    # ------------------------------------------------------------------
    def init_state(self, resume: bool = True):
        params = self.model.init(jax.random.PRNGKey(self.tc.seed))
        opt_state = self.opt.init(params)
        start = 0
        if self.ckpt and resume:
            latest = self.ckpt.latest_step()
            if latest is not None:
                shardings = None
                if self.dist is not None and self.dist.mesh is not None:
                    # every leaf needs a REAL sharding (a None leaf would
                    # break tree_map structure matching in restore): scalar
                    # opt state rides the mesh replicated
                    ps = self.dist.params_shardings(params)
                    shardings = {"params": ps,
                                 "opt": self.opt.state_shardings(
                                     ps, self.dist.replicated())}
                (params, opt_state), meta = self._restore(
                    latest, params, opt_state, shardings)
                start = meta["step"]
        return params, opt_state, start

    def _restore(self, step, params, opt_state, shardings=None):
        tree, meta = self.ckpt.restore(step, {"params": params,
                                              "opt": opt_state},
                                       shardings=shardings)
        return (tree["params"], tree["opt"]), meta

    # ------------------------------------------------------------------
    def ensure_bundle(self, params, batch) -> StepBundle:
        """Build (or return) the compiled split-step bundle.

        AOT path: one ``jit.lower(...).compile()`` yields both the
        executable and the optimized HLO text, so cost attribution never
        costs a second compile."""
        if self.bundle is None:
            grad_fn, opt_fn = make_split_train_step(self.model, self.opt)
            compiled = jax.jit(grad_fn).lower(params, batch).compile()
            gemm_frac = None
            try:
                from repro.launch.hlo_cost import expanded_cost
                cost = expanded_cost(compiled.as_text(), num_devices=1)
                t_gemm = cost.flops / _ROOFLINE_FLOPS_S
                t_other = cost.bytes / _ROOFLINE_BYTES_S
                if t_gemm + t_other > 0.0:
                    gemm_frac = min(0.95, max(0.05,
                                              t_gemm / (t_gemm + t_other)))
            except Exception:
                gemm_frac = None          # attribution is best-effort
            self.bundle = StepBundle(
                grad_step=compiled,
                opt_step=jax.jit(opt_fn, donate_argnums=(0, 1, 2)),
                gemm_frac=gemm_frac)
        return self.bundle

    def train_iteration(self, params, opt_state, tracer=None):
        """One fully-instrumented iteration of the REAL loop.

        Identical math to ``run()``'s fused step, but split so every phase
        is a genuine host-visible span: ``dataloader.next`` (PYTHON),
        ``train.step`` (fwd+bwd, fenced on the grads, split into
        ``xla.gemm``/``xla.other`` depth-2 sub-events by the HLO cost
        model), ``optimizer.step`` (fenced on the new params), and
        ``ckpt.save`` when a checkpoint interval hits.  ``tracer`` may be
        None or inactive — the loop then runs unobserved (the overhead
        benchmark's baseline).  Returns ``(params, opt_state, metrics)``.
        """
        ph = tracer.phase if tracer is not None else _noop_phase
        with ph("dataloader.next", Kind.PYTHON):
            batch_np = self.loader.next()
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            if self.data_burn_s > 0.0:    # injected fault: CPU-burning loader
                deadline = time.perf_counter() + self.data_burn_s
                x = 1.0
                while time.perf_counter() < deadline:
                    x = x * 1.0000001 + 1.0
        bundle = self.ensure_bundle(params, batch)
        res = self._step_resource
        t0 = time.perf_counter()
        grads, metrics = bundle.grad_step(params, batch)
        if self.step_pad_s > 0.0:         # injected fault: slow device step
            time.sleep(self.step_pad_s)
        jax.block_until_ready(grads)
        t1 = time.perf_counter()
        if tracer is not None and tracer.active:
            tracer.add_event("train.step", Kind.GPU, t0, t1, depth=1,
                             resource=res)
            if bundle.gemm_frac is not None:
                cut = t0 + (t1 - t0) * bundle.gemm_frac
                tracer.add_event("xla.gemm", Kind.GPU, t0, cut, depth=2,
                                 resource=res)
                tracer.add_event("xla.other", Kind.GPU, cut, t1, depth=2,
                                 resource=res)
        with ph("optimizer.step", Kind.GPU, resource=res,
                fence=lambda: new_params):
            new_params, new_opt, opt_metrics = bundle.opt_step(
                grads, opt_state, params)
        self._iter += 1
        if self.ckpt and self.tc.ckpt_every \
                and self._iter % self.tc.ckpt_every == 0:
            with ph("ckpt.save", Kind.PYTHON):
                self.ckpt.save(self._iter, {"params": new_params,
                                            "opt": new_opt})
        if self.gc_pause_s > 0.0 and self._iter % max(1, self.gc_every) == 0:
            # injected fault: unsynchronized gc stall (C2P3 stand-in)
            with ph("runtime.gc", Kind.PYTHON):
                gc.collect()
                time.sleep(self.gc_pause_s)
        m = dict(metrics)
        m.update(opt_metrics)
        return new_params, new_opt, m

    # ------------------------------------------------------------------
    def run(self, steps: Optional[int] = None):
        params, opt_state, start = self.init_state()
        n = steps or self.tc.steps
        tracer = self.pt.tracer if self.pt else None
        for step in range(start, start + n):
            batch_np = self._next()
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            if tracer:
                with tracer.phase("train.step", Kind.GPU, depth=1,
                                  fence=lambda: metrics["loss"]):
                    params, opt_state, metrics = self._jit_step(
                        params, opt_state, batch)
            else:
                params, opt_state, metrics = self._jit_step(
                    params, opt_state, batch)
            self._opt_anchor()
            if (step + 1) % self.tc.log_every == 0 or step == start:
                m = {k: float(v) for k, v in metrics.items()}
                self.history.append({"step": step + 1, **m})
                print(f"step {step+1:5d} loss {m['loss']:.4f} "
                      f"nll {m['nll']:.4f} gnorm {m['grad_norm']:.3f} "
                      f"lr {m['lr']:.2e}", flush=True)
            if self.ckpt and self.tc.ckpt_every \
                    and (step + 1) % self.tc.ckpt_every == 0:
                self.ckpt.save(step + 1, {"params": params,
                                          "opt": opt_state})
            params, opt_state = self._maybe_mitigate(params, opt_state,
                                                     step + 1)
        if self.ckpt:
            self.ckpt.save(start + n, {"params": params, "opt": opt_state},
                           async_=False)
        self.loader.close()
        return params, opt_state

    # ------------------------------------------------------------------
    def _maybe_mitigate(self, params, opt_state, step: int):
        """PerfTracker output drives fault tolerance (DESIGN.md §4).
        Returns the (possibly rolled-back) live state."""
        if not self.pt or not self.pt.results:
            return params, opt_state
        res = self.pt.results.pop()
        self.last_diagnosis = res
        plans = plan_mitigations(res.diagnoses, fleet_size=1)
        for p in plans:
            if p.action == Action.NONE:
                continue
            self.mitigations.append((step, p))
            print(f"[perftracker] step {step}: {res.trigger.reason if res.trigger else '?'} -> "
                  f"{p.action.value}: {p.detail}", flush=True)
            # both actions begin with an immediate checkpoint: replace
            # re-meshes from it, checkpoint_now protects against the
            # widespread-hardware abnormality getting worse
            if p.action in (Action.REPLACE_HOSTS, Action.CHECKPOINT_NOW) \
                    and self.ckpt:
                self.ckpt.save(step, {"params": params, "opt": opt_state})
            # rollback is REAL (DESIGN.md §14): restore the latest valid
            # on-disk step into the live loop; with nothing usable on
            # disk the state is honestly left as-is (no faked cure)
            if p.action == Action.ROLLBACK_TO_CHECKPOINT and self.ckpt:
                latest = self.ckpt.latest_step()
                if latest is not None:
                    try:
                        (params, opt_state), meta = self._restore(
                            latest, params, opt_state)
                        self._iter = meta["step"]
                        print(f"[perftracker] rolled back to step "
                              f"{meta['step']}", flush=True)
                    except CheckpointError as e:
                        print(f"[perftracker] rollback failed: {e}",
                              flush=True)
        return params, opt_state
