"""Production training loop: jit'd train step with sharded state, PerfTracker
attached (import-only anchors), async checkpointing, elastic restart, and
mitigation hooks (localizer output -> checkpoint-now + re-mesh).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.ckpt.checkpoint import Checkpointer
from repro.core.events import Kind
from repro.core.mitigation import Action, plan_mitigations
from repro.data.pipeline import DataConfig, DataLoader, SyntheticLM
from repro.dist.sharding import DistCtx
from repro.instrument.hooks import PerfTracker, PerfTrackerConfig
from repro.models.transformer import Transformer
from repro.optim.adamw import AdamW, OptConfig
from repro.train.step import make_train_step


@dataclass
class TrainConfig:
    steps: int = 50
    log_every: int = 10
    ckpt_every: int = 0              # 0 = off
    ckpt_dir: str = ""
    remat: str = "none"
    folded: bool = False
    perftracker: bool = True
    pt_window_s: float = 1.0
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ModelConfig, data: DataConfig,
                 opt_cfg: OptConfig, tc: TrainConfig,
                 dist: Optional[DistCtx] = None):
        self.cfg, self.data_cfg, self.tc = cfg, data, tc
        self.dist = dist
        self.model = Transformer(cfg, dist=dist, remat=tc.remat,
                                 folded=tc.folded)
        self.opt = AdamW(opt_cfg)
        self.source = SyntheticLM(cfg, data)
        self.loader = DataLoader(self.source)
        step_fn = make_train_step(self.model, self.opt)
        self._jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
        self.pt: Optional[PerfTracker] = None
        if tc.perftracker:
            self.pt = PerfTracker(PerfTrackerConfig(
                window_s=tc.pt_window_s,
                family="moe" if cfg.is_moe else "dense"))
            self._next, self._opt_anchor = self.pt.wrap(
                self.loader.next, lambda: None)
        else:
            self._next, self._opt_anchor = self.loader.next, lambda: None
        self.ckpt = Checkpointer(tc.ckpt_dir) if tc.ckpt_dir else None
        self.history: list = []
        self.mitigations: list = []
        self.last_diagnosis = None       # most recent consumed PT result

    # ------------------------------------------------------------------
    def init_state(self, resume: bool = True):
        params = self.model.init(jax.random.PRNGKey(self.tc.seed))
        opt_state = self.opt.init(params)
        start = 0
        if self.ckpt and resume:
            latest = self.ckpt.latest_step()
            if latest is not None:
                shardings = None
                if self.dist is not None and self.dist.mesh is not None:
                    ps = self.dist.params_shardings(params)
                    shardings = {"params": ps,
                                 "opt": self.opt.state_shardings(ps, None)}
                (params, opt_state), meta = self._restore(latest, params,
                                                          opt_state)
                start = meta["step"]
        return params, opt_state, start

    def _restore(self, step, params, opt_state):
        tree, meta = self.ckpt.restore(step, {"params": params,
                                              "opt": opt_state})
        return (tree["params"], tree["opt"]), meta

    # ------------------------------------------------------------------
    def run(self, steps: Optional[int] = None):
        params, opt_state, start = self.init_state()
        n = steps or self.tc.steps
        tracer = self.pt.tracer if self.pt else None
        for step in range(start, start + n):
            batch_np = self._next()
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            if tracer:
                with tracer.phase("train.step", Kind.GPU, depth=1,
                                  fence=lambda: metrics["loss"]):
                    params, opt_state, metrics = self._jit_step(
                        params, opt_state, batch)
            else:
                params, opt_state, metrics = self._jit_step(
                    params, opt_state, batch)
            self._opt_anchor()
            if (step + 1) % self.tc.log_every == 0 or step == start:
                m = {k: float(v) for k, v in metrics.items()}
                self.history.append({"step": step + 1, **m})
                print(f"step {step+1:5d} loss {m['loss']:.4f} "
                      f"nll {m['nll']:.4f} gnorm {m['grad_norm']:.3f} "
                      f"lr {m['lr']:.2e}", flush=True)
            if self.ckpt and self.tc.ckpt_every \
                    and (step + 1) % self.tc.ckpt_every == 0:
                self.ckpt.save(step + 1, {"params": params,
                                          "opt": opt_state})
            self._maybe_mitigate(params, opt_state, step + 1)
        if self.ckpt:
            self.ckpt.save(start + n, {"params": params, "opt": opt_state},
                           async_=False)
        self.loader.close()
        return params, opt_state

    # ------------------------------------------------------------------
    def _maybe_mitigate(self, params, opt_state, step: int):
        """PerfTracker output drives fault tolerance (DESIGN.md §4)."""
        if not self.pt or not self.pt.results:
            return
        res = self.pt.results.pop()
        self.last_diagnosis = res
        plans = plan_mitigations(res.diagnoses, fleet_size=1)
        for p in plans:
            if p.action == Action.NONE:
                continue
            self.mitigations.append((step, p))
            print(f"[perftracker] step {step}: {res.trigger.reason if res.trigger else '?'} -> "
                  f"{p.action.value}: {p.detail}", flush=True)
            # both actions begin with an immediate checkpoint: replace
            # re-meshes from it, checkpoint_now protects against the
            # widespread-hardware abnormality getting worse
            if p.action in (Action.REPLACE_HOSTS, Action.CHECKPOINT_NOW) \
                    and self.ckpt:
                self.ckpt.save(step, {"params": params, "opt": opt_state})
