"""Deterministic synthetic token pipeline with a prefetching loader.

The loader exposes ``next()`` — one of PerfTracker's two anchors. A
``delay_s`` knob injects storage slowness (used by examples/tests to
reproduce paper case C2P1 online).

Data is generated from a counting PRNG keyed by (seed, step, shard), so any
(worker, step) pair is reproducible regardless of fleet size — elastic
restarts resume mid-epoch deterministically.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass
from typing import Dict

import numpy as np

from repro.configs.base import ModelConfig


@dataclass
class DataConfig:
    batch: int = 8
    seq_len: int = 128
    seed: int = 1234
    shard: int = 0              # this host's DP shard index
    num_shards: int = 1
    prefetch: int = 2
    delay_s: float = 0.0        # injected storage latency (C2P1 repro)


class SyntheticLM:
    """Markov-ish synthetic token stream: next-token structure so a real
    model can overfit it (loss decreases — used in examples/train_lm.py)."""

    def __init__(self, cfg: ModelConfig, data: DataConfig):
        self.cfg = cfg
        self.data = data

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        d = self.data
        rng = np.random.default_rng(
            (d.seed, step, d.shard))
        B, S, V = d.batch, d.seq_len, self.cfg.vocab_size
        # structured stream: tok[t+1] = (a*tok[t] + b) % V with noise
        a = 31, 17
        x = np.zeros((B, S + 1), np.int64)
        x[:, 0] = rng.integers(0, V, B)
        mult = rng.integers(1, 8, B)[:, None]
        for t in range(S):
            nxt = (x[:, t] * 31 + 17 * mult[:, 0]) % V
            noise = rng.random(B) < 0.05
            x[:, t + 1] = np.where(noise, rng.integers(0, V, B), nxt)
        out = {"tokens": x[:, :-1].astype(np.int32),
               "labels": x[:, 1:].astype(np.int32)}
        if self.cfg.frontend == "audio":
            rngf = np.random.default_rng((d.seed, step, d.shard, 7))
            out = {"embeds": rngf.normal(
                size=(B, S, self.cfg.d_model)).astype(np.float32),
                "labels": out["labels"]}
        elif self.cfg.frontend == "vision":
            F = min(self.cfg.frontend_tokens, S - 1)
            rngf = np.random.default_rng((d.seed, step, d.shard, 7))
            out = {"embeds": rngf.normal(
                size=(B, F, self.cfg.d_model)).astype(np.float32),
                "tokens": out["tokens"][:, :S - F],
                "labels": out["labels"]}
        return out


class DataLoader:
    """Prefetching loader; ``next()`` is the PerfTracker anchor."""

    def __init__(self, source: SyntheticLM, start_step: int = 0):
        self.source = source
        self.step = start_step
        self._q: "queue.Queue" = queue.Queue(
            maxsize=max(1, source.data.prefetch))
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._produce_step = start_step
        self._thread.start()

    def _producer(self):
        while not self._stop.is_set():
            b = self.source.batch_at(self._produce_step)
            self._produce_step += 1
            while not self._stop.is_set():
                try:
                    self._q.put(b, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def next(self) -> Dict[str, np.ndarray]:
        if self.source.data.delay_s:
            time.sleep(self.source.data.delay_s)   # injected storage fault
        b = self._q.get()
        self.step += 1
        return b

    def close(self):
        self._stop.set()
