from repro.data.pipeline import DataConfig, DataLoader, SyntheticLM  # noqa: F401
