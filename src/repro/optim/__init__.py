from repro.optim.adamw import AdamW, OptConfig, lr_schedule, global_norm  # noqa: F401
