"""AdamW from scratch (no optax): fp32 master weights + moments, global-norm
clipping, name-based weight-decay masking, warmup+cosine schedule.

State layout mirrors the param tree so the FSDP/TP shardings of the params
apply leaf-for-leaf to m / v / master (ZeRO-3: optimizer state is sharded
exactly like its parameter).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

NO_DECAY_TOKENS = ("norm", "scale", "bias", "ln", "A_log", "dt_bias",
                   "/D", "bi", "bo", "bq", "bk", "bv")


@dataclass(frozen=True)
class OptConfig:
    lr_peak: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_schedule(c: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, c.warmup_steps)
    prog = (step - c.warmup_steps) / jnp.maximum(
        1.0, c.total_steps - c.warmup_steps)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = c.min_lr_ratio + (1 - c.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return c.lr_peak * jnp.where(step < c.warmup_steps, warm, cos)


def _decay_mask(params) -> Any:
    def one(kp, leaf):
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        return not any(t in path for t in NO_DECAY_TOKENS)
    return jax.tree_util.tree_map_with_path(one, params)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


class AdamW:
    def __init__(self, cfg: OptConfig):
        self.cfg = cfg

    def init(self, params) -> Dict[str, Any]:
        f32 = lambda t: jax.tree_util.tree_map(
            lambda x: jnp.zeros(x.shape, jnp.float32), t)
        # copy=True: master must never alias the (donatable) param buffers
        master = jax.tree_util.tree_map(
            lambda x: jnp.array(x, dtype=jnp.float32, copy=True), params)
        return {"m": f32(params), "v": f32(params), "master": master,
                "step": jnp.zeros((), jnp.int32)}

    def update(self, grads, state, params) -> Tuple[Any, Dict[str, Any],
                                                    Dict[str, jax.Array]]:
        c = self.cfg
        step = state["step"] + 1
        lr = lr_schedule(c, step)
        g32 = jax.tree_util.tree_map(
            lambda g: g.astype(jnp.float32), grads)
        gnorm = global_norm(g32)
        scale = jnp.minimum(1.0, c.clip_norm / jnp.maximum(gnorm, 1e-12)) \
            if c.clip_norm else jnp.float32(1.0)
        g32 = jax.tree_util.tree_map(lambda g: g * scale, g32)

        b1c = 1 - c.b1 ** step.astype(jnp.float32)
        b2c = 1 - c.b2 ** step.astype(jnp.float32)
        mask = _decay_mask(params)

        def upd(g, m, v, w, decay):
            m = c.b1 * m + (1 - c.b1) * g
            v = c.b2 * v + (1 - c.b2) * jnp.square(g)
            mh = m / b1c
            vh = v / b2c
            delta = mh / (jnp.sqrt(vh) + c.eps)
            if decay:
                delta = delta + c.weight_decay * w
            return m, v, w - lr * delta

        flat_g, treedef = jax.tree_util.tree_flatten(g32)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        flat_w = treedef.flatten_up_to(state["master"])
        flat_mask = treedef.flatten_up_to(mask)
        new_m, new_v, new_w = [], [], []
        for g, m, v, w, dk in zip(flat_g, flat_m, flat_v, flat_w, flat_mask):
            m2, v2, w2 = upd(g, m, v, w, dk)
            new_m.append(m2); new_v.append(v2); new_w.append(w2)
        master = jax.tree_util.tree_unflatten(treedef, new_w)
        new_state = {
            "m": jax.tree_util.tree_unflatten(treedef, new_m),
            "v": jax.tree_util.tree_unflatten(treedef, new_v),
            "master": master,
            "step": step,
        }
        new_params = jax.tree_util.tree_map(
            lambda w, p: w.astype(p.dtype), master, params)
        return new_params, new_state, {"lr": lr, "grad_norm": gnorm}

    def state_shardings(self, param_shardings, replicated):
        """Shardings for the opt state given the params' shardings.
        ``replicated`` is a NamedSharding for scalars."""
        return {"m": param_shardings, "v": param_shardings,
                "master": param_shardings, "step": replicated}
