"""Gradient compression for cross-pod (DCN) data parallelism.

At 1000+ nodes the inter-pod gradient all-reduce crosses the slowest fabric.
Two compressors:

  * ``bf16``  — cast grads to bf16 for the reduction (2x traffic cut;
    error-free in practice at LLM scales);
  * ``int8``  — per-tensor symmetric int8 quantization with ERROR FEEDBACK
    (residual carried in the optimizer state; Seide et al. / 1-bit-SGD
    lineage): 4x traffic cut, unbiased in the long run.

``compressed_psum`` runs inside shard_map over the pod axis; the in-pod
reduction stays full precision (ICI is cheap), only the DCN hop is
compressed — the hierarchical schedule from DESIGN.md §7.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree_int8(grads, residual):
    """Error feedback: g' = g + residual; transmit Q(g'); residual = g'-Q."""
    if residual is None:
        residual = jax.tree_util.tree_map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q, s = quantize_int8(gf)
        deq = dequantize_int8(q, s)
        return (q, s), gf - deq

    qs = jax.tree_util.tree_map(one, grads, residual,
                                is_leaf=lambda x: isinstance(x, jax.Array))
    quant = jax.tree_util.tree_map(lambda t: t[0], qs,
                                   is_leaf=lambda t: isinstance(t, tuple)
                                   and len(t) == 2)
    new_res = jax.tree_util.tree_map(lambda t: t[1], qs,
                                     is_leaf=lambda t: isinstance(t, tuple)
                                     and len(t) == 2)
    return quant, new_res


def psum_compressed(grads, axis_name: str, method: str = "bf16",
                    residual=None):
    """All-reduce ``grads`` over ``axis_name`` with compression. Returns
    (mean_grads_f32, new_residual). Call inside shard_map."""
    if method == "none":
        out = jax.tree_util.tree_map(
            lambda g: jax.lax.pmean(g.astype(jnp.float32), axis_name), grads)
        return out, residual
    if method == "bf16":
        out = jax.tree_util.tree_map(
            lambda g: jax.lax.pmean(g.astype(jnp.bfloat16),
                                    axis_name).astype(jnp.float32), grads)
        return out, residual
    if method == "int8":
        if residual is None:
            residual = jax.tree_util.tree_map(
                lambda g: jnp.zeros(g.shape, jnp.float32), grads)

        def one(g, r):
            gf = g.astype(jnp.float32) + r
            # SHARED scale across the axis (tiny pmax of a scalar) so the
            # int32-summed payload dequantizes exactly
            s = jax.lax.pmax(jnp.max(jnp.abs(gf)) / 127.0 + 1e-30,
                             axis_name)
            q = jnp.clip(jnp.round(gf / s), -127, 127).astype(jnp.int8)
            new_r = gf - q.astype(jnp.float32) * s
            tot = jax.lax.psum(q.astype(jnp.int32), axis_name)
            n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
            return tot.astype(jnp.float32) * s / n, new_r

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_r = treedef.flatten_up_to(residual)
        outs, ress = [], []
        for g, r in zip(flat_g, flat_r):
            o, nr = one(g, r)
            outs.append(o)
            ress.append(nr)
        return (jax.tree_util.tree_unflatten(treedef, outs),
                jax.tree_util.tree_unflatten(treedef, ress))
    raise ValueError(method)


def compression_ratio(method: str) -> float:
    return {"none": 1.0, "bf16": 2.0, "int8": 4.0}[method]
