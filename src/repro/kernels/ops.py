"""Jit'd public wrappers for the Pallas kernels.

On this CPU container kernels run with ``interpret=True`` (the Pallas
interpreter executes the kernel body for correctness); on TPU backends the
same calls lower to Mosaic. ``auto_interpret()`` picks per-backend.
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.pattern_summary import pattern_summary as _psum
from repro.kernels.ssd_scan import ssd_scan as _ssd


def auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "softcap", "scale",
                                   "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, softcap=0.0,
                    scale=0.0, block_q=128, block_k=128, interpret=None):
    interpret = auto_interpret() if interpret is None else interpret
    return _flash(q, k, v, causal=causal, window=window, softcap=softcap,
                  scale=scale, block_q=block_q, block_k=block_k,
                  interpret=interpret)


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, Bm, Cm, chunk=128, interpret=None):
    interpret = auto_interpret() if interpret is None else interpret
    return _ssd(x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret)


@partial(jax.jit, static_argnames=("block_events", "interpret"))
def pattern_summary(u, block_events=8, interpret=None):
    interpret = auto_interpret() if interpret is None else interpret
    return _psum(u, block_events=block_events, interpret=interpret)
