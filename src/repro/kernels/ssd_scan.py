"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

Grid (B, H, NC) with the chunk axis innermost: the running inter-chunk state
(N, P) lives in VMEM scratch and is carried across the NC iterations of one
(b, h) cell — the chunk recurrence is sequential by construction, so the
kernel keeps the state resident instead of round-tripping HBM (the TPU
adaptation of the paper's GPU SSD kernel; DESIGN.md §2).

Per chunk (all in VMEM, MXU for the three matmuls):
  cum   = cumsum(dt * A)                          (Q,)
  CB    = C @ B^T  masked by decay L              (Q, Q)
  y     = (CB * L) @ x  +  (C @ state) * exp(cum) (Q, P)
  state = exp(cum[-1]) * state + (B * w)^T @ x    (N, P)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref, *,
            q: int, nc: int):
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)      # (Q,)
    A = a_ref[0]                               # scalar (negative)
    Bm = b_ref[0, 0].astype(jnp.float32)       # (Q, N)
    Cm = c_ref[0, 0].astype(jnp.float32)       # (Q, N)

    dA = dt * A
    cum = jnp.cumsum(dA)                       # (Q,)
    # intra-chunk
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q,Q)
    rel = cum[:, None] - cum[None, :]
    tril = (jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
            >= jax.lax.broadcasted_iota(jnp.int32, (q, q), 1))
    Lmat = jnp.where(tril, jnp.exp(rel), 0.0) * dt[None, :]
    y = jax.lax.dot_general(CB * Lmat, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # inter-chunk
    state = state_ref[...]                     # (N, P)
    y += jax.lax.dot_general(Cm, state, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32) \
        * jnp.exp(cum)[:, None]
    # state update
    w = jnp.exp(cum[-1] - cum) * dt            # (Q,)
    ds = jax.lax.dot_general(Bm * w[:, None], x, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    state_ref[...] = state * jnp.exp(cum[-1]) + ds
    y_ref[0, 0] = y.astype(y_ref.dtype)


def ssd_scan(x, dt, A, Bm, Cm, chunk: int = 128, interpret: bool = True):
    """x: (B,S,H,P); dt: (B,S,H); A: (H,); Bm/Cm: (B,S,G,N).
    Returns y: (B,S,H,P)."""
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q

    xt = x.transpose(0, 2, 1, 3)               # (B,H,S,P)
    dtt = dt.transpose(0, 2, 1)                # (B,H,S)
    bt = Bm.transpose(0, 2, 1, 3)              # (B,G,S,N)
    ct = Cm.transpose(0, 2, 1, 3)

    kernel = functools.partial(_kernel, q=Q, nc=nc)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, Q), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c: (b, h // rep, c, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c: (b, h // rep, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, Q, P), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, A.astype(jnp.float32), bt, ct)
    return out.transpose(0, 2, 1, 3)
