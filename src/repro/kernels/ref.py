"""Pure-jnp/numpy oracles for every Pallas kernel (the allclose targets for
tests/test_kernels.py shape/dtype sweeps)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.patterns import critical_duration

NEG_INF = -1.0e30


# -- flash attention ---------------------------------------------------------

def attention_oracle(q, k, v, *, causal=True, window=0, softcap=0.0,
                     scale=0.0):
    """Unblocked softmax attention with GQA. q: (B,Sq,H,D); k/v: (B,S,KV,D)."""
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = scale or 1.0 / math.sqrt(D)
    qg = q.reshape(B, Sq, KV, G, D)
    s = jnp.einsum("btkgd,bskd->btkgs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    qpos = jnp.arange(Sq)
    kpos = jnp.arange(k.shape[1])
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window:
        mask &= qpos[:, None] - kpos[None, :] < window
    s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("btkgs,bskd->btkgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, v.shape[-1]).astype(q.dtype)


# -- SSD scan ----------------------------------------------------------------

def ssd_oracle(x, dt, A, Bm, Cm):
    """Naive sequential state-space recurrence (fp32).
    x: (B,S,H,P); dt: (B,S,H); A: (H,); Bm/Cm: (B,S,G,N)."""
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    xf = np.asarray(x, np.float64)
    dtf = np.asarray(dt, np.float64)
    Af = np.asarray(A, np.float64)
    Bf = np.repeat(np.asarray(Bm, np.float64), rep, axis=2)  # (B,S,H,N)
    Cf = np.repeat(np.asarray(Cm, np.float64), rep, axis=2)
    state = np.zeros((B, H, N, P))
    y = np.zeros_like(xf)
    for t in range(S):
        a = np.exp(dtf[:, t] * Af)                    # (B,H)
        state = state * a[..., None, None] + np.einsum(
            "bhn,bhp,bh->bhnp", Bf[:, t], xf[:, t], dtf[:, t])
        y[:, t] = np.einsum("bhn,bhnp->bhp", Cf[:, t], state)
    return jnp.asarray(y, x.dtype)


# -- pattern summary -----------------------------------------------------------

def pattern_summary_oracle(u: np.ndarray) -> np.ndarray:
    """Per-row (mean, std, frac_len) via the exact Algorithm-1 search
    (repro.core.patterns.critical_duration)."""
    out = []
    for row in np.asarray(u, np.float64):
        n = len(row)
        if row.sum() <= 0:
            out.append((0.0, 0.0, 1.0))
            continue
        lo, hi = critical_duration(row)
        seg = row[lo:hi]
        out.append((float(seg.mean()), float(seg.std()),
                    (hi - lo) / n))
    return np.asarray(out, np.float32)
