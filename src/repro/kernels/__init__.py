from repro.kernels.ops import flash_attention, pattern_summary, ssd_scan  # noqa: F401
