"""Pallas TPU flash attention (GQA + causal + sliding-window + softcap).

Canonical TPU schedule: grid (batch, q_heads, NQ, NK) with the NK axis
innermost; online-softmax running stats (m, l) and the output accumulator
live in VMEM scratch and persist across the NK iterations of one (b, h, i)
cell. BlockSpecs tile q/k/v into (BQ, D)/(BK, D) VMEM blocks, MXU-aligned
(BQ, BK multiples of 128 on TPU; head_dim is the lane dim).

Validated in interpret mode against repro.kernels.ref.attention_oracle
(tests/test_kernels.py sweeps shapes/dtypes).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int, softcap: float,
            bq: int, bk: int, nk: int):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (BQ, D)
    k = k_ref[0, 0].astype(jnp.float32)          # (BK, D)
    v = v_ref[0, 0].astype(jnp.float32)          # (BK, Dv)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap

    qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    l_new = l_prev * alpha + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(j == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, scale: float = 0.0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True):
    """q: (B, Sq, H, D); k/v: (B, Skv, KV, D). Returns (B, Sq, H, D)."""
    B, Sq, H, D = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    bq, bk = min(block_q, Sq), min(block_k, Skv)
    assert Sq % bq == 0 and Skv % bk == 0
    nq, nk = Sq // bq, Skv // bk
    scale = scale or 1.0 / math.sqrt(D)

    # layout: (B, H, S, D) blocks
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               window=window, softcap=softcap,
                               bq=bq, bk=bk, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),     # running max m
            pltpu.VMEM((bq,), jnp.float32),     # running denom l
            pltpu.VMEM((bq, D), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
