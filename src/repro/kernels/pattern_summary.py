"""Pallas TPU kernel for PerfTracker's behavior-pattern summarization
(paper §4.2, Algorithm 1) — the observability hot loop at 10 kHz x 20 s x
thousands of events per worker.

TPU-native re-think (DESIGN.md §2): the paper's per-event sequential binary
search becomes, per event row, ceil(log2(n))+1 *vectorized* feasibility
passes over the sample vector:

  zero-run length   rl(i) = i - cummax(where(u>0, i, -1))
  splitter(i, g)    = rl(i) > g           (inside a zero-run beyond g)
  region start s(i) = cummax(where(start, i, 0))
  region mass at i  = csum(i+1) - csum(s(i))
  feasible(g)       = max_i [not splitter] region_mass >= 0.8 * total

then (mu, sigma, len) of the max-mass region at the optimal g. Everything is
row-parallel (events block 8 x samples 128-lane tiles, VPU-only — no MXU).

Output per event: (mean, std, frac_len) over the critical execution duration.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

MASS_FRACTION = 0.8


def _region_stats(u, g):
    """Vectorized max-mass feasible region for gap bound g.
    u: (E, n) f32. Returns (mass (E,), lo (E,), hi (E,)) of the best region
    (hi exclusive); regions are maximal runs without zero-gaps > g."""
    E, n = u.shape
    idx = jax.lax.broadcasted_iota(jnp.int32, (E, n), 1)
    nz = u > 0.0
    last_nz = jax.lax.cummax(jnp.where(nz, idx, -1), axis=1)
    rl = idx - last_nz                      # zero-run length at i (0 if nz)
    split = rl > g
    # region starts: first non-split position after a split (or i==0)
    prev_split = jnp.concatenate(
        [jnp.ones((E, 1), jnp.bool_), split[:, :-1]], axis=1)
    start = (~split) & prev_split
    start_idx = jax.lax.cummax(jnp.where(start, idx, 0), axis=1)
    csum = jnp.cumsum(u, axis=1)
    csum0 = jnp.concatenate([jnp.zeros((E, 1), u.dtype), csum[:, :-1]],
                            axis=1)
    # mass of region up to and including i
    mass_i = jnp.where(~split, csum - jnp.take_along_axis(
        csum0, start_idx, axis=1), -1.0)
    best = jnp.argmax(mass_i, axis=1)                    # (E,)
    best_mass = jnp.take_along_axis(mass_i, best[:, None], axis=1)[:, 0]
    lo = jnp.take_along_axis(start_idx, best[:, None], axis=1)[:, 0]
    hi = best + 1
    return best_mass, lo, hi


def _trim(u, lo, hi):
    """Trim leading/trailing zeros of [lo, hi) per row (vectorized)."""
    E, n = u.shape
    idx = jax.lax.broadcasted_iota(jnp.int32, (E, n), 1)
    inside = (idx >= lo[:, None]) & (idx < hi[:, None]) & (u > 0)
    big = jnp.int32(n + 1)
    lo2 = jnp.min(jnp.where(inside, idx, big), axis=1)
    hi2 = jnp.max(jnp.where(inside, idx + 1, 0), axis=1)
    lo2 = jnp.where(lo2 == big, lo, lo2)
    hi2 = jnp.maximum(hi2, lo2)
    return lo2, hi2


def _kernel(u_ref, out_ref, *, n: int, iters: int):
    u = u_ref[...].astype(jnp.float32)        # (BE, n)
    E = u.shape[0]
    total = u.sum(axis=1)
    target = MASS_FRACTION * total - 1e-9

    def body(_, carry):
        lo_g, hi_g, best_g = carry
        g = (lo_g + hi_g) // 2
        mass, _, _ = _region_stats(u, g[:, None])
        feas = mass >= target
        best_g = jnp.where(feas, g, best_g)
        hi_g = jnp.where(feas, g - 1, hi_g)
        lo_g = jnp.where(feas, lo_g, g + 1)
        return lo_g, hi_g, best_g

    lo_g = jnp.zeros((E,), jnp.int32)
    hi_g = jnp.full((E,), n, jnp.int32)
    best_g = jnp.full((E,), n, jnp.int32)
    lo_g, hi_g, best_g = jax.lax.fori_loop(
        0, iters, body, (lo_g, hi_g, best_g))

    mass, lo, hi = _region_stats(u, best_g[:, None])
    lo, hi = _trim(u, lo, hi)
    idx = jax.lax.broadcasted_iota(jnp.int32, u.shape, 1)
    inside = (idx >= lo[:, None]) & (idx < hi[:, None])
    cnt = jnp.maximum((hi - lo).astype(jnp.float32), 1.0)
    mean = jnp.where(inside, u, 0.0).sum(axis=1) / cnt
    var = jnp.where(inside, jnp.square(u - mean[:, None]), 0.0
                    ).sum(axis=1) / cnt
    # all-zero rows: whole window, mean/std 0
    empty = total <= 0.0
    mean = jnp.where(empty, 0.0, mean)
    var = jnp.where(empty, 0.0, var)
    frac = jnp.where(empty, 1.0, cnt / n)
    out_ref[...] = jnp.stack(
        [mean, jnp.sqrt(var), frac], axis=1).astype(out_ref.dtype)


def pattern_summary(u, block_events: int = 8, interpret: bool = True):
    """u: (E, n) utilization samples in [0,1] (zero-padded rows ok).
    Returns (E, 3): [mu, sigma, critical-duration fraction]."""
    E, n = u.shape
    be = min(block_events, E)
    pad = (-E) % be
    if pad:
        u = jnp.concatenate([u, jnp.zeros((pad, n), u.dtype)], axis=0)
    iters = max(1, math.ceil(math.log2(n + 1)) + 1)
    kernel = functools.partial(_kernel, n=n, iters=iters)
    out = pl.pallas_call(
        kernel,
        grid=((E + pad) // be,),
        in_specs=[pl.BlockSpec((be, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((be, 3), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((E + pad, 3), jnp.float32),
        interpret=interpret,
    )(u)
    return out[:E]
