"""Batched serving engine: continuous batched decode with prefill, KV/SSM
caches, temperature sampling, and PerfTracker serve-mode anchors
(request.dequeue / decode.step play the roles of the two anchors)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.events import Kind
from repro.instrument.hooks import PerfTracker, PerfTrackerConfig
from repro.models.transformer import Transformer
from repro.train.step import make_serve_step


@dataclass
class ServeConfig:
    batch: int = 4
    max_len: int = 128
    temperature: float = 0.0        # 0 = greedy
    seed: int = 0
    perftracker: bool = False


class Engine:
    def __init__(self, cfg: ModelConfig, params, sc: ServeConfig,
                 dist=None):
        self.cfg, self.sc = cfg, sc
        self.model = Transformer(cfg, dist=dist)
        self.params = params
        self._step = jax.jit(make_serve_step(self.model),
                             donate_argnums=(1,))
        self.pt: Optional[PerfTracker] = None
        if sc.perftracker:
            self.pt = PerfTracker(PerfTrackerConfig(window_s=0.5))

    def generate(self, prompts: np.ndarray, n_new: int) -> np.ndarray:
        """prompts: (B, P) int32. Returns (B, P+n_new)."""
        sc = self.sc
        B, P = prompts.shape
        cache = self.model.init_cache(B, sc.max_len)
        rng = jax.random.PRNGKey(sc.seed)
        toks = [prompts[:, i] for i in range(P)]
        tracer = self.pt.tracer if self.pt else None

        logits = None
        # prefill token-by-token (tiny configs; production path would use
        # the chunked prefill_step — see launch/dryrun.py prefill cells)
        for t in range(P + n_new - 1):
            if t < P:
                cur = jnp.asarray(toks[t])[:, None]
            else:
                cur = nxt[:, None]  # noqa: F821
            batch = {"tokens": cur}
            if tracer:
                with tracer.phase("decode.step", Kind.GPU, depth=1):
                    logits, cache = self._step(self.params, cache, batch,
                                               jnp.int32(t))
            else:
                logits, cache = self._step(self.params, cache, batch,
                                           jnp.int32(t))
            lg = logits[:, 0, :self.cfg.vocab_size]
            if sc.temperature > 0:
                rng, k = jax.random.split(rng)
                nxt = jax.random.categorical(k, lg / sc.temperature, axis=-1)
            else:
                nxt = jnp.argmax(lg, axis=-1)
            if t >= P - 1:
                toks.append(np.asarray(nxt))
        return np.stack(toks, axis=1)
