"""Serving engine package.

``Engine``/``ServeConfig`` (the real jax serving engine) are exposed
lazily (PEP 562) so that importing :mod:`repro.serve.playbook` — pure
ladder rules the mitigation registry needs — never pulls in jax.
"""
from __future__ import annotations

_ENGINE_EXPORTS = ("Engine", "ServeConfig")

__all__ = list(_ENGINE_EXPORTS)


def __getattr__(name: str):
    if name in _ENGINE_EXPORTS:
        from repro.serve import engine
        return getattr(engine, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
