"""Serving-channel mitigation ladders (DESIGN.md §13).

Importing this module registers ladder rules for the ``slo`` channel in
the core registry (``repro.core.mitigation.register_ladder``) — the core
dispatch is never edited.  The rules are keyed ONLY on (channel, Kind)
plus the generic shape of the abnormality (how much of the fleet it
covers, which workers); they contain no knowledge of any fault model or
named scenario.

The serving playbook's two actions (both already understood by the
mitigation engine):

  * ``SHED_LOAD``         — reject/route the excess: the cure when the
    fleet as a whole is over capacity (arrival burst, KV working set
    larger than device memory).  Replacing hosts cannot help — every
    replacement inherits the same load;
  * ``DRAIN_AND_REPLACE`` — drain in-flight requests on the flagged
    hosts, then drop them and re-mesh on standbys: the cure when the SLO
    violation is pinned to sick serving hosts (hot/throttled decode GPU,
    degraded NIC).  World effect identical to training's
    ``REPLACE_HOSTS`` (the engine executes both through
    ``replace_hosts``), but the serving protocol drains first so no
    user-visible request is dropped mid-stream.
"""
from __future__ import annotations

from typing import List

from repro.core import channels
from repro.core.events import Kind
from repro.core.mitigation import (Action, Diagnosis, MitigationPlan,
                                   _frac_ws, register_ladder)


@register_ladder(channels.SLO, Kind.GPU, Kind.COMM)
def _slo_hardware_ladder(d: Diagnosis, fleet_size: int
                         ) -> List[MitigationPlan]:
    # SLO violation traced to hardware (decode GEMMs or token-path
    # collectives) on a SUBSET of serving hosts: drain + replace them;
    # when the signature survives on the replacements, shed load while a
    # human investigates.  Fleet-wide hardware slowness is not a
    # replacement problem — shed load first.
    a = d.abnormality
    frac, ws = _frac_ws(d, fleet_size)
    if ws and frac < 0.5:
        return [
            MitigationPlan(
                Action.DRAIN_AND_REPLACE, ws,
                f"SLO violation pinned to these hosts ({a.function}): "
                "drain in-flight requests, replace, re-mesh on standbys"),
            MitigationPlan(
                Action.SHED_LOAD, [],
                "violation survived host replacement -> shed load and "
                "page serving on-call"),
        ]
    return [
        MitigationPlan(
            Action.SHED_LOAD, [],
            f"{a.kind.name} slowness on {frac:.0%} of the serving fleet: "
            "shed load to restore the SLO, then investigate capacity"),
        MitigationPlan(
            Action.FLAG_CODE, [],
            f"persists under reduced load -> optimize {a.function}"),
    ]


@register_ladder(channels.SLO, Kind.PYTHON)
def _slo_queue_ladder(d: Diagnosis, fleet_size: int) -> List[MitigationPlan]:
    # SLO violation traced to host-side Python (admission/dequeue wait):
    # the fleet is over capacity — shed load; a subset-only backlog gets
    # a drain-and-replace fallback (sick local scheduler)
    a = d.abnormality
    frac, ws = _frac_ws(d, fleet_size)
    ladder = [MitigationPlan(
        Action.SHED_LOAD, [],
        f"request backlog in {a.function}: arrival rate exceeds serving "
        "capacity — shed load until the queue drains")]
    if ws and frac < 0.5:
        ladder.append(MitigationPlan(
            Action.DRAIN_AND_REPLACE, ws,
            "backlog persists and only these hosts are implicated -> "
            "drain and replace them"))
    else:
        ladder.append(MitigationPlan(
            Action.FLAG_CODE, [],
            "backlog persists under reduced load -> optimize admission/"
            "scheduling path"))
    return ladder


@register_ladder(channels.SLO, Kind.MEM)
def _slo_mem_ladder(d: Diagnosis, fleet_size: int) -> List[MitigationPlan]:
    # SLO violation traced to memory traffic (KV block reads): the
    # resident working set exceeds device memory — shed load until it
    # fits; persisting under reduced load means the cache policy itself
    # needs work
    a = d.abnormality
    return [
        MitigationPlan(
            Action.SHED_LOAD, [],
            f"memory traffic dominates {a.function}: KV working set "
            "exceeds device memory — shed load until it fits"),
        MitigationPlan(
            Action.FLAG_CODE, [],
            "thrash persists under reduced load -> revisit KV block "
            "size / eviction policy"),
    ]
